"""Benchmark programs — analogues of the paper's 10 real workloads
(taxi / movie-ratings / startup analyses; filter, feature-add, aggregation,
merge, multi-print, reuse-heavy).  Each program takes the sources dict and
runs plain-Pandas-style code against the LaFP API.

Programs return a value (forcing computation); sizes scale with --scale.
"""
from __future__ import annotations

import numpy as np

import repro.core as core
from repro.core.func import flush, print as lprint


def build_sources(scale: int, tmpdir: str | None = None, seed: int = 0):
    """Synthetic datasets sized ``scale`` rows (taxi) and scale//4 (movies),
    written as partitioned npz when tmpdir is given (out-of-core path)."""
    rng = np.random.default_rng(seed)
    n = scale
    taxi = {
        "fare_amount": rng.uniform(-5, 100, n),
        "passenger_count": rng.integers(0, 7, n).astype(np.int64),
        "pickup_datetime": rng.integers(1_577_836_800, 1_609_459_200, n),
        "trip_miles": rng.uniform(0, 30, n),
        "tip": rng.uniform(0, 20, n),
        "tolls": rng.uniform(0, 10, n),
        "extra1": rng.uniform(0, 1, n),
        "extra2": rng.uniform(0, 1, n),
        "extra3": rng.integers(0, 100, n).astype(np.int64),
        "extra4": rng.uniform(0, 1, n),
        "vendor": rng.integers(0, 4, n).astype(np.int64),
    }
    m = max(scale // 4, 100)
    ratings = {
        "movie_id": rng.integers(0, 2000, m).astype(np.int64),
        "user_id": rng.integers(0, 50_000, m).astype(np.int64),
        "rating": rng.uniform(0.5, 5.0, m),
        "ts": rng.integers(1_000_000_000, 1_600_000_000, m),
        "junk1": rng.uniform(0, 1, m),
        "junk2": rng.uniform(0, 1, m),
    }
    movies = {
        "movie_id": np.arange(2000),
        "year": rng.integers(1950, 2024, 2000).astype(np.int64),
        "genre": rng.integers(0, 12, 2000).astype(np.int64),
    }
    startups = {
        "funding": rng.lognormal(14, 2, max(n // 2, 100)),
        "employees": rng.integers(1, 5000, max(n // 2, 100)).astype(np.int64),
        "sector": rng.integers(0, 20, max(n // 2, 100)).astype(np.int64),
        "founded": rng.integers(1990, 2024, max(n // 2, 100)).astype(np.int64),
        "unused1": rng.uniform(0, 1, max(n // 2, 100)),
        "unused2": rng.uniform(0, 1, max(n // 2, 100)),
    }
    part = max(scale // 16, 1024)
    if tmpdir is not None:
        from repro.core.source import write_npz_source
        return {
            "taxi": write_npz_source(f"{tmpdir}/taxi", taxi, part),
            "ratings": write_npz_source(f"{tmpdir}/ratings", ratings, part),
            "movies": write_npz_source(f"{tmpdir}/movies", movies, 2000),
            "startups": write_npz_source(f"{tmpdir}/startups", startups, part),
        }
    return {
        "taxi": core.InMemorySource(taxi, part, name="taxi"),
        "ratings": core.InMemorySource(ratings, part, name="ratings"),
        "movies": core.InMemorySource(movies, 2000, name="movies"),
        "startups": core.InMemorySource(startups, part, name="startups"),
    }


# --- the 10 programs -------------------------------------------------------

def prog_taxi_agg(S):
    df = core.read_source(S["taxi"])
    df = df[df["fare_amount"] > 0]
    df["day"] = (df["pickup_datetime"] // 86400 + 3) % 7
    return df.groupby(["day"])["passenger_count"].sum().compute()


def prog_taxi_feature(S):
    df = core.read_source(S["taxi"])
    df["total"] = df["fare_amount"] + df["tip"] + df["tolls"]
    df = df[df["total"] > 20]
    return df.groupby(["vendor"])["total"].mean().compute()


def prog_taxi_filter_only(S):
    df = core.read_source(S["taxi"])
    df = df[(df["trip_miles"] > 10.0) & (df["fare_amount"] > 30.0)]
    return df["tip"].mean().compute()


def prog_ratings_join(S):
    r = core.read_source(S["ratings"])
    m = core.read_source(S["movies"])
    j = r.merge(m, on="movie_id")
    j = j[j["year"] >= 2000]
    return j.groupby(["genre"])["rating"].mean().compute()


def prog_ratings_top(S):
    r = core.read_source(S["ratings"])
    g = r.groupby(["movie_id"])["rating"].mean()
    return g.sort_values("rating", ascending=False).head(10).compute()


def prog_startup_sort(S):
    df = core.read_source(S["startups"])
    df = df[df["funding"] > 1e6]
    return df.sort_values("funding", ascending=False).head(50).compute()


def prog_startup_distinct(S):
    df = core.read_source(S["startups"])
    df = df[df["employees"] > 100]
    return df.drop_duplicates(subset=("sector",)).compute()


def prog_multi_print(S):
    df = core.read_source(S["taxi"])
    lprint("rows loaded")
    df = df[df["fare_amount"] > 0]
    per_day = df.groupby(["vendor"])["trip_miles"].mean()
    lprint(per_day)
    avg = df["fare_amount"].mean()
    lprint(f"avg fare: {avg}")
    flush()
    return True


def _heavy_feature(a):
    """Deliberately expensive elementwise chain — stands in for the paper's
    CSV parse + feature engineering that makes recompute costly."""
    out = np.abs(a) + 1.0
    for _ in range(6):
        out = np.sqrt(np.log1p(out) + 1.0) * 1.7 + np.abs(np.sin(out))
    return out


def prog_reuse_stu(S):
    """The 'stu'-like reuse-heavy program (paper §5.3: 13× from persist).
    The shared subexpression df (filter + heavy feature) is forced three
    times; live_df persists it after the first.

    The projection to the three future-live columns is what the paper's
    LAA-based rewriter inserts (without it, persisting must conservatively
    keep all 11 columns and costs more than it saves — measured in
    EXPERIMENTS §Paper-validation)."""
    df = core.read_source(S["taxi"])
    df = df[df["fare_amount"] > 0]
    df["total"] = (df["fare_amount"] + df["tip"]).apply(_heavy_feature)
    df = df[["vendor", "passenger_count", "total"]]   # ← LAA rewrite
    a = df.groupby(["vendor"])["total"].mean().compute(live_df=[df])
    b = df.groupby(["passenger_count"])["total"].sum().compute(live_df=[df])
    c = df["total"].mean().compute(live_df=[])
    return (a, b, c)


def prog_wide_projection(S):
    """Uses 2 of 11 columns — column selection's best case (paper Fig. 4)."""
    df = core.read_source(S["taxi"])
    return df.groupby(["vendor"])["fare_amount"].max().compute()


PROGRAMS = {
    "taxi_agg": prog_taxi_agg,
    "taxi_feature": prog_taxi_feature,
    "taxi_filter": prog_taxi_filter_only,
    "ratings_join": prog_ratings_join,
    "ratings_top": prog_ratings_top,
    "startup_sort": prog_startup_sort,
    "startup_distinct": prog_startup_distinct,
    "multi_print": prog_multi_print,
    "reuse_stu": prog_reuse_stu,
    "wide_projection": prog_wide_projection,
}
