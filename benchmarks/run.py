"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

* fig12_applicability — programs completing under a memory budget, per
  backend, with/without optimization           (paper Fig. 12)
* fig13_exec_time     — absolute runtime per backend, optimized (Fig. 13)
* fig14_speedup       — % runtime improvement from the optimizer (Fig. 14)
* fig15_memory        — % peak-memory reduction (streaming meter) (Fig. 15)
* analysis_overhead   — JIT static-analysis wall time        (paper §5.3)
* ablation_persist    — reuse-heavy program, persist on/off  (paper §5.3/5.4)
* kernels             — dataframe-kernel microbenchmarks (XLA oracle path)
* rewrites            — plan-rewrite figure: sort+head vs the TopK rewrite,
                        native nlargest vs the old fallback path
* scan_pushdown       — columnar-IO figure: bytes read with scan pushdown +
                        zone-map pruning on vs full read (scan_pushdown.json)
* observability       — telemetry overhead: uninstrumented vs disabled vs
                        profiled, plus the trace_golden Chrome trace
* serving             — concurrent sessions over repeated plan shapes:
                        p50/p99 latency and planning seconds, plan cache
                        cold vs warm (serving.json)
* roofline            — summary of dryrun_baseline.json when present

Scale: REPRO_BENCH_SCALE rows for the taxi table (default 200k ≈ laptop
seconds; the paper's 1.4/4.2/12.6 GB correspond to ~2e7/6e7/1.8e8 rows).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

SCALE = int(os.environ.get("REPRO_BENCH_SCALE", 200_000))
_ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def _bench_meta(t0: float) -> dict:
    """Common ``meta`` block for every figure's JSON artifact: figure wall
    time, session peak bytes, registered engine set, scale, timestamp."""
    import datetime
    from repro.core import engine_names
    from repro.core.context import get_context
    return {
        "wall_seconds": round(time.perf_counter() - t0, 3),
        "peak_bytes": int(getattr(get_context(), "last_peak_bytes", 0) or 0),
        "engines": sorted(engine_names()),
        "scale_rows": SCALE,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }


def _fresh_ctx(backend, budget=None):
    from repro.core import get_context
    ctx = get_context()
    ctx.reset()
    ctx.backend = backend
    ctx.memory_budget = budget
    ctx.print_fn = lambda *a: None
    return ctx


def _run_program(fn, sources, backend, budget=None, optimize=True,
                 placement=None):
    """Returns (seconds, peak_bytes, ok)."""
    from repro.core.backends import MemoryBudgetExceeded
    ctx = _fresh_ctx(backend, budget)
    if placement is not None:
        ctx.backend_options["placement"] = placement
    if not optimize:
        import repro.core.runtime as rt
        import repro.core.optimizer as opt
        orig = opt.optimize
        rt.optimize = lambda roots, c=None, enable=(): orig(roots, c, ())
    t0 = time.perf_counter()
    ok = True
    try:
        fn(sources)
    except MemoryBudgetExceeded:
        ok = False
    finally:
        if not optimize:
            import repro.core.optimizer as opt
            import repro.core.runtime as rt
            rt.optimize = opt.optimize
    return time.perf_counter() - t0, ctx.last_peak_bytes, ok


def fig12_applicability():
    """Programs that complete under a memory budget (out-of-memory analogue
    of the paper's 12.6 GB runs — the budget is ~35% of the dataset)."""
    from .programs import PROGRAMS, build_sources
    sources = build_sources(SCALE)
    taxi = sources["taxi"]
    dataset_bytes = taxi.total_rows() * taxi.schema.row_bytes()
    budget = int(dataset_bytes * 0.35)
    for backend in ("streaming",):
        for optimize in (False, True):
            t0 = time.perf_counter()
            succ = 0
            for name, fn in PROGRAMS.items():
                _, _, ok = _run_program(fn, sources, backend, budget,
                                        optimize)
                succ += int(ok)
            label = "LaFP" if optimize else "plain"
            emit(f"fig12_{backend}_{label}",
                 (time.perf_counter() - t0) * 1e6,
                 f"{succ}/{len(PROGRAMS)} programs under "
                 f"{budget / 1e6:.0f}MB budget")


def fig13_exec_time():
    import tempfile
    from .programs import PROGRAMS, build_sources
    with tempfile.TemporaryDirectory() as td:
        sources = build_sources(SCALE, tmpdir=td)   # disk-backed (paper CSVs)
        for backend in ("eager", "streaming", "distributed"):
            for name, fn in PROGRAMS.items():
                secs, _, ok = _run_program(fn, sources, backend)
                emit(f"fig13_{backend}_{name}", secs * 1e6,
                     "ok" if ok else "fail")


def fig14_speedup():
    import tempfile
    from .programs import PROGRAMS, build_sources
    with tempfile.TemporaryDirectory() as td:
        sources = build_sources(SCALE, tmpdir=td)   # disk-backed (paper CSVs)
        for backend in ("eager", "streaming"):
            for name, fn in PROGRAMS.items():
                t_plain, _, ok1 = _run_program(fn, sources, backend,
                                               optimize=False)
                t_opt, _, ok2 = _run_program(fn, sources, backend,
                                             optimize=True)
                if ok1 and ok2 and t_plain > 0:
                    imp = 100.0 * (t_plain - t_opt) / t_plain
                    emit(f"fig14_{backend}_{name}", t_opt * 1e6,
                         f"improvement={imp:.1f}%")


def fig15_memory():
    from .programs import PROGRAMS, build_sources
    sources = build_sources(SCALE)
    for name, fn in PROGRAMS.items():
        _, m_plain, ok1 = _run_program(fn, sources, "streaming",
                                       optimize=False)
        _, m_opt, ok2 = _run_program(fn, sources, "streaming",
                                     optimize=True)
        if ok1 and ok2 and m_plain:
            red = 100.0 * (m_plain - m_opt) / m_plain
            emit(f"fig15_{name}", m_opt, f"mem_reduction={red:.1f}%")


def backend_selection():
    """Planner-quality figure (beyond paper): AUTO — operator-granular
    segments (default) and the legacy per-root placement — vs each fixed
    backend across small/medium/large synthetic sources.  Emits CSV rows
    plus ``backend_selection.json`` with per-program regret for both AUTO
    strategies and an ``operator_regret_le_per_root`` flag per program, so
    the trajectory can track the two placements against each other."""
    from repro.core import get_context
    from .programs import PROGRAMS, build_sources
    prog_names = ("taxi_agg", "taxi_filter", "ratings_join")
    scales = {"small": max(SCALE // 20, 2_000), "medium": SCALE,
              "large": SCALE * 4}
    fixed_backends = ("eager", "streaming", "distributed")
    auto_modes = (("auto_operator", "operator"), ("auto_per_root", "per_root"))
    runners = ([(b, b, None) for b in fixed_backends]
               + [(key, "auto", mode) for key, mode in auto_modes])
    t_fig = time.perf_counter()
    out: dict = {"scale_rows": dict(scales), "results": {}}
    for label, scale in scales.items():
        sources = build_sources(scale)
        taxi = sources["taxi"]
        # large runs under a budget (~50% of the taxi table): AUTO must
        # notice eager doesn't fit and route around it
        budget = None
        if label == "large":
            budget = int(taxi.total_rows() * taxi.schema.row_bytes() * 0.5)
        res: dict = {}
        out["results"][label] = res
        for key, backend, placement in runners:
            total = 0.0
            ok_all = True
            chosen: list[str] = []
            per_program: dict = {}
            for name in prog_names:
                try:
                    secs, _, ok = _run_program(PROGRAMS[name], sources,
                                               backend, budget,
                                               placement=placement)
                except Exception:  # noqa: BLE001 — a broken backend is a
                    secs, ok = 0.0, False  # "fail" data point, not an abort
                per_program[name] = {"seconds": secs, "ok": ok}
                total += secs
                ok_all = ok_all and ok
                if backend == "auto":
                    ctx = get_context()
                    prog_chose = sorted({d.cost.backend
                                         for d in ctx.planner_decisions})
                    per_program[name]["auto_chose"] = prog_chose
                    per_program[name]["device_resident_handoffs"] = sum(
                        "device-resident" in line
                        for line in ctx.planner_trace)
                    chosen.extend(prog_chose)
            # only the streaming backend wires the budget into a MemoryMeter;
            # under a budget, eager/distributed run unconstrained and are not
            # a fair regret baseline
            enforced = budget is None or backend in ("streaming", "auto")
            rec = {"seconds": total, "ok": ok_all,
                   "budget_enforced": enforced, "per_program": per_program}
            if chosen:
                rec["auto_chose"] = sorted(set(chosen))
            res[key] = rec
            emit(f"backend_selection_{label}_{key}", total * 1e6,
                 ("ok" if ok_all else "fail")
                 + (f" chose={'+'.join(sorted(set(chosen)))}" if chosen else ""))
        # regret per AUTO strategy vs the best fixed backend, per program
        baselines = [res[b] for b in fixed_backends
                     if res[b]["budget_enforced"]]
        for key, _mode in auto_modes:
            rec = res[key]
            if not rec["ok"]:
                continue
            regrets: dict = {}
            for name in prog_names:
                best = [b["per_program"][name]["seconds"] for b in baselines
                        if b["per_program"][name]["ok"]]
                if best and rec["per_program"][name]["ok"]:
                    regrets[name] = (rec["per_program"][name]["seconds"]
                                     / max(min(best), 1e-12))
            rec["per_program_regret"] = regrets
            totals = [b["seconds"] for b in baselines if b["ok"]]
            if totals:
                rec["regret_vs_best_fixed"] = rec["seconds"] / min(totals)
                emit(f"backend_selection_{label}_{key}_regret",
                     rec["seconds"] * 1e6,
                     f"auto/best_fixed={rec['regret_vs_best_fixed']:.2f}x")
        # "auto" mirrors the default strategy so older trajectory tooling
        # keeps reading the same keys
        res["auto"] = res["auto_operator"]
        if "regret_vs_best_fixed" in res["auto_operator"]:
            res["regret_vs_best_fixed"] = (
                res["auto_operator"]["regret_vs_best_fixed"])
        op_r = res["auto_operator"].get("per_program_regret", {})
        pr_r = res["auto_per_root"].get("per_program_regret", {})
        if op_r and pr_r:
            res["operator_regret_le_per_root"] = {
                name: op_r[name] <= pr_r[name] * 1.05  # 5% timing jitter
                for name in op_r if name in pr_r}
        # native-distributed-join figure: did AUTO select (and by selection,
        # cost-win with) the distributed engine on the join-bearing program,
        # and did its segment chain pass a device-resident handoff?
        jd = res["auto_operator"]["per_program"].get("ratings_join", {})
        res["join_distributed_selected"] = (
            "distributed" in jd.get("auto_chose", []))
        res["join_device_resident_handoffs"] = jd.get(
            "device_resident_handoffs", 0)
        emit(f"backend_selection_{label}_join_distributed", 0.0,
             f"selected={res['join_distributed_selected']} "
             f"device_resident_handoffs={res['join_device_resident_handoffs']}")
    out["meta"] = _bench_meta(t_fig)
    path = os.environ.get("REPRO_BENCH_SELECTION_OUT",
                          "backend_selection.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    emit("backend_selection_json", 0.0, path)
    _explain_golden()


def _explain_golden():
    """Golden ``pd.explain()`` output for the CI artifact: one AUTO run of
    the join-bearing program, reported as the stable text plan plus the
    typed records in JSON."""
    import json as _json

    from repro.core import explain, get_context
    from .programs import PROGRAMS, build_sources
    t_fig = time.perf_counter()
    sources = build_sources(max(SCALE // 20, 2_000))
    ctx = _fresh_ctx("auto")
    PROGRAMS["ratings_join"](sources)
    report = explain(ctx=get_context())
    text_path = os.environ.get("REPRO_EXPLAIN_GOLDEN_OUT",
                               "explain_golden.txt")
    with open(text_path, "w") as f:
        f.write(report.render() + "\n")
    report_dict = report.to_dict()
    report_dict["meta"] = _bench_meta(t_fig)
    with open(os.path.splitext(text_path)[0] + ".json", "w") as f:
        _json.dump(report_dict, f, indent=2, default=str)
    emit("explain_golden", 0.0,
         f"{text_path} runs={len(report.runs)} "
         f"segments={sum(len(r.segments) for r in report.runs)}")


def api_coverage():
    """PandasBench-style API-coverage figure: run the plain-pandas corpus
    (`benchmarks/api_corpus.py`) through the `repro.pandas` facade and count
    per program how many operations were served natively (lazy graph
    nodes), served via the measured fallback protocol, or failed.  Writes
    ``api_coverage.json``."""
    import repro.pandas as pd
    from repro.core import graph as G
    from repro.core.context import session
    from .api_corpus import CORPUS

    t_fig = time.perf_counter()
    out: dict = {"programs": {}, "totals": {"native_nodes": 0, "fallback": 0,
                                            "failed": 0, "programs_ok": 0}}
    for name, prog in CORPUS:
        rng = np.random.default_rng(0)
        with session(name=f"api_coverage:{name}") as ctx:
            ctx.print_fn = lambda *a: None
            nodes_before = next(G._ids)
            t0 = time.perf_counter()
            ok = True
            error = None
            try:
                prog(pd, rng)
            except Exception as e:  # noqa: BLE001 — coverage gap, not abort
                ok = False
                error = f"{type(e).__name__}: {e}"
            secs = time.perf_counter() - t0
            nodes = next(G._ids) - nodes_before - 1
            served = [ev for ev in ctx.fallback_trace if ev.status == "fallback"]
            failed = [ev for ev in ctx.fallback_trace if ev.status == "failed"]
            rec = {
                "ok": ok,
                "seconds": secs,
                "native_nodes": nodes,
                "fallback": len(served),
                "failed": len(failed),
                "fallback_ops": sorted({ev.op for ev in served}),
                "failed_ops": sorted({ev.op for ev in failed}),
            }
            if error:
                rec["error"] = error
            out["programs"][name] = rec
            out["totals"]["native_nodes"] += nodes
            out["totals"]["fallback"] += len(served)
            out["totals"]["failed"] += len(failed)
            out["totals"]["programs_ok"] += int(ok)
            emit(f"api_coverage_{name}", secs * 1e6,
                 f"{'ok' if ok else 'FAIL'} native={nodes} "
                 f"fallback={len(served)} failed={len(failed)}")
    total = out["totals"]
    ops = total["native_nodes"] + total["fallback"] + total["failed"]
    total["fallback_share"] = total["fallback"] / max(ops, 1)
    out["meta"] = _bench_meta(t_fig)
    path = os.environ.get("REPRO_API_COVERAGE_OUT", "api_coverage.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    emit("api_coverage_json", 0.0,
         f"{path} ok={total['programs_ok']}/{len(CORPUS)} "
         f"fallback_share={total['fallback_share']:.3f}")


def rewrites():
    """Plan-rewrite figure: the same ``sort_values().head(k)`` program with
    the rewrite pass on (runs as the TopK partial sort) and off (full sort,
    the ``session(rewrites=False)`` escape hatch), plus native ``nlargest``
    (TopK lowering) vs the pre-rewrite fallback path (materialize + pandas
    kernel).  Min-over-reps timings; writes ``rewrites.json``."""
    import repro.pandas as pd
    from repro.core.context import session

    t_fig = time.perf_counter()
    n, k = SCALE, 100
    rng = np.random.default_rng(0)
    arrays = {"key": rng.permutation(n).astype(np.float64),
              "val": rng.integers(0, 1000, n).astype(np.float64)}
    reps = int(os.environ.get("REPRO_REWRITE_REPS", 5))
    out: dict = {"rows": n, "k": k, "reps": reps, "results": {}}

    def best_of(engine, rewrites_flag, prog):
        best = float("inf")
        for _ in range(reps + 1):            # first rep is jit/cache warmup
            with session(engine=engine, rewrites=rewrites_flag) as ctx:
                ctx.print_fn = lambda *a: None
                df = pd.from_arrays(arrays)
                t0 = time.perf_counter()
                prog(df)
                dt = time.perf_counter() - t0
            best = min(best, dt)
        return best

    def sort_head(df):
        df.sort_values("key", ascending=False).head(k).compute()

    def nlargest(df):
        df.nlargest(k, "key").compute()

    def nlargest_fallback(df):
        # the pre-rewrite protocol: materialize the whole frame, run the
        # pandas kernel on the host copy
        import pandas as pd_real
        res = df.compute()
        pd_real.DataFrame({c: np.asarray(v)
                           for c, v in res.columns.items()}).nlargest(k, "key")

    for engine in ("eager", "streaming"):
        t_topk = best_of(engine, True, sort_head)
        t_full = best_of(engine, False, sort_head)
        speedup = t_full / max(t_topk, 1e-12)
        out["results"][f"sort_head_{engine}"] = {
            "topk_seconds": t_topk, "full_sort_seconds": t_full,
            "speedup": speedup}
        emit(f"rewrites_sort_head_{engine}", t_topk * 1e6,
             f"full_sort={t_full * 1e6:.1f}us speedup={speedup:.2f}x")
    t_native = best_of("eager", True, nlargest)
    t_fb = best_of("eager", True, nlargest_fallback)
    out["results"]["nlargest_eager"] = {
        "native_seconds": t_native, "fallback_seconds": t_fb,
        "speedup": t_fb / max(t_native, 1e-12)}
    emit("rewrites_nlargest_eager", t_native * 1e6,
         f"fallback={t_fb * 1e6:.1f}us "
         f"speedup={t_fb / max(t_native, 1e-12):.2f}x")
    out["meta"] = _bench_meta(t_fig)
    path = os.environ.get("REPRO_REWRITES_OUT", "rewrites.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    emit("rewrites_json", 0.0, path)


def fusion():
    """Rowwise-fusion figure: a filter → assign → assign → fillna chain with
    the fusion pass on (one ``FusedRowwise`` node: single jitted dispatch on
    eager, one chunk-loop body on streaming) and off (op-at-a-time, one
    intermediate table per operator).  Min-over-reps timings; writes
    ``fusion.json`` (CI gates on the fused speedup)."""
    import repro.pandas as pd
    from repro.core.context import session

    t_fig = time.perf_counter()
    n = SCALE
    rng = np.random.default_rng(0)
    arrays = {"a": rng.normal(size=n),
              "b": rng.integers(0, 1000, n).astype(np.float64),
              "c": rng.normal(size=n)}
    reps = int(os.environ.get("REPRO_FUSION_REPS", 7))
    out: dict = {"rows": n, "reps": reps, "results": {}}

    def chain(df):
        r = df[df["b"] > 10.0]
        r = r.assign(x=r["a"] * 2.0 + r["c"])
        r = r.assign(y=r["x"].clip(-1.0, 1.0))
        r = r.assign(z=(r["y"] - r["a"] * 0.5).round(2))
        r = r[["x", "y", "z"]]
        r = r.fillna(0.0)
        r.compute()

    def best_of(engine, fusion_flag):
        best = float("inf")
        for _ in range(reps + 1):            # first rep is jit/cache warmup
            with session(engine=engine, fusion=fusion_flag) as ctx:
                ctx.print_fn = lambda *a: None
                df = pd.from_arrays(arrays)
                t0 = time.perf_counter()
                chain(df)
                dt = time.perf_counter() - t0
            best = min(best, dt)
        return best

    for engine in ("eager", "streaming"):
        t_fused = best_of(engine, True)
        t_unfused = best_of(engine, False)
        speedup = t_unfused / max(t_fused, 1e-12)
        out["results"][engine] = {
            "fused_seconds": t_fused, "unfused_seconds": t_unfused,
            "speedup": speedup}
        emit(f"fusion_{engine}", t_fused * 1e6,
             f"unfused={t_unfused * 1e6:.1f}us speedup={speedup:.2f}x")
    out["meta"] = _bench_meta(t_fig)
    path = os.environ.get("REPRO_FUSION_OUT", "fusion.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    emit("fusion_json", 0.0, path)


def scan_pushdown():
    """Columnar-IO figure: a selective filter over a sorted on-disk key,
    scan pushdown + zone-map pruning on (dead partitions never leave the
    disk) vs the full-read escape hatch (``session(pushdown=False,
    zonemap=False)``).  Parquet when pyarrow is available, NPZ fallback
    otherwise.  Writes ``scan_pushdown.json``; CI gates on
    ``bytes_reduction >= 2``."""
    import tempfile

    import repro.core as core
    from repro.core.context import session

    t_fig = time.perf_counter()
    n = max(SCALE, 65_536)
    n_parts = 16
    rows = -(-n // n_parts)
    rng = np.random.default_rng(0)
    arrays = {"key": np.arange(n, dtype=np.float64),
              "a": rng.random(n), "b": rng.random(n), "c": rng.random(n)}
    cut = float(n - rows)            # keeps exactly the last partition live
    reps = int(os.environ.get("REPRO_SCAN_REPS", 3))
    out: dict = {"rows": n, "partitions": n_parts, "reps": reps}

    with tempfile.TemporaryDirectory() as td:
        try:
            from repro.io.parquet import write_parquet_source
            src = write_parquet_source(os.path.join(td, "t"), arrays, rows)
            out["format"] = "parquet"
        except ImportError:
            from repro.core.source import write_npz_source
            src = write_npz_source(os.path.join(td, "t"), arrays, rows)
            out["format"] = "npz"

        def run(**opts):
            best, counters = float("inf"), {}
            for _ in range(reps + 1):        # first rep is warmup
                with session(engine="streaming", **opts) as ctx:
                    ctx.print_fn = lambda *a: None
                    df = core.read_source(src)
                    r = df[df["key"] >= cut]
                    t0 = time.perf_counter()
                    float(r["a"].sum()), float(r["b"].sum())
                    best = min(best, time.perf_counter() - t0)
                    counters = {k: v for k, v in ctx.metrics.snapshot().items()
                                if k.startswith("io.")}
            return best, counters

        t_on, io_on = run()
        t_off, io_off = run(pushdown=False, zonemap=False)

    b_on, b_off = io_on.get("io.bytes_read", 0), io_off.get("io.bytes_read", 0)
    reduction = b_off / max(b_on, 1)
    out["results"] = {
        "pushdown": {"seconds": t_on, "io": io_on},
        "fullread": {"seconds": t_off, "io": io_off},
        "bytes_pushdown": b_on,
        "bytes_fullread": b_off,
        "bytes_reduction": reduction,
        "speedup": t_off / max(t_on, 1e-12),
    }
    out["meta"] = _bench_meta(t_fig)
    path = os.environ.get("REPRO_SCAN_PUSHDOWN_OUT", "scan_pushdown.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    emit("scan_pushdown_on", t_on * 1e6,
         f"{out['format']} bytes={b_on / 1e6:.1f}MB "
         f"loaded={io_on.get('io.partitions_loaded', 0)} "
         f"pruned={io_on.get('io.partitions_pruned', 0)}")
    emit("scan_pushdown_off", t_off * 1e6,
         f"bytes={b_off / 1e6:.1f}MB "
         f"loaded={io_off.get('io.partitions_loaded', 0)}")
    emit("scan_pushdown_json", 0.0,
         f"{path} reduction={reduction:.1f}x "
         f"speedup={t_off / max(t_on, 1e-12):.2f}x")


def analysis_overhead():
    """Paper §5.3: 0.04–0.59 s static-analysis overhead."""
    import inspect
    from repro.core.source_analysis import analyze_source
    from . import programs
    src = inspect.getsource(programs)
    t0 = time.perf_counter()
    for _ in range(5):
        analyze_source(src)
    dt = (time.perf_counter() - t0) / 5
    emit("analysis_overhead_whole_module", dt * 1e6, f"{dt * 1000:.1f}ms")


def ablation_persist():
    """Paper §5.3/§5.4: reuse-heavy program with persist on/off ('stu':
    13× speedup at 2.3× memory in the paper)."""
    from .programs import build_sources, prog_reuse_stu

    import tempfile

    def run(use_live):
        ctx = _fresh_ctx("streaming")
        with tempfile.TemporaryDirectory() as td:
            # disk-backed + 8× scale: recompute really re-reads (the paper's
            # 13× shows at 12.6 GB; the effect needs IO-bound reuse)
            sources = build_sources(SCALE * 8, tmpdir=td)
            import repro.core.runtime as rt
            orig = rt.plan_persists   # patch the name runtime actually calls
            if not use_live:
                rt.plan_persists = lambda roots, live: set()
            try:
                t0 = time.perf_counter()
                prog_reuse_stu(sources)
                dt = time.perf_counter() - t0
            finally:
                rt.plan_persists = orig
        return dt, ctx.last_peak_bytes

    t_on, m_on = run(True)
    t_off, m_off = run(False)
    emit("ablation_persist_on", t_on * 1e6, f"peak={m_on/1e6:.1f}MB")
    emit("ablation_persist_off", t_off * 1e6,
         f"peak={m_off/1e6:.1f}MB speedup={t_off/max(t_on,1e-9):.2f}x "
         f"mem_ratio={m_on/max(m_off,1):.2f}x")


def kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    n = 1 << 18
    codes = jnp.asarray(rng.integers(0, 64, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    mask = jnp.asarray(rng.random(n) < 0.5)
    cfg = ops.KernelConfig(impl="xla")
    for name, fn in [
        ("groupby_sum", lambda: ops.groupby_sum(codes, vals, 64, cfg)),
        ("filter_compact", lambda: ops.filter_compact(vals, mask, cfg)),
        ("zonemap", lambda: ops.zonemap(vals, 4096, cfg)),
    ]:
        jax.block_until_ready(fn())  # warmup
        reps = 20
        t0 = time.perf_counter()
        for _ in range(reps):
            r = fn()
        jax.block_until_ready(r)
        dt = (time.perf_counter() - t0) / reps
        emit(f"kernel_{name}_xla_n{n}", dt * 1e6,
             f"{n / dt / 1e6:.0f}M rows/s")


def _unwrapped_physical():
    """Context manager swapping every traced physical operator for its
    undecorated original (kept on ``__wrapped__``) across the physical
    package and its submodules — the no-instrumentation baseline for the
    observability figure."""
    import contextlib

    import repro.core.physical as X
    from repro.core.physical import (groupby, join, reduce, rowwise, sharded,
                                     sort)

    @contextlib.contextmanager
    def cm():
        mods = [X, rowwise, groupby, join, sort, reduce, sharded]
        saved = []
        for mod in mods:
            for name in dir(mod):
                fn = getattr(mod, name)
                orig = getattr(fn, "__wrapped__", None)
                if (orig is not None and callable(fn) and getattr(
                        fn, "__module__", "").startswith(
                            "repro.core.physical")):
                    saved.append((mod, name, fn))
                    setattr(mod, name, orig)
        try:
            yield
        finally:
            for mod, name, fn in saved:
                setattr(mod, name, fn)

    return cm()


def observability():
    """Telemetry-overhead figure: the same AUTO program under three modes —
    *baseline* (physical operators unwrapped, no instrumentation at all),
    *disabled* (instrumented, no profile attached — the production
    default), and *enabled* (under ``pd.profile()``).  Disabled ≈ baseline
    keeps the no-op fast path honest (CI asserts < 3%).  Writes
    ``observability.json`` plus ``trace_golden.json`` — Chrome trace-event
    JSON loadable in https://ui.perfetto.dev."""
    import statistics

    from repro.obs import profile as obs_profile
    from repro.obs import validate_chrome_trace
    from .programs import PROGRAMS, build_sources

    t_fig = time.perf_counter()
    sources = build_sources(max(SCALE // 4, 5_000))
    prog = PROGRAMS["taxi_agg"]

    def run_once():
        _fresh_ctx("auto")
        t0 = time.perf_counter()
        prog(sources)
        return time.perf_counter() - t0

    def run_enabled():
        from repro.core import get_context
        _fresh_ctx("auto")
        t0 = time.perf_counter()
        with obs_profile(ctx=get_context()) as prof:
            prog(sources)
        return time.perf_counter() - t0, prof

    reps = int(os.environ.get("REPRO_OBS_REPS", 9))
    run_once()                                   # warmup: jit, source caches
    with _unwrapped_physical():
        run_once()
    base_t, dis_t, en_t = [], [], []
    prof = None
    for _ in range(reps):                        # interleave against drift
        with _unwrapped_physical():
            base_t.append(run_once())
        dis_t.append(run_once())
        secs, prof = run_enabled()
        en_t.append(secs)
    # min is the noise-robust statistic for wall times (noise only adds)
    base, dis, en = min(base_t), min(dis_t), min(en_t)
    wall_dis_pct = 100.0 * (dis - base) / base
    wall_en_pct = 100.0 * (en - base) / base

    # The disabled-mode overhead a run *actually pays* is deterministic
    # arithmetic, not a noisy subtraction of two ~10ms wall times on a
    # shared machine: (no-op wrapper cost × operator calls + timed-span
    # cost × segment spans) / baseline wall time.  Both per-call costs are
    # measured directly (min over batches).
    from repro.core import physical as X
    from repro.obs import Tracer
    table = {"v": np.arange(512.0)}

    def _per_call(fn, calls=5_000, batches=5):
        best = float("inf")
        for _ in range(batches):
            t0 = time.perf_counter()
            for _ in range(calls):
                fn(table, 64)
            best = min(best, (time.perf_counter() - t0) / calls)
        return best

    noop_s = max(0.0, _per_call(X.apply_head)
                 - _per_call(X.apply_head.__wrapped__))
    trc = Tracer()
    t0 = time.perf_counter()
    for _ in range(5_000):
        with trc.timed_span("x"):
            pass
    span_s = (time.perf_counter() - t0) / 5_000

    op_calls = len(prof.find("operator"))
    timed_spans = len(prof.find("segment"))
    dis_pct = 100.0 * (op_calls * noop_s + timed_spans * span_s) / base

    trace = prof.to_chrome_trace()
    validate_chrome_trace(trace)
    tpath = os.environ.get("REPRO_TRACE_GOLDEN_OUT", "trace_golden.json")
    with open(tpath, "w") as f:
        json.dump(trace, f)

    out = {
        "program": "taxi_agg",
        "reps": reps,
        "seconds": {"baseline": base, "disabled": dis, "enabled": en},
        "samples": {"baseline": base_t, "disabled": dis_t, "enabled": en_t},
        "median_seconds": {"baseline": statistics.median(base_t),
                           "disabled": statistics.median(dis_t),
                           "enabled": statistics.median(en_t)},
        "per_call": {"noop_wrapper_ns": noop_s * 1e9,
                     "timed_span_ns": span_s * 1e9,
                     "operator_calls": op_calls,
                     "timed_spans": timed_spans},
        "overhead": {"disabled_pct": dis_pct,
                     "enabled_pct": wall_en_pct,
                     "wall_disabled_pct": wall_dis_pct},
        "profile": {"spans": len(prof.spans),
                    "span_names": sorted(prof.span_names()),
                    "counters": prof.counters},
        "trace_golden": {"path": tpath,
                         "events": len(trace["traceEvents"])},
    }
    out["meta"] = _bench_meta(t_fig)
    path = os.environ.get("REPRO_OBS_OUT", "observability.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    emit("observability_baseline", base * 1e6, "uninstrumented")
    emit("observability_disabled", dis * 1e6,
         f"overhead={dis_pct:.3f}% noop_wrapper={noop_s * 1e9:.0f}ns/call "
         f"x{op_calls} calls (wall_delta={wall_dis_pct:.2f}%)")
    emit("observability_enabled", en * 1e6,
         f"overhead={wall_en_pct:.2f}% spans={len(prof.spans)}")
    emit("observability_json", 0.0, path)


def serving():
    """Concurrent-serving figure: many sessions across threads running a
    mixed workload of repeated plan shapes.  Reports p50/p99 request
    latency and mean planning seconds cold (plan cache off) vs warm
    (cache on, after warmup), plus the cache hit rate — the warm/cold
    planning ratio is the headline number (CI asserts < 0.1)."""
    import statistics
    from concurrent.futures import ThreadPoolExecutor

    import repro.core as core
    from repro.core.context import session
    from repro.core.planner.plancache import default_plan_cache

    t_fig = time.perf_counter()
    n = max(20_000, SCALE // 10)
    rng = np.random.default_rng(42)
    src = core.InMemorySource({
        "fare": rng.uniform(0, 100, n),
        "vendor": rng.integers(0, 4, n).astype(np.int64),
        "tip": rng.uniform(0, 20, n),
    }, partition_rows=max(1024, n // 16))

    def p_groupby():
        df = core.read_source(src)
        return (df[df["fare"] > 50.0]
                .groupby("vendor").agg({"total": ("tip", "sum")}).compute())

    def p_topk():
        df = core.read_source(src)
        return df.sort_values("fare", ascending=False).head(25).compute()

    def p_filter_sort():
        df = core.read_source(src)
        return df[df["tip"] > 15.0].sort_values("tip").compute()

    programs = (p_groupby, p_topk, p_filter_sort)
    threads, sessions_per_thread, rounds = 4, 2, 2
    cache = default_plan_cache()

    def serve_session(enable_cache, latencies, plan_secs):
        with session(engine="auto", engines=("eager", "streaming"),
                     plan_cache=enable_cache, name="serving") as ctx:
            ctx.print_fn = lambda *a: None
            for _ in range(rounds):
                for prog in programs:
                    t0 = time.perf_counter()
                    prog()
                    latencies.append(time.perf_counter() - t0)
                    plan_secs.append(ctx.last_plan_seconds)

    def run_tier(enable_cache):
        """threads × sessions_per_thread concurrent sessions; returns the
        pooled per-request latencies and planning seconds."""
        def worker(_):
            lat, plan = [], []
            for _ in range(sessions_per_thread):
                serve_session(enable_cache, lat, plan)
            return lat, plan

        all_lat, all_plan = [], []
        with ThreadPoolExecutor(max_workers=threads) as pool:
            for lat, plan in pool.map(worker, range(threads)):
                all_lat.extend(lat)
                all_plan.extend(plan)
        return all_lat, all_plan

    # cold tier: the plan-cache-off escape hatch — every request pays
    # optimize + segment DP (same concurrency as the warm tier so the
    # latency percentiles are comparable)
    cold_lat, cold_plan = run_tier(False)

    # warm tier: cache on, one serial warmup session, then concurrent load
    cache.clear()
    serve_session(True, [], [])
    before = cache.stats()
    warm_lat, warm_plan = run_tier(True)
    after = cache.stats()

    hits = after["hits"] - before["hits"]
    misses = after["misses"] - before["misses"]
    hit_rate = hits / max(1, hits + misses)
    cold_plan_mean = statistics.fmean(cold_plan)
    # planning cost on the warm tier measured on the hits themselves
    # (bind time); falls back to the tier mean if nothing hit
    warm_hit_mean = (
        (after["mean_hit_plan_seconds"] * after["hits"]
         - before["mean_hit_plan_seconds"] * before["hits"]) / hits
        if hits else statistics.fmean(warm_plan))
    ratio = warm_hit_mean / cold_plan_mean if cold_plan_mean else 0.0

    def pct(xs, q):
        xs = sorted(xs)
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    out = {
        "workload": {
            "threads": threads,
            "sessions_per_thread": sessions_per_thread,
            "requests_per_session": rounds * len(programs),
            "programs": [p.__name__ for p in programs],
            "rows": n,
        },
        "cold": {
            "requests": len(cold_lat),
            "p50_seconds": pct(cold_lat, 0.50),
            "p99_seconds": pct(cold_lat, 0.99),
            "mean_plan_seconds": cold_plan_mean,
        },
        "warm": {
            "requests": len(warm_lat),
            "p50_seconds": pct(warm_lat, 0.50),
            "p99_seconds": pct(warm_lat, 0.99),
            "mean_plan_seconds": statistics.fmean(warm_plan),
            "mean_hit_plan_seconds": warm_hit_mean,
            "hits": hits,
            "misses": misses,
            "hit_rate": hit_rate,
        },
        "warm_cold_plan_ratio": ratio,
        "meta": _bench_meta(t_fig),
    }
    path = os.environ.get("REPRO_SERVING_OUT", "serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    emit("serving_cold_p50", out["cold"]["p50_seconds"] * 1e6,
         f"plan={cold_plan_mean * 1e6:.0f}us")
    emit("serving_warm_p50", out["warm"]["p50_seconds"] * 1e6,
         f"plan={warm_hit_mean * 1e6:.0f}us hit_rate={hit_rate:.2f}")
    emit("serving_plan_ratio", ratio * 1e6,
         f"warm/cold={ratio:.4f} json={path}")


def roofline():
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_baseline.json")
    if not os.path.exists(path):
        emit("roofline_table", 0.0, "dryrun_baseline.json missing — run "
             "python -m repro.launch.dryrun --all --mesh both --out it")
        return
    rows = json.load(open(path))
    for r in rows:
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             rf[rf["dominant"] + "_s"] * 1e6,
             f"dom={rf['dominant']} frac={r['roofline_fraction']:.3f}")


ALL_FIGURES = (fig12_applicability, fig13_exec_time, fig14_speedup,
               fig15_memory, backend_selection, api_coverage, rewrites,
               fusion, scan_pushdown, analysis_overhead, ablation_persist,
               kernels,
               observability, serving, roofline)


def main(argv: list[str] | None = None) -> None:
    """Run all figures, or only the ones named on the command line:

        PYTHONPATH=src python -m benchmarks.run api_coverage
    """
    import sys
    argv = list(sys.argv[1:] if argv is None else argv)
    by_name = {fn.__name__: fn for fn in ALL_FIGURES}
    unknown = [a for a in argv if a not in by_name]
    if unknown:
        raise SystemExit(f"unknown figure(s) {unknown}; "
                         f"choose from {sorted(by_name)}")
    selected = [by_name[a] for a in argv] or list(ALL_FIGURES)
    t0 = time.perf_counter()
    for fn in selected:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            emit(f"ERROR_{fn.__name__}", 0.0, f"{type(e).__name__}: {e}")
    emit("total_wall", (time.perf_counter() - t0) * 1e6, "")


if __name__ == "__main__":
    main()
