"""PandasBench-style API-coverage corpus: small *plain pandas* programs run
unmodified through the `repro.pandas` facade.

Each program takes the facade module ``pd`` and a seeded numpy rng, builds
its own small data, and forces at least one result.  The harness
(`benchmarks/run.py api_coverage`) measures per program how many operations
were served natively (lazy graph nodes), via the fallback protocol
(``ctx.fallback_trace``), or failed — coverage is a number, not a claim."""
from __future__ import annotations

import numpy as np


def _taxi(pd, rng, n=4_000):
    return pd.DataFrame({
        "fare": rng.uniform(-5, 100, n),
        "tip": rng.uniform(0, 20, n),
        "passengers": rng.integers(1, 7, n).astype(np.int64),
        "vendor": [["acme", "beta", "cabco"][i] for i in
                   rng.integers(0, 3, n)],
        "pickup": (1_577_836_800 + rng.integers(0, 366 * 86400, n)),
    })


def filter_groupby(pd, rng):
    df = _taxi(pd, rng)
    df = df[df["fare"] > 0]
    df["tip_rate"] = df["tip"] / df["fare"]
    return df.groupby("vendor")["tip_rate"].mean().compute()


def feature_engineering(pd, rng):
    df = _taxi(pd, rng)
    df["day"] = df["pickup"].dt.dayofweek
    df["quarter"] = df["pickup"].dt.quarter        # native: DtField expr
    df["fare_clipped"] = df["fare"].clip(0, 50)    # native: rowwise expr
    return df.groupby("quarter")["fare_clipped"].sum().compute()


def order_statistics(pd, rng):
    df = _taxi(pd, rng)
    top = df.nlargest(10, "fare")                  # native: TopK(select)
    return float(top["fare"].median().compute())   # native: Reduce(median)


def missing_data(pd, rng):
    df = _taxi(pd, rng)
    df["maybe"] = df["fare"] / df["fare"].round()  # injects NaN/inf-ish cells
    clean = df.dropna()                            # fallback: materialize
    return len(clean.compute().columns)


def join_and_concat(pd, rng):
    rides = _taxi(pd, rng, n=2_000)
    vendors = pd.DataFrame({"vendor": ["acme", "beta", "cabco"],
                            "fee": [1.0, 2.0, 0.5]})
    j = pd.merge(rides, vendors, on="vendor")
    both = pd.concat([j, j])
    return both.groupby("vendor")["fee"].count().compute()


def string_and_counts(pd, rng):
    df = _taxi(pd, rng)
    mask = df["vendor"].str.contains("a")          # native: vocab predicate
    counts = df[mask]["vendor"].value_counts()     # fallback: materialize
    return counts.compute()


def robust_statistics(pd, rng):
    df = _taxi(pd, rng)
    spread = df["fare"].std()                      # fallback: materialize
    q90 = df["fare"].quantile(0.9)                 # fallback: materialize
    by_vendor = df.groupby("vendor").median()      # fallback: materialize
    return (spread, q90, by_vendor.compute())


def sort_head_describe(pd, rng):
    df = _taxi(pd, rng)
    ordered = df.sort_values("fare", ascending=False).head(20)
    avg = ordered["tip"].mean()
    return float(avg.compute())


def datetime_pipeline(pd, rng):
    df = pd.DataFrame({
        "when": ["2021-03-01", "2021-06-15", "2021-06-16", "2021-11-30"],
        "amount": [1.0, 2.0, 3.0, 4.0],
    })
    df["month"] = df["when"].dt.month
    df["doy"] = df["when"].dt.dayofyear            # fallback: wrapped UDF
    return df.groupby("month")["amount"].sum().compute()


def unsupported_ops(pd, rng):
    """Deliberately leans on unimplemented API — measures the *failed*
    bucket (each gap is recorded in the trace before raising)."""
    df = _taxi(pd, rng, n=500)
    failures = 0
    for call in (lambda: df.pivot_table(index="vendor"),
                 lambda: df.melt(),
                 lambda: df["fare"].ewm(span=3)):
        try:
            call()
        except (AttributeError, NotImplementedError):
            failures += 1
    return failures


CORPUS = [
    ("filter_groupby", filter_groupby),
    ("feature_engineering", feature_engineering),
    ("order_statistics", order_statistics),
    ("missing_data", missing_data),
    ("join_and_concat", join_and_concat),
    ("string_and_counts", string_and_counts),
    ("robust_statistics", robust_statistics),
    ("sort_head_describe", sort_head_describe),
    ("datetime_pipeline", datetime_pipeline),
    ("unsupported_ops", unsupported_ops),
]
