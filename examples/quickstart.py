"""Quickstart: the paper's two-line change (Fig. 2).

A plain-Pandas-style program running on the LaFP lazy engine: the import and
``pd.analyze()`` are the only deviations from pandas.  Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro.core.lazy as pd                     # ① the import swap
from repro.core.func import print, flush         # lazy print (§3.3)

pd.analyze()                                      # ② JIT static analysis

# -- build a demo CSV-like dataset in memory --------------------------------
rng = np.random.default_rng(0)
N = 200_000
df = pd.from_arrays({
    "fare_amount": rng.uniform(-5, 100, N),
    "passenger_count": rng.integers(0, 7, N).astype(np.int64),
    "pickup_datetime": rng.integers(1_577_836_800, 1_609_459_200, N),
    "tip": rng.uniform(0, 20, N),
    # columns below are never used — column selection drops them at the scan
    "unused_a": rng.uniform(0, 1, N),
    "unused_b": rng.uniform(0, 1, N),
    "unused_c": rng.integers(0, 9, N).astype(np.int64),
})

print(df.head())                                  # lazy: doesn't force

df = df[df["fare_amount"] > 0]                    # predicate pushdown
df["day"] = df.pickup_datetime.dt.dayofweek       # feature add
p_per_day = df.groupby(["day"])["passenger_count"].sum()
print(p_per_day)                                  # still lazy

avg_fare = df.fare_amount.mean()
print(f"Average fare: {avg_fare}")                # deferred f-string (§3.3)

flush()                                           # force everything, in order

# show what the optimizer did
from repro.core import get_context
import builtins
builtins.print("\noptimizer trace:")
for t in get_context().optimizer_trace:
    builtins.print("  •", t)
