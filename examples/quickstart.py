"""Quickstart: the paper's two-line change (Fig. 2).

A plain-Pandas program running on the LaFP lazy engine.  The import swap and
``pd.analyze()`` are the ONLY deviations from pandas — ``analyze()`` also
rebinds this script's ``print``/``len`` to their lazy sink-building versions
(the paper's JIT program rewrite), so output stays deferred without a third
import.  Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro.pandas as pd                        # ① the import swap

pd.analyze()                                      # ② JIT static analysis

# -- a plain-pandas program from here on ------------------------------------
rng = np.random.default_rng(0)
N = 200_000
df = pd.DataFrame({
    "fare_amount": rng.uniform(-5, 100, N),
    "passenger_count": rng.integers(0, 7, N).astype(np.int64),
    "pickup_datetime": rng.integers(1_577_836_800, 1_609_459_200, N),
    "tip": rng.uniform(0, 20, N),
    # columns below are never used — column selection drops them at the scan
    "unused_a": rng.uniform(0, 1, N),
    "unused_b": rng.uniform(0, 1, N),
    "unused_c": rng.integers(0, 9, N).astype(np.int64),
})

print(df.head())                                  # lazy: doesn't force

df = df[df["fare_amount"] > 0]                    # predicate pushdown
df["day"] = df.pickup_datetime.dt.dayofweek       # feature add (native)
df["quarter"] = df.pickup_datetime.dt.quarter     # fallback: wrapped UDF
p_per_day = df.groupby(["day"])["passenger_count"].sum()
print(p_per_day)                                  # still lazy

top = df.nlargest(3, "fare_amount")               # fallback: materializes
print(top)

avg_fare = df.fare_amount.mean()
print(f"Average fare: {avg_fare}")                # deferred f-string (§3.3)

# -- diagnostic epilogue (not part of the pandas program) -------------------
pd.flush()                                        # force everything, in order

import builtins
ctx = pd.get_context()
builtins.print("\noptimizer trace:")
for t in ctx.optimizer_trace:
    builtins.print("  •", t)
builtins.print("fallback trace (API served eagerly, measured):")
for ev in ctx.fallback_trace:
    builtins.print("  •", ev)
