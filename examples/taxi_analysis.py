"""Out-of-core analytics: the same program on all three backends, each run
in its own isolated session (fresh persist cache / sinks / stats), with a
memory budget that only the streaming backend satisfies (paper Fig. 12).

    PYTHONPATH=src python examples/taxi_analysis.py
"""
import tempfile
import time

import numpy as np

import repro.pandas as pd
from repro.core.source import write_npz_source


def program(src):
    df = pd.read_source(src)
    df = df[(df["fare_amount"] > 0) & (df["trip_miles"] < 50)]
    df["per_mile"] = df["fare_amount"] / (df["trip_miles"] + 0.1)
    by_vendor = df.groupby(["vendor"])["per_mile"].mean()
    top = by_vendor.sort_values("per_mile", ascending=False).head(3)
    return top.compute()


def main():
    rng = np.random.default_rng(0)
    N = 500_000
    arrays = {
        "fare_amount": rng.uniform(-5, 100, N),
        "trip_miles": rng.uniform(0, 60, N),
        "vendor": rng.integers(0, 6, N).astype(np.int64),
        "unused1": rng.uniform(0, 1, N),
        "unused2": rng.uniform(0, 1, N),
    }
    with tempfile.TemporaryDirectory() as td:
        src = write_npz_source(f"{td}/taxi", arrays, partition_rows=50_000)
        dataset = src.total_rows() * src.schema.row_bytes()
        budget = dataset // 4                     # deliberately too small
        print(f"dataset {dataset/1e6:.0f} MB, budget {budget/1e6:.0f} MB")
        for backend in ("eager", "streaming", "distributed"):
            # session-scoped context: backend choice, budget and peak
            # accounting are isolated per run — no cross-backend bleed
            with pd.session(engine=backend, memory_budget=budget) as ctx:
                t0 = time.perf_counter()
                try:
                    res = program(src)
                    status = f"ok in {time.perf_counter()-t0:.2f}s"
                    if backend == "streaming":
                        status += f" (peak {ctx.last_peak_bytes/1e6:.0f} MB)"
                except Exception as e:   # noqa: BLE001
                    status = f"FAILED: {type(e).__name__}"
                    res = None
                print(f"{backend:12s}: {status}")
                if res is not None:
                    print(res)
        # note: only streaming respects the budget; eager/distributed load
        # the working set whole (the paper's Pandas/Modin behaviour).


if __name__ == "__main__":
    main()
