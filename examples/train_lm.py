"""End-to-end driver: train a ~100M-param llama-style model for a few
hundred steps on CPU, with the LaFP lazy engine as the input pipeline,
async checkpointing, and resume-on-restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.pipeline import (PipelineConfig, PrefetchIterator,
                                 TokenPipeline, synthetic_token_source)
from repro.launch.train import build_state
from repro.train.loop import LoopConfig, Trainer
from repro.train.optim import OptimConfig
from repro.train.train_step import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    # ~100M params: llama-3.2 family shape, scaled down
    arch = dataclasses.replace(
        get_config("llama3.2-3b"),
        name="llama-100m", d_model=640, n_heads=10, n_kv_heads=5,
        head_dim=64, d_ff=1792, n_groups=10, vocab=32000,
        activation_dtype=jax.numpy.float32, remat=False)
    total, _ = arch.param_count()
    print(f"model: {arch.name}  params={total/1e6:.0f}M")

    tcfg = TrainConfig(optim=OptimConfig(lr=3e-4, warmup_steps=20,
                                         total_steps=args.steps))
    train_step = jax.jit(make_train_step(arch, tcfg), donate_argnums=(0,))

    src = synthetic_token_source(2048, args.seq, arch.vocab, seed=0)
    pipe = TokenPipeline(src, PipelineConfig(batch=args.batch, seq=args.seq,
                                             min_doc_len=2))
    trainer = Trainer(train_step, build_state(arch), PrefetchIterator(iter(pipe)),
                      LoopConfig(total_steps=args.steps, ckpt_every=50,
                                 log_every=10, ckpt_dir=args.ckpt_dir),
                      pipeline_state=pipe.state)
    trainer.try_resume()       # picks up after a crash/preemption
    summary = trainer.run()
    print("summary:", summary)


if __name__ == "__main__":
    main()
