"""Serve a small model with batched requests through the continuous-batching
engine (slot-based KV caches, greedy/temperature sampling).

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.layers import init_from_spec
from repro.models.transformer import model_spec
from repro.serve.engine import Engine, Request


def main():
    arch = dataclasses.replace(
        get_config("qwen2.5-3b").smoke(),
        name="qwen-serve-demo", d_model=128, n_groups=4, vocab=512)
    params = init_from_spec(model_spec(arch), jax.random.PRNGKey(0))
    total, _ = arch.param_count()
    print(f"serving {arch.name} ({total/1e6:.1f}M params)")

    eng = Engine(arch, params, max_batch=4, max_seq=64, temperature=0.8)
    rng = np.random.default_rng(0)
    for rid in range(6):
        prompt = rng.integers(0, arch.vocab, rng.integers(2, 8))
        eng.submit(Request(rid=rid, prompt=prompt, max_new=12))

    t0 = time.perf_counter()
    done = eng.run(max_steps=200)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
