"""Engine-author quickstart: add a fourth engine WITHOUT touching core.

Run:  PYTHONPATH=src python examples/engine_plugin.py

The registry contract (see README "Writing an engine"):

1. implement ``execute(roots, ctx) -> {node_id: host value}``;
2. describe yourself with a ``BackendCapability`` (native ops, cost
   constants, peak model);
3. ``repro.register_engine(name, factory, capability)`` — or ship a
   ``repro.engines`` entry point (``tests/plugin_engine/`` is a complete
   pip-installable example, including the chunk-parallel process pool).

After registration the engine is addressable by name everywhere, becomes
an AUTO candidate, calibrates from observed runtimes under its own
stats-store namespace, and shows up in ``pd.explain()`` records.
"""
import numpy as np

import repro
import repro.pandas as pd
from repro.core import graph as G
from repro.core import physical as X
from repro.core.engines import ALL_OPS, BackendCapability


class LoudHostEngine:
    """A deliberately tiny engine: topological host-numpy evaluation via
    the public physical-operator layer, narrating every operator."""

    name = "loud"

    def execute(self, roots, ctx):
        results = {}
        for n in G.walk(roots):
            vals = [results[i.id] for i in n.inputs]
            print(f"  [loud] {n.op}#{n.id}")
            results[n.id] = self._eval(n, vals, ctx)
        return {r.id: results[r.id] for r in roots}

    def _eval(self, n, vals, ctx):
        if isinstance(n, G.Scan):
            parts = [n.source.load_partition(pi, n.columns)
                     for pi in range(n.source.n_partitions)
                     if pi not in n.skip_partitions]
            return {c: np.concatenate([np.asarray(p[c]) for p in parts])
                    for c in parts[0]} if parts else {}
        if isinstance(n, G.Filter):
            return X.apply_filter(vals[0], n.predicate)
        if isinstance(n, G.GroupByAgg):
            return X.apply_groupby_agg(vals[0], n.keys, n.aggs)
        if isinstance(n, G.Reduce):
            return X.apply_reduce(vals[0], n.column, n.fn)
        if isinstance(n, G.Length):
            return X.table_rows(vals[0])
        raise NotImplementedError(n.op)


def main():
    repro.register_engine("loud", LoudHostEngine, BackendCapability(
        name="loud",
        native_ops=frozenset({"scan", "filter", "groupby_agg", "reduce",
                              "length"}) & ALL_OPS,
        startup_cost=1e5, scan_cost_per_byte=2.0, row_cost=2.0,
        parallelism=1.0, transfer_cost_per_byte=1.0, fallback_penalty=1e6,
        peak_model="resident"), replace=True)
    print("registered engines:", repro.engine_names())

    rng = np.random.default_rng(0)
    with pd.session(engine="loud") as ctx:
        df = pd.DataFrame({"fare": rng.uniform(0, 100, 10_000),
                           "vendor": rng.integers(0, 4, 10_000)})
        out = df[df["fare"] > 50].groupby("vendor")["fare"].mean().compute()
        print("result rows:", out.rows())

    # the same engine as an AUTO candidate, visible in pd.explain()
    with pd.session(engine="auto") as ctx:
        df = pd.DataFrame({"fare": rng.uniform(0, 100, 10_000),
                           "vendor": rng.integers(0, 4, 10_000)})
        df[df["fare"] > 50].groupby("vendor")["fare"].mean().compute()
        report = pd.explain()
        print(report.render())
        cand = {c.engine for s in report.runs[-1].segments
                for c in s.candidates}
        print("AUTO considered:", sorted(cand))


if __name__ == "__main__":
    main()
