"""Lazy print (§3.3), forced computation (§3.4), common computation reuse
(§3.5), metadata (§3.6)."""
import numpy as np

import repro.core as core
from repro.core import BackendEngines, get_context
from repro.core.func import flush, len as llen, print as lprint


def test_lazy_print_order_preserved(taxi_arrays):
    ctx = get_context()
    out = []
    ctx.print_fn = out.append
    df = core.from_arrays(taxi_arrays)
    lprint("first")
    lprint("second", df.head(2))
    lprint("third")
    assert out == []                      # nothing printed yet (lazy)
    flush()
    assert out[0] == "first"
    assert out[1].startswith("second")
    assert out[2] == "third"


def test_lazy_print_fstring_scalar(taxi_arrays):
    ctx = get_context()
    out = []
    ctx.print_fn = out.append
    df = core.from_arrays(taxi_arrays)
    avg = df["fare_amount"].mean()
    lprint(f"avg: {avg}")                 # defers via escape marker
    assert out == []
    flush()
    expected = float(np.mean(taxi_arrays["fare_amount"]))
    shown = float(out[0].split(":")[1])
    assert abs(shown - expected) < 1e-3


def test_forced_compute_processes_pending_prints(taxi_arrays):
    """§3.4: a force point executes pending sinks first, in order."""
    ctx = get_context()
    out = []
    ctx.print_fn = out.append
    df = core.from_arrays(taxi_arrays)
    lprint("before-force")
    _ = df[df["fare_amount"] > 0].compute()    # force point
    assert out == ["before-force"]


def test_lazy_len(taxi_arrays):
    df = core.from_arrays(taxi_arrays)
    n = llen(df)
    assert int(n.compute()) == len(taxi_arrays["fare_amount"])
    assert llen([1, 2, 3]) == 3                # passthrough for non-frames


def test_common_computation_reuse(taxi_arrays):
    """§3.5: live_df persists the shared subexpression across force points."""
    ctx = get_context()
    df = core.from_arrays(taxi_arrays, partition_rows=2048)
    df = df[df["fare_amount"] > 0]
    df["day"] = (df["pickup_datetime"] // 86400) % 7
    p = df.groupby(["day"])["passenger_count"].sum()
    _ = p.compute(live_df=[df])          # df is live → persisted
    assert ctx.persist_stats["misses"] >= 1
    before_hits = ctx.persist_stats["hits"]
    _ = df["fare_amount"].mean().compute(live_df=[])
    assert ctx.persist_stats["hits"] > before_hits


def test_persist_cache_evicted_after_last_use(taxi_arrays):
    ctx = get_context()
    df = core.from_arrays(taxi_arrays, partition_rows=2048)
    df = df[df["fare_amount"] > 0]
    p = df.groupby(["passenger_count"])["trip_miles"].mean()
    _ = p.compute(live_df=[df])
    assert len(ctx.persist_cache) >= 1
    # next force with no live frames → cache evicted (paper's last-use rule)
    _ = df["fare_amount"].mean().compute(live_df=[])
    assert len(ctx.persist_cache) == 0


def test_metadata_dtype_narrowing(taxi_arrays):
    from repro.core.metadata import compute_metadata, dtype_overrides_for
    src = core.InMemorySource(taxi_arrays, partition_rows=4096)
    md = compute_metadata(src)
    assert md.rows == len(taxi_arrays["fare_amount"])
    over = dtype_overrides_for(src, readonly_cols={"passenger_count"})
    assert over.get("passenger_count") == "int8"
    # not read-only → not narrowed (paper's category guard)
    over2 = dtype_overrides_for(src, readonly_cols=set())
    assert "passenger_count" not in over2


def test_metadata_backend_choice(taxi_arrays):
    from repro.core.metadata import choose_backend
    src = core.InMemorySource(taxi_arrays, partition_rows=4096)
    assert choose_backend(src, available_bytes=1 << 34) == BackendEngines.EAGER
    assert choose_backend(src, available_bytes=1 << 10) == \
        BackendEngines.STREAMING


def test_dict_encoding_roundtrip():
    from repro.core.source import encode_strings
    vals = ["nyc", "sf", "nyc", "la", "sf", "nyc"]
    codes, vocab = encode_strings(vals)
    assert codes.dtype == np.int32
    assert [vocab[c] for c in codes] == vals


def test_str_accessor_filters_on_codes(rng):
    names = ["red", "green", "blue"]
    raw = [names[i] for i in rng.integers(0, 3, 500)]
    from repro.core.source import encode_strings
    codes, vocab = encode_strings(raw)
    df = core.from_arrays({"color": codes, "v": rng.normal(size=500)},
                          dicts={"color": vocab})
    out = df[df["color"].str.eq("red")].compute()
    assert out.rows() == raw.count("red")
    out2 = df[df["color"].str.isin(["red", "blue"])].compute()
    assert out2.rows() == raw.count("red") + raw.count("blue")
