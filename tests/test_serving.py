"""Concurrent-serving battery: N threads × M sessions over mixed programs
(session isolation, metric integrity, plan-cache hits after warmup),
multi-process StatsStore append/compaction without loss, torn-read safety,
and TraceLog append races."""
import os
import subprocess
import sys
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np

import repro.core as core
from repro.core.context import get_context, session
from repro.core.planner.feedback import StatsStore
from repro.core.planner.plancache import default_plan_cache
from repro.obs.events import TraceLog

# ---------------------------------------------------------------------------
# Shared workload: three program shapes over immutable shared sources
# (sources are read-only after ingest — sharing them across sessions is part
# of the documented concurrency contract).

_N = 8_000
_RNG = np.random.default_rng(42)
_FARE = _RNG.uniform(0, 100, _N)
_VENDOR = _RNG.integers(0, 4, _N).astype(np.int64)
_TIP = _RNG.uniform(0, 20, _N)
_SRC = core.InMemorySource(
    {"fare": _FARE, "vendor": _VENDOR, "tip": _TIP}, partition_rows=1024)


def _prog_filter_groupby():
    df = core.read_source(_SRC)
    out = (df[df["fare"] > 50.0]
           .groupby("vendor").agg({"total": ("tip", "sum")}).compute())
    return np.sort(np.asarray(out["total"], dtype=np.float64))


def _prog_topk():
    df = core.read_source(_SRC)
    out = df.sort_values("fare", ascending=False).head(25).compute()
    return np.asarray(out["fare"], dtype=np.float64)


def _prog_filter_sort():
    df = core.read_source(_SRC)
    out = df[df["tip"] > 15.0].sort_values("tip").compute()
    return np.asarray(out["tip"], dtype=np.float64)


_PROGRAMS = (_prog_filter_groupby, _prog_topk, _prog_filter_sort)


def _expected():
    mask = _FARE > 50.0
    gb = np.sort(np.asarray(
        [_TIP[mask & (_VENDOR == v)].sum() for v in np.unique(_VENDOR[mask])],
        dtype=np.float64))
    order = np.argsort(-_FARE, kind="stable")
    topk = _FARE[order][:25]
    tips = np.sort(_TIP[_TIP > 15.0])
    return gb, topk, tips


_EXPECTED = _expected()


def _run_session(worker_id: int, session_idx: int):
    """One serving session: runs every program once, returns everything the
    assertions need (results + the session's own counters)."""
    with session(engine="auto", engines=("eager", "streaming"),
                 name=f"w{worker_id}s{session_idx}") as ctx:
        assert get_context() is ctx      # thread-local stack isolation
        results = [p() for p in _PROGRAMS]
        snap = ctx.metrics.snapshot()
        return {
            "results": results,
            "exec_count": ctx.exec_count,
            "runs": len(ctx.run_records),
            "forces": len(ctx.force_log),
            "hits": snap.get("plan_cache.hits", 0),
            "misses": snap.get("plan_cache.misses", 0),
            "uncacheable": snap.get("plan_cache.uncacheable", 0),
        }


def test_concurrent_sessions_stress():
    """N threads × M sessions running the mixed workload concurrently:
    every result correct, every session's metrics internally consistent,
    and the process-global plan cache hot after a serial warmup."""
    threads, sessions_per_thread = 4, 3
    cache = default_plan_cache()
    cache.clear()
    # serial warmup: one session populates the cache for each program shape
    _run_session(-1, 0)
    before = cache.stats()

    def worker(worker_id):
        return [_run_session(worker_id, s)
                for s in range(sessions_per_thread)]

    with ThreadPoolExecutor(max_workers=threads) as pool:
        per_thread = list(pool.map(worker, range(threads)))

    total_requests = 0
    for thread_sessions in per_thread:
        assert len(thread_sessions) == sessions_per_thread
        for sess in thread_sessions:
            # correctness: concurrent execution never corrupts results
            for got, want in zip(sess["results"], _EXPECTED):
                np.testing.assert_allclose(got, want, rtol=1e-5)
            # isolation: each session saw exactly its own three requests
            assert sess["exec_count"] == len(_PROGRAMS)
            assert sess["runs"] == len(_PROGRAMS)
            assert sess["forces"] == len(_PROGRAMS)
            # metric integrity: every force point classified exactly once
            assert (sess["hits"] + sess["misses"] + sess["uncacheable"]
                    == sess["exec_count"])
            total_requests += sess["exec_count"]

    after = cache.stats()
    hit_delta = after["hits"] - before["hits"]
    assert total_requests == threads * sessions_per_thread * len(_PROGRAMS)
    # after warmup the repeated shapes must mostly hit; the floor is
    # deliberately loose (races can duplicate a miss per key, and noisy
    # calibration can move a session's stats epoch)
    assert hit_delta >= total_requests // 3, (before, after)


def test_tracelog_concurrent_append_consistent():
    """The bounded trace ring under an append race: never over limit, no
    lost eviction counts, no exceptions."""
    log = TraceLog(limit=64)
    per_thread, n_threads = 500, 8

    def hammer(tid):
        for i in range(per_thread):
            log.append(f"{tid}:{i}")

    ts = [threading.Thread(target=hammer, args=(t,)) for t in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(log) <= 64
    assert len(log) + log.dropped == per_thread * n_threads


# ---------------------------------------------------------------------------
# Multi-process StatsStore: append-log + lock-guarded compaction.

_WRITER = """\
import sys
sys.path.insert(0, {src!r})
from repro.core.planner.feedback import StatsStore
store = StatsStore()
name = sys.argv[1]
path = sys.argv[2]
for i in range(20):
    store.record_runtime("eng_" + name, 1000.0 + i, 0.01 + i * 1e-4)
    store.record_peak("eng_" + name, 1 << 20, est_peak=1 << 19)
    store.record(("obs", name, i), rows=100 + i, nbytes=800 + i)
    store.save(path)   # one delta line per iteration, under the file lock
print("done")
"""


def test_statsstore_multiprocess_append_merges_without_loss(tmp_path):
    """Two processes appending runtime/peak/cardinality feedback to the
    same stats path concurrently: compaction merges both streams without
    losing a sample."""
    path = str(tmp_path / "stats.json")
    script = _WRITER.format(src=os.path.abspath("src"))
    procs = [subprocess.Popen([sys.executable, "-c", script, name, path],
                              stdout=subprocess.PIPE, text=True)
             for name in ("a", "b")]
    for p in procs:
        out, _ = p.communicate(timeout=120)
        assert p.returncode == 0 and "done" in out

    merged = StatsStore()
    assert merged.load(path)
    # every sample from both writers survived (20 < the 64-sample ring)
    assert len(merged.runtime_samples["eng_a"]) == 20
    assert len(merged.runtime_samples["eng_b"]) == 20
    assert len(merged.peak_samples["eng_a"]) == 20
    assert len(merged.peak_samples["eng_b"]) == 20
    for name in ("a", "b"):
        for i in range(20):
            assert merged.lookup(("obs", name, i)) == {
                "rows": float(100 + i), "nbytes": float(800 + i)}
    # explicit compaction folds the log into the base and truncates it
    merged.compact(path)
    assert os.path.getsize(path + ".log") == 0
    again = StatsStore()
    assert again.load(path)
    assert len(again.runtime_samples["eng_a"]) == 20
    assert again.lookup(("obs", "b", 19)) is not None


_CHURN_WRITER = """\
import sys
sys.path.insert(0, {src!r})
from repro.core.planner import feedback as F
F._COMPACT_LOG_BYTES = 256      # force a compaction every few appends
store = F.StatsStore()
path = sys.argv[1]
for i in range(300):
    store.record_runtime("eng", 1000.0 + i, 0.01)
    store.record(("churn", i), rows=i, nbytes=8 * i)
    store.save(path)
print("done")
"""


def test_statsstore_reader_never_sees_torn_file(tmp_path):
    """A reader polling while a writer appends and compacts continuously
    must always parse a consistent snapshot — the shared file lock means
    no read overlaps the replace/truncate pair."""
    path = str(tmp_path / "stats.json")
    script = _CHURN_WRITER.format(src=os.path.abspath("src"))
    proc = subprocess.Popen([sys.executable, "-c", script, path],
                            stdout=subprocess.PIPE, text=True)
    reads = 0
    try:
        while proc.poll() is None:
            reader = StatsStore()
            if reader.load(path):     # raises on a torn file — never should
                reads += 1
    finally:
        out, _ = proc.communicate(timeout=120)
    assert proc.returncode == 0 and "done" in out
    assert reads > 0
    final = StatsStore()
    assert final.load(path)
    # the last delta is never lost across all those compactions
    assert final.lookup(("churn", 299)) == {"rows": 299.0,
                                            "nbytes": 8.0 * 299}


def test_statsstore_thread_safety_smoke():
    """In-memory mutation from many threads: no lost samples below the
    ring cap, no exceptions from concurrent calibration reads."""
    store = StatsStore()
    n_threads, per_thread = 8, 50

    def work(tid):
        for i in range(per_thread):
            store.record(("t", tid, i), rows=i, nbytes=i)
            store.record_runtime(f"eng{tid}", 100.0 + i, 0.01)
            store.calibration()
            store.peak_calibration()

    ts = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(store.observed) == n_threads * per_thread
    for tid in range(n_threads):
        assert len(store.runtime_samples[f"eng{tid}"]) == per_thread
