"""Tests for the `repro.pandas` drop-in facade: pandas-shaped entry points,
the working BACKEND_ENGINE module property, the measured fallback protocol
(round-trip correctness vs pure-numpy references), hardened read_csv
inference, and the deprecation shim."""
import os
import warnings

import numpy as np
import pytest

import repro.pandas as pd
from repro.core import BackendEngines, get_context


def _taxi_frame(rng, n=2_000):
    return pd.DataFrame({
        "fare": rng.uniform(-5, 100, n),
        "tip": rng.uniform(0, 20, n),
        "vendor": [["acme", "beta", "cabco"][i]
                   for i in rng.integers(0, 3, n)],
        "pickup": 1_577_836_800 + rng.integers(0, 366 * 86400, n),
    }), None


# ---------------------------------------------------------------------------
# entry points


def test_dataframe_constructor_encodes_strings_and_datetimes(rng):
    df = pd.DataFrame({
        "x": [1, 2, 3],
        "s": ["a", "b", "a"],
        "when": ["2021-01-01", "2021-06-01", "2021-12-31"],
    })
    res = df.compute()
    assert np.asarray(res["x"]).tolist() == [1, 2, 3]
    assert list(res.decode("s")) == ["a", "b", "a"]
    assert np.asarray(res["when"])[0] == 1609459200  # epoch seconds


def test_series_constructor_and_reduction():
    s = pd.Series([1.0, 2.0, 3.0], name="v")
    assert float(s.sum().compute()) == pytest.approx(6.0)


def test_dataframe_from_records_and_2d_array():
    df = pd.DataFrame([{"a": 1, "b": 2.0}, {"a": 3, "b": 4.0}])
    assert df.compute().rows() == 2
    df2 = pd.DataFrame(np.ones((4, 2)), columns=["x", "y"])
    assert sorted(df2.columns) == ["x", "y"]


def test_concat_native_and_merge(rng):
    a = pd.DataFrame({"k": [1, 2], "v": [1.0, 2.0]})
    b = pd.DataFrame({"k": [3], "v": [3.0]})
    c = pd.concat([a, b])
    assert c.compute().rows() == 3
    assert not get_context().fallback_trace  # vocab-compatible: stayed lazy
    m = pd.merge(c, pd.DataFrame({"k": [1, 3], "w": [9.0, 7.0]}), on="k")
    assert m.compute().rows() == 2


def test_concat_vocab_mismatch_falls_back():
    a = pd.DataFrame({"s": ["a", "b"], "v": [1.0, 2.0]})
    b = pd.DataFrame({"s": ["z", "b"], "v": [3.0, 4.0]})
    c = pd.concat([a, b])
    res = c.compute()
    assert res.rows() == 4
    assert list(res.decode("s")) == ["a", "b", "z", "b"]
    assert any(ev.op == "concat" for ev in get_context().fallback_trace)


def test_to_datetime_on_string_column():
    df = pd.DataFrame({"when": ["2021-01-01", "2021-06-01"], "v": [1, 2]},)
    # re-encode as plain strings that did NOT auto-parse: build via Series
    s = pd.to_datetime("2021-01-01")
    assert s == 1609459200


def test_isna_lazy_and_eager():
    s = pd.Series([1.0, np.nan, 3.0], name="x")
    assert np.asarray(pd.isna(s).compute()).tolist() == [False, True, False]
    assert pd.isna(np.nan) and not pd.isna(1.0)
    assert np.asarray(pd.notna(s).compute()).tolist() == [True, False, True]


# ---------------------------------------------------------------------------
# BACKEND_ENGINE module property (satellite: the seed bug)


def test_backend_engine_assignment_round_trips():
    pd.BACKEND_ENGINE = "streaming"
    assert get_context().backend == "streaming"
    assert pd.BACKEND_ENGINE == "streaming"
    pd.BACKEND_ENGINE = "eager"
    assert get_context().backend == "eager"


def test_backend_engine_accepts_deprecated_enum_members():
    # the alias layer: enum members are str subclasses equal to the names,
    # still accepted everywhere — but the facade warns about them
    with pytest.warns(DeprecationWarning):
        pd.BACKEND_ENGINE = pd.BackendEngines.STREAMING
    assert get_context().backend == "streaming"
    assert pd.BACKEND_ENGINE == BackendEngines.STREAMING
    with pytest.warns(DeprecationWarning):
        pd.BACKEND_ENGINE = pd.BackendEngines.EAGER
    assert get_context().backend == BackendEngines.EAGER


def test_backend_engine_rejects_junk_and_unknown_names():
    with pytest.raises(TypeError):
        pd.BACKEND_ENGINE = 42
    with pytest.raises(ValueError):
        pd.BACKEND_ENGINE = "no-such-engine"


def test_backend_engine_is_session_scoped():
    pd.BACKEND_ENGINE = "eager"
    with pd.session(engine="distributed"):
        assert pd.BACKEND_ENGINE == "distributed"
        pd.BACKEND_ENGINE = "streaming"
    assert pd.BACKEND_ENGINE == "eager"


# ---------------------------------------------------------------------------
# fallback protocol: round-trip correctness vs pure numpy


def test_nlargest_native_matches_numpy(rng):
    # nlargest lowers to the native TopK node — correct values, no fallback
    df, _ = _taxi_frame(rng)
    fares = np.asarray(df.compute()["fare"])
    top = np.asarray(df.nlargest(5, "fare").compute()["fare"])
    expect = np.sort(fares)[::-1][:5]
    np.testing.assert_allclose(top, expect)
    assert not [e for e in get_context().fallback_trace
                if e.op == "DataFrame.nlargest"]


def test_fallback_series_stats_match_numpy(rng):
    df, _ = _taxi_frame(rng)
    fares = np.asarray(df.compute()["fare"])
    # median graduated to a native Reduce node: it is lazy now
    assert float(df["fare"].median().compute()) == \
        pytest.approx(np.median(fares))
    assert df["fare"].std() == pytest.approx(np.std(fares, ddof=1))
    assert df["fare"].quantile(0.9) == pytest.approx(np.quantile(fares, 0.9))


def test_fallback_dropna_roundtrip():
    df = pd.DataFrame({"a": [1.0, np.nan, 3.0], "b": [1, 2, 3]})
    res = df.dropna().compute()
    assert res.rows() == 2
    assert np.asarray(res["b"]).tolist() == [1, 3]


def test_fallback_value_counts_keeps_vocab():
    df = pd.DataFrame({"s": ["a", "b", "a", "a"], "v": [1, 2, 3, 4]})
    vc = df["s"].value_counts().compute()
    assert dict(zip(vc.decode("value"), np.asarray(vc["count"]).tolist())) \
        == {"a": 3, "b": 1}


def test_fallback_elementwise_stays_lazy(rng):
    df, _ = _taxi_frame(rng)
    before = get_context().exec_count
    rooted = df["fare"].sqrt()             # wrapped UDF — must not force
    assert get_context().exec_count == before
    ev = get_context().fallback_trace[-1]
    assert ev.op == "Series.sqrt" and ev.reason == "wrapped-udf"
    vals = np.asarray(rooted.compute())
    ref = np.sqrt(np.asarray(df.compute()["fare"]))
    np.testing.assert_allclose(vals, ref)


def test_clip_round_native_no_fallback(rng):
    # clip/round are native rowwise exprs now — lazy, exact, no fallback
    df, _ = _taxi_frame(rng)
    before = get_context().exec_count
    expr = df["fare"].clip(5, 40).round(1)
    assert get_context().exec_count == before
    vals = np.asarray(expr.compute())
    ref = np.round(np.clip(np.asarray(df.compute()["fare"]), 5, 40), 1)
    np.testing.assert_allclose(vals, ref, rtol=1e-6)  # float32 round
    assert not [e for e in get_context().fallback_trace
                if e.op in ("Series.clip", "Series.round")]


def test_fallback_cumsum_is_whole_column_correct(rng):
    # order-dependent op must NOT be computed per partition
    arr = rng.uniform(0, 1, 5_000)
    df = pd.from_arrays({"x": arr}, partition_rows=512)
    out = np.asarray(df["x"].cumsum().compute())
    # engine may narrow float64→float32 (§3.6); values must match the whole-
    # column prefix sum, not a per-partition restart
    np.testing.assert_allclose(out, np.cumsum(arr), rtol=1e-3)


def test_fallback_dt_quarter_and_dayofyear():
    df = pd.DataFrame({"when": ["2021-01-15", "2021-05-01", "2021-12-31"],
                       "v": [1, 2, 3]})
    assert np.asarray(df["when"].dt.quarter.compute()).tolist() == [1, 2, 4]
    assert np.asarray(df["when"].dt.dayofyear.compute()).tolist() == [15, 121, 365]


def test_fallback_groupby_median_matches_numpy():
    df = pd.DataFrame({"k": [0, 0, 1, 1, 1], "v": [1.0, 3.0, 2.0, 4.0, 6.0]})
    res = df.groupby("k")["v"].median().compute()
    assert np.asarray(res["k"]).tolist() == [0, 1]
    assert np.asarray(res["v"]).tolist() == [2.0, 4.0]


def test_fallback_str_ops():
    df = pd.DataFrame({"s": ["abc", "bcd", "xyz"], "v": [1, 2, 3]})
    hits = df[df["s"].str.contains("bc")].compute()
    assert hits.rows() == 2
    lens = np.asarray(df["s"].str.len().compute())
    assert lens.tolist() == [3, 3, 3]
    upper = df["s"].str.upper()
    assert list(upper.frame.compute().decode("s")) == ["ABC", "BCD", "XYZ"]


def test_unsupported_op_recorded_then_raises(rng):
    df, _ = _taxi_frame(rng)
    with pytest.raises(AttributeError):
        df.pivot_table(index="vendor")
    with pytest.raises(AttributeError):
        df["fare"].ewm(span=3)
    failed = [e for e in get_context().fallback_trace if e.status == "failed"]
    assert {e.op for e in failed} == {"DataFrame.pivot_table", "Series.ewm"}


def test_unsupported_program_completes_via_fallback(rng):
    """The acceptance-criteria program shape: unsupported-op program
    completes with the op recorded rather than raising."""
    df, _ = _taxi_frame(rng)
    df = df[df["fare"] > 0]
    top = df.nlargest(50, "fare")          # native TopK since the rewrite PR
    result = top.groupby("vendor").median()  # not native — fallback
    assert result.compute().rows() >= 1
    ops = {e.op for e in get_context().fallback_trace}
    assert "GroupBy.median" in ops and "DataFrame.nlargest" not in ops


def test_shape_and_columns(rng):
    df, _ = _taxi_frame(rng, n=100)
    assert df.shape == (100, 4)
    assert sorted(df.columns) == ["fare", "pickup", "tip", "vendor"]
    assert any(e.op == "DataFrame.shape" for e in get_context().fallback_trace)


def test_drop_is_native_projection(rng):
    df, _ = _taxi_frame(rng, n=50)
    before = len(get_context().fallback_trace)
    res = df.drop(columns=["tip", "pickup"]).compute()
    assert sorted(res.columns) == ["fare", "vendor"]
    assert len(get_context().fallback_trace) == before


def test_fallback_query_multi_clause(rng):
    df = pd.DataFrame({"a": [1, 2, 1, 3], "b": [2.0, 2.0, 9.0, 2.0]})
    res = df.query("a == 1 and b == 2").compute()
    assert res.rows() == 1
    res = df.query("a == 3 or b == 9").compute()
    assert res.rows() == 2


def test_fallback_shift_negative_periods():
    s = pd.Series([5.0, 1.0, 3.0], name="x")
    fwd = np.asarray(s.shift(1).compute())
    assert np.isnan(fwd[0]) and fwd[1] == 5.0
    back = np.asarray(pd.Series([5.0, 1.0, 3.0], name="x").shift(-1).compute())
    assert back[0] == 1.0 and back[1] == 3.0 and np.isnan(back[2])


def test_fallback_rank_averages_ties():
    r = np.asarray(pd.Series([1.0, 1.0, 2.0], name="x").rank().compute())
    assert r.tolist() == [1.5, 1.5, 3.0]


def test_dataframe_iso_looking_strings_stay_strings():
    df = pd.DataFrame({"s": ["2020-01-01 to 2020-02-01",
                             "2020-03-01 to 2020-04-01"]})
    assert list(df.compute().decode("s")) == [
        "2020-01-01 to 2020-02-01", "2020-03-01 to 2020-04-01"]


def test_concat_fallback_union_fills_missing_columns():
    a = pd.DataFrame({"k": ["a", "b"], "v": [1, 2]})
    b = pd.DataFrame({"k": ["z"], "u": [9.0]})
    res = pd.concat([a, b]).compute()
    assert res.rows() == 3
    v = np.asarray(res["v"])
    assert v[0] == 1.0 and np.isnan(v[2])
    u = np.asarray(res["u"])
    assert np.isnan(u[0]) and u[2] == 9.0


def test_groupby_fallback_on_empty_frame():
    df = pd.DataFrame({"g": [1, 2], "v": [1.0, 2.0]})
    empty = df[df["v"] > 100].groupby("g").median()
    assert empty.compute().rows() == 0


def test_columns_and_drop_preserve_order():
    df = pd.DataFrame({"b": [1], "a": [2], "x": [3]})
    assert df.columns == ["b", "a", "x"]   # construction order, not sorted
    assert df.drop(columns=["x"]).columns == ["b", "a"]
    df["z"] = df["a"] + 1
    assert df.columns == ["b", "a", "x", "z"]


# ---------------------------------------------------------------------------
# read_csv hardening (satellite)


def _write_csv(tmp_path, text):
    p = os.path.join(tmp_path, "t.csv")
    with open(p, "w") as f:
        f.write(text)
    return p


def test_read_csv_blank_numeric_cells_become_nan(tmp_path):
    p = _write_csv(str(tmp_path), "a,b\n1,2.5\n,3.5\n3,\n")
    res = pd.read_csv(p).compute()
    a = np.asarray(res["a"])
    assert a.dtype.kind == "f"            # ints fell back to float-with-NaN
    assert np.isnan(a[1]) and a[0] == 1.0
    b = np.asarray(res["b"])
    assert np.isnan(b[2]) and b[1] == 3.5


def test_read_csv_int_column_stays_int(tmp_path):
    p = _write_csv(str(tmp_path), "a\n1\n2\n3\n")
    arr = np.asarray(pd.read_csv(p).compute()["a"])
    assert arr.dtype.kind == "i"          # engine may narrow the int width
    assert arr.tolist() == [1, 2, 3]


def test_read_csv_datetime_probe_skips_na_cells(tmp_path):
    p = _write_csv(str(tmp_path), "d\nna\n2021-02-03\n2021-02-04\n")
    from repro.pandas.io import NAT_SENTINEL
    d = np.asarray(pd.read_csv(p).compute()["d"])
    assert d.dtype.kind == "i"
    assert d[0] == NAT_SENTINEL and d[1] == 1612310400


def test_read_csv_skips_blank_lines(tmp_path):
    p = _write_csv(str(tmp_path), "a,b\n1,2\n\n3,4\n")
    res = pd.read_csv(p).compute()
    assert res.rows() == 2


def test_read_csv_na_tokens_in_string_column(tmp_path):
    p = _write_csv(str(tmp_path), "s\nfoo\nbar\nfoo\n")
    res = pd.read_csv(p).compute()
    assert list(res.decode("s")) == ["foo", "bar", "foo"]


# ---------------------------------------------------------------------------
# deprecation shim


def test_core_lazy_shim_importable_and_deprecated():
    import importlib
    import repro.core.lazy as lazy_shim
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        importlib.reload(lazy_shim)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    # same objects as the facade
    assert lazy_shim.from_arrays is pd.from_arrays
    assert lazy_shim.read_csv is pd.read_csv
    assert lazy_shim.LazyFrame is pd.LazyFrame


def test_core_lazy_shim_backend_engine_round_trips():
    import repro.core.lazy as lazy_shim
    lazy_shim.BACKEND_ENGINE = BackendEngines.STREAMING
    assert get_context().backend == "streaming"
    assert pd.BACKEND_ENGINE == BackendEngines.STREAMING


def test_two_line_program_via_facade(taxi_arrays):
    pd.analyze()
    df = pd.from_arrays(taxi_arrays)
    out = df[df["fare_amount"] > 50].compute()
    assert out.rows() == int((taxi_arrays["fare_amount"] > 50).sum())
