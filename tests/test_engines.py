"""Open engine-registry tests: registration, string-named engine API,
planner integration of plug-in engines (candidates, calibration flip,
explain records), stats-store persistence through registry namespaces,
content-fingerprint cache tokens, metered peaks, and the native
distributed head."""
import os
import sys

import numpy as np
import pytest

import repro
import repro.core as core
import repro.pandas as pd
from repro.core import get_context
from repro.core import graph as G
from repro.core.engines import (ALL_OPS, BackendCapability, UnknownEngineError,
                                default_registry)
from repro.core.planner.feedback import MIN_RUNTIME_SAMPLES

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "plugin_engine"))
import repro_pool_engine  # noqa: E402

repro_pool_engine.register()


def _uniform_source(n=10_000, partition_rows=1024, seed=0):
    rng = np.random.default_rng(seed)
    return core.InMemorySource({
        "fare": rng.uniform(0, 100, n),
        "vendor": rng.integers(0, 4, n).astype(np.int64),
        "miles": rng.uniform(0, 30, n),
    }, partition_rows)


def _dummy_cap(name, **kw):
    base = dict(name=name, native_ops=ALL_OPS, startup_cost=1e9,
                scan_cost_per_byte=9.0, row_cost=9.0, parallelism=1.0,
                transfer_cost_per_byte=1.0, fallback_penalty=1.0)
    base.update(kw)
    return BackendCapability(**base)


# ---------------------------------------------------------------------------
# Registry mechanics


def test_builtin_engines_registered_by_name():
    names = repro.engine_names()
    for n in ("eager", "streaming", "distributed"):
        assert n in names
    cap = repro.get_capability("streaming")
    assert cap.peak_model == "chunked" and cap.streams_partitions


def test_register_engine_rejects_reserved_and_duplicate_names():
    with pytest.raises(ValueError):
        repro.register_engine("auto", lambda: None, _dummy_cap("auto"))
    repro.register_engine("dup-test", lambda: None, _dummy_cap("dup-test"))
    try:
        with pytest.raises(ValueError):
            repro.register_engine("dup-test", lambda: None,
                                  _dummy_cap("dup-test"))
        repro.register_engine("dup-test", lambda: None,
                              _dummy_cap("dup-test"), replace=True)
    finally:
        repro.unregister_engine("dup-test")
    assert "dup-test" not in repro.engine_names()


def test_unknown_engine_errors_list_registered_names():
    with pytest.raises(UnknownEngineError) as ei:
        repro.get_capability("warp-drive")
    assert "eager" in str(ei.value)


def test_create_engine_filters_foreign_options():
    # streaming accepts chunk_rows but not placement — both arrive mixed in
    # backend_options and the factory must get only its own
    eng = repro.create_engine("streaming",
                              {"chunk_rows": 512, "placement": "per_root"})
    assert eng.chunk_rows == 512


def test_capability_name_is_forced_to_registry_key():
    repro.register_engine("renamed", lambda: None, _dummy_cap("other"))
    try:
        assert repro.get_capability("renamed").name == "renamed"
    finally:
        repro.unregister_engine("renamed")


# ---------------------------------------------------------------------------
# Plug-in engine: selectable by name, AUTO candidate, calibration flip


def test_pool_engine_runs_fixed_by_name():
    ctx = get_context()
    ctx.print_fn = lambda *a: None
    with pd.session(engine="pool") as sctx:
        sctx.print_fn = lambda *a: None
        df = pd.DataFrame({"x": np.arange(5000.0),
                           "k": (np.arange(5000) % 5).astype(np.int64)})
        out = df[df["x"] > 100].groupby("k")["x"].sum().compute()
        assert out.rows() == 5
        samples = sctx.stats_store.runtime_samples.get("pool")
        assert samples, "pool run recorded no calibration sample"


def test_pool_engine_appears_in_auto_candidate_records():
    ctx = get_context()
    ctx.backend = "auto"
    src = _uniform_source(n=5000)
    df = core.read_source(src)
    df[df["fare"] > 10.0].compute()
    d = ctx.planner_decisions[0]
    assert "pool" in d.candidates
    rep = pd.explain()
    seg = rep.runs[-1].segments[0]
    engines_seen = {c.engine for c in seg.candidates}
    assert "pool" in engines_seen
    # the chosen engine has an empty reason; rejected ones carry one
    chosen = [c for c in seg.candidates if c.chosen]
    assert len(chosen) == 1 and chosen[0].reason == ""
    rejected = [c for c in seg.candidates if not c.chosen]
    assert rejected and all(c.reason for c in rejected)


def _calibrate_pool_fastest(store):
    for _ in range(MIN_RUNTIME_SAMPLES):
        store.record_runtime("pool", 1.0, 1e-9)
        for other in ("eager", "streaming", "distributed"):
            store.record_runtime(other, 1.0, 1000.0)


def test_auto_selects_pool_engine_once_calibrated():
    """The pluggability acceptance: a runtime-registered engine becomes the
    AUTO choice when runtime calibration shows it measured-cheaper."""
    ctx = get_context()
    ctx.backend = "auto"
    ctx.print_fn = lambda *a: None
    src = _uniform_source(n=5000)

    def run():
        df = core.read_source(src)
        return df[df["fare"] > 10.0].compute()

    run()
    assert ctx.planner_decisions[0].backend != "pool"   # dominated a priori
    _calibrate_pool_fastest(ctx.stats_store)
    out = run()
    assert ctx.planner_decisions[0].backend == "pool"
    assert out.rows() > 0
    assert any("-> pool" in line for line in ctx.planner_trace)


def test_engine_allowlist_excludes_plugin_from_auto():
    with pd.session(engine="auto", engines=("eager", "streaming")) as ctx:
        ctx.print_fn = lambda *a: None
        _calibrate_pool_fastest(ctx.stats_store)
        src = _uniform_source(n=5000)
        df = core.read_source(src)
        df[df["fare"] > 10.0].compute()
        d = ctx.planner_decisions[0]
        assert d.backend in ("eager", "streaming")
        assert "pool" not in d.candidates and "distributed" not in d.candidates


# ---------------------------------------------------------------------------
# Stats-store persistence round-trips through registry namespaces (incl. a
# runtime-registered engine)


def test_stats_persistence_round_trip_flips_auto_in_second_session(tmp_path):
    import json
    path = str(tmp_path / "stats.json")
    src = _uniform_source(n=5000)

    with pd.session(engine="auto", stats_path=path) as ctx:
        ctx.print_fn = lambda *a: None
        _calibrate_pool_fastest(ctx.stats_store)
        df = core.read_source(src)
        df[df["fare"] > 10.0].compute()      # executes → saves the store
        assert ctx.planner_decisions[0].backend == "pool"

    with open(path) as f:
        data = json.load(f)
    assert "pool" in data["runtime_samples"], (
        "registry namespace missing from persisted store")

    # "restart": a fresh session reloads the store; AUTO decisions reflect
    # the first session's calibration — including the plug-in engine's
    with pd.session(engine="auto", stats_path=path) as ctx2:
        ctx2.print_fn = lambda *a: None
        assert ctx2.stats_store.cost_scale("pool") is not None
        df = core.read_source(src)
        df[df["fare"] > 10.0].compute()
        assert ctx2.planner_decisions[0].backend == "pool"


# ---------------------------------------------------------------------------
# InMemorySource content-fingerprint cache tokens (ROADMAP open item)


def test_inmemory_cache_token_is_content_fingerprint():
    arrays = {"x": np.arange(1000.0), "k": np.arange(1000) % 5}
    a = core.InMemorySource({k: v.copy() for k, v in arrays.items()})
    b = core.InMemorySource({k: v.copy() for k, v in arrays.items()})
    assert a.cache_token() == b.cache_token()          # same content
    changed = {k: v.copy() for k, v in arrays.items()}
    changed["x"][0] = -1.0
    c = core.InMemorySource(changed)
    assert a.cache_token() != c.cache_token()          # different bytes
    d = core.InMemorySource({"x": arrays["x"].astype(np.float32),
                             "k": arrays["k"].copy()})
    assert a.cache_token() != d.cache_token()          # different dtype


def test_inmemory_cardinality_feedback_survives_restart(tmp_path):
    """Persisted observed cardinalities key on the content fingerprint, so
    a fresh process (fresh source *object*) over the same data reuses
    them — previously only disk-backed sources did."""
    from repro.core.optimizer import optimize
    from repro.core.planner.stats import estimate_plan
    path = str(tmp_path / "stats.json")
    arrays = {"fare": np.concatenate([np.zeros(9800),
                                      np.linspace(1, 100, 200)])}

    with pd.session(engine="eager", stats_path=path) as ctx:
        ctx.print_fn = lambda *a: None
        src = core.InMemorySource({k: v.copy() for k, v in arrays.items()},
                                  partition_rows=1024)
        df = core.read_source(src)
        df[df["fare"] > 50.0].compute()
        assert len(ctx.stats_store) >= 1

    with pd.session(engine="auto", stats_path=path) as ctx2:
        ctx2.print_fn = lambda *a: None
        src2 = core.InMemorySource({k: v.copy() for k, v in arrays.items()},
                                   partition_rows=1024)
        df2 = core.read_source(src2)
        node = df2[df2["fare"] > 50.0]._node
        roots, _ = optimize([node], ctx2)
        est = estimate_plan(roots, ctx2)
        assert est[roots[0].id].exact, (
            "restart-simulating session did not reuse in-memory feedback")
        actual = int((arrays["fare"] > 50.0).sum())
        assert est[roots[0].id].rows == pytest.approx(actual)


# ---------------------------------------------------------------------------
# Metered peaks beyond the streaming meter (ROADMAP open item)


def test_eager_runs_meter_peak_and_feed_calibration():
    ctx = get_context()
    ctx.backend = "eager"
    src = _uniform_source(n=20_000, partition_rows=1024)
    df = core.read_source(src)
    df[df["fare"] > 10.0].compute()
    assert ctx.last_run_peak_engine == "eager"
    assert ctx.last_run_peak_bytes > 0
    samples = ctx.stats_store.peak_samples.get("eager")
    assert samples, "eager run recorded no (est, observed) peak sample"
    est, obs = samples[-1]
    assert est > 0 and obs > 0


def test_auto_segment_on_eager_records_peak_sample():
    ctx = get_context()
    ctx.backend = "auto"
    src = _uniform_source(n=5000)
    df = core.read_source(src)
    df[df["fare"] > 10.0].compute()
    chosen = ctx.planner_decisions[0].backend
    assert ctx.stats_store.peak_samples.get(chosen)


# ---------------------------------------------------------------------------
# Native distributed head (ROADMAP open item)


def test_distributed_head_no_gather_no_reshard(monkeypatch):
    import repro.core.physical as X
    from repro.core.backends import get_backend
    from repro.core.physical import sharded as S
    src = core.InMemorySource({"x": np.arange(5000, dtype=np.int64)},
                              partition_rows=512)
    scan = G.Scan(src)
    head = G.Head(scan, 40)
    gathers = {"n": 0}
    shards = {"n": 0}
    orig_gather = S.ShardedTable.gather

    def counting_gather(self):
        gathers["n"] += 1
        return orig_gather(self)

    orig_shard = S.shard_host_table

    def counting_shard(*a, **k):
        shards["n"] += 1
        return orig_shard(*a, **k)

    monkeypatch.setattr(S.ShardedTable, "gather", counting_gather)
    monkeypatch.setattr(S, "shard_host_table", counting_shard)
    monkeypatch.setattr(X, "shard_host_table", counting_shard)
    be = get_backend("distributed")
    res = be.execute([head], get_context())[head.id]
    np.testing.assert_array_equal(np.asarray(res["x"]), np.arange(40))
    assert shards["n"] == 1, "head re-sharded the table"
    assert gathers["n"] == 1, "head gathered beyond final materialization"


def test_distributed_head_negative_n_falls_back_to_pandas_semantics():
    """pandas ``head(-n)`` means all-but-last-n; the native masked head
    only serves n >= 0 and negative n must take the host fallback."""
    from repro.core.backends import get_backend
    src = core.InMemorySource({"x": np.arange(10, dtype=np.int64)},
                              partition_rows=4)
    head = G.Head(G.Scan(src), -2)
    res = get_backend("distributed").execute([head], get_context())[head.id]
    np.testing.assert_array_equal(np.asarray(res["x"]), np.arange(8))


def test_allowlist_matching_no_engine_raises():
    """A typo'd allow-list must error, not silently fall back to the full
    candidate set (which would dispatch to the excluded engines)."""
    from repro.core.planner.select import candidate_engines
    with pd.session(engine="auto", engines=("streamin",)) as ctx:
        with pytest.raises(UnknownEngineError):
            candidate_engines(ctx)


def test_enum_members_warn_at_public_entry_points():
    with pytest.warns(DeprecationWarning):
        pd.BACKEND_ENGINE = pd.BackendEngines.STREAMING
    assert get_context().backend == "streaming"
    with pytest.warns(DeprecationWarning):
        pd.set_backend(pd.BackendEngines.EAGER)
    with pytest.warns(DeprecationWarning):
        with pd.session(engine=pd.BackendEngines.EAGER):
            pass


def test_record_execution_peak_is_per_run_not_session_max():
    """A big metered run must not leak its peak into a later engine's
    namespace: record_execution keys on *this run's* peak."""
    ctx = get_context()
    ctx.backend = "streaming"
    big = _uniform_source(n=50_000, partition_rows=2048)
    core.read_source(big).compute()
    streaming_peak = ctx.stats_store.backend_peaks["streaming"]
    assert streaming_peak > 0
    ctx.backend = "eager"
    tiny = core.InMemorySource({"x": np.arange(8, dtype=np.int64)})
    core.read_source(tiny).compute()
    eager_peak = ctx.stats_store.backend_peaks.get("eager", 0)
    assert 0 < eager_peak == ctx.last_run_peak_bytes
    assert eager_peak < streaming_peak


def test_sharded_head_masks_across_shard_gaps():
    """head(n) after a filter: the valid prefix spans shards with gaps; the
    masked head must keep exactly the first n valid rows in row order."""
    jax = pytest.importorskip("jax")
    from repro.core.physical import ShardedTable, sharded_head
    import jax.numpy as jnp
    S = max(1, len(jax.devices()))
    per = 16
    x = jnp.arange(S * per).reshape(S, per)
    valid = (x % 3 == 0)
    t = ShardedTable({"x": x}, valid)
    out = sharded_head(t, 5)
    got = out.gather()["x"]
    expected = np.arange(S * per)[np.asarray(valid).reshape(-1)][:5]
    np.testing.assert_array_equal(np.asarray(got), expected)
    assert out.rows() == min(5, int(np.asarray(valid).sum()))


# ---------------------------------------------------------------------------
# pd.explain(): typed records + stable text plan


def test_explain_covers_every_segment_handoff_fallback_and_scale(monkeypatch):
    import dataclasses as dc

    from repro.core import backends as B
    orig = dict(B.CAPABILITIES)
    # force a two-segment split (cheap chunked scan/filter, group-by only
    # native elsewhere) so the report must contain a handoff
    monkeypatch.setitem(
        B.CAPABILITIES, "streaming",
        dc.replace(orig["streaming"],
                   native_ops=frozenset(orig["streaming"].native_ops
                                        - {"groupby_agg"}),
                   scan_cost_per_byte=0.001, row_cost=0.001,
                   fallback_penalty=1e7))
    monkeypatch.setitem(
        B.CAPABILITIES, "eager",
        dc.replace(orig["eager"], scan_cost_per_byte=1e4))
    monkeypatch.setitem(
        B.CAPABILITIES, "distributed",
        dc.replace(orig["distributed"], startup_cost=1e14))
    monkeypatch.setitem(
        B.CAPABILITIES, "pool",
        dc.replace(B.CAPABILITIES["pool"], startup_cost=1e14))
    ctx = get_context()
    ctx.backend = "auto"
    ctx.print_fn = lambda *a: None
    src = _uniform_source(n=20_000, partition_rows=1024)
    df = core.read_source(src)
    df[df["fare"] > 10.0].groupby("vendor")["miles"].sum().compute()
    # a facade fallback event too
    pd.Series(np.arange(10.0), name="v").std()

    rep = ctx.report()
    auto_runs = [r for r in rep.runs if r.engine == "auto"]
    assert auto_runs, rep.runs
    run = auto_runs[0]
    assert len(run.segments) == 2
    assert [s.engine for s in run.segments] == ["streaming", "eager"]
    # every segment priced every candidate or recorded why not
    for seg in run.segments:
        assert seg.candidates, "segment without candidate records"
        assert sum(c.chosen for c in seg.candidates) == 1
    # the cross-segment value shows up as a typed handoff with payload kind
    assert run.handoffs, "no handoff records for a two-segment run"
    h = run.handoffs[0]
    assert h.payload_kind == "table" and not h.device_resident
    assert h.producer == "streaming" and "eager" in h.consumers
    # fallback events covered
    assert any(f.op == "Series.std" for f in rep.fallbacks)
    # calibration scales covered once enough samples exist
    _calibrate_pool_fastest(ctx.stats_store)
    rep2 = ctx.report()
    cal = {c.engine: c for c in rep2.calibration}
    assert cal["pool"].cost_scale == pytest.approx(1e-9)
    # stable text plan renders every piece
    text = rep2.render()
    assert "seg0 -> streaming" in text and "seg1 -> eager" in text
    assert "handoff" in text and "fallback" in text and "calibration:" in text


def test_explain_plan_only_does_not_execute():
    ctx = get_context()
    ctx.backend = "auto"
    src = _uniform_source(n=5000)
    df = core.read_source(src)
    before = ctx.exec_count
    rep = pd.explain(df[df["fare"] > 10.0])
    assert ctx.exec_count == before          # nothing ran
    assert len(rep.runs) == 1
    run = rep.runs[0]
    assert run.force_reason == "explain" and run.executed == ()
    assert run.segments and run.segments[0].ops
    assert {c.engine for c in run.segments[0].candidates} >= {
        "eager", "streaming", "distributed"}
    assert isinstance(rep.to_dict(), dict)


def test_explain_report_is_json_serializable():
    import json
    ctx = get_context()
    ctx.backend = "auto"
    src = _uniform_source(n=2000)
    core.read_source(src).compute()
    rep = pd.explain()
    json.dumps(rep.to_dict(), default=str)


def test_metadata_choose_backend_returns_engine_names():
    from repro.core.metadata import choose_backend
    src = _uniform_source(n=1000)
    assert choose_backend(src, available_bytes=1 << 34) == "eager"
    small = choose_backend(src, available_bytes=1 << 10)
    assert small == "streaming"
