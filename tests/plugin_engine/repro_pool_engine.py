"""Reference **out-of-tree** engine: chunk-parallel process-pool execution.

This module is the pluggability proof for the open engine registry
(`repro.core.engines`): it lives outside `src/repro`, is **never imported
by core**, and registers itself at runtime —

    import repro_pool_engine
    repro_pool_engine.register()

— or automatically via the ``repro.engines`` entry point when installed
(``pip install ./tests/plugin_engine``).  Once registered it is a
first-class engine: selectable by name (``pd.session(engine="pool")``,
``pd.BACKEND_ENGINE = "pool"``), an AUTO candidate priced by its declared
:class:`BackendCapability`, runtime-calibrated under its own stats-store
namespace, and visible in ``pd.explain()`` candidate records.

Execution model: host-numpy topological evaluation (pandas-conformant —
it reuses the engine's public physical operators), with row-preserving
pipeline ops split into fixed-size chunks and mapped across a
``ProcessPoolExecutor`` when their payloads pickle; anything that doesn't
pickle (closures, lambdas) silently runs inline, chunk by chunk.  Workers
use the ``spawn`` start method so the parent's JAX state never leaks into
children.  ``REPRO_POOL_WORKERS=0`` forces fully-inline chunk execution
(useful on CI machines where process pools are slow to warm).

Standard multiprocessing caveat: like any spawn/forkserver pool, scripts
using this engine should guard their entry point with ``if __name__ ==
"__main__":`` — an unguarded ``__main__`` is re-executed during worker
start-up.  If workers cannot come up at all (interactive sessions), the
startup ping times out and the engine permanently falls back to inline
chunk execution — same results, one process."""
from __future__ import annotations

import os
import pickle
from typing import Any

import numpy as np

from repro.core import graph as G
from repro.core.engines import ALL_OPS, BackendCapability

CHUNK_ROWS = 1 << 14

# deliberately dominated a-priori constants: an *uncalibrated* planner
# never picks the pool engine over the built-ins, but once runtime
# calibration shows it measured-fast (see test_engines.py) AUTO flips to
# it — exactly the contract the registry promises plug-ins
CAPABILITY = BackendCapability(
    name="pool",
    native_ops=ALL_OPS,
    startup_cost=5e4,
    scan_cost_per_byte=2.0,
    row_cost=3.0,
    parallelism=2.0,
    transfer_cost_per_byte=1.0,
    fallback_penalty=1.0,
    peak_model="resident",
    # opt in to Scan.pushdown: _load_scan delegates to the shared
    # repro.io.scan loader, which applies pushed-down conjuncts at load
    # time.  Without this flag the optimizer keeps Filter nodes above
    # scans for any plan that could land on this engine.
    scan_pushdown=True,
)

_ROWWISE = ("filter", "project", "assign", "rename", "astype", "fillna",
            "map_rows")

_EXECUTOR = None


def _workers() -> int:
    env = os.environ.get("REPRO_POOL_WORKERS")
    if env is not None:
        return max(0, int(env))
    return min(2, os.cpu_count() or 1)


def _worker_loop(tasks, results):
    """Worker process main loop (module-level: importable under spawn)."""
    while True:
        i, args = tasks.get()
        try:
            out = "pong" if args == "ping" else _run_chunk(args)
            results.put((i, True, out))
        except Exception as e:  # noqa: BLE001 — report, keep serving
            results.put((i, False, f"{type(e).__name__}: {e}"))


class _MiniPool:
    """Minimal process pool over **daemon** workers: daemons can never
    block interpreter exit (the failure mode of a broken
    ``ProcessPoolExecutor``), and a startup ping detects environments where
    spawned children cannot come up (e.g. an interactive ``__main__``)
    before any real work is routed to them."""

    def __init__(self, workers: int):
        import multiprocessing as mp
        try:
            ctx = mp.get_context("forkserver")   # never forks JAX state
        except ValueError:
            ctx = mp.get_context("spawn")
        self._tasks = ctx.Queue()
        self._results = ctx.Queue()
        self._procs = [ctx.Process(target=_worker_loop,
                                   args=(self._tasks, self._results),
                                   daemon=True)
                       for _ in range(workers)]
        for p in self._procs:
            p.start()
        self.map([  # startup ping: one per worker, short timeout
            "ping"] * workers, timeout=10)

    def map(self, items, timeout: float = 120):
        import queue as q
        for i, it in enumerate(items):
            self._tasks.put((i, it))
        out = [None] * len(items)
        for _ in range(len(items)):
            try:
                i, ok, payload = self._results.get(timeout=timeout)
            except q.Empty:
                raise TimeoutError("pool worker did not answer") from None
            if not ok:
                raise RuntimeError(payload)
            out[i] = payload
        return out


def _executor():
    """Lazy singleton pool; any failure permanently disables it
    (``False``) and the engine runs its chunks inline instead."""
    global _EXECUTOR
    if _EXECUTOR is None:
        if _workers() <= 0:
            _EXECUTOR = False
            return None
        try:
            _EXECUTOR = _MiniPool(_workers())
        except Exception:  # noqa: BLE001 — no pool → inline chunks
            _EXECUTOR = False
    return _EXECUTOR or None


def _disable_executor():
    global _EXECUTOR
    _EXECUTOR = False


def _rowwise_chunk(op: str, spec, part: dict[str, np.ndarray]
                   ) -> dict[str, np.ndarray]:
    """Apply one row-preserving op to one chunk.  Pure numpy + the expr
    tree's own ``evaluate`` — importable standalone in a spawned worker."""
    if op == "filter":
        mask = np.asarray(spec.evaluate(part), bool)
        return {k: v[mask] for k, v in part.items()}
    if op == "project":
        return {c: part[c] for c in spec}
    if op == "assign":
        name, expr = spec
        rows = len(next(iter(part.values()))) if part else 0
        val = expr.evaluate(part)
        if np.isscalar(val) or getattr(val, "ndim", 1) == 0:
            val = np.full((rows,), val)
        out = dict(part)
        out[name] = np.asarray(val)
        return out
    if op == "rename":
        return {spec.get(k, k): v for k, v in part.items()}
    if op == "astype":
        out = dict(part)
        for c, dt in spec.items():
            out[c] = out[c].astype(dt)
        return out
    if op == "fillna":
        value, columns = spec
        out = dict(part)
        for c in (columns or list(out)):
            arr = out[c]
            if arr.dtype.kind == "f":
                out[c] = np.where(np.isnan(arr), value, arr)
        return out
    if op == "map_rows":
        return spec(dict(part))
    raise NotImplementedError(op)


def _run_chunk(args):
    """Worker entry point (module-level: picklable under spawn)."""
    op, spec, part = args
    return _rowwise_chunk(op, spec, part)


class PoolEngine:
    """Chunk-parallel process-pool engine over host numpy tables."""

    name = "pool"

    def __init__(self, chunk_rows: int = CHUNK_ROWS,
                 pool_workers: int | None = None):
        self.chunk_rows = chunk_rows
        self.pool_workers = pool_workers

    # -- chunk-parallel rowwise pipeline ------------------------------------

    @staticmethod
    def _rowwise_spec(n: G.Node):
        if isinstance(n, G.Filter):
            return "filter", n.predicate
        if isinstance(n, G.Project):
            return "project", tuple(n.columns)
        if isinstance(n, G.Assign):
            return "assign", (n.name, n.expr)
        if isinstance(n, G.Rename):
            return "rename", dict(n.mapping)
        if isinstance(n, G.AsType):
            return "astype", dict(n.dtypes)
        if isinstance(n, G.FillNa):
            return "fillna", (n.value, n.columns)
        if isinstance(n, G.MapRows):
            return "map_rows", n.fn
        raise NotImplementedError(n.op)

    def _chunks(self, table: dict[str, np.ndarray]):
        rows = len(next(iter(table.values()))) if table else 0
        if rows == 0:
            yield table
            return
        for lo in range(0, rows, self.chunk_rows):
            yield {k: v[lo:lo + self.chunk_rows] for k, v in table.items()}

    @staticmethod
    def _concat(parts: list[dict[str, np.ndarray]]) -> dict[str, np.ndarray]:
        if len(parts) == 1:
            return parts[0]
        return {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}

    def _rowwise(self, n: G.Node, table: dict[str, np.ndarray]):
        op, spec = self._rowwise_spec(n)
        chunks = list(self._chunks(table))
        pool = _executor() if self.pool_workers is None else (
            _executor() if self.pool_workers > 0 else None)
        if pool is not None and len(chunks) > 1:
            try:
                pickle.dumps((op, spec))             # closures can't travel
            except Exception:  # noqa: BLE001 — run inline instead
                pool = None
        if pool is not None and len(chunks) > 1:
            try:
                out = pool.map([(op, spec, c) for c in chunks], timeout=120)
                return self._concat(out)
            except Exception:  # noqa: BLE001 — broken/hung pool: disable it
                _disable_executor()
        return self._concat([_rowwise_chunk(op, spec, c) for c in chunks])

    # -- node evaluation (host numpy; non-rowwise ops reuse the public
    # physical-operator layer) ----------------------------------------------

    def _load_scan(self, n: G.Scan) -> dict[str, np.ndarray]:
        # the shared loader honors Scan.pushdown / skip_partitions /
        # dtype_overrides — the contract behind CAPABILITY.scan_pushdown
        from repro.io.scan import (empty_scan_table, load_scan_partition,
                                   scan_partition_indices)
        parts = [load_scan_partition(n, pi)
                 for pi in scan_partition_indices(n)]
        if not parts:
            return empty_scan_table(n)
        return self._concat(parts)

    def eval_node(self, n: G.Node, vals: list[Any], ctx) -> Any:
        from repro.core import physical as X
        if isinstance(n, G.Handoff):
            return X.handoff_value(n)
        if isinstance(n, G.Materialized):
            return {k: np.asarray(v) for k, v in n.table.items()}
        if isinstance(n, G.Scan):
            return self._load_scan(n)
        if n.op in _ROWWISE:
            return self._rowwise(n, vals[0])
        if isinstance(n, G.FusedRowwise):
            # host tables take the sequential member path inside the shared
            # physical implementation — semantics identical to the chain
            return X.apply_fused_rowwise(vals[0], n.ops)
        if isinstance(n, G.Head):
            return {k: v[: n.n] for k, v in vals[0].items()}
        if isinstance(n, G.SortValues):
            return X.apply_sort(vals[0], n.by, n.ascending)
        if isinstance(n, G.TopK):
            return X.apply_top_k(vals[0], n.by, n.n, n.ascending, n.mode)
        if isinstance(n, G.DropDuplicates):
            return X.apply_drop_duplicates(vals[0], n.subset)
        if isinstance(n, G.GroupByAgg):
            return X.apply_groupby_agg(vals[0], n.keys, n.aggs)
        if isinstance(n, G.Join):
            return X.apply_join(vals[0], vals[1], n.on, n.how, n.suffixes)
        if isinstance(n, G.Concat):
            return X.apply_concat(vals)
        if isinstance(n, G.Reduce):
            return X.apply_reduce(vals[0], n.column, n.fn)
        if isinstance(n, G.Length):
            return X.table_rows(vals[0])
        if isinstance(n, G.SinkPrint):
            from repro.core.sinks import render_sink
            render_sink(n, vals[: n.n_data], ctx)
            return None
        raise NotImplementedError(f"pool: {n.op}")

    # -- driver (refcounted topological walk, like the resident engines) ----

    def execute(self, roots: list[G.Node], ctx) -> dict[int, Any]:
        order = G.walk(roots)
        refcount: dict[int, int] = {}
        for n in order:
            for i in n.inputs:
                refcount[i.id] = refcount.get(i.id, 0) + 1
        root_ids = {r.id for r in roots}
        results: dict[int, Any] = {}
        for n in order:
            vals = [results[i.id] for i in n.inputs]
            key = getattr(n, "cache_key", None)
            if key is None:
                try:
                    key = n.key()
                except Exception:  # noqa: BLE001 — side-effect nodes
                    key = None
            if (key is not None and not isinstance(n, G.SinkPrint)
                    and key in ctx.persist_cache):
                ctx.persist_stats["hits"] += 1
                results[n.id] = ctx.persist_cache[key]
            else:
                results[n.id] = self.eval_node(n, vals, ctx)
                if n.persist and not isinstance(
                        n, (G.SinkPrint, G.Materialized)) and key is not None:
                    ctx.persist_stats["misses"] += 1
                    ctx.persist_cache[key] = results[n.id]
            for i in n.inputs:
                refcount[i.id] -= 1
                if refcount[i.id] == 0 and i.id not in root_ids:
                    if not i.persist:
                        results[i.id] = None
        return {rid: results.get(rid) for rid in root_ids}


def register():
    """Register the pool engine (idempotent).  This is both the manual
    runtime-registration hook and the ``repro.engines`` entry-point target."""
    import repro
    repro.register_engine("pool", PoolEngine, CAPABILITY, replace=True)
