"""Differential conformance suite: every ``benchmarks/api_corpus.py``
program runs through the ``repro.pandas`` facade under EAGER, STREAMING and
AUTO, and its result must equal real-pandas ground truth — values, dtype
kinds, and NaN placement — via the shared ``assert_frame_matches`` helper.

Ground truth is computed by hand-written plain-pandas reference programs
(``_REFS``) that mirror the corpus semantics (PandasBench-style: a facade
reproduction is only credible against a systematic differential corpus).

Precision note: the eager backend runs jax in x32 mode, so float64 pandas
results are compared at float32-friendly tolerances and exact dtypes are
compared at *kind* granularity (float/int/bool/object), not width.
"""
from __future__ import annotations

import os
import sys

import numpy as np
import pytest

pd_real = pytest.importorskip("pandas")

import repro.pandas as rpd  # noqa: E402
from repro.core import BackendEngines, get_context  # noqa: E402
from repro.core.lazyframe import Result  # noqa: E402

from benchmarks.api_corpus import CORPUS, _taxi  # noqa: E402

# the reference out-of-tree plug-in engine (tests/plugin_engine/): registered
# at runtime — never imported by core — and held to the same differential
# ground truth as the built-ins.  When pip-installed (CI plug-in job) it is
# discovered through the ``repro.engines`` entry point instead; the path
# append is a no-op then.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "plugin_engine"))
import repro_pool_engine  # noqa: E402

repro_pool_engine.register()

ENGINES = ("eager", "streaming", "auto", "pool")


# ---------------------------------------------------------------------------
# Canonicalization: both sides become {col: np.ndarray} dicts / scalars /
# tuples so one comparator covers frames, series-like outputs and scalars.


def _canon_actual(obj):
    """Facade output → canonical form (vocab columns decode to strings)."""
    if isinstance(obj, Result):
        out = {}
        for k, v in obj.columns.items():
            arr = np.asarray(v)
            if k in obj.vocab:
                out[k] = np.asarray([obj.vocab[k][int(c)] for c in arr],
                                    dtype=object)
            else:
                out[k] = arr
        return out
    if isinstance(obj, tuple):
        return tuple(_canon_actual(x) for x in obj)
    if isinstance(obj, dict):
        return {k: np.asarray(v) for k, v in obj.items()}
    arr = np.asarray(obj)
    if arr.ndim == 0:
        return arr[()]
    return arr


def _canon_expected(obj):
    """Plain-pandas ground truth → canonical form."""
    if isinstance(obj, pd_real.DataFrame):
        out = {}
        for k in obj.columns:
            col = obj[k]
            if col.dtype == object or str(col.dtype).startswith(
                    ("string", "category")):
                out[k] = col.astype(str).to_numpy(dtype=object)
            else:
                out[k] = col.to_numpy()
        return out
    if isinstance(obj, pd_real.Series):
        return _canon_expected(obj.reset_index())
    if isinstance(obj, tuple):
        return tuple(_canon_expected(x) for x in obj)
    return obj


def _sort_rows(cols: dict, by: list[str]) -> dict:
    keys = [np.asarray(cols[b]).astype(str) if cols[b].dtype == object
            else np.asarray(cols[b]) for b in reversed(by)]
    idx = np.lexsort(keys)
    return {k: v[idx] for k, v in cols.items()}


def _assert_scalar(actual, expected, rtol, atol):
    a = np.asarray(actual, dtype=np.float64)[()]
    e = np.asarray(expected, dtype=np.float64)[()]
    if np.isnan(e):
        assert np.isnan(a), f"expected NaN, got {a}"
        return
    np.testing.assert_allclose(a, e, rtol=rtol, atol=atol)


_KIND_GROUPS = {"f": "float", "i": "int", "u": "int", "b": "bool",
                "O": "object", "U": "object", "S": "object"}


def assert_frame_matches(actual, expected, rtol=1e-3, atol=1e-6,
                         sort_by=None):
    """`assert_frame_equal`-style comparison between a canonicalized facade
    result and real-pandas ground truth: same columns, row count, dtype
    *kinds*, NaN placement, and (tolerance-aware) values."""
    actual = _canon_actual(actual)
    expected = _canon_expected(expected)
    if isinstance(expected, tuple):
        assert isinstance(actual, tuple) and len(actual) == len(expected)
        for a, e in zip(actual, expected):
            assert_frame_matches(a, e, rtol=rtol, atol=atol, sort_by=sort_by)
        return
    if not isinstance(expected, dict):
        _assert_scalar(actual, expected, rtol, atol)
        return
    assert isinstance(actual, dict), f"expected frame, got {type(actual)}"
    assert set(actual) == set(expected), (
        f"column mismatch: {sorted(actual)} vs {sorted(expected)}")
    a_rows = {len(np.asarray(v)) for v in actual.values()}
    e_rows = {len(np.asarray(v)) for v in expected.values()}
    assert a_rows == e_rows, f"row count mismatch: {a_rows} vs {e_rows}"
    if sort_by:
        actual = _sort_rows(actual, sort_by)
        expected = _sort_rows(expected, sort_by)
    for k in expected:
        a, e = np.asarray(actual[k]), np.asarray(expected[k])
        ak = _KIND_GROUPS.get(a.dtype.kind, a.dtype.kind)
        ek = _KIND_GROUPS.get(e.dtype.kind, e.dtype.kind)
        assert ak == ek, f"dtype kind mismatch on {k!r}: {a.dtype} vs {e.dtype}"
        if ek == "float":
            a64, e64 = a.astype(np.float64), e.astype(np.float64)
            np.testing.assert_array_equal(
                np.isnan(a64), np.isnan(e64),
                err_msg=f"NaN placement differs on {k!r}")
            mask = ~np.isnan(e64)
            np.testing.assert_allclose(a64[mask], e64[mask], rtol=rtol,
                                       atol=atol, err_msg=f"column {k!r}")
        elif ek in ("int", "bool"):
            np.testing.assert_array_equal(a.astype(np.int64),
                                          e.astype(np.int64),
                                          err_msg=f"column {k!r}")
        else:
            np.testing.assert_array_equal(a.astype(str), e.astype(str),
                                          err_msg=f"column {k!r}")


# ---------------------------------------------------------------------------
# Plain-pandas reference programs (ground truth), mirroring api_corpus.
# ``_taxi`` builds identical data for both sides: the rng draw sequence is
# the same and real pandas accepts the same dict-of-arrays constructor.


def _ref_filter_groupby(rng):
    df = _taxi(pd_real, rng)
    df = df[df["fare"] > 0].copy()
    df["tip_rate"] = df["tip"] / df["fare"]
    return df.groupby("vendor")["tip_rate"].mean().reset_index()


def _ref_feature_engineering(rng):
    df = _taxi(pd_real, rng)
    ts = pd_real.to_datetime(df["pickup"], unit="s")
    df["day"] = ts.dt.dayofweek
    df["quarter"] = ts.dt.quarter
    df["fare_clipped"] = df["fare"].clip(0, 50)
    return df.groupby("quarter")["fare_clipped"].sum().reset_index()


def _ref_order_statistics(rng):
    df = _taxi(pd_real, rng)
    return df.nlargest(10, "fare")["fare"].median()


def _ref_missing_data(rng):
    df = _taxi(pd_real, rng)
    df["maybe"] = df["fare"] / df["fare"].round()
    clean = df.dropna()
    return len(clean.columns)


def _ref_join_and_concat(rng):
    rides = _taxi(pd_real, rng, n=2_000)
    vendors = pd_real.DataFrame({"vendor": ["acme", "beta", "cabco"],
                                 "fee": [1.0, 2.0, 0.5]})
    j = pd_real.merge(rides, vendors, on="vendor")
    both = pd_real.concat([j, j])
    return both.groupby("vendor")["fee"].count().reset_index()


def _ref_string_and_counts(rng):
    df = _taxi(pd_real, rng)
    mask = df["vendor"].str.contains("a")
    vc = df[mask]["vendor"].value_counts()
    return pd_real.DataFrame({"value": vc.index.to_numpy(dtype=object),
                              "count": vc.to_numpy()})


def _ref_robust_statistics(rng):
    df = _taxi(pd_real, rng)
    spread = df["fare"].std()
    q90 = df["fare"].quantile(0.9)
    by_vendor = df.groupby("vendor").median().reset_index()
    return (spread, q90, by_vendor)


def _ref_sort_head_describe(rng):
    df = _taxi(pd_real, rng)
    ordered = df.sort_values("fare", ascending=False).head(20)
    return float(ordered["tip"].mean())


def _ref_datetime_pipeline(rng):
    df = pd_real.DataFrame({
        "when": ["2021-03-01", "2021-06-15", "2021-06-16", "2021-11-30"],
        "amount": [1.0, 2.0, 3.0, 4.0],
    })
    ts = pd_real.to_datetime(df["when"])
    df["month"] = ts.dt.month
    return df.groupby("month")["amount"].sum().reset_index()


def _ref_unsupported_ops(rng):
    # this corpus program *measures* the failed-op bucket; ground truth is
    # the number of deliberately-unimplemented calls, not a pandas value
    return 3


_REFS = {
    "filter_groupby": (_ref_filter_groupby, {"sort_by": ["vendor"]}),
    "feature_engineering": (_ref_feature_engineering,
                            {"sort_by": ["quarter"]}),
    "order_statistics": (_ref_order_statistics, {}),
    "missing_data": (_ref_missing_data, {}),
    "join_and_concat": (_ref_join_and_concat, {"sort_by": ["vendor"]}),
    "string_and_counts": (_ref_string_and_counts, {"sort_by": ["value"]}),
    "robust_statistics": (_ref_robust_statistics, {"sort_by": ["vendor"]}),
    "sort_head_describe": (_ref_sort_head_describe, {}),
    "datetime_pipeline": (_ref_datetime_pipeline, {"sort_by": ["month"]}),
    "unsupported_ops": (_ref_unsupported_ops, {}),
}

_GROUND_TRUTH: dict[str, object] = {}


def _ground_truth(name):
    if name not in _GROUND_TRUTH:
        ref, _ = _REFS[name]
        _GROUND_TRUTH[name] = ref(np.random.default_rng(0))
    return _GROUND_TRUTH[name]


def test_every_corpus_program_has_a_reference():
    assert {name for name, _ in CORPUS} == set(_REFS)


@pytest.mark.parametrize("rewrites", (True, False),
                         ids=("rewrites", "no-rewrites"))
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("name,prog", CORPUS, ids=[n for n, _ in CORPUS])
def test_conformance(engine, name, prog, rewrites):
    # every corpus program must be invariant under the plan-rewrite pass:
    # session(rewrites=False) is the escape hatch users get, and running
    # the whole corpus both ways is the differential proof the rules are
    # semantics-preserving (not merely pandas-plausible)
    ctx = get_context()
    ctx.backend = engine
    ctx.backend_options["rewrites"] = rewrites
    ctx.print_fn = lambda *a: None
    rng = np.random.default_rng(0)
    actual = prog(rpd, rng)
    ref, opts = _REFS[name]
    assert_frame_matches(actual, _ground_truth(name), **opts)


def _assert_bit_identical(a, b):
    """Exact equality between two facade outputs: same canonical columns,
    same dtypes, byte-identical values (NaN placement included)."""
    a, b = _canon_actual(a), _canon_actual(b)
    assert type(a) is type(b)
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            assert a[k].dtype == b[k].dtype, k
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    elif isinstance(a, tuple):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _assert_bit_identical(x, y)
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("name,prog", CORPUS, ids=[n for n, _ in CORPUS])
def test_conformance_plan_cache(engine, name, prog):
    # a warm plan-cache hit must be bit-identical to a cold plan — the
    # cache elides planning work, never changes what runs.  The corpus runs
    # three times: once with the cache disabled (session escape hatch),
    # then twice with it on so the final run binds a cached template.
    from repro.core.context import session
    from repro.core.planner.plancache import default_plan_cache

    default_plan_cache().clear()
    with session(engine=engine, plan_cache=False, name="cold") as ctx:
        ctx.print_fn = lambda *a: None
        cold = prog(rpd, np.random.default_rng(0))
        assert ctx.metrics.counter("plan_cache.hits") == 0
        assert ctx.metrics.counter("plan_cache.misses") == 0
    with session(engine=engine, name="warm") as ctx:
        ctx.print_fn = lambda *a: None
        prog(rpd, np.random.default_rng(0))
        warm = prog(rpd, np.random.default_rng(0))
        snap = ctx.metrics.snapshot()
        # every force point was classified exactly once: warm hit, cold
        # store, or an honest uncacheable bypass (UDF/MapRows/print sink).
        # Hit-*rate* floors live in test_plancache/test_serving — here a
        # rerun may legitimately miss when its own feedback moved the
        # stats epoch between runs.
        classified = (snap.get("plan_cache.hits", 0)
                      + snap.get("plan_cache.misses", 0)
                      + snap.get("plan_cache.uncacheable", 0))
        assert classified == ctx.exec_count
    _assert_bit_identical(warm, cold)
    _, opts = _REFS[name]
    assert_frame_matches(warm, _ground_truth(name), **opts)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("name,prog", CORPUS, ids=[n for n, _ in CORPUS])
def test_conformance_pushdown(engine, name, prog):
    # the scan-pushdown pass must be invisible to results: every corpus
    # program under session(pushdown=True) is bit-identical to the same
    # program with the pass disabled (the escape hatch), on every engine
    from repro.core.context import session

    with session(engine=engine, pushdown=True, name="pdon") as ctx:
        ctx.print_fn = lambda *a: None
        on = prog(rpd, np.random.default_rng(0))
    with session(engine=engine, pushdown=False, name="pdoff") as ctx:
        ctx.print_fn = lambda *a: None
        off = prog(rpd, np.random.default_rng(0))
    _assert_bit_identical(on, off)
    _, opts = _REFS[name]
    assert_frame_matches(on, _ground_truth(name), **opts)


# ---------------------------------------------------------------------------
# Source-kind conformance: the same taxi data materialized as an NPZ
# directory or a Parquet directory (repro.io) must be bit-identical to the
# in-memory source through a representative pipeline, on every engine.

SOURCE_KINDS = ("memory", "npz", "parquet")


def _taxi_source(kind, base, rng, n=4_000, partition_rows=512):
    from repro.core.source import encode_strings, write_npz_source
    vendors = [["acme", "beta", "cabco"][i] for i in rng.integers(0, 3, n)]
    codes, vocab = encode_strings(vendors)
    arrays = {
        "fare": rng.uniform(-5, 100, n),
        "tip": rng.uniform(0, 20, n),
        "vendor": codes,
        "pickup": (1_577_836_800
                   + rng.integers(0, 366 * 86400, n)).astype(np.int64),
    }
    dicts, datetimes = {"vendor": vocab}, ("pickup",)
    if kind == "memory":
        return core.InMemorySource(arrays, partition_rows, dicts=dicts,
                                   datetimes=datetimes)
    if kind == "npz":
        return write_npz_source(os.path.join(base, "npz"), arrays,
                                partition_rows, dicts=dicts,
                                datetimes=datetimes)
    pytest.importorskip("pyarrow")
    from repro.io.parquet import write_parquet_source
    return write_parquet_source(os.path.join(base, "parquet"), arrays,
                                partition_rows, dicts=dicts,
                                datetimes=datetimes)


def _source_pipeline(src):
    df = core.read_source(src)
    r = df[df["fare"] > 60.0]
    return (r.groupby("vendor")
            .agg({"m": ("tip", "mean"), "n": ("fare", "count")})
            .compute())


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("kind", [k for k in SOURCE_KINDS if k != "memory"])
def test_conformance_source_kinds(engine, kind, tmp_path):
    ctx = get_context()
    ctx.backend = engine
    base = _source_pipeline(
        _taxi_source("memory", str(tmp_path), np.random.default_rng(0)))
    disk = _source_pipeline(
        _taxi_source(kind, str(tmp_path), np.random.default_rng(0)))
    _assert_bit_identical(disk, base)


@pytest.mark.parametrize("fusion", (True, False), ids=("fused", "unfused"))
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("name,prog", CORPUS, ids=[n for n, _ in CORPUS])
def test_conformance_fusion(engine, name, prog, fusion):
    # the rowwise fusion pass must be invisible to results: every corpus
    # program under session(fusion=True) is bit-identical to the same
    # program with the pass disabled, on every engine
    from repro.core.context import session

    with session(engine=engine, fusion=fusion, name="fz") as ctx:
        ctx.print_fn = lambda *a: None
        got = prog(rpd, np.random.default_rng(0))
    with session(engine=engine, fusion=not fusion, name="fz2") as ctx:
        ctx.print_fn = lambda *a: None
        other = prog(rpd, np.random.default_rng(0))
    _assert_bit_identical(got, other)
    _, opts = _REFS[name]
    assert_frame_matches(got, _ground_truth(name), **opts)


# ---------------------------------------------------------------------------
# Distributed-engine conformance: join / sort / distinct programs.  These
# paths were untested eager fallbacks before the native distributed
# operators (physical/sharded.py) — each program runs under the DISTRIBUTED
# backend through the core API and must equal real-pandas ground truth.

import repro.core as core  # noqa: E402

_VENDORS = ["acme", "beta", "cabco", "dax"]


def _dist_tables(rng, n=4_000):
    codes = rng.integers(0, 4, n).astype(np.int32)
    zone = rng.integers(0, 50, n).astype(np.int64)
    # unique sort key, exactly representable in float32 (device precision)
    fare = rng.permutation(n).astype(np.float64) + 0.5
    tip = rng.integers(0, 20, n).astype(np.int64)
    src = core.InMemorySource(
        {"vendor": codes, "zone": zone, "fare": fare, "tip": tip},
        partition_rows=512, dicts={"vendor": _VENDORS})
    fees = rng.uniform(0.5, 2.0, 4)
    fee_src = core.InMemorySource(
        {"vendor": np.arange(4, dtype=np.int32), "fee": fees},
        partition_rows=4, dicts={"vendor": _VENDORS})
    pdf = pd_real.DataFrame({"vendor": [_VENDORS[c] for c in codes],
                             "zone": zone, "fare": fare, "tip": tip})
    fee_pdf = pd_real.DataFrame({"vendor": _VENDORS, "fee": fees})
    return src, fee_src, pdf, fee_pdf


def _dist_join(src, fee_src, pdf, fee_pdf, n):
    rides = core.read_source(src)
    j = rides.merge(core.read_source(fee_src), on="vendor")
    j = j[j["fare"] > n / 2]
    expected = pd_real.merge(pdf, fee_pdf, on="vendor")
    return j.compute(), expected[expected["fare"] > n / 2]


def _dist_sort(src, fee_src, pdf, fee_pdf, n):
    df = core.read_source(src)
    out = df.sort_values("fare", ascending=False).compute()
    return out, pdf.sort_values("fare", ascending=False)


def _dist_distinct(src, fee_src, pdf, fee_pdf, n):
    df = core.read_source(src)
    out = df.drop_duplicates(subset=("vendor", "zone")).compute()
    return out, pdf.drop_duplicates(["vendor", "zone"])


def _dist_head(src, fee_src, pdf, fee_pdf, n):
    # filter first so the head prefix spans valid-row gaps across shards —
    # the native masked head must still reproduce pandas row order exactly
    df = core.read_source(src)
    out = df[df["tip"] > 4].head(37).compute()
    return out, pdf[pdf["tip"] > 4].head(37)


# join compares order-insensitively (pandas merge ordering is only loosely
# specified); sort, distinct and head compare row order *exactly* — the
# native range-partition sort, keep-first distinct, and leading-shard
# masked head must reproduce pandas order
_DIST_CASES = {
    "join": (_dist_join, {"sort_by": ["fare"]}),
    "sort": (_dist_sort, {}),
    "distinct": (_dist_distinct, {}),
    "head": (_dist_head, {}),
}


@pytest.mark.parametrize("name", sorted(_DIST_CASES))
def test_distributed_conformance(name):
    ctx = get_context()
    ctx.backend = "distributed"
    ctx.print_fn = lambda *a: None
    rng = np.random.default_rng(7)
    n = 4_000
    prog, opts = _DIST_CASES[name]
    actual, expected = prog(*_dist_tables(rng, n), n)
    assert_frame_matches(actual, expected, **opts)
