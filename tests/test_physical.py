"""Tests for the unified physical-operator layer (`repro.core.physical`):

1. layer surface — the package exposes the operator set and the
   ``exec_common`` shim still re-exports it;
2. native distributed join/sort/distinct — broadcast-hash and
   shuffle-by-dict-code paths agree with the host kernels at every shard
   count, including a hypothesis property (native join ≡ eager join on
   random dict-coded keys);
3. the distributed backend really runs these ops natively (no eager
   fallback) and keeps results pandas-shaped;
4. device-resident handoffs — a distributed→distributed segment chain
   passes a ``ShardedTable`` payload with no intermediate host gather;
5. stats-store persistence and peak-estimate calibration (satellites).
"""
import json
import os

import numpy as np
import pytest

import repro.core as core
from repro.core import BackendEngines, get_context
from repro.core import expr as E
from repro.core import graph as G
from repro.core import physical as X
from repro.core.backends.distributed import DistributedBackend, _default_mesh
from repro.core.physical.sharded import ShardedTable


def _mesh():
    return _default_mesh()


def _probe_arrays(rng, n=3000):
    return {
        "k": rng.integers(0, 40, n).astype(np.int64),
        "zone": rng.integers(0, 12, n).astype(np.int32),
        "val": rng.integers(-50, 50, n).astype(np.int64),
        "f": rng.uniform(0, 100, n),
    }


# ---------------------------------------------------------------------------
# Layer surface


def test_exec_common_shim_reexports_physical_layer():
    from repro.core import exec_common as XC
    for name in ("apply_join", "apply_groupby_agg", "apply_sort",
                 "apply_drop_duplicates", "to_host_value", "handoff_value",
                 "ShardedTable", "sharded_join", "sharded_sort",
                 "sharded_distinct", "shard_host_table"):
        assert getattr(XC, name) is getattr(X, name), name


def test_backends_bind_the_shared_physical_layer():
    import repro.core.backends.eager as eb
    import repro.core.backends.streaming as sb
    import repro.core.backends.distributed as db
    assert eb.X is X and sb.X is X and db.X is X


# ---------------------------------------------------------------------------
# Native distributed operators ≡ host kernels


def _assert_tables_equal(actual: dict, expected: dict, rtol=1e-6):
    assert set(actual) == set(expected)
    for c in expected:
        a = np.asarray(actual[c], np.float64)
        e = np.asarray(expected[c], np.float64)
        np.testing.assert_allclose(a, e, rtol=rtol, err_msg=f"column {c!r}")


@pytest.mark.parametrize("how", ["inner", "left"])
def test_broadcast_hash_join_matches_host_kernel(how, rng):
    probe = _probe_arrays(rng)
    build = {"k": np.arange(40, dtype=np.int64),
             "fee": rng.uniform(0, 1, 40),
             "f": rng.uniform(0, 1, 40)}          # overlap column → suffixes
    mesh = _mesh()
    t = X.shard_host_table(probe, mesh, "data")
    out = X.sharded_join(t, build, ["k"], how, ("_x", "_y"), mesh, "data")
    assert isinstance(out, ShardedTable), "broadcast path not taken"
    _assert_tables_equal(out.gather(), X.apply_join(probe, build, ["k"], how))


@pytest.mark.parametrize("how", ["inner", "left"])
def test_shuffle_join_matches_host_kernel(how, rng):
    probe = _probe_arrays(rng)
    # duplicate build keys force the shuffle-by-dict-code path
    build = {"k": rng.integers(0, 25, 400).astype(np.int64),
             "fee": rng.uniform(0, 1, 400)}
    mesh = _mesh()
    t = X.shard_host_table(probe, mesh, "data")
    out = X.sharded_join(t, build, ["k"], how, ("_x", "_y"), mesh, "data")
    assert isinstance(out, ShardedTable)
    _assert_tables_equal(out.gather(), X.apply_join(probe, build, ["k"], how))


def test_multi_key_join_matches_host_kernel(rng):
    probe = _probe_arrays(rng)
    build = {"k": rng.integers(0, 40, 60).astype(np.int64),
             "zone": rng.integers(0, 12, 60).astype(np.int32),
             "fee": rng.uniform(0, 1, 60)}
    mesh = _mesh()
    t = X.shard_host_table(probe, mesh, "data")
    out = X.sharded_join(t, build, ["k", "zone"], "inner", ("_x", "_y"),
                         mesh, "data")
    assert isinstance(out, ShardedTable)
    _assert_tables_equal(out.gather(),
                         X.apply_join(probe, build, ["k", "zone"], "inner"))


def test_join_with_empty_build_side(rng):
    """Empty build tables must not crash the host kernel — the distributed
    shuffle join feeds it per-shard key buckets that can be empty."""
    probe = _probe_arrays(rng, 50)
    empty = {"k": np.zeros(0, np.int64), "fee": np.zeros(0)}
    lj = X.apply_join(probe, empty, ["k"], "left")
    assert X.table_rows(lj) == 50
    assert np.isnan(np.asarray(lj["fee"])).all()
    assert X.table_rows(X.apply_join(probe, empty, ["k"], "inner")) == 0


def test_shuffle_join_skewed_keys_leave_empty_buckets(rng):
    """All build rows share one key: with n_shards > 1 every other shard's
    build bucket is empty (the multishard CI job exercises this for real;
    at one shard it degenerates gracefully)."""
    probe = {"k": np.arange(8, dtype=np.int64).repeat(10),
             "v": np.arange(80, dtype=np.int64)}
    build = {"k": np.full(64, 2, dtype=np.int64),
             "fee": rng.uniform(0, 1, 64)}
    mesh = _mesh()
    t = X.shard_host_table(probe, mesh, "data")
    for how in ("inner", "left"):
        out = X.sharded_join(t, build, ["k"], how, ("_x", "_y"),
                             mesh, "data")
        assert isinstance(out, ShardedTable)
        ref = X.apply_join(probe, build, ["k"], how)
        got = out.gather()
        for c in ref:
            a = np.asarray(got[c], np.float64)
            e = np.asarray(ref[c], np.float64)
            np.testing.assert_array_equal(np.isnan(a), np.isnan(e))
            m = ~np.isnan(e)
            np.testing.assert_allclose(a[m], e[m], rtol=1e-6,
                                       err_msg=f"{how}:{c}")


def test_non_integer_keys_fall_back(rng):
    probe = _probe_arrays(rng)
    build = {"f": rng.uniform(0, 100, 10), "fee": rng.uniform(0, 1, 10)}
    mesh = _mesh()
    t = X.shard_host_table(probe, mesh, "data")
    assert X.sharded_join(t, build, ["f"], "inner", ("_x", "_y"),
                          mesh, "data") is None


@pytest.mark.parametrize("ascending", [True, False])
def test_sharded_sort_matches_host_kernel(ascending, rng):
    probe = _probe_arrays(rng)
    mesh = _mesh()
    t = X.shard_host_table(probe, mesh, "data")
    out = X.sharded_sort(t, ["k", "val"], ascending, mesh, "data")
    assert isinstance(out, ShardedTable)
    _assert_tables_equal(out.gather(),
                         X.apply_sort(probe, ["k", "val"], ascending))


def test_sharded_distinct_matches_host_kernel(rng):
    probe = _probe_arrays(rng)
    mesh = _mesh()
    t = X.shard_host_table(probe, mesh, "data")
    out = X.sharded_distinct(t, ("k", "zone"), mesh, "data")
    assert isinstance(out, ShardedTable)
    _assert_tables_equal(out.gather(),
                         X.apply_drop_duplicates(probe, ["k", "zone"]))


# ---------------------------------------------------------------------------
# The distributed backend runs join/sort/distinct natively


def _dist_src(rng, n=4000, partition_rows=512):
    return core.InMemorySource(_probe_arrays(rng, n), partition_rows)


def test_distributed_backend_join_sort_distinct_native(rng, monkeypatch):
    """No eager fallback fires for join/sort/distinct on dict-coded keys."""
    src = _dist_src(rng)
    fee = core.InMemorySource(
        {"k": np.arange(40, dtype=np.int64),
         "fee": rng.uniform(0, 1, 40)}, 64)
    backend = DistributedBackend()

    banned = {"join", "sort_values", "drop_duplicates"}

    def no_fallback(n, vals):
        assert n.op not in banned, f"{n.op} fell back to eager"
        return DistributedBackend._fallback_node(backend, n, vals)

    monkeypatch.setattr(backend, "_fallback_node", no_fallback)
    ctx = get_context()
    scan, feescan = G.Scan(src), G.Scan(fee)
    join = G.Join(scan, feescan, ["k"], "inner")
    srt = G.SortValues(join, ["k", "val"])
    dd = G.DropDuplicates(srt, ("k",))
    res = backend.execute([dd], ctx)[dd.id]
    # ground truth through the shared host kernels
    full = {k: np.asarray(v) for k, v in src._arrays.items()}
    feet = {k: np.asarray(v) for k, v in fee._arrays.items()}
    ref = X.apply_drop_duplicates(
        X.apply_sort(X.apply_join(full, feet, ["k"], "inner"),
                     ["k", "val"]), ["k"])
    _assert_tables_equal(res, ref)


# ---------------------------------------------------------------------------
# Device-resident handoff: distributed→distributed chain, no host gather


def test_distributed_chain_handoff_stays_device_resident(rng, monkeypatch):
    from repro.core.planner.cost import CostEstimate
    from repro.core.planner.select import Decision
    from repro.core.runtime import execute_segments

    src = _dist_src(rng)
    scan = G.Scan(src)
    filt = G.Filter(scan, E.BinOp("gt", E.Col("f"), E.Lit(25.0)))
    srt = G.SortValues(filt, ["k", "val"])

    def dec(roots, nodes, boundary=()):
        return Decision(roots=list(roots), backend=BackendEngines.DISTRIBUTED,
                        cost=CostEstimate("distributed", 1.0, 0.0, {}),
                        rejected={}, nodes=list(nodes),
                        boundary=list(boundary))

    gathers = {"n": 0}
    orig_gather = ShardedTable.gather

    def counting_gather(self):
        gathers["n"] += 1
        return orig_gather(self)

    monkeypatch.setattr(ShardedTable, "gather", counting_gather)
    ctx = get_context()
    decisions = [dec([filt], [scan, filt]), dec([srt], [srt], boundary=[filt])]
    results, names = execute_segments(decisions, ctx,
                                      final_root_ids={srt.id})
    assert names == "distributed"
    # the boundary payload crossed as a ShardedTable: exactly one gather —
    # the final root materialization — and the trace records the payload type
    assert gathers["n"] == 1
    assert any("payload=ShardedTable" in line and "device-resident" in line
               for line in ctx.planner_trace), ctx.planner_trace
    full = {k: np.asarray(v) for k, v in src._arrays.items()}
    ref = X.apply_sort({k: v[full["f"] > 25.0] for k, v in full.items()},
                       ["k", "val"])
    _assert_tables_equal(results[srt.id], ref)


def test_handoff_sharded_payload_usable_by_every_backend(rng):
    """A ShardedTable handoff payload is consumed in place by distributed
    and gathered defensively by host engines."""
    from repro.core.backends import get_backend
    probe = _probe_arrays(rng, 200)
    t = X.shard_host_table(probe, _mesh(), "data")
    ctx = get_context()
    for kind in (BackendEngines.EAGER, BackendEngines.STREAMING,
                 BackendEngines.DISTRIBUTED):
        h = G.Handoff(t, ("sharded-handoff-test",), producer="filter")
        f = G.Filter(h, E.BinOp("ge", E.Col("zone"), E.Lit(6)))
        res = get_backend(kind).execute([f], ctx)[f.id]
        assert isinstance(res, dict), kind
        ref = {k: v[probe["zone"] >= 6] for k, v in probe.items()}
        _assert_tables_equal(res, ref)


# ---------------------------------------------------------------------------
# Stats-store persistence (satellite)


def test_stats_store_roundtrips_through_json(tmp_path):
    from repro.core.planner.feedback import MIN_RUNTIME_SAMPLES, StatsStore
    store = StatsStore()
    store.record(("scan", ("npz", "/data/taxi"), None), 1234, 99_000)
    for _ in range(MIN_RUNTIME_SAMPLES):
        store.record_runtime("eager", 1e5, 0.2)
        store.record_peak("streaming", 5_000_000, est_peak=10_000_000)
    path = str(tmp_path / "stats.json")
    store.save(path)
    fresh = StatsStore()
    assert fresh.load(path)
    assert fresh.lookup(("scan", ("npz", "/data/taxi"), None))["rows"] == 1234
    assert fresh.cost_scale("eager") == pytest.approx(2e-6)
    assert fresh.peak_scale("streaming") == pytest.approx(0.5)
    assert fresh.backend_peaks["streaming"] == 5_000_000


def test_session_stats_path_persists_calibration_across_sessions(tmp_path):
    from repro.core.context import session
    from repro.core.planner.feedback import MIN_RUNTIME_SAMPLES
    path = str(tmp_path / "cal.json")
    src_arrays = {"x": np.arange(500, dtype=np.int64)}
    with session(engine="eager", stats_path=path) as ctx:
        for _ in range(MIN_RUNTIME_SAMPLES):
            ctx.stats_store.record_runtime("streaming", 1e4, 0.05)
        df = core.from_arrays(dict(src_arrays), partition_rows=128)
        df[df["x"] > 100].compute()      # any execute saves the store
    assert os.path.exists(path)
    with session(engine="eager", stats_path=path) as ctx2:
        # reloaded on startup: calibration survives the "restart"
        assert ctx2.stats_store.cost_scale("streaming") == pytest.approx(5e-6)
        assert len(ctx2.stats_store) >= 1   # cardinalities reloaded too


def test_stats_cache_dir_env_enables_context_persistence(tmp_path, monkeypatch):
    from repro.core.context import LaFPContext
    monkeypatch.setenv("REPRO_STATS_CACHE_DIR", str(tmp_path))
    ctx = LaFPContext(name="envtest")
    assert ctx.stats_path == str(tmp_path / "envtest.json")
    ctx.stats_store.record_runtime("eager", 1.0, 1.0)
    ctx.stats_store.save(ctx.stats_path)
    ctx2 = LaFPContext(name="envtest")
    assert ctx2.stats_store.runtime_samples["eager"]


# ---------------------------------------------------------------------------
# Peak calibration (satellite): observed peaks recalibrate estimates


def test_streaming_runs_record_peak_samples(rng):
    ctx = get_context()
    ctx.backend = BackendEngines.STREAMING
    src = _dist_src(rng, n=5000)
    df = core.read_source(src)
    df[df["f"] > 10.0].compute()
    samples = ctx.stats_store.peak_samples.get("streaming")
    assert samples, "streaming run recorded no (est, observed) peak sample"
    est, obs = samples[-1]
    assert est > 0 and obs > 0


def test_npz_cache_token_tracks_directory_content(tmp_path):
    """Same path + same content → same token (stats feedback survives
    restarts); rewritten content → fresh token (persist cache can never
    serve stale results for structurally-identical plans)."""
    from repro.core.source import NpzDirectorySource, write_npz_source
    p = str(tmp_path / "src")
    t1 = write_npz_source(p, {"x": np.arange(10)}).cache_token()
    assert NpzDirectorySource(p).cache_token() == t1
    t2 = write_npz_source(p, {"x": np.arange(10) * 2}).cache_token()
    assert t2 != t1


def test_peak_samples_record_raw_not_calibrated_estimates(rng):
    """Calibration samples must pair the *pre-scale* model estimate with
    the observed peak — recording the calibrated value would drag the
    regressed scale back toward 1 on every subsequent run."""
    from repro.core.planner.cost import CostEstimate
    from repro.core.planner.select import Decision
    from repro.core.runtime import execute_segments
    src = _dist_src(rng, n=2000)
    scan = G.Scan(src)
    f = G.Filter(scan, E.BinOp("gt", E.Col("f"), E.Lit(10.0)))
    cost = CostEstimate("streaming", 1.0, 2e6, {}, raw_peak_bytes=1e6)
    d = Decision(roots=[f], backend=BackendEngines.STREAMING, cost=cost,
                 rejected={}, nodes=[scan, f])
    ctx = get_context()
    execute_segments([d], ctx, final_root_ids={f.id})
    est, obs = ctx.stats_store.peak_samples["streaming"][-1]
    assert est == 1e6      # the raw estimate, not the calibrated 2e6
    assert obs > 0


def test_distributed_rowwise_fallback_is_traced(rng):
    """A native row-wise path failure falls back AND records why."""
    import repro.core.expr as E2

    def host_udf(a):
        return np.asarray(a) + 1.0     # forces __array__ on the tracer

    src = _dist_src(rng, 500)
    scan = G.Scan(src)
    a = G.Assign(scan, "g", E2.UDF(host_udf, (E2.Col("f"),)))
    ctx = get_context()
    res = DistributedBackend().execute([a], ctx)[a.id]
    assert X.table_rows(res) == 500
    assert any("native path failed" in line and "assign" in line
               for line in ctx.planner_trace), ctx.planner_trace


def test_peak_scale_recalibrates_budget_feasibility(rng):
    """A measured observed/estimated peak ratio ≫ 1 makes the planner
    distrust an engine's optimistic peak estimate: a candidate whose raw
    estimate fits the budget is rejected once calibration scales it over."""
    from repro.core.planner.feedback import MIN_PEAK_SAMPLES
    from repro.core.planner.select import plan_placement
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _dist_src(rng, n=20_000)
    scan = G.Scan(src)
    f = G.Filter(scan, E.BinOp("gt", E.Col("f"), E.Lit(10.0)))
    base = plan_placement([f], ctx)
    raw_peaks = {d.cost.backend: d.cost.peak_bytes for d in base}
    # every engine's real peak is measured at 100× its estimate
    for name in ("eager", "streaming", "distributed"):
        for _ in range(MIN_PEAK_SAMPLES):
            ctx.stats_store.record_peak(name, int(1e12), est_peak=1e10)
    decisions = plan_placement([f], ctx)
    for d in decisions:
        assert d.cost.peak_bytes == pytest.approx(
            raw_peaks[d.cost.backend] * 100.0, rel=1e-6)
    assert any(line.startswith("auto: peak-calibration")
               for line in ctx.planner_trace)
