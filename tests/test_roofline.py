"""Roofline extraction tests: collective parsing, scan-aware trip-count
multipliers, loop-accumulator handling."""
from repro.launch.roofline import (collective_bytes, scan_aware_analysis,
                                   RooflineTerms)

SIMPLE = """
HloModule test, is_scheduled=true

ENTRY %main.1 (p0: f32[256]) -> f32[256] {
  %p0 = f32[256]{0} parameter(0)
  %ar = f32[256]{0} all-reduce(%p0), replica_groups=[16,16]<=[256]
  ROOT %out = f32[256]{0} add(%ar, %ar)
}
"""

SCANNED = """
HloModule test, is_scheduled=true

%cond.1 (arg: (s32[], f32[64])) -> pred[] {
  %arg = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %k = s32[] constant(28)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body.1 (arg: (s32[], f32[64])) -> (s32[], f32[64]) {
  %arg = (s32[], f32[64]) parameter(0)
  %x = f32[64]{0} get-tuple-element(%arg), index=1
  %ar2 = f32[64]{0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %acc = f32[1792]{0} dynamic-update-slice(%ar2, %ar2, %ar2)
  ROOT %t = (s32[], f32[64]) tuple(%ar2, %ar2)
}

ENTRY %main.2 (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %w = (s32[], f32[64]) while(%p0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"28"}}
  ROOT %gte = f32[64]{0} get-tuple-element(%w), index=1
}
"""


def test_static_collective_bytes():
    out = collective_bytes(SIMPLE)
    assert out["all-reduce"] == 256 * 4
    assert out["count"] == 1


def test_scan_aware_multiplies_by_trip_count():
    sa = scan_aware_analysis(SCANNED)
    # in-loop all-reduce counted 28×
    assert sa["coll"]["all-reduce"] == 28 * 64 * 4
    static = collective_bytes(SCANNED)
    assert static["all-reduce"] == 64 * 4      # spec-literal: body once


def test_scan_aware_accumulator_not_quadratic():
    sa = scan_aware_analysis(SCANNED)
    # the (1792,) dynamic-update-slice writes 1/28 of the buffer per step:
    # total ≈ buffer size (×2 rw), NOT 28 × buffer
    dus_contrib = 1792 * 4 * 2
    assert sa["result_bytes"] < dus_contrib + 28 * (64 * 4) * 2 * 4


def test_dominant_and_fraction():
    t = RooflineTerms(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=0,
                      coll_breakdown={}, compute_s=1.0, memory_s=2.0,
                      collective_s=0.0)
    assert t.dominant == "memory"
    assert abs(t.roofline_fraction(197e12) - 0.5) < 1e-6
    # ideal above all terms → capped at 1
    assert t.roofline_fraction(197e12 * 4) == 1.0


def test_model_flops_convention():
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import model_flops_per_step
    arch = get_config("llama3_2_3b")
    mf = model_flops_per_step(arch, SHAPES["train_4k"], 256)
    total, active = arch.param_count()
    assert abs(mf - 6 * active * 256 * 4096 / 256) / mf < 1e-6
