"""Tests for the cost-based adaptive planner (planner/): statistics &
selectivity estimation, cost-model monotonicity, AUTO backend selection
under a memory budget, feedback recalibration, and runtime-flag survival
across optimizer rewrites."""
import numpy as np
import pytest

import repro.core as core
from repro.core import BackendEngines, get_context
from repro.core import expr as E
from repro.core import graph as G
from repro.core.backends import CAPABILITIES, get_backend
from repro.core.optimizer import _conjuncts, _rebuild, optimize, order_conjuncts
from repro.core.planner.cost import plan_cost
from repro.core.planner.stats import (TableStats, estimate_plan,
                                      predicate_selectivity, source_stats)


def _uniform_source(n=10_000, partition_rows=1024, seed=0):
    rng = np.random.default_rng(seed)
    return core.InMemorySource({
        "fare": rng.uniform(0, 100, n),
        "vendor": rng.integers(0, 4, n).astype(np.int64),
        "miles": rng.uniform(0, 30, n),
    }, partition_rows)


# ---------------------------------------------------------------------------
# Statistics / selectivity


def test_source_stats_from_metadata():
    src = _uniform_source(n=5000)
    st = source_stats(src)
    assert st.rows == 5000
    assert st.exact
    # vendor is an int column with span 0..3 → NDV 4 from zone maps
    assert src.column_ndv("vendor") == 4
    assert st.col_ndv("vendor") == 4
    lo, hi = st.zonemap["fare"]
    assert 0 <= lo < hi <= 100
    assert st.total_bytes == pytest.approx(5000 * 24)


def test_column_ndv_dict_vocab():
    src = core.InMemorySource(
        {"city": np.array([0, 1, 2, 0, 1], dtype=np.int32)},
        dicts={"city": ["nyc", "sf", "la"]})
    assert src.column_ndv("city") == 3


def test_range_selectivity_against_zonemap():
    src = _uniform_source()
    st = source_stats(src)
    sel = predicate_selectivity(
        E.BinOp("lt", E.Col("fare"), E.Lit(25.0)), st)
    assert sel == pytest.approx(0.25, abs=0.05)
    sel_hi = predicate_selectivity(
        E.BinOp("gt", E.Col("fare"), E.Lit(25.0)), st)
    assert sel_hi == pytest.approx(0.75, abs=0.05)


def test_equality_selectivity_against_ndv():
    src = _uniform_source()
    st = source_stats(src)
    sel = predicate_selectivity(
        E.BinOp("eq", E.Col("vendor"), E.Lit(2)), st)
    assert sel == pytest.approx(0.25, abs=0.01)
    conj = E.BinOp("and",
                   E.BinOp("eq", E.Col("vendor"), E.Lit(2)),
                   E.BinOp("lt", E.Col("fare"), E.Lit(50.0)))
    assert predicate_selectivity(conj, st) == pytest.approx(0.125, abs=0.03)


def test_filter_propagation_through_dag():
    src = _uniform_source(n=8000)
    scan = G.Scan(src)
    f = G.Filter(scan, E.BinOp("lt", E.Col("fare"), E.Lit(50.0)))
    gb = G.GroupByAgg(f, ["vendor"], {"m": ("miles", "sum")})
    est = estimate_plan([gb])
    assert est[f.id].rows == pytest.approx(4000, rel=0.15)
    # group-by output capped at the key NDV
    assert est[gb.id].rows <= 4


# ---------------------------------------------------------------------------
# Cost model


def test_cost_monotone_in_rows():
    for kind in CAPABILITIES:
        costs = []
        for n in (1000, 10_000, 100_000):
            src = _uniform_source(n=n)
            scan = G.Scan(src)
            f = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
            stats = estimate_plan([f])
            costs.append(plan_cost([f], stats, kind).total)
        assert costs[0] < costs[1] < costs[2], kind


def test_streaming_peak_below_eager_for_aggregation():
    src = _uniform_source(n=50_000, partition_rows=2048)
    scan = G.Scan(src)
    gb = G.GroupByAgg(scan, ["vendor"], {"m": ("miles", "sum")})
    stats = estimate_plan([gb])
    eager = plan_cost([gb], stats, BackendEngines.EAGER)
    streaming = plan_cost([gb], stats, BackendEngines.STREAMING)
    assert streaming.peak_bytes < eager.peak_bytes / 4


def test_get_backend_auto_raises():
    with pytest.raises(ValueError):
        get_backend(BackendEngines.AUTO)


def test_join_costed_by_build_side():
    """Join pricing follows the hash-join model: the distributed engine
    charges a cheap broadcast for small build sides and an all-to-all
    shuffle of both sides for large ones, so a big-probe/small-build join
    prices below eager while a big-build join pays the exchange."""
    from repro.core.planner.cost import node_work
    src = _uniform_source(n=100)
    probe, build = G.Scan(src), G.Scan(src)
    join = G.Join(probe, build, ["vendor"])

    def stats_for(build_rows):
        mk = lambda rows: TableStats(rows=float(rows),
                                     col_bytes={"vendor": 8.0, "fare": 8.0},
                                     ndv={}, zonemap={})
        return {probe.id: mk(1_000_000), build.id: mk(build_rows),
                join.id: mk(1_000_000)}

    dist = CAPABILITIES[BackendEngines.DISTRIBUTED]
    eager = CAPABILITIES[BackendEngines.EAGER]
    small, big = stats_for(1_000), stats_for(1_000_000)
    assert small[build.id].total_bytes <= dist.broadcast_join_bytes
    assert big[build.id].total_bytes > dist.broadcast_join_bytes
    # broadcast: small-build distributed join beats eager on a big probe
    assert node_work(join, small, dist) < node_work(join, small, eager)
    # shuffle: the big build pays the all-to-all of both sides on top of
    # the compute growth — strictly more than the broadcast surcharge
    shuffle_extra = (node_work(join, big, dist)
                     - node_work(join, big, eager) * dist.parallelism
                     / eager.parallelism)
    assert (node_work(join, big, dist) - node_work(join, small, dist)
            > (big[build.id].total_bytes - small[build.id].total_bytes))
    assert shuffle_extra > 0


# ---------------------------------------------------------------------------
# AUTO selection


def test_auto_small_workload_dispatches_eager():
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _uniform_source(n=5000)
    df = core.read_source(src)
    df = df[df["fare"] > 10.0]
    res = df.compute()
    assert res.rows() == int((np.asarray(src._arrays["fare"]) > 10.0).sum())
    assert len(ctx.planner_decisions) == 1
    assert ctx.planner_decisions[0].backend == BackendEngines.EAGER
    assert any("-> eager" in line for line in ctx.planner_trace)


def test_auto_over_budget_dispatches_streaming():
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _uniform_source(n=50_000, partition_rows=2048)
    # tight enough that no whole-table engine fits — not even distributed
    # with its peak divided across every forced host device (multishard CI)
    ctx.memory_budget = int(50_000 * 24 * 0.08)
    df = core.read_source(src)
    df = df[df["fare"] > 10.0]
    out = df.groupby("vendor")["miles"].sum().compute()
    assert out.rows() == 4
    assert ctx.planner_decisions[0].backend == BackendEngines.STREAMING
    assert any("budget!" in line for line in ctx.planner_trace)
    # the streaming run really stayed under the budget (meter enforced)
    assert ctx.last_peak_bytes <= ctx.memory_budget


def test_auto_results_match_fixed_backend():
    arrays = {"x": np.arange(1000, dtype=np.int64),
              "y": np.linspace(0, 1, 1000)}
    ctx = get_context()
    ctx.backend = BackendEngines.EAGER
    ref = core.from_arrays(dict(arrays), partition_rows=128)
    ref = ref[ref["x"] % 3 == 0].compute()
    ctx.reset()
    ctx.backend = BackendEngines.AUTO
    df = core.from_arrays(dict(arrays), partition_rows=128)
    res = df[df["x"] % 3 == 0].compute()
    np.testing.assert_allclose(np.asarray(res["y"]), np.asarray(ref["y"]))


# ---------------------------------------------------------------------------
# Feedback recalibration


def test_feedback_recalibrates_estimates_within_10pct():
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    # heavily skewed column: the uniformity assumption over the zone map is
    # badly wrong a priori (~50% estimated vs ~2% actual)
    vals = np.concatenate([np.zeros(9800), np.linspace(1, 100, 200)])
    src = core.InMemorySource({"fare": vals, "k": np.arange(10_000) % 7},
                              partition_rows=1024)

    def build():
        df = core.read_source(src)
        return df[df["fare"] > 50.0]

    pred_actual = int((vals > 50.0).sum())
    roots0, _ = optimize([build()._node], ctx)
    est0 = estimate_plan(roots0, ctx)
    prior_err = abs(est0[roots0[0].id].rows - pred_actual) / pred_actual
    assert prior_err > 1.0          # a-priori estimate is way off

    build().compute()               # execute once → feedback recorded
    assert len(ctx.stats_store) >= 1

    roots1, _ = optimize([build()._node], ctx)
    est1 = estimate_plan(roots1, ctx)
    post_err = abs(est1[roots1[0].id].rows - pred_actual) / max(pred_actual, 1)
    assert post_err <= 0.10


def test_feedback_influences_next_placement():
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _uniform_source(n=20_000, partition_rows=1024)
    df = core.read_source(src)
    df[df["fare"] > 10.0].compute()
    n_before = len(ctx.stats_store)
    assert n_before >= 1
    # second run of the same plan consults the store (estimates exact)
    df2 = core.read_source(src)
    node = df2[df2["fare"] > 10.0]._node
    roots, _ = optimize([node], ctx)
    est = estimate_plan(roots, ctx)
    assert est[roots[0].id].exact


# ---------------------------------------------------------------------------
# Selectivity-ordered filter fusion


def test_order_conjuncts_most_selective_first():
    src = _uniform_source()
    scan = G.Scan(src)
    weak = E.BinOp("gt", E.Col("fare"), E.Lit(1.0))       # ~0.99
    strong = E.BinOp("eq", E.Col("vendor"), E.Lit(0))     # 0.25
    f = G.Filter(scan, E.BinOp("and", weak, strong))
    roots, _ = order_conjuncts([f], None, trace=None)
    conj = _conjuncts(roots[0].predicate)
    assert conj[0].key() == strong.key()
    assert conj[1].key() == weak.key()


def test_order_conjuncts_traced_via_optimize():
    ctx = get_context()
    src = _uniform_source()
    scan = G.Scan(src)
    f1 = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(1.0)))
    f2 = G.Filter(f1, E.BinOp("eq", E.Col("vendor"), E.Lit(0)))
    optimize([f2], ctx)
    assert any(t.startswith("order_conjuncts") for t in ctx.optimizer_trace)


# ---------------------------------------------------------------------------
# Rewrite-flag survival (optimizer._rebuild regression)


def test_rebuild_carries_runtime_flags():
    src = _uniform_source(n=100)
    scan = G.Scan(src)
    f = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(0.0)))
    a = G.Assign(f, "z", E.BinOp("mul", E.Col("miles"), E.Lit(2.0)))
    a.persist = True
    a.cache_key = ("logical-key",)
    a.result = {"sentinel": np.zeros(1)}
    # replace the deep scan → every ancestor is cloned via with_inputs
    new_scan = G.Scan(src, columns=("fare", "miles"))
    roots, idmap = _rebuild([a], {scan.id: new_scan})
    na = roots[0]
    assert na is not a
    assert na.persist is True
    assert na.cache_key == ("logical-key",)
    assert na.result is a.result
    assert idmap[a.id] is na


def test_persist_marked_node_is_rewrite_barrier():
    """A planned materialization point must not be fused/rewritten away —
    its cached value is keyed on its own (logical) shape (§3.5)."""
    from repro.core.optimizer import push_filters
    src = _uniform_source(n=1000)
    scan = G.Scan(src)
    inner = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    inner.persist = True
    outer = G.Filter(inner, E.BinOp("lt", E.Col("miles"), E.Lit(5.0)))
    roots, _ = push_filters([outer])
    # no fusion: both filters survive, persist mark intact on the inner one
    ops = [n.op for n in G.walk(roots)]
    assert ops == ["scan", "filter", "filter"]
    assert G.walk(roots)[1].persist is True


def test_hybrid_grouping_never_splits_shared_subtrees():
    from repro.core.planner.select import plan_placement
    ctx = get_context()
    src = _uniform_source(n=20_000, partition_rows=1024)
    scan = G.Scan(src)
    shared = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    a = G.GroupByAgg(shared, ["vendor"], {"m": ("miles", "sum")})
    b = G.SortValues(shared, ["fare"])
    decisions = plan_placement([a, b], ctx)
    groups = [{n.id for n in G.walk(d.roots)} for d in decisions]
    for i, g1 in enumerate(groups):
        for g2 in groups[i + 1:]:
            assert not (g1 & g2), "shared subtree split across backends"
    assert sum(len(d.roots) for d in decisions) == 2


# ---------------------------------------------------------------------------
# Runtime-calibrated costs (feedback → cost-constant regression)


def test_cost_scale_least_squares_regression():
    from repro.core.planner.feedback import MIN_RUNTIME_SAMPLES, StatsStore
    store = StatsStore()
    # below the sample floor the scale is not trusted
    for _ in range(MIN_RUNTIME_SAMPLES - 1):
        store.record_runtime("eager", 1e5, 0.1)
    assert store.cost_scale("eager") is None
    store.record_runtime("eager", 1e5, 0.1)
    assert store.cost_scale("eager") == pytest.approx(1e-6)
    # regression through the origin over mixed workloads
    store2 = StatsStore()
    for w, s in ((1e4, 0.02), (2e4, 0.04), (4e4, 0.08)):
        store2.record_runtime("streaming", w, s)
    assert store2.cost_scale("streaming") == pytest.approx(2e-6)
    assert store2.calibration() == {"streaming": pytest.approx(2e-6)}


def test_calibration_flips_auto_to_measured_cheaper_engine():
    """Regression test for the feedback loop: with a-priori constants AUTO
    picks eager for a small scan+filter, but after N observed runs showing
    eager is measured-slow and streaming measured-fast, the same workload
    flips to streaming."""
    from repro.core.planner.feedback import MIN_RUNTIME_SAMPLES
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _uniform_source(n=5000)

    def run():
        df = core.read_source(src)
        return df[df["fare"] > 10.0].compute()

    run()
    assert ctx.planner_decisions[0].backend == BackendEngines.EAGER
    # N observed runs with skewed runtimes: eager 1000 s/work-unit,
    # streaming 1e-9 s/work-unit
    for _ in range(MIN_RUNTIME_SAMPLES):
        ctx.stats_store.record_runtime("eager", 1.0, 1000.0)
        ctx.stats_store.record_runtime("streaming", 1.0, 1e-9)
    run()
    assert ctx.planner_decisions[0].backend == BackendEngines.STREAMING
    assert any(line.startswith("auto: calibration")
               for line in ctx.planner_trace)
    assert any("cal=x" in line for line in ctx.planner_trace)


def test_fixed_backend_runs_record_calibration_samples():
    """Every execution (not just AUTO) contributes (est work, seconds)
    samples, so ordinary runs calibrate future AUTO choices."""
    ctx = get_context()
    ctx.backend = BackendEngines.EAGER
    src = _uniform_source(n=2000)
    df = core.read_source(src)
    df[df["fare"] > 10.0].compute()
    samples = ctx.stats_store.runtime_samples.get("eager")
    assert samples, "fixed eager run recorded no runtime sample"
    est_work, seconds = samples[-1]
    assert est_work > 0 and seconds >= 0


# ---------------------------------------------------------------------------
# Pricing failures are recorded, never silently dropped


def test_pricing_failure_recorded_in_rejected(monkeypatch):
    import repro.core.planner.select as sel
    real_plan_cost = sel.plan_cost

    def exploding(roots, stats, kind, *args, **kwargs):
        if kind == BackendEngines.DISTRIBUTED:
            raise ZeroDivisionError("synthetic pricing bug")
        return real_plan_cost(roots, stats, kind, *args, **kwargs)

    monkeypatch.setattr(sel, "plan_cost", exploding)
    ctx = get_context()
    src = _uniform_source(n=5000)
    scan = G.Scan(src)
    f = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    decisions = sel.plan_placement([f], ctx)
    assert len(decisions) == 1
    reason = decisions[0].rejected.get("distributed")
    assert reason is not None and "pricing-failed" in reason
    assert "ZeroDivisionError" in reason
    assert any("pricing-failed" in line for line in ctx.planner_trace)


def test_node_pricing_failure_recorded_in_rejected(monkeypatch):
    """The operator-granular DP also surfaces per-node pricing failures."""
    import repro.core.planner.select as sel
    real_node_work = sel.node_work

    def exploding(n, stats, cap):
        if cap.name == "distributed":
            raise KeyError("synthetic per-node pricing bug")
        return real_node_work(n, stats, cap)

    monkeypatch.setattr(sel, "node_work", exploding)
    ctx = get_context()
    src = _uniform_source(n=5000)
    scan = G.Scan(src)
    f = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    decisions = sel.plan_placement([f], ctx)
    assert any("pricing-failed" in d.rejected.get("distributed", "")
               for d in decisions)


# ---------------------------------------------------------------------------
# Operator-granular segments + handoff execution


def _skewed_capabilities(monkeypatch):
    """Capability constants that make streaming the clear winner for
    scan/filter but punitive for group-by (not native), forcing a split."""
    import dataclasses as dc

    from repro.core import backends as B
    orig = B.CAPABILITIES
    monkeypatch.setitem(
        B.CAPABILITIES, BackendEngines.STREAMING,
        dc.replace(orig[BackendEngines.STREAMING],
                   native_ops=frozenset(
                       orig[BackendEngines.STREAMING].native_ops
                       - {"groupby_agg"}),
                   scan_cost_per_byte=0.001, row_cost=0.001,
                   fallback_penalty=1e7))
    monkeypatch.setitem(
        B.CAPABILITIES, BackendEngines.EAGER,
        dc.replace(orig[BackendEngines.EAGER], scan_cost_per_byte=1e4))
    monkeypatch.setitem(
        B.CAPABILITIES, BackendEngines.DISTRIBUTED,
        dc.replace(orig[BackendEngines.DISTRIBUTED], startup_cost=1e14))


def test_operator_granular_split_executes_through_handoff(monkeypatch):
    """A plan whose cheapest placement splits mid-pipeline really executes
    as two segments chained by a Handoff, and the hybrid result matches a
    single-backend run."""
    _skewed_capabilities(monkeypatch)
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _uniform_source(n=20_000, partition_rows=1024)
    df = core.read_source(src)
    out = df[df["fare"] > 10.0].groupby("vendor")["miles"].sum().compute()
    decisions = ctx.planner_decisions
    assert len(decisions) == 2
    assert decisions[0].backend == BackendEngines.STREAMING
    # scan_pushdown absorbs the filter into the scan, so the streaming
    # segment is the single pushdown scan
    assert [n.op for n in decisions[0].nodes] == ["scan"]
    assert decisions[1].backend == BackendEngines.EAGER
    assert [n.op for n in decisions[1].nodes] == ["groupby_agg"]
    assert [b.op for b in decisions[1].boundary] == ["scan"]
    assert any("handoff<-" in line for line in ctx.planner_trace)
    # node sets partition the plan: no operator runs twice
    seg_ids = [frozenset(n.id for n in d.nodes) for d in decisions]
    assert not (seg_ids[0] & seg_ids[1])
    # hybrid result equals the fixed eager result
    from repro.core.context import LaFPContext, pop_session, push_session
    push_session(LaFPContext(name="ref"))
    try:
        df2 = core.read_source(src)
        ref = df2[df2["fare"] > 10.0].groupby("vendor")["miles"].sum().compute()
    finally:
        pop_session()
    np.testing.assert_array_equal(np.asarray(out["vendor"]),
                                  np.asarray(ref["vendor"]))
    np.testing.assert_allclose(np.asarray(out["miles"], np.float64),
                               np.asarray(ref["miles"], np.float64),
                               rtol=5e-4)


def test_handoff_node_evaluates_on_every_backend():
    from repro.core.backends import get_backend
    table = {"x": np.arange(8, dtype=np.int64),
             "y": np.linspace(0.0, 1.0, 8)}
    ctx = get_context()
    for kind in (BackendEngines.EAGER, BackendEngines.STREAMING,
                 BackendEngines.DISTRIBUTED):
        h = G.Handoff({k: v.copy() for k, v in table.items()},
                      ("test-handoff",), producer="filter")
        f = G.Filter(h, E.BinOp("ge", E.Col("x"), E.Lit(4)))
        backend = get_backend(kind)
        res = backend.execute([f], ctx)[f.id]
        assert isinstance(res, dict), kind
        np.testing.assert_array_equal(np.asarray(res["x"]),
                                      np.arange(4, 8))


def test_segment_decisions_respect_memory_budget():
    """Every feasible segment's estimated peak fits the budget; segments
    that cannot fit anywhere are explicitly marked infeasible."""
    from repro.core.planner.select import plan_placement
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _uniform_source(n=50_000, partition_rows=2048)
    ctx.memory_budget = int(50_000 * 24 * 0.3)
    scan = G.Scan(src)
    f = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    gb = G.GroupByAgg(f, ["vendor"], {"m": ("miles", "sum")})
    decisions = plan_placement([gb], ctx)
    for d in decisions:
        if d.feasible:
            assert d.cost.peak_bytes <= ctx.memory_budget
        else:
            assert all("budget!" in r or "pricing-failed" in r
                       for r in d.rejected.values())


def test_backend_options_mix_planner_and_engine_keys():
    """Planner-level options (placement) coexist with engine options
    (chunk_rows) in ``backend_options`` — backends are constructed with
    exactly the keys they accept, on both the fixed and AUTO paths."""
    ctx = get_context()
    ctx.backend = BackendEngines.STREAMING
    ctx.backend_options.update(placement="per_root", chunk_rows=512)
    src = _uniform_source(n=2000)
    df = core.read_source(src)
    assert df[df["fare"] > 10.0].compute().rows() > 0
    ctx.backend = BackendEngines.AUTO
    df = core.read_source(src)
    assert df[df["fare"] > 10.0].compute().rows() > 0


def test_per_root_placement_option_still_available():
    """The PR-1 per-root strategy remains selectable (regret baseline for
    benchmarks/run.py backend_selection)."""
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    ctx.backend_options["placement"] = "per_root"
    src = _uniform_source(n=5000)
    df = core.read_source(src)
    res = df[df["fare"] > 10.0].compute()
    assert res.rows() > 0
    assert len(ctx.planner_decisions) == 1
    assert not ctx.planner_decisions[0].boundary


def test_persist_mark_survives_full_optimize():
    ctx = get_context()
    src = _uniform_source(n=1000)
    scan = G.Scan(src)
    a = G.Assign(scan, "z", E.BinOp("mul", E.Col("miles"), E.Lit(2.0)))
    f = G.Filter(a, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    f.persist = True
    roots, idmap = optimize([f], ctx)
    # pushdown rewrites the subtree; the node the old root maps to must
    # still carry the persist mark
    assert idmap[f.id].persist is True
