"""Tests for the cost-based adaptive planner (planner/): statistics &
selectivity estimation, cost-model monotonicity, AUTO backend selection
under a memory budget, feedback recalibration, and runtime-flag survival
across optimizer rewrites."""
import numpy as np
import pytest

import repro.core as core
from repro.core import BackendEngines, get_context
from repro.core import expr as E
from repro.core import graph as G
from repro.core.backends import CAPABILITIES, get_backend
from repro.core.optimizer import _conjuncts, _rebuild, optimize, order_conjuncts
from repro.core.planner.cost import plan_cost
from repro.core.planner.stats import (TableStats, estimate_plan,
                                      predicate_selectivity, source_stats)


def _uniform_source(n=10_000, partition_rows=1024, seed=0):
    rng = np.random.default_rng(seed)
    return core.InMemorySource({
        "fare": rng.uniform(0, 100, n),
        "vendor": rng.integers(0, 4, n).astype(np.int64),
        "miles": rng.uniform(0, 30, n),
    }, partition_rows)


# ---------------------------------------------------------------------------
# Statistics / selectivity


def test_source_stats_from_metadata():
    src = _uniform_source(n=5000)
    st = source_stats(src)
    assert st.rows == 5000
    assert st.exact
    # vendor is an int column with span 0..3 → NDV 4 from zone maps
    assert src.column_ndv("vendor") == 4
    assert st.col_ndv("vendor") == 4
    lo, hi = st.zonemap["fare"]
    assert 0 <= lo < hi <= 100
    assert st.total_bytes == pytest.approx(5000 * 24)


def test_column_ndv_dict_vocab():
    src = core.InMemorySource(
        {"city": np.array([0, 1, 2, 0, 1], dtype=np.int32)},
        dicts={"city": ["nyc", "sf", "la"]})
    assert src.column_ndv("city") == 3


def test_range_selectivity_against_zonemap():
    src = _uniform_source()
    st = source_stats(src)
    sel = predicate_selectivity(
        E.BinOp("lt", E.Col("fare"), E.Lit(25.0)), st)
    assert sel == pytest.approx(0.25, abs=0.05)
    sel_hi = predicate_selectivity(
        E.BinOp("gt", E.Col("fare"), E.Lit(25.0)), st)
    assert sel_hi == pytest.approx(0.75, abs=0.05)


def test_equality_selectivity_against_ndv():
    src = _uniform_source()
    st = source_stats(src)
    sel = predicate_selectivity(
        E.BinOp("eq", E.Col("vendor"), E.Lit(2)), st)
    assert sel == pytest.approx(0.25, abs=0.01)
    conj = E.BinOp("and",
                   E.BinOp("eq", E.Col("vendor"), E.Lit(2)),
                   E.BinOp("lt", E.Col("fare"), E.Lit(50.0)))
    assert predicate_selectivity(conj, st) == pytest.approx(0.125, abs=0.03)


def test_filter_propagation_through_dag():
    src = _uniform_source(n=8000)
    scan = G.Scan(src)
    f = G.Filter(scan, E.BinOp("lt", E.Col("fare"), E.Lit(50.0)))
    gb = G.GroupByAgg(f, ["vendor"], {"m": ("miles", "sum")})
    est = estimate_plan([gb])
    assert est[f.id].rows == pytest.approx(4000, rel=0.15)
    # group-by output capped at the key NDV
    assert est[gb.id].rows <= 4


# ---------------------------------------------------------------------------
# Cost model


def test_cost_monotone_in_rows():
    for kind in CAPABILITIES:
        costs = []
        for n in (1000, 10_000, 100_000):
            src = _uniform_source(n=n)
            scan = G.Scan(src)
            f = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
            stats = estimate_plan([f])
            costs.append(plan_cost([f], stats, kind).total)
        assert costs[0] < costs[1] < costs[2], kind


def test_streaming_peak_below_eager_for_aggregation():
    src = _uniform_source(n=50_000, partition_rows=2048)
    scan = G.Scan(src)
    gb = G.GroupByAgg(scan, ["vendor"], {"m": ("miles", "sum")})
    stats = estimate_plan([gb])
    eager = plan_cost([gb], stats, BackendEngines.EAGER)
    streaming = plan_cost([gb], stats, BackendEngines.STREAMING)
    assert streaming.peak_bytes < eager.peak_bytes / 4


def test_get_backend_auto_raises():
    with pytest.raises(ValueError):
        get_backend(BackendEngines.AUTO)


# ---------------------------------------------------------------------------
# AUTO selection


def test_auto_small_workload_dispatches_eager():
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _uniform_source(n=5000)
    df = core.read_source(src)
    df = df[df["fare"] > 10.0]
    res = df.compute()
    assert res.rows() == int((np.asarray(src._arrays["fare"]) > 10.0).sum())
    assert len(ctx.planner_decisions) == 1
    assert ctx.planner_decisions[0].backend == BackendEngines.EAGER
    assert any("-> eager" in line for line in ctx.planner_trace)


def test_auto_over_budget_dispatches_streaming():
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _uniform_source(n=50_000, partition_rows=2048)
    ctx.memory_budget = int(50_000 * 24 * 0.3)  # eager can't fit the table
    df = core.read_source(src)
    df = df[df["fare"] > 10.0]
    out = df.groupby("vendor")["miles"].sum().compute()
    assert out.rows() == 4
    assert ctx.planner_decisions[0].backend == BackendEngines.STREAMING
    assert any("budget!" in line for line in ctx.planner_trace)
    # the streaming run really stayed under the budget (meter enforced)
    assert ctx.last_peak_bytes <= ctx.memory_budget


def test_auto_results_match_fixed_backend():
    arrays = {"x": np.arange(1000, dtype=np.int64),
              "y": np.linspace(0, 1, 1000)}
    ctx = get_context()
    ctx.backend = BackendEngines.EAGER
    ref = core.from_arrays(dict(arrays), partition_rows=128)
    ref = ref[ref["x"] % 3 == 0].compute()
    ctx.reset()
    ctx.backend = BackendEngines.AUTO
    df = core.from_arrays(dict(arrays), partition_rows=128)
    res = df[df["x"] % 3 == 0].compute()
    np.testing.assert_allclose(np.asarray(res["y"]), np.asarray(ref["y"]))


# ---------------------------------------------------------------------------
# Feedback recalibration


def test_feedback_recalibrates_estimates_within_10pct():
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    # heavily skewed column: the uniformity assumption over the zone map is
    # badly wrong a priori (~50% estimated vs ~2% actual)
    vals = np.concatenate([np.zeros(9800), np.linspace(1, 100, 200)])
    src = core.InMemorySource({"fare": vals, "k": np.arange(10_000) % 7},
                              partition_rows=1024)

    def build():
        df = core.read_source(src)
        return df[df["fare"] > 50.0]

    pred_actual = int((vals > 50.0).sum())
    roots0, _ = optimize([build()._node], ctx)
    est0 = estimate_plan(roots0, ctx)
    prior_err = abs(est0[roots0[0].id].rows - pred_actual) / pred_actual
    assert prior_err > 1.0          # a-priori estimate is way off

    build().compute()               # execute once → feedback recorded
    assert len(ctx.stats_store) >= 1

    roots1, _ = optimize([build()._node], ctx)
    est1 = estimate_plan(roots1, ctx)
    post_err = abs(est1[roots1[0].id].rows - pred_actual) / max(pred_actual, 1)
    assert post_err <= 0.10


def test_feedback_influences_next_placement():
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    src = _uniform_source(n=20_000, partition_rows=1024)
    df = core.read_source(src)
    df[df["fare"] > 10.0].compute()
    n_before = len(ctx.stats_store)
    assert n_before >= 1
    # second run of the same plan consults the store (estimates exact)
    df2 = core.read_source(src)
    node = df2[df2["fare"] > 10.0]._node
    roots, _ = optimize([node], ctx)
    est = estimate_plan(roots, ctx)
    assert est[roots[0].id].exact


# ---------------------------------------------------------------------------
# Selectivity-ordered filter fusion


def test_order_conjuncts_most_selective_first():
    src = _uniform_source()
    scan = G.Scan(src)
    weak = E.BinOp("gt", E.Col("fare"), E.Lit(1.0))       # ~0.99
    strong = E.BinOp("eq", E.Col("vendor"), E.Lit(0))     # 0.25
    f = G.Filter(scan, E.BinOp("and", weak, strong))
    roots, _ = order_conjuncts([f], None, trace=None)
    conj = _conjuncts(roots[0].predicate)
    assert conj[0].key() == strong.key()
    assert conj[1].key() == weak.key()


def test_order_conjuncts_traced_via_optimize():
    ctx = get_context()
    src = _uniform_source()
    scan = G.Scan(src)
    f1 = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(1.0)))
    f2 = G.Filter(f1, E.BinOp("eq", E.Col("vendor"), E.Lit(0)))
    optimize([f2], ctx)
    assert any(t.startswith("order_conjuncts") for t in ctx.optimizer_trace)


# ---------------------------------------------------------------------------
# Rewrite-flag survival (optimizer._rebuild regression)


def test_rebuild_carries_runtime_flags():
    src = _uniform_source(n=100)
    scan = G.Scan(src)
    f = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(0.0)))
    a = G.Assign(f, "z", E.BinOp("mul", E.Col("miles"), E.Lit(2.0)))
    a.persist = True
    a.cache_key = ("logical-key",)
    a.result = {"sentinel": np.zeros(1)}
    # replace the deep scan → every ancestor is cloned via with_inputs
    new_scan = G.Scan(src, columns=("fare", "miles"))
    roots, idmap = _rebuild([a], {scan.id: new_scan})
    na = roots[0]
    assert na is not a
    assert na.persist is True
    assert na.cache_key == ("logical-key",)
    assert na.result is a.result
    assert idmap[a.id] is na


def test_persist_marked_node_is_rewrite_barrier():
    """A planned materialization point must not be fused/rewritten away —
    its cached value is keyed on its own (logical) shape (§3.5)."""
    from repro.core.optimizer import push_filters
    src = _uniform_source(n=1000)
    scan = G.Scan(src)
    inner = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    inner.persist = True
    outer = G.Filter(inner, E.BinOp("lt", E.Col("miles"), E.Lit(5.0)))
    roots, _ = push_filters([outer])
    # no fusion: both filters survive, persist mark intact on the inner one
    ops = [n.op for n in G.walk(roots)]
    assert ops == ["scan", "filter", "filter"]
    assert G.walk(roots)[1].persist is True


def test_hybrid_grouping_never_splits_shared_subtrees():
    from repro.core.planner.select import plan_placement
    ctx = get_context()
    src = _uniform_source(n=20_000, partition_rows=1024)
    scan = G.Scan(src)
    shared = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    a = G.GroupByAgg(shared, ["vendor"], {"m": ("miles", "sum")})
    b = G.SortValues(shared, ["fare"])
    decisions = plan_placement([a, b], ctx)
    groups = [{n.id for n in G.walk(d.roots)} for d in decisions]
    for i, g1 in enumerate(groups):
        for g2 in groups[i + 1:]:
            assert not (g1 & g2), "shared subtree split across backends"
    assert sum(len(d.roots) for d in decisions) == 2


def test_persist_mark_survives_full_optimize():
    ctx = get_context()
    src = _uniform_source(n=1000)
    scan = G.Scan(src)
    a = G.Assign(scan, "z", E.BinOp("mul", E.Col("miles"), E.Lit(2.0)))
    f = G.Filter(a, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    f.persist = True
    roots, idmap = optimize([f], ctx)
    # pushdown rewrites the subtree; the node the old root maps to must
    # still carry the persist mark
    assert idmap[f.id].persist is True
