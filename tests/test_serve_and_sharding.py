"""Serving engine + sharding rules + data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs, shape_applicable
from repro.models.layers import init_from_spec
from repro.models.transformer import model_spec


def test_engine_generates_tokens():
    from repro.serve.engine import Engine, Request
    cfg = get_config("qwen2_5_3b").smoke()
    params = init_from_spec(model_spec(cfg), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_seq=32)
    eng.submit(Request(rid=1, prompt=np.array([1, 2, 3]), max_new=5))
    eng.submit(Request(rid=2, prompt=np.array([4, 5]), max_new=4))
    eng.submit(Request(rid=3, prompt=np.array([6]), max_new=3))  # queued
    done = eng.run(max_steps=40)
    assert {r.rid for r in done} == {1, 2, 3}
    assert len(done[0].out_tokens) >= 3
    for r in done:
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_param_shardings_divisibility():
    from repro.distributed.sharding import param_shardings, spec_for
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # llama kv=8 over model=16 conceptually; with shape-aware fallback the
    # spec must drop the model axis for non-divisible dims
    big = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    fm = FakeMesh()
    s = spec_for((3072, 8, 128), ("embed", "heads", None), fm)
    assert s == P("data", None, None)       # 8 ≢ 0 (mod 16) → replicated
    s2 = spec_for((3072, 32, 128), ("embed", "heads", None), fm)
    assert s2 == P("data", "model", None)


def test_cache_shardings_structure():
    from repro.distributed.sharding import cache_shardings
    from repro.models.transformer import cache_shapes
    cfg = get_config("llama3_2_3b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = cache_shapes(cfg, 128, 1024)
    sh = cache_shardings(mesh, tree, 128)
    # group leaves: (n_groups, B, S, H, hd) — batch must be dim 1
    leaf = jax.tree.leaves(sh["group"])[0]
    assert isinstance(leaf.spec, P)


def test_all_cells_have_input_specs():
    for arch_name in ("musicgen-large", "jamba-v0.1-52b", "xlstm-350m"):
        arch = get_config(arch_name)
        for shape in SHAPES.values():
            ok, _ = shape_applicable(arch, shape)
            if not ok:
                continue
            specs = input_specs(arch, shape)
            assert all(hasattr(v, "shape") or isinstance(v, (dict, list, tuple))
                       for v in specs.values())


def test_long500k_skip_rule():
    assert not shape_applicable(get_config("llama3.2-3b"),
                                SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("xlstm-350m"), SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("jamba-v0.1-52b"),
                            SHAPES["long_500k"])[0]
    assert not shape_applicable(get_config("gemma3-4b"),
                                SHAPES["long_500k"])[0]


def test_token_pipeline_filters_and_batches():
    from repro.core import get_context
    from repro.data.pipeline import (PipelineConfig, TokenPipeline,
                                     synthetic_token_source)
    src = synthetic_token_source(128, 16, vocab=100, seed=0)
    pipe = TokenPipeline(src, PipelineConfig(batch=8, seq=16, min_doc_len=4,
                                             min_quality=0.25))
    it = iter(pipe)
    batch = next(it)
    assert batch["tokens"].shape == (8, 16)
    assert batch["tokens"].dtype == np.int32
    assert batch["labels"].shape == (8, 16)
    assert (batch["labels"][:, -1] == -100).all()
    # column selection happened: only token columns read from the source
    trace = get_context().optimizer_trace
    assert any("column_selection" in t for t in trace)


def test_pipeline_deterministic_across_restart():
    from repro.data.pipeline import (PipelineConfig, PipelineState,
                                     TokenPipeline, synthetic_token_source)
    src = synthetic_token_source(64, 8, vocab=50, seed=3)
    cfg = PipelineConfig(batch=4, seq=8)
    p1 = TokenPipeline(src, cfg)
    it1 = iter(p1)
    batches = [next(it1) for _ in range(5)]
    # "restart" from the cursor after batch 2
    p2 = TokenPipeline(src, cfg)
    p2.state = PipelineState(epoch=0, batch_index=2, rng_state=cfg.seed)
    it2 = iter(p2)
    resumed = next(it2)
    np.testing.assert_array_equal(resumed["tokens"], batches[2]["tokens"])


def test_prefetch_iterator_drains():
    from repro.data.pipeline import PrefetchIterator
    out = list(PrefetchIterator(iter(range(7)), depth=2))
    assert out == list(range(7))
