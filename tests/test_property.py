"""Property-based tests (hypothesis) for the system's invariants:

1. optimizer soundness — random pipelines produce identical results with and
   without every optimization rule;
2. predicate-pushdown safety over random predicates and op orders;
3. streaming/eager equivalence under random partition sizes;
4. kernel compaction/aggregation laws.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as core
from repro.core import BackendEngines, get_context
from repro.core.optimizer import optimize

COLS = ["a", "b", "c"]


@st.composite
def small_table(draw):
    n = draw(st.integers(8, 200))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    return {
        "a": rng.integers(-10, 10, n).astype(np.int64),
        "b": rng.normal(size=n),
        "c": rng.integers(0, 5, n).astype(np.int64),
    }


@st.composite
def pipeline_ops(draw):
    """A random sequence of frame ops as (kind, args) tuples."""
    ops = []
    for _ in range(draw(st.integers(1, 5))):
        kind = draw(st.sampled_from(
            ["filter_gt", "filter_lt", "assign", "sort", "head", "rename"]))
        col = draw(st.sampled_from(COLS))
        val = draw(st.integers(-5, 5))
        ops.append((kind, col, val))
    return ops


def _apply_ops(df, ops, renamed):
    for kind, col, val in ops:
        col = renamed.get(col, col)
        if kind == "filter_gt":
            df = df[df[col] > val]
        elif kind == "filter_lt":
            df = df[df[col] < val]
        elif kind == "assign":
            df[f"x_{col}"] = df[col] * 2 + val
        elif kind == "sort":
            df = df.sort_values(col)
        elif kind == "head":
            df = df.head(max(1, abs(val)) * 5)
        elif kind == "rename":
            new = f"{col}_r"
            df = df.rename({col: new})
            renamed[col] = new
    return df


def _values(res):
    return {k: np.asarray(v) for k, v in res.columns.items()}


@settings(max_examples=25, deadline=None)
@given(table=small_table(), ops=pipeline_ops())
def test_optimizer_soundness_random_pipelines(table, ops):
    """optimized(pipeline) == unoptimized(pipeline) for random programs."""
    get_context().reset()
    ctx = get_context()
    from repro.core.backends import get_backend
    be = get_backend(BackendEngines.EAGER)

    def build():
        df = core.from_arrays(table, partition_rows=32)
        return _apply_ops(df, ops, {})

    node = build()._node
    plain_roots, _ = optimize([node], ctx, enable=())
    opt_roots, _ = optimize([node], ctx)
    pv = be.execute(plain_roots, ctx)[plain_roots[0].id]
    ov = be.execute(opt_roots, ctx)[opt_roots[0].id]
    assert set(pv.keys()) == set(ov.keys())
    for k in pv:
        np.testing.assert_allclose(np.asarray(pv[k], dtype=np.float64),
                                   np.asarray(ov[k], dtype=np.float64),
                                   rtol=1e-5, atol=1e-8)


@st.composite
def rewrite_idiom_ops(draw):
    """Pipelines dense in the idioms the rewrite engine targets: sorted
    heads, sort+dedup, vectorizable row-UDFs, filtered self-concats."""
    ops = []
    for _ in range(draw(st.integers(1, 4))):
        kind = draw(st.sampled_from(
            ["sort_head", "sort_head_desc", "sort_dedup", "udf",
             "concat_filter", "filter_gt"]))
        col = draw(st.sampled_from(COLS))
        val = draw(st.integers(-5, 5))
        ops.append((kind, col, val))
    return ops


def _apply_idioms(pd_mod, df, ops):
    for kind, col, val in ops:
        if kind == "sort_head":
            df = df.sort_values(col).head(max(1, abs(val)) * 4)
        elif kind == "sort_head_desc":
            df = df.sort_values(col, ascending=False).head(max(1, abs(val)) * 4)
        elif kind == "sort_dedup":
            df = df.sort_values(col).drop_duplicates()
        elif kind == "udf":
            df = df.apply_rows(
                lambda t, c=col, v=val: dict(t, **{f"u_{c}": t[c] * 2 + v}))
        elif kind == "concat_filter":
            cat = pd_mod.concat([df, df.head(20)])
            df = cat[cat[col] > val]
        elif kind == "filter_gt":
            df = df[df[col] > val]
    return df


@settings(max_examples=25, deadline=None)
@given(table=small_table(), ops=rewrite_idiom_ops())
def test_rewritten_plans_equal_unrewritten(table, ops):
    """Plan-rewrite soundness: for idiom-dense random pipelines, the
    rewritten plan's result equals the plan with the rewrite pass disabled
    (the ``session(rewrites=False)`` escape hatch), row order included."""
    import repro.pandas as rpd
    res = {}
    for flag in (True, False):
        with rpd.session(engine="eager", rewrites=flag) as ctx:
            ctx.print_fn = lambda *a: None
            df = rpd.from_arrays(table, partition_rows=32)
            res[flag] = _values(_apply_idioms(rpd, df, ops).compute())
    assert set(res[True]) == set(res[False])
    for k in res[True]:
        np.testing.assert_array_equal(np.asarray(res[True][k]),
                                      np.asarray(res[False][k]),
                                      err_msg=f"column {k!r}")


@settings(max_examples=15, deadline=None)
@given(table=small_table(), ops=pipeline_ops(),
       part=st.sampled_from([7, 32, 1000]))
def test_streaming_matches_eager(table, ops, part):
    get_context().reset()
    ctx = get_context()

    def run(backend):
        ctx.backend = backend
        df = core.from_arrays(table, partition_rows=part)
        return _values(_apply_ops(df, ops, {}).compute())

    ev = run(BackendEngines.EAGER)
    sv = run(BackendEngines.STREAMING)
    assert set(ev.keys()) == set(sv.keys())
    for k in ev:
        # eager runs f32 (jax x32), streaming f64 — compare at f32 precision
        np.testing.assert_allclose(np.asarray(ev[k], np.float64),
                                   np.asarray(sv[k], np.float64),
                                   rtol=5e-4, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(table=small_table(),
       keycol=st.sampled_from(["a", "c"]),
       fn=st.sampled_from(["sum", "mean", "min", "max", "count"]))
def test_groupby_partial_combine_law(table, keycol, fn):
    """Streaming partial+combine group-by == whole-table group-by."""
    get_context().reset()
    ctx = get_context()
    res = {}
    for backend, part in ((BackendEngines.EAGER, 10 ** 6),
                          (BackendEngines.STREAMING, 16)):
        ctx.backend = backend
        df = core.from_arrays(table, partition_rows=part)
        g = getattr(df.groupby([keycol])["b"], fn)()
        res[backend] = _values(g.sort_values(keycol).compute())
    e, s = res[BackendEngines.EAGER], res[BackendEngines.STREAMING]
    np.testing.assert_array_equal(e[keycol], s[keycol])
    # f32 (eager/jax) vs f64 (streaming/np) accumulation
    np.testing.assert_allclose(np.asarray(e["b"], np.float64),
                               np.asarray(s["b"], np.float64), rtol=5e-4,
                               atol=1e-6)


@st.composite
def calibration_scales(draw):
    """Randomized measured sec/work scales spanning orders of magnitude —
    skewed calibrations push the operator-granular planner into different
    (possibly split) placements."""
    return {name: draw(st.sampled_from([1e-9, 1e-6, 1e-3, 1.0]))
            for name in ("eager", "streaming", "distributed")}


@settings(max_examples=15, deadline=None)
@given(table=small_table(), ops=pipeline_ops(), scales=calibration_scales())
def test_operator_granular_auto_matches_fixed_backend(table, ops, scales):
    """Whatever segments the operator-granular planner picks (under any
    runtime calibration), the hybrid result equals forcing one backend."""
    from repro.core.planner.feedback import MIN_RUNTIME_SAMPLES
    get_context().reset()
    ctx = get_context()

    ctx.backend = BackendEngines.EAGER
    df = core.from_arrays(table, partition_rows=32)
    ref = _values(_apply_ops(df, ops, {}).compute())

    ctx.reset()
    ctx.backend = BackendEngines.AUTO
    for name, s in scales.items():
        for _ in range(MIN_RUNTIME_SAMPLES):
            ctx.stats_store.record_runtime(name, 1.0, s)
    df = core.from_arrays(table, partition_rows=32)
    av = _values(_apply_ops(df, ops, {}).compute())

    assert set(ref.keys()) == set(av.keys())
    for k in ref:
        # engines differ in float width (eager f32, streaming f64)
        np.testing.assert_allclose(np.asarray(ref[k], np.float64),
                                   np.asarray(av[k], np.float64),
                                   rtol=5e-4, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(table=small_table(), ops=pipeline_ops(),
       budget=st.sampled_from([1 << 10, 1 << 14, 1 << 20, None]))
def test_planner_segments_respect_memory_budget(table, ops, budget):
    """Every segment the planner emits either fits ``ctx.memory_budget``
    (estimated peak) or is explicitly marked infeasible with every
    alternative rejected for the budget too."""
    from repro.core.optimizer import optimize as opt
    from repro.core.planner.select import plan_placement
    get_context().reset()
    ctx = get_context()
    ctx.backend = BackendEngines.AUTO
    ctx.memory_budget = budget
    df = core.from_arrays(table, partition_rows=32)
    node = _apply_ops(df, ops, {})._node
    roots, _ = opt([node], ctx)
    decisions = plan_placement(roots, ctx)
    seen: set[int] = set()
    for d in decisions:
        if budget is not None and d.feasible:
            assert d.cost.peak_bytes <= budget
        elif budget is not None:
            assert all("budget!" in r or "pricing-failed" in r
                       for r in d.rejected.values())
        # segments partition the plan: no operator is assigned twice
        ids = {n.id for n in d.nodes}
        assert not (ids & seen)
        seen |= ids


@settings(max_examples=20, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=300),
       st.integers(0, 2 ** 16))
def test_filter_compact_properties(mask, seed):
    """Kernel law: packed prefix == input[mask]; tail is zero."""
    import jax.numpy as jnp
    from repro.kernels.filter_compact import filter_compact
    rng = np.random.default_rng(seed)
    mask = np.asarray(mask)
    vals = rng.normal(size=mask.shape[0]).astype(np.float32)
    packed, count = filter_compact(jnp.asarray(vals), jnp.asarray(mask),
                                   block_rows=64)
    packed = np.asarray(packed)
    assert int(count) == int(mask.sum())
    np.testing.assert_allclose(packed[: int(count)], vals[mask], rtol=1e-6)
    assert not packed[int(count):].any()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 500), st.integers(0, 2 ** 16))
def test_groupby_sum_kernel_total_preserved(groups, n, seed):
    """Σ_g out[g] == Σ values (mass conservation)."""
    import jax.numpy as jnp
    from repro.kernels.groupby_sum import groupby_sum
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, groups, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    out = np.asarray(groupby_sum(jnp.asarray(codes), jnp.asarray(vals),
                                 groups, block_rows=64))
    np.testing.assert_allclose(out.sum(), vals.sum(), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Native distributed join ≡ eager join on random dict-coded keys


@st.composite
def _dist_join_case(draw):
    seed = draw(st.integers(0, 2 ** 16))
    n = draw(st.integers(1, 300))
    b = draw(st.integers(1, 64))
    domain = draw(st.integers(1, 30))
    how = draw(st.sampled_from(["inner", "left"]))
    rng = np.random.default_rng(seed)
    probe = {"k": rng.integers(0, domain, n).astype(np.int64),
             "v": rng.integers(-100, 100, n).astype(np.int64)}
    build = {"k": rng.integers(0, domain, b).astype(np.int64),
             "w": rng.integers(-100, 100, b).astype(np.int64)}
    return probe, build, how


@settings(max_examples=40, deadline=None)
@given(case=_dist_join_case())
def test_native_distributed_join_equals_eager_join(case):
    """Whatever native path fires (broadcast-hash for unique small builds,
    shuffle-by-dict-code otherwise), the device-resident result equals the
    eager host hash join exactly — values AND probe-order row order."""
    from repro.core import physical as X
    from repro.core.backends.distributed import _default_mesh
    from repro.core.physical.sharded import ShardedTable
    probe, build, how = case
    mesh = _default_mesh()
    t = X.shard_host_table(probe, mesh, "data")
    out = X.sharded_join(t, build, ["k"], how, ("_x", "_y"), mesh, "data")
    ref = X.apply_join(probe, build, ["k"], how)
    assert isinstance(out, ShardedTable)
    got = out.gather()
    assert set(got) == set(ref)
    for c in ref:   # integer payloads: equality is exact, order included
        np.testing.assert_array_equal(np.asarray(got[c], np.int64),
                                      np.asarray(ref[c], np.int64),
                                      err_msg=f"{how}:{c}")


# ---------------------------------------------------------------------------
# 5. plan-cache fingerprint laws (planner/plancache.py): structural identity
#    collides, any op/param/schema mutation separates, and the stats epoch
#    reacts to exactly the feedback a plan can see.


@st.composite
def fp_pipeline(draw):
    ops = []
    for _ in range(draw(st.integers(1, 6))):
        kind = draw(st.sampled_from(
            ["filter_gt", "filter_lt", "assign", "sort", "head", "project"]))
        col = draw(st.sampled_from(COLS))
        val = draw(st.integers(-5, 5))
        ops.append((kind, col, val))
    return ops


def _fp_source(seed=0, n=500):
    rng = np.random.default_rng(seed)
    return core.InMemorySource({
        "a": rng.integers(-10, 10, n).astype(np.int64),
        "b": rng.normal(size=n),
        "c": rng.integers(0, 5, n).astype(np.int64),
    }, 128)


def _fp_build(src, ops):
    from repro.core import expr as E
    from repro.core import graph as G
    node = G.Scan(src)
    for kind, col, val in ops:
        if kind == "filter_gt":
            node = G.Filter(node, E.BinOp("gt", E.Col(col), E.Lit(val)))
        elif kind == "filter_lt":
            node = G.Filter(node, E.BinOp("lt", E.Col(col), E.Lit(val)))
        elif kind == "assign":
            node = G.Assign(node, f"x_{col}", E.BinOp(
                "add", E.BinOp("mul", E.Col(col), E.Lit(2)), E.Lit(val)))
        elif kind == "sort":
            node = G.SortValues(node, [col])
        elif kind == "head":
            node = G.Head(node, max(1, abs(val)) * 5)
        elif kind == "project":
            node = G.Project(node, COLS)
    return [node]


@settings(max_examples=60, deadline=None)
@given(ops=fp_pipeline(), seed_a=st.integers(0, 99), seed_b=st.integers(0, 99))
def test_fingerprint_structural_identity_collides(ops, seed_a, seed_b):
    """Identical shapes collide — including over *different* data (the
    source cache_token is deliberately not part of the fingerprint)."""
    from repro.core.context import LaFPContext
    from repro.core.planner.plancache import plan_fingerprint
    ctx = LaFPContext(name="prop")
    fp_a = plan_fingerprint(_fp_build(_fp_source(seed_a), ops), ctx)
    fp_b = plan_fingerprint(_fp_build(_fp_source(seed_b), ops), ctx)
    assert fp_a == fp_b


@settings(max_examples=60, deadline=None)
@given(ops=fp_pipeline(),
       extra=st.sampled_from(["filter_gt", "assign", "head"]),
       col=st.sampled_from(COLS))
def test_fingerprint_shape_mutation_separates(ops, extra, col):
    from repro.core.context import LaFPContext
    from repro.core.planner.plancache import plan_fingerprint
    ctx = LaFPContext(name="prop")
    src = _fp_source()
    base = plan_fingerprint(_fp_build(src, ops), ctx)
    longer = plan_fingerprint(_fp_build(src, ops + [(extra, col, 7)]), ctx)
    assert base != longer


@settings(max_examples=30, deadline=None)
@given(ops=fp_pipeline(), val=st.integers(6, 20))
def test_fingerprint_param_mutation_separates(ops, val):
    """Changing one op parameter (a filter constant) separates."""
    from repro.core.context import LaFPContext
    from repro.core.planner.plancache import plan_fingerprint
    ctx = LaFPContext(name="prop")
    src = _fp_source()
    probe = [("filter_gt", "a", 0)] + ops
    mutated = [("filter_gt", "a", val)] + ops
    assert (plan_fingerprint(_fp_build(src, probe), ctx)
            != plan_fingerprint(_fp_build(src, mutated), ctx))


@settings(max_examples=30, deadline=None)
@given(ops=fp_pipeline(), rows=st.integers(1, 10 ** 6))
def test_stats_epoch_sees_own_plan_only(ops, rows):
    """Recording a cardinality for a node of THIS plan moves the epoch;
    feedback about unrelated plans leaves it alone."""
    from repro.core.context import LaFPContext
    from repro.core.planner.plancache import stats_epoch
    ctx = LaFPContext(name="prop")
    roots = _fp_build(_fp_source(), ops)
    e0 = stats_epoch(roots, ctx)
    ctx.stats_store.record(("unrelated", "key"), rows=rows, nbytes=8 * rows)
    assert stats_epoch(roots, ctx) == e0
    ctx.stats_store.record(roots[0].key(), rows=rows, nbytes=8 * rows)
    assert stats_epoch(roots, ctx) != e0
