"""End-to-end behaviour tests for the LaFP system (paper §5).

The paper's regression methodology (§5.2): results computed with
optimizations on every backend must hash-equal the unoptimized Pandas-
analogue result.
"""
import hashlib

import numpy as np
import pytest

import repro.core as core
from repro.core import BackendEngines, get_context
from repro.core.optimizer import optimize

from conftest import make_taxi_arrays


def _result_hash(res) -> str:
    """md5 of value-normalized columns (backends differ in concrete dtypes —
    int32 vs int64, float32 vs float64 — but must agree on values)."""
    h = hashlib.md5()
    for name in sorted(res.columns):
        arr = np.asarray(res.columns[name])
        arr = np.round(arr.astype(np.float64), 4)
        h.update(name.encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _taxi_program(df):
    df = df[df["fare_amount"] > 0]
    df["day"] = (df["pickup_datetime"] // 86400 + 3) % 7
    return df.groupby(["day"])["passenger_count"].sum().sort_values("day")


@pytest.mark.parametrize("backend", [BackendEngines.EAGER,
                                     BackendEngines.STREAMING,
                                     BackendEngines.DISTRIBUTED])
def test_backend_results_hash_equal(taxi_arrays, backend):
    """Paper §5.2: optimized results identical across all backends."""
    ctx = get_context()
    # reference: eager, optimizer disabled (plain Pandas analogue)
    ctx.backend = BackendEngines.EAGER
    ref_frame = _taxi_program(core.from_arrays(taxi_arrays,
                                               partition_rows=4096))
    roots, _ = optimize([ref_frame._node], ctx, enable=())
    from repro.core.backends import get_backend
    ref_val = get_backend(BackendEngines.EAGER).execute(roots, ctx)[roots[0].id]
    from repro.core.lazyframe import Result
    ref_hash = _result_hash(Result(ref_val))

    ctx.backend = backend
    out = _taxi_program(core.from_arrays(taxi_arrays,
                                         partition_rows=4096)).compute()
    assert _result_hash(out) == ref_hash


def test_two_line_change_api(taxi_arrays):
    """Paper Fig. 2: import + analyze() are the only changes."""
    import repro.core.lazy as pd
    pd.analyze()
    df = pd.from_arrays(taxi_arrays)
    out = df[df["fare_amount"] > 50].compute()
    mask = taxi_arrays["fare_amount"] > 50
    assert out.rows() == int(mask.sum())


def test_larger_than_budget_succeeds_streaming(taxi_arrays):
    """Paper Fig. 12 mechanism: streaming completes under a budget that the
    eager path exceeds."""
    ctx = get_context()
    total_bytes = sum(a.nbytes for a in taxi_arrays.values())
    ctx.memory_budget = total_bytes // 3
    ctx.backend = BackendEngines.STREAMING
    df = core.from_arrays(taxi_arrays, partition_rows=1000)
    df = df[df["fare_amount"] > 0]
    res = df.groupby(["passenger_count"])["trip_miles"].mean().compute()
    assert res.rows() == 7
    assert ctx.last_peak_bytes <= ctx.memory_budget


def test_streaming_budget_violation_raises(taxi_arrays):
    from repro.core.backends import MemoryBudgetExceeded
    ctx = get_context()
    ctx.memory_budget = 10_000     # absurdly small
    ctx.backend = BackendEngines.STREAMING
    df = core.from_arrays(taxi_arrays, partition_rows=1000)
    with pytest.raises(MemoryBudgetExceeded):
        df.sort_values("fare_amount").compute()


def test_optimizations_preserve_join(rng):
    ctx = get_context()
    n = 5000
    left = {"k": rng.integers(0, 50, n), "v": rng.normal(size=n),
            "junk": rng.normal(size=n)}
    right = {"k": np.arange(50), "w": rng.normal(size=50)}
    for backend in (BackendEngines.EAGER, BackendEngines.STREAMING):
        ctx.backend = backend
        l = core.from_arrays(left, partition_rows=512)
        r = core.from_arrays(right)
        j = l.merge(r, on="k")
        j = j[j["w"] > 0]
        out = j.compute()
        wpos = right["w"] > 0
        expected = sum(int(wpos[k]) for k in left["k"])
        assert out.rows() == expected, backend
