"""Model-stack tests: per-arch smoke (reduced configs, one forward/train
step, output shapes + no NaNs), decode↔train consistency, chunked-vs-dense
equivalences for attention / mamba / mLSTM."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import attention as A
from repro.models.layers import init_from_spec
from repro.models.transformer import forward, init_cache, model_spec

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, T, key=KEY):
    if cfg.modality == "text":
        return {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab)}
    return {"embeds": jax.random.normal(key, (B, T, cfg.d_model),
                                        jnp.float32)}


@pytest.mark.parametrize("name", list_archs())
def test_arch_smoke_forward_and_train_step(name):
    """Assignment requirement: reduced config, one forward + one train step
    on CPU, asserting shapes and no NaNs."""
    from repro.train.optim import OptimConfig, init_opt_state
    from repro.train.train_step import TrainConfig, make_train_step
    cfg = get_config(name).smoke()
    B, T = 2, 16
    params = init_from_spec(model_spec(cfg), KEY)
    inputs = _inputs(cfg, B, T)
    logits, _, aux = forward(params, cfg, inputs, mode="train")
    assert logits.shape == (B, T, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # one train step
    batch = dict(inputs)
    batch["labels"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    tcfg = TrainConfig(optim=OptimConfig(lr=1e-3, warmup_steps=1,
                                         total_steps=10))
    step = make_train_step(cfg, tcfg)
    state = {"params": params, "opt": init_opt_state(params)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("name", ["llama3_2_3b", "xlstm_350m",
                                  "jamba_v0_1_52b", "deepseek_v2_lite_16b"])
def test_decode_matches_teacher_forcing(name):
    """Prefix-decode consistency: decoding token-by-token from an empty
    cache reproduces the train-mode logits (same prefix)."""
    cfg = get_config(name).smoke()
    B, T = 1, 8
    params = init_from_spec(model_spec(cfg), KEY)
    inputs = _inputs(cfg, B, T)
    full_logits, _, _ = forward(params, cfg, inputs, mode="train")

    cache = init_cache(cfg, B, T + 2, jnp.float32)
    cache_len = jnp.zeros((B,), jnp.int32)
    outs = []
    for t in range(T):
        step_in = ({"tokens": inputs["tokens"][:, t:t + 1]}
                   if cfg.modality == "text"
                   else {"embeds": inputs["embeds"][:, t:t + 1]})
        lg, cache, _ = forward(params, cfg, step_in, mode="decode",
                               cache=cache, cache_len=cache_len)
        cache_len = cache_len + 1
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-3)


def test_chunked_attention_matches_dense():
    rng = np.random.default_rng(0)
    B, T, H, Hkv, hd = 2, 64, 4, 2, 16
    cfg = A.AttnConfig(d_model=64, n_heads=H, n_kv_heads=Hkv, head_dim=hd,
                       kv_chunk=16, attn_impl="chunked")
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, hd)), jnp.float32)
    scale = hd ** -0.5
    dense = A._sdpa(q, k, v, A._causal_mask(T, T, 0, None)[None], scale)
    chunked = A._chunked_sdpa(q, k, v, scale, None, 16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)


def test_chunked_attention_sliding_window():
    rng = np.random.default_rng(1)
    B, T, H, hd, W = 1, 64, 2, 8, 24
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    scale = hd ** -0.5
    dense = A._sdpa(q, k, v, A._causal_mask(T, T, 0, W)[None], scale)
    chunked = A._chunked_sdpa(q, k, v, scale, W, 16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)


def test_mamba_chunked_equals_stepwise():
    """The chunked associative-scan path must equal step-by-step decode."""
    from repro.models.ssm import MambaConfig, mamba_forward, mamba_spec
    cfg = MambaConfig(d_model=16, d_state=4, chunk=8)
    spec = mamba_spec(cfg, "m")
    params = init_from_spec(spec, KEY)["m"]
    rng = np.random.default_rng(0)
    B, L = 2, 32
    x = jnp.asarray(rng.normal(size=(B, L, 16)) * 0.3, jnp.float32)
    full, _ = mamba_forward(params, cfg, x)
    # stepwise with cache
    cache = (jnp.zeros((B, cfg.d_conv - 1, cfg.d_inner)),
             jnp.zeros((B, cfg.d_inner, cfg.d_state)))
    outs = []
    for t in range(L):
        o, cache = mamba_forward(params, cfg, x[:, t:t + 1], cache)
        outs.append(o[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-3, atol=1e-4)


def test_mlstm_chunked_equals_stepwise():
    from repro.models.xlstm import XLSTMConfig, mlstm_forward, mlstm_spec
    cfg = XLSTMConfig(d_model=16, n_heads=2, chunk=8)
    spec = mlstm_spec(cfg, "m")
    params = init_from_spec(spec, KEY)["m"]
    rng = np.random.default_rng(0)
    B, L = 2, 32
    x = jnp.asarray(rng.normal(size=(B, L, 16)) * 0.3, jnp.float32)
    full, _ = mlstm_forward(params, cfg, x)
    cache = (jnp.zeros((B, 2, cfg.head_dim, cfg.head_dim)),
             jnp.zeros((B, 2, cfg.head_dim)))
    outs = []
    for t in range(L):
        o, cache = mlstm_forward(params, cfg, x[:, t:t + 1], cache)
        outs.append(o[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-3, atol=1e-4)


def test_moe_routes_all_tokens(rng):
    from repro.models.moe import MoEConfig, moe_forward, moe_spec
    cfg = MoEConfig(d_model=16, n_routed=8, n_shared=1, top_k=2,
                    d_ff_expert=32, capacity_factor=8.0)  # no drops
    params = init_from_spec(moe_spec(cfg, "m"), KEY)["m"]
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    out, aux = moe_forward(params, cfg, x)
    assert out.shape == x.shape
    assert float(aux) > 0
    assert not bool(jnp.any(jnp.isnan(out)))


def test_param_counts_match_published():
    expect = {"deepseek_moe_16b": 16.4e9, "mistral_nemo_12b": 12.2e9,
              "jamba_v0_1_52b": 52e9, "xlstm_350m": 0.35e9}
    for name, target in expect.items():
        total, _ = get_config(name).param_count()
        assert abs(total - target) / target < 0.12, (name, total)
