import numpy as np
import pytest

from repro.core.context import LaFPContext, pop_session, push_session


@pytest.fixture(autouse=True)
def fresh_context():
    """Each test runs inside its own pushed session — the one place test
    isolation happens (no scattered get_context().reset() calls).  The
    process-global plan cache is cleared for the same reason: a warm hit
    from another test's same-shaped plan would skip the optimization a
    test means to observe."""
    from repro.core.planner.plancache import default_plan_cache
    default_plan_cache().clear()
    ctx = push_session(LaFPContext(name="test"))
    yield ctx
    pop_session()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_taxi_arrays(rng, n=20_000):
    """Taxi-like frame used across tests (paper's running example)."""
    return {
        "fare_amount": rng.uniform(-5, 100, n),
        "passenger_count": rng.integers(0, 7, n).astype(np.int64),
        "pickup_datetime": rng.integers(1_600_000_000, 1_610_000_000, n),
        "trip_miles": rng.uniform(0, 30, n),
        "unused_a": rng.uniform(0, 1, n),
        "unused_b": rng.integers(0, 9, n).astype(np.int64),
    }


@pytest.fixture
def taxi_arrays(rng):
    return make_taxi_arrays(rng)
