"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py),
running the Pallas kernels in interpret mode (TPU-target BlockSpecs)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.filter_compact import filter_compact
from repro.kernels.groupby_sum import groupby_sum
from repro.kernels.zonemap import zonemap


@pytest.mark.parametrize("n", [17, 256, 1000, 4096])
@pytest.mark.parametrize("g", [1, 7, 100])
@pytest.mark.parametrize("vdim", [0, 1, 5])
def test_groupby_sum_sweep(rng, n, g, vdim):
    codes = rng.integers(0, g, n).astype(np.int32)
    if vdim == 0:
        vals = rng.normal(size=n).astype(np.float32)
    else:
        vals = rng.normal(size=(n, vdim)).astype(np.float32)
    got = groupby_sum(jnp.asarray(codes), jnp.asarray(vals), g,
                      block_rows=256)
    want = ref.groupby_sum_ref(jnp.asarray(codes), jnp.asarray(vals), g)
    # blocked vs flat accumulation order → f32 rounding differences
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [np.int32, np.int64, np.float32])
def test_groupby_sum_dtypes(rng, dtype):
    codes = rng.integers(0, 9, 500).astype(np.int32)
    vals = rng.integers(0, 100, 500).astype(dtype) if dtype != np.float32 \
        else rng.normal(size=500).astype(dtype)
    got = groupby_sum(jnp.asarray(codes), jnp.asarray(vals), 9)
    want = ref.groupby_sum_ref(jnp.asarray(codes),
                               jnp.asarray(vals).astype(jnp.float32), 9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-3)


def test_groupby_sum_out_of_range_codes(rng):
    codes = np.array([0, 5, 99, 2, -1, 5], np.int32)   # 99/-1 out of range
    vals = np.ones(6, np.float32)
    got = np.asarray(groupby_sum(jnp.asarray(codes), jnp.asarray(vals), 6))
    assert got.sum() == 4.0          # only in-range rows contribute
    assert got[5] == 2.0


@pytest.mark.parametrize("n", [1, 63, 512, 1537, 8192])
@pytest.mark.parametrize("p", [0.0, 0.3, 1.0])
def test_filter_compact_sweep(rng, n, p):
    vals = rng.normal(size=n).astype(np.float32)
    mask = rng.random(n) < p
    got, cnt = filter_compact(jnp.asarray(vals), jnp.asarray(mask),
                              block_rows=128)
    want, wcnt = ref.filter_compact_ref(jnp.asarray(vals), jnp.asarray(mask))
    assert int(cnt) == int(wcnt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("n,block", [(100, 64), (4096, 512), (10000, 1024)])
def test_zonemap_sweep(rng, n, block):
    vals = rng.normal(size=n).astype(np.float32)
    mn, mx = zonemap(jnp.asarray(vals), block_rows=block)
    rmn, rmx = ref.zonemap_ref(jnp.asarray(vals), block)
    np.testing.assert_allclose(np.asarray(mn), np.asarray(rmn))
    np.testing.assert_allclose(np.asarray(mx), np.asarray(rmx))


def test_chunked_compaction_large(rng):
    vals = rng.normal(size=100_000).astype(np.float32)
    mask = rng.random(100_000) < 0.2
    got, cnt = ops.filter_compact_chunked(
        jnp.asarray(vals), jnp.asarray(mask), chunk=1 << 14,
        cfg=ops.KernelConfig(impl="pallas"))
    assert int(cnt) == int(mask.sum())
    np.testing.assert_allclose(np.asarray(got)[: int(cnt)], vals[mask],
                               rtol=1e-6)


def test_kernel_config_dispatch():
    cfg_x = ops.KernelConfig(impl="xla")
    cfg_p = ops.KernelConfig(impl="pallas")
    codes = jnp.asarray(np.array([0, 1, 1], np.int32))
    vals = jnp.asarray(np.array([1.0, 2.0, 3.0], np.float32))
    x = np.asarray(ops.groupby_sum(codes, vals, 2, cfg_x))
    p = np.asarray(ops.groupby_sum(codes, vals, 2, cfg_p))
    np.testing.assert_allclose(x, p, rtol=1e-6)
    assert ops.KernelConfig(impl="auto").resolved() == "xla"  # CPU host
