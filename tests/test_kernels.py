"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py),
running the Pallas kernels in interpret mode (TPU-target BlockSpecs)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.filter_compact import filter_compact
from repro.kernels.groupby_sum import groupby_sum
from repro.kernels.zonemap import zonemap


@pytest.mark.parametrize("n", [17, 256, 1000, 4096])
@pytest.mark.parametrize("g", [1, 7, 100])
@pytest.mark.parametrize("vdim", [0, 1, 5])
def test_groupby_sum_sweep(rng, n, g, vdim):
    codes = rng.integers(0, g, n).astype(np.int32)
    if vdim == 0:
        vals = rng.normal(size=n).astype(np.float32)
    else:
        vals = rng.normal(size=(n, vdim)).astype(np.float32)
    got = groupby_sum(jnp.asarray(codes), jnp.asarray(vals), g,
                      block_rows=256)
    want = ref.groupby_sum_ref(jnp.asarray(codes), jnp.asarray(vals), g)
    # blocked vs flat accumulation order → f32 rounding differences
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [np.int32, np.int64, np.float32])
def test_groupby_sum_dtypes(rng, dtype):
    codes = rng.integers(0, 9, 500).astype(np.int32)
    vals = rng.integers(0, 100, 500).astype(dtype) if dtype != np.float32 \
        else rng.normal(size=500).astype(dtype)
    got = groupby_sum(jnp.asarray(codes), jnp.asarray(vals), 9)
    want = ref.groupby_sum_ref(jnp.asarray(codes),
                               jnp.asarray(vals).astype(jnp.float32), 9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-3)


def test_groupby_sum_out_of_range_codes(rng):
    codes = np.array([0, 5, 99, 2, -1, 5], np.int32)   # 99/-1 out of range
    vals = np.ones(6, np.float32)
    got = np.asarray(groupby_sum(jnp.asarray(codes), jnp.asarray(vals), 6))
    assert got.sum() == 4.0          # only in-range rows contribute
    assert got[5] == 2.0


@pytest.mark.parametrize("n", [1, 63, 512, 1537, 8192])
@pytest.mark.parametrize("p", [0.0, 0.3, 1.0])
def test_filter_compact_sweep(rng, n, p):
    vals = rng.normal(size=n).astype(np.float32)
    mask = rng.random(n) < p
    got, cnt = filter_compact(jnp.asarray(vals), jnp.asarray(mask),
                              block_rows=128)
    want, wcnt = ref.filter_compact_ref(jnp.asarray(vals), jnp.asarray(mask))
    assert int(cnt) == int(wcnt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("n,block", [(100, 64), (4096, 512), (10000, 1024)])
def test_zonemap_sweep(rng, n, block):
    vals = rng.normal(size=n).astype(np.float32)
    mn, mx = zonemap(jnp.asarray(vals), block_rows=block)
    rmn, rmx = ref.zonemap_ref(jnp.asarray(vals), block)
    np.testing.assert_allclose(np.asarray(mn), np.asarray(rmn))
    np.testing.assert_allclose(np.asarray(mx), np.asarray(rmx))


def test_chunked_compaction_large(rng):
    vals = rng.normal(size=100_000).astype(np.float32)
    mask = rng.random(100_000) < 0.2
    got, cnt = ops.filter_compact_chunked(
        jnp.asarray(vals), jnp.asarray(mask), chunk=1 << 14,
        cfg=ops.KernelConfig(impl="pallas"))
    assert int(cnt) == int(mask.sum())
    np.testing.assert_allclose(np.asarray(got)[: int(cnt)], vals[mask],
                               rtol=1e-6)


def test_kernel_config_dispatch():
    cfg_x = ops.KernelConfig(impl="xla")
    cfg_p = ops.KernelConfig(impl="pallas")
    codes = jnp.asarray(np.array([0, 1, 1], np.int32))
    vals = jnp.asarray(np.array([1.0, 2.0, 3.0], np.float32))
    x = np.asarray(ops.groupby_sum(codes, vals, 2, cfg_x))
    p = np.asarray(ops.groupby_sum(codes, vals, 2, cfg_p))
    np.testing.assert_allclose(x, p, rtol=1e-6)
    assert ops.KernelConfig(impl="auto").resolved() == "xla"  # CPU host


# ---------------------------------------------------------------------------
# Boundary shapes, differential against ref.py on both impls — the fused
# physical path leans on these exact edges (empty partitions, filters that
# kill every row, row counts that don't fill a block, NaN-bearing columns).

_IMPLS = [ops.KernelConfig(impl="xla"), ops.KernelConfig(impl="pallas")]
_IMPL_IDS = ["xla", "pallas"]


@pytest.mark.parametrize("cfg", _IMPLS, ids=_IMPL_IDS)
def test_filter_compact_empty_input(cfg):
    vals = jnp.zeros((0,), jnp.float32)
    mask = jnp.zeros((0,), bool)
    got, cnt = ops.filter_compact(vals, mask, cfg)
    want, wcnt = ref.filter_compact_ref(vals, mask)
    assert int(cnt) == int(wcnt) == 0
    assert got.shape == want.shape == (0,)


@pytest.mark.parametrize("cfg", _IMPLS, ids=_IMPL_IDS)
@pytest.mark.parametrize("n", [1, 127, 1000])
def test_filter_compact_all_false_mask(rng, cfg, n):
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    mask = jnp.zeros((n,), bool)
    got, cnt = ops.filter_compact(vals, mask, cfg)
    want, wcnt = ref.filter_compact_ref(vals, mask)
    assert int(cnt) == int(wcnt) == 0
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("cfg", _IMPLS, ids=_IMPL_IDS)
@pytest.mark.parametrize("n", [1, 65, 129, 1023])   # never a block multiple
def test_filter_compact_non_block_multiple(rng, cfg, n):
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    mask = jnp.asarray(rng.random(n) < 0.5)
    got, cnt = ops.filter_compact(vals, mask, cfg)
    want, wcnt = ref.filter_compact_ref(vals, mask)
    assert int(cnt) == int(wcnt)
    np.testing.assert_allclose(np.asarray(got)[: int(cnt)],
                               np.asarray(want)[: int(wcnt)], rtol=1e-6)


@pytest.mark.parametrize("cfg", _IMPLS, ids=_IMPL_IDS)
def test_filter_compact_nan_values_survive(rng, cfg):
    vals = rng.normal(size=257).astype(np.float32)
    vals[::5] = np.nan
    mask = rng.random(257) < 0.4
    got, cnt = ops.filter_compact(jnp.asarray(vals), jnp.asarray(mask), cfg)
    packed = np.asarray(got)[: int(cnt)]
    expect = vals[mask]
    assert int(cnt) == int(mask.sum())
    np.testing.assert_array_equal(np.isnan(packed), np.isnan(expect))
    np.testing.assert_allclose(packed[~np.isnan(expect)],
                               expect[~np.isnan(expect)], rtol=1e-6)


@pytest.mark.parametrize("cfg", _IMPLS, ids=_IMPL_IDS)
def test_groupby_sum_empty_input(cfg):
    got = ops.groupby_sum(jnp.zeros((0,), jnp.int32),
                          jnp.zeros((0,), jnp.float32), 4, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(4, np.float32))


@pytest.mark.parametrize("cfg", _IMPLS, ids=_IMPL_IDS)
@pytest.mark.parametrize("n", [1, 130, 999])
def test_groupby_sum_non_block_multiple(rng, cfg, n):
    codes = jnp.asarray(rng.integers(0, 5, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    got = ops.groupby_sum(codes, vals, 5, cfg)
    want = ref.groupby_sum_ref(codes, vals, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=1e-3)


@pytest.mark.parametrize("cfg", _IMPLS, ids=_IMPL_IDS)
def test_zonemap_empty_input(cfg):
    mn, mx = ops.zonemap(jnp.zeros((0,), jnp.float32), 64, cfg)
    assert mn.shape == mx.shape == (0,)


@pytest.mark.parametrize("cfg", _IMPLS, ids=_IMPL_IDS)
@pytest.mark.parametrize("n", [1, 63, 4097])
def test_zonemap_non_block_multiple(rng, cfg, n):
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    mn, mx = ops.zonemap(vals, 64, cfg)
    rmn, rmx = ref.zonemap_ref(vals, 64)
    np.testing.assert_allclose(np.asarray(mn), np.asarray(rmn))
    np.testing.assert_allclose(np.asarray(mx), np.asarray(rmx))
    # global reduction matches the raw column (partition-skip contract)
    assert np.asarray(mn).min() == np.asarray(vals).min()
    assert np.asarray(mx).max() == np.asarray(vals).max()
