"""Plan-rewrite engine tests: rule matching, safety guards, fixpoint
termination, differential equivalence (rewritten ≡ unrewritten), explain
records, and the pre-execution linter."""
from __future__ import annotations

import numpy as np
import pytest

import repro.pandas as rpd
from repro.core import get_context
from repro.core import graph as G
from repro.core.optimizer import optimize
from repro.core.rewrite import (DEFAULT_RULES, apply_rewrites,
                                default_rules)
from repro.lint import lint_source


def _frame(rng, n=500):
    return rpd.from_arrays({
        "a": rng.integers(0, 8, n).astype(np.float64),
        "b": rng.random(n),
        "c": rng.integers(0, 3, n).astype(np.float64),
    })


def _ops(roots):
    return [n.op for n in G.walk(roots)]


# ---------------------------------------------------------------------------
# Rule matching / guards


def test_sort_head_collapses_to_top_k(rng):
    df = _frame(rng)
    node = df.sort_values("b").head(7)._node
    roots, _, events = apply_rewrites([node])
    ops = _ops(roots)
    assert "top_k" in ops and "sort_values" not in ops and "head" not in ops
    (ev,) = events
    assert ev.rule == "sort_head_to_top_k"
    top = next(n for n in G.walk(roots) if n.op == "top_k")
    assert top.n == 7 and top.by == ("b",) and top.mode == "sort"


def test_nlargest_lowers_to_top_k_directly(rng):
    # nlargest doesn't need the rewrite: the facade lowers it natively
    df = _frame(rng)
    node = df.nlargest(5, "b")._node
    assert node.op == "top_k" and node.mode == "select"


def test_dedup_reorders_before_ascending_sort(rng):
    df = _frame(rng)
    node = df.sort_values("a").drop_duplicates()._node
    roots, _, events = apply_rewrites([node])
    assert [ev.rule for ev in events] == ["dedup_before_sort"]
    root = roots[0]
    assert root.op == "sort_values" and root.inputs[0].op == "drop_duplicates"


@pytest.mark.parametrize("case", ("descending", "subset"))
def test_dedup_guard_blocks_unsafe_commutes(rng, case):
    df = _frame(rng)
    if case == "descending":
        node = df.sort_values("a", ascending=False).drop_duplicates()._node
    else:
        node = df.sort_values("a").drop_duplicates(subset=("a",))._node
    _, _, events = apply_rewrites([node])
    assert not [ev for ev in events if ev.rule == "dedup_before_sort"]


def test_multi_parent_sort_is_not_absorbed(rng):
    # the sorted frame is used twice: collapsing it into TopK would steal
    # the other consumer's input
    df = _frame(rng).sort_values("b")
    head = df.head(3)._node
    full = df._node                              # second consumer of the sort
    _, _, events = apply_rewrites([head, full])
    assert not events


def test_persist_mark_blocks_rewrite(rng):
    df = _frame(rng)
    node = df.sort_values("b").head(3)._node
    node.inputs[0].persist = True                 # planned reuse point
    _, _, events = apply_rewrites([node])
    assert not events


def test_filter_pushes_through_concat(rng):
    df = _frame(rng)
    cat = rpd.concat([df, df])
    node = cat[cat["a"] > 3]._node
    roots, _, events = apply_rewrites([node])
    assert [ev.rule for ev in events] == ["filter_through_concat"]
    root = roots[0]
    assert root.op == "concat"
    assert all(c.op == "filter" for c in root.inputs)


def test_map_rows_vectorizes_to_native_exprs(rng):
    df = _frame(rng)
    node = df.apply_rows(lambda t: {"a": t["a"], "s": t["a"] + 2 * t["b"]},
                         name="lin")._node
    roots, _, events = apply_rewrites([node])
    assert [ev.rule for ev in events] == ["map_rows_vectorize"]
    ops = _ops(roots)
    assert "map_rows" not in ops and "assign" in ops and "project" in ops


def test_map_rows_with_control_flow_stays_opaque(rng):
    df = _frame(rng)

    def udf(t):
        if t["a"] is not None and t["a"]:          # truthiness aborts trace
            return {"a": t["a"]}
        return {"a": t["b"]}

    node = df.apply_rows(udf)._node
    _, _, events = apply_rewrites([node])
    assert not events


def test_fixpoint_terminates_and_chains_rules(rng):
    # dedup-before-sort leaves a SortValues on top; a Head above it must
    # then collapse with *that* sort into TopK on the deduped input —
    # two different rules firing across fixpoint iterations
    df = _frame(rng)
    node = df.sort_values("a").drop_duplicates().head(4)._node
    roots, _, events = apply_rewrites([node])
    rules = sorted(ev.rule for ev in events)
    assert rules == ["dedup_before_sort", "sort_head_to_top_k"]
    ops = _ops(roots)
    assert ops.count("top_k") == 1 and "sort_values" not in ops


def test_default_rules_have_linter_metadata():
    assert default_rules() is DEFAULT_RULES
    for rule in DEFAULT_RULES:
        assert rule.name and rule.summary


# ---------------------------------------------------------------------------
# Differential equivalence: rewritten ≡ unrewritten


def _run_idioms(engine, rewrites, seed):
    with rpd.session(engine=engine, rewrites=rewrites) as ctx:
        ctx.print_fn = lambda *a: None
        rng = np.random.default_rng(seed)
        df = _frame(rng, n=1_000)
        outs = []
        outs.append(df.sort_values("b", ascending=False).head(13)
                    .to_numpy_table())
        outs.append(df.sort_values("b").head(2_000).to_numpy_table())  # k>rows
        outs.append(df.sort_values("a").drop_duplicates().to_numpy_table())
        outs.append(df.apply_rows(
            lambda t: {"b": t["a"], "a": t["b"], "z": t["a"] * t["c"] + 1})
            .to_numpy_table())                     # column-swapping UDF
        cat = rpd.concat([df, df.head(200)])
        outs.append(cat[cat["c"] >= 1].to_numpy_table())
        outs.append(df.nlargest(9, "b").to_numpy_table())
        outs.append(df.nsmallest(9, "b").to_numpy_table())
    return outs


@pytest.mark.parametrize("engine", ("eager", "streaming"))
def test_rewritten_plans_match_unrewritten(engine):
    for seed in (0, 1, 2):
        on = _run_idioms(engine, True, seed)
        off = _run_idioms(engine, False, seed)
        for i, (x, y) in enumerate(zip(on, off)):
            assert list(x) == list(y), f"idiom {i}: column mismatch"
            for k in x:
                np.testing.assert_array_equal(
                    np.asarray(x[k]), np.asarray(y[k]),
                    err_msg=f"idiom {i} col {k!r} (seed {seed})")


def test_session_rewrites_false_disables_pass(rng):
    with rpd.session(engine="eager", rewrites=False) as ctx:
        df = _frame(rng)
        node = df.sort_values("b").head(3)._node
        roots, _ = optimize([node], ctx)
        assert "top_k" not in _ops(roots)
        assert not getattr(ctx, "_pending_rewrites", None)
        assert not ctx.metrics.snapshot().get("rewrite.applied")


# ---------------------------------------------------------------------------
# Observability: trace, metric, explain records


def test_rewrite_emits_trace_metric_and_explain_record(rng):
    with rpd.session(engine="eager") as ctx:
        ctx.print_fn = lambda *a: None
        df = _frame(rng)
        _ = df.sort_values("b").head(3).to_numpy_table()
        assert ctx.metrics.snapshot().get("rewrite.applied") == 1
        kinds = [getattr(t, "kind", None) for t in ctx.optimizer_trace]
        assert "rewrite" in kinds
        rep = rpd.explain()
        recs = rep.runs[-1].rewrites
        assert len(recs) == 1
        (rec,) = recs
        assert rec.rule == "sort_head_to_top_k"
        assert rec.before_op == "head" and rec.after_op == "top_k"
        assert rec.cost_delta is not None and rec.cost_delta < 0
        assert "rewrite sort_head_to_top_k" in rep.render()
        # drained: a second report must not repeat the records
        assert not getattr(ctx, "_pending_rewrites", None)


def test_plan_only_explain_reports_rewrites(rng):
    with rpd.session(engine="eager") as ctx:
        ctx.print_fn = lambda *a: None
        df = _frame(rng)
        rep = rpd.explain(df.sort_values("b").head(3))
        assert rep.runs[0].rewrites
        assert rep.runs[0].rewrites[0].rule == "sort_head_to_top_k"


# ---------------------------------------------------------------------------
# Pre-execution linter


_LINT_PROGRAM = '''
import repro.pandas as pd
df = pd.read_csv("rides.csv")
top = df.sort_values("fare").head(10)
uniq = df.sort_values("fare").drop_duplicates()
skip = df.sort_values("fare", ascending=False).drop_duplicates()
big = df.nlargest(5, "fare")
dev = df["fare"].std()
boom = df.pivot_table(index="fare")
vec = df.apply_rows(lambda t: {"x": t["fare"] * 2})
'''


def test_linter_classifies_idioms_and_gaps():
    diags = lint_source(_LINT_PROGRAM)
    by_kind = {}
    for d in diags:
        by_kind.setdefault(d.kind, []).append(d)
    assert [d.line for d in by_kind["rewrite.top_k"]] == [4]
    assert [d.line for d in by_kind["rewrite.dedup_before_sort"]] == [5]
    assert [d.line for d in by_kind["native.top_k"]] == [7]
    assert [d.line for d in by_kind["fallback.materialize"]] == [8]
    assert [d.line for d in by_kind["fallback.failed"]] == [9]
    assert [d.line for d in by_kind["rewrite.vectorize"]] == [10]
    # the guarded-out descending dedup (line 6) must NOT be advertised
    assert 6 not in [d.line for d in diags]
    failed = by_kind["fallback.failed"][0]
    assert failed.level == "warn" and "pivot_table" in failed.message


def test_linter_cli_exit_codes(tmp_path):
    from repro.lint import main
    bad = tmp_path / "bad.py"
    bad.write_text(_LINT_PROGRAM)
    good = tmp_path / "good.py"
    good.write_text('import repro.pandas as pd\n'
                    'df = pd.read_csv("r.csv")\n'
                    'print(df.sort_values("a").head(3))\n')
    assert main([str(good)]) == 0
    assert main([str(bad)]) == 1           # fallback.failed → regression
    assert main([]) == 2


def test_analyze_attaches_diagnostics_and_explain_surfaces_them(tmp_path):
    prog = tmp_path / "prog.py"
    prog.write_text(
        "import numpy as np\n"
        "import repro.pandas as rpd\n"
        "from repro.core import get_context\n"
        "def run():\n"
        "    df = rpd.from_arrays({'a': np.arange(20.0)})\n"
        "    return df.sort_values('a').head(3)\n")
    import importlib.util
    spec = importlib.util.spec_from_file_location("lint_prog", prog)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    with rpd.session(engine="eager") as ctx:
        ctx.print_fn = lambda *a: None
        decorated = rpd.analyze(mod.run)
        _ = decorated()
        diags = ctx.analysis.get("diagnostics")
        assert diags and diags[0].kind == "rewrite.top_k"
        assert diags[0].line == 6          # absolute file line of the idiom
        rep = rpd.explain()
        assert rep.diagnostics and rep.diagnostics[0].kind == "rewrite.top_k"
        assert "[rewrite.top_k]" in rep.render()
