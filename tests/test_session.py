"""Session-scoped context tests: nesting, isolation of persist caches /
sinks / stats stores / traces, thread safety, and sink flushing on exit."""
import threading

import numpy as np
import pytest

import repro.pandas as pd
from repro.core import BackendEngines, get_context
from repro.core.context import (LaFPContext, pop_session, push_session,
                                session_depth)


def test_get_context_returns_stack_top():
    outer = get_context()
    with pd.session() as inner:
        assert get_context() is inner
        assert inner is not outer
    assert get_context() is outer


def test_nested_sessions_isolate_backend_and_budget():
    with pd.session(engine="streaming", memory_budget=123):
        assert get_context().backend == "streaming"
        assert get_context().memory_budget == 123
        with pd.session(engine="distributed"):
            assert get_context().backend == "distributed"
            assert get_context().memory_budget is None
        assert get_context().backend == "streaming"
    assert get_context().backend == "eager"


def test_session_backend_kwarg_is_deprecated_but_works():
    with pytest.warns(DeprecationWarning):
        with pd.session(backend=BackendEngines.STREAMING):
            assert get_context().backend == "streaming"
    with pytest.raises(TypeError):
        with pd.session(engine="eager", backend="streaming"):
            pass


def test_session_engine_allowlist_restricts_auto_candidates():
    from repro.core.planner.select import candidate_engines
    with pd.session(engine="auto", engines=("eager", "streaming")) as ctx:
        assert candidate_engines(ctx) == ("eager", "streaming")
    with pd.session(engine="auto") as ctx:
        cands = candidate_engines(ctx)
        assert "eager" in cands and "streaming" in cands \
            and "distributed" in cands


def test_nested_sessions_do_not_share_persist_or_sinks_or_stats(rng):
    arrays = {"x": rng.uniform(0, 1, 1000), "k": rng.integers(0, 5, 1000)}
    with pd.session() as outer:
        df = pd.from_arrays(arrays)
        df.compute()
        outer_cache_keys = set(outer.persist_cache)
        outer_stats = outer.stats_store
        outer.print_fn = lambda *a: None
        from repro.core.func import print as lazy_print
        lazy_print(df.head())               # pending sink in outer
        assert outer.pending_sinks
        with pd.session() as inner:
            assert inner.persist_cache == {}
            assert inner.pending_sinks == []
            assert inner.stats_store is not outer_stats
            inner.print_fn = lambda *a: None
            df2 = pd.from_arrays(arrays)
            df2[df2["x"] > 0.5].compute()
            assert set(outer.persist_cache) == outer_cache_keys
        # inner popped; outer sink still pending and flushable
        assert get_context() is outer
        assert outer.pending_sinks


def test_session_flushes_pending_sinks_on_clean_exit(rng):
    lines = []
    with pd.session() as ctx:
        ctx.print_fn = lambda *a: lines.append(a)
        from repro.core.func import print as lazy_print
        df = pd.from_arrays({"x": np.arange(10.0)})
        lazy_print(df.head(3))
        assert not lines                    # still lazy inside the block
    assert lines                            # flushed at session exit


def test_session_exception_pops_without_flush(rng):
    lines = []
    with pytest.raises(RuntimeError):
        with pd.session() as ctx:
            ctx.print_fn = lambda *a: lines.append(a)
            from repro.core.func import print as lazy_print
            lazy_print(pd.from_arrays({"x": np.arange(4.0)}))
            raise RuntimeError("boom")
    assert not lines


def test_fallback_trace_is_session_scoped(rng):
    df = pd.from_arrays({"x": rng.uniform(0, 1, 100)})
    with pd.session():
        pd.from_arrays({"x": rng.uniform(0, 1, 100)})["x"].std()
        assert any(e.op == "Series.std"
                   for e in get_context().fallback_trace)
    assert not any(e.op == "Series.std"
                   for e in get_context().fallback_trace)


def test_push_pop_explicit():
    depth = session_depth()
    ctx = push_session(LaFPContext(name="manual"))
    assert get_context() is ctx
    assert session_depth() == depth + 1
    assert pop_session() is ctx
    assert session_depth() == depth


def test_thread_safety_smoke(rng):
    """Each thread's session stack is private: concurrent sessions with
    different backends never observe each other's state."""
    errors = []

    def worker(backend, n):
        try:
            for _ in range(n):
                with pd.session(engine=backend) as ctx:
                    assert get_context() is ctx
                    assert get_context().backend == backend
                    df = pd.from_arrays({"x": np.arange(50.0)})
                    res = df[df["x"] > 10].compute()
                    assert res.rows() == 39
                    assert get_context() is ctx
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(b, 5))
               for b in ("eager", "streaming")
               for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors


def test_default_session_still_works_for_module_scripts():
    from repro.core.context import default_context
    base = default_context()
    assert base.session_name == "default"
    # the test fixture pushed a session, so the default is shadowed
    assert get_context() is not base


def test_concurrent_sessions_profile_isolation(rng):
    """Telemetry is session-scoped: two threads profiling their own
    sessions each collect only their own spans and counters — no
    cross-talk through the module-global tracing gate."""
    from repro.obs import profile

    results = {}
    errors = []
    barrier = threading.Barrier(2)

    def worker(name, n_rows):
        try:
            with pd.session(engine="auto", name=name) as ctx:
                barrier.wait(timeout=10)
                with profile() as prof:
                    for _ in range(3):
                        df = pd.from_arrays(
                            {"x": np.arange(float(n_rows)),
                             "tag": np.full(n_rows, hash(name) % 97)})
                        res = df[df["x"] > 1].compute()
                        assert res.rows() == n_rows - 2
                results[name] = (prof, ctx)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=("prof-a", 64)),
               threading.Thread(target=worker, args=("prof-b", 128))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors

    for name, n_rows in (("prof-a", 64), ("prof-b", 128)):
        prof, ctx = results[name]
        # every span was produced on this session's own thread
        assert prof.session == name
        execs = prof.find("execute")
        assert len(execs) == 3
        tids = {s.thread_id for s in prof.spans}
        assert len(tids) == 1
        # operator row counts reflect THIS session's data, not the other's
        for s in prof.find("operator", op="filter"):
            assert s.attrs.get("rows_in") == n_rows
        # counters are per-session: each profiled block recorded its own
        # calibration samples, not the union of both threads' work
        assert prof.counters.get("calibration.runtime_samples", 0) >= 1
    a_spans = {s.id for s in results["prof-a"][0].spans}
    b_spans = {s.id for s in results["prof-b"][0].spans}
    assert not a_spans & b_spans
