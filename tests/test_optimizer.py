"""Unit tests for the task-graph optimizer rules (paper §3)."""
import numpy as np

import repro.core as core
from repro.core import expr as E
from repro.core import graph as G
from repro.core import get_context
from repro.core.optimizer import (column_selection, cse, optimize,
                                  push_filters, zone_map_pruning)


def _scan(arrays, partition_rows=1000):
    src = core.InMemorySource(arrays, partition_rows)
    return G.Scan(src)


def _walk_ops(roots):
    return [n.op for n in G.walk(roots)]


def test_filter_pushdown_below_assign(taxi_arrays):
    s = _scan(taxi_arrays)
    a = G.Assign(s, "day", E.BinOp("mod", E.Col("pickup_datetime"),
                                   E.Lit(7)))
    f = G.Filter(a, E.BinOp("gt", E.Col("fare_amount"), E.Lit(0)))
    roots, _ = push_filters([f])
    ops = _walk_ops(roots)
    # filter now sits directly on the scan, assign on top
    assert ops == ["scan", "filter", "assign"]


def test_filter_not_pushed_when_uses_assigned_col(taxi_arrays):
    s = _scan(taxi_arrays)
    a = G.Assign(s, "day", E.BinOp("mod", E.Col("pickup_datetime"), E.Lit(7)))
    f = G.Filter(a, E.BinOp("eq", E.Col("day"), E.Lit(3)))
    roots, _ = push_filters([f])
    assert _walk_ops(roots) == ["scan", "assign", "filter"]


def test_filter_fusion(taxi_arrays):
    s = _scan(taxi_arrays)
    f1 = G.Filter(s, E.BinOp("gt", E.Col("fare_amount"), E.Lit(0)))
    f2 = G.Filter(f1, E.BinOp("lt", E.Col("fare_amount"), E.Lit(50)))
    roots, _ = push_filters([f2])
    ops = _walk_ops(roots)
    assert ops.count("filter") == 1
    pred = roots[0].predicate
    assert isinstance(pred, E.BinOp) and pred.op == "and"


def test_filter_not_pushed_below_groupby(taxi_arrays):
    s = _scan(taxi_arrays)
    g = G.GroupByAgg(s, ["passenger_count"], {"fare": ("fare_amount", "mean")})
    f = G.Filter(g, E.BinOp("gt", E.Col("fare"), E.Lit(10)))
    roots, _ = push_filters([f])
    assert _walk_ops(roots) == ["scan", "groupby_agg", "filter"]


def test_filter_pushed_into_join_left(taxi_arrays, rng):
    left = _scan(taxi_arrays)
    right = _scan({"passenger_count": np.arange(7),
                   "weight": rng.normal(size=7)})
    j = G.Join(left, right, ["passenger_count"])
    f = G.Filter(j, E.BinOp("gt", E.Col("fare_amount"), E.Lit(0)))
    roots, _ = push_filters([f])
    ops = _walk_ops(roots)
    assert ops[-1] == "join"            # filter no longer on top
    assert "filter" in ops


def test_cse_merges_identical_subgraphs(taxi_arrays):
    s1 = _scan(taxi_arrays)
    # two structurally identical filters over the same source object
    src = s1.source
    a = G.Filter(G.Scan(src), E.BinOp("gt", E.Col("fare_amount"), E.Lit(0)))
    b = G.Filter(G.Scan(src), E.BinOp("gt", E.Col("fare_amount"), E.Lit(0)))
    r1 = G.Reduce(a, "fare_amount", "sum")
    r2 = G.Reduce(b, "fare_amount", "mean")
    roots, _ = cse([r1, r2])
    nodes = G.walk(roots)
    assert sum(1 for n in nodes if n.op == "filter") == 1
    assert sum(1 for n in nodes if n.op == "scan") == 1


def test_column_selection_narrows_scan(taxi_arrays):
    s = _scan(taxi_arrays)
    f = G.Filter(s, E.BinOp("gt", E.Col("fare_amount"), E.Lit(0)))
    g = G.GroupByAgg(f, ["passenger_count"], {"n": (None, "count")})
    roots, _ = column_selection([g], get_context())
    scan = [n for n in G.walk(roots) if n.op == "scan"][0]
    assert set(scan.columns) == {"fare_amount", "passenger_count"}


def test_dead_assign_elimination(taxi_arrays):
    s = _scan(taxi_arrays)
    a = G.Assign(s, "temp", E.BinOp("mul", E.Col("trip_miles"), E.Lit(2.0)))
    r = G.Reduce(a, "fare_amount", "mean")
    roots, _ = column_selection([r], get_context())
    assert "assign" not in _walk_ops(roots)


def test_zone_map_pruning_sorted_column(rng):
    # sorted column → zone maps are disjoint → most partitions pruned
    n = 10_000
    arrays = {"ts": np.arange(n), "v": rng.normal(size=n)}
    s = _scan(arrays, partition_rows=1000)
    f = G.Filter(s, E.BinOp("ge", E.Col("ts"), E.Lit(9000)))
    roots, _ = zone_map_pruning([f])
    scan = [n_ for n_ in G.walk(roots) if n_.op == "scan"][0]
    assert len(scan.skip_partitions) == 9


def test_zone_map_prune_respects_modified_columns(rng):
    n = 5000
    arrays = {"ts": np.arange(n), "v": rng.normal(size=n)}
    s = _scan(arrays, partition_rows=1000)
    # ts is overwritten before the filter → its zone map must NOT be used
    a = G.Assign(s, "ts", E.BinOp("sub", E.Lit(5000), E.Col("ts")))
    f = G.Filter(a, E.BinOp("ge", E.Col("ts"), E.Lit(4500)))
    roots, _ = zone_map_pruning([f])
    scan = [n_ for n_ in G.walk(roots) if n_.op == "scan"][0]
    assert len(scan.skip_partitions) == 0


def test_optimized_equals_unoptimized(taxi_arrays):
    ctx = get_context()
    df = core.from_arrays(taxi_arrays, partition_rows=2000)
    df = df[df["fare_amount"] > 10]
    df["x2"] = df["trip_miles"] * 2.0
    agg = df.groupby(["passenger_count"])["x2"].mean()
    node = agg._node
    from repro.core.backends import get_backend
    from repro.core import BackendEngines
    be = get_backend(BackendEngines.EAGER)
    plain_roots, _ = optimize([node], ctx, enable=())   # no rules
    opt_roots, _ = optimize([node], ctx)
    plain = be.execute(plain_roots, ctx)
    opt = be.execute(opt_roots, ctx)
    # node ids differ; compare values
    pv = list(plain.values())[0]
    ov = list(opt.values())[0]
    for k in pv:
        np.testing.assert_allclose(np.asarray(pv[k]), np.asarray(ov[k]),
                                   rtol=1e-6)
