"""Telemetry subsystem (repro.obs): span trees from pd.profile(), the
no-op fast path, counters, bounded trace logs, structured planner events,
Chrome-trace/JSONL export, and the explain() span linkage."""
import json

import numpy as np
import pytest

import repro.pandas as pd
from repro.core import get_context
from repro.obs import (NOOP_SPAN, PlannerEvent, Profile, TraceLog, Tracer,
                       profile, tracing_active, validate_chrome_trace)


def _corpus_program():
    """api_corpus-style plain-pandas program: filter → assign → groupby,
    a join, and a fallback op."""
    df = pd.from_arrays({"fare": np.arange(200.0),
                         "vendor": np.arange(200) % 5})
    df = df[df["fare"] > 10.0]
    df["tip"] = df["fare"] * 0.2
    by_vendor = df.groupby("vendor")["tip"].sum().compute()
    std = df["fare"].std()                          # measured fallback
    return by_vendor, std


# ---------------------------------------------------------------------------
# The acceptance scenario: profile a program, get the full span tree.


def test_profile_span_tree_covers_plan_segments_operators():
    with pd.session(engine="auto", name="tree"):
        with profile() as prof:
            _corpus_program()
    names = prof.span_names()
    assert {"execute", "plan", "segment", "operator"} <= names
    # every executed segment span has a nonzero duration and an engine attr
    segs = prof.find("segment")
    assert segs
    for s in segs:
        assert s.duration > 0
        assert s.attrs.get("engine")
    # the leading filter is pushed into the scan (scan_pushdown), so the
    # rowwise chain reduces to the single assign; the pushdown row
    # accounting replaces the old fused-operator row attrs
    ops = {s.attrs.get("op") for s in prof.find("operator")}
    assert "assign" in ops and "groupby_agg" in ops
    assert prof.counters.get("io.pushdown_rows_in", 0) >= 200
    assert prof.counters.get("io.pushdown_rows_out", 0) >= 189
    assert prof.counters.get("io.pushdown_rows_out", 0) < \
        prof.counters.get("io.pushdown_rows_in", 0)
    # spans nest: plan and segment are children of an execute span
    exec_ids = {s.id for s in prof.find("execute")}
    assert all(s.parent_id in exec_ids for s in prof.find("plan"))
    assert all(s.parent_id in exec_ids for s in segs)
    # the fallback op surfaced as both an event span and a counter
    assert prof.find("fallback")
    assert prof.counters.get("fallback.served", 0) >= 1
    assert prof.counters.get("calibration.runtime_samples", 0) >= 1


def test_profile_render_is_indented_tree_with_counters():
    with pd.session(engine="auto", name="rendered"):
        with profile() as prof:
            _corpus_program()
    text = prof.render()
    assert text.splitlines()[0].startswith("profile session=rendered")
    assert "  execute " in text
    assert "    segment " in text            # child of execute: deeper indent
    assert "op=assign" in text               # the filter was pushed into the scan
    assert "counters:" in text


def test_explain_segments_link_to_measured_spans():
    with pd.session(engine="auto", name="linked"):
        with profile() as prof:
            _corpus_program()
        report = pd.explain()
    span_ids = {s.id for s in prof.find("segment")}
    executed = [seg for run in report.runs for seg in run.segments]
    assert executed
    assert all(seg.span_id in span_ids for seg in executed)
    assert any(f"span=#{seg.span_id}" in report.render() for seg in executed)
    # plan-only explain has no measured spans to link
    df = pd.from_arrays({"x": np.arange(8.0)})
    plan_only = pd.explain(df[df["x"] > 3])
    assert all(seg.span_id is None
               for run in plan_only.runs for seg in run.segments)


# ---------------------------------------------------------------------------
# No-op fast path.


def test_tracing_disabled_by_default_and_spans_are_noop():
    ctx = get_context()
    assert not tracing_active()
    assert ctx.tracer.span("anything") is NOOP_SPAN
    assert not NOOP_SPAN                    # falsy: cheap "if sp:" guards
    with profile():
        assert tracing_active()
        assert ctx.tracer.span("real") is not NOOP_SPAN
        ctx.tracer.span("real").finish()
    assert not tracing_active()
    assert ctx.tracer.span("after") is NOOP_SPAN


def test_traced_op_passes_through_untouched_when_disabled():
    from repro.core import physical as X
    assert not tracing_active()
    table = {"v": np.arange(10.0)}
    out = X.apply_head(table, 3)
    assert len(out["v"]) == 3
    # the original is preserved for the uninstrumented benchmark baseline
    assert X.apply_head.__wrapped__ is not X.apply_head
    np.testing.assert_array_equal(
        X.apply_head.__wrapped__(table, 3)["v"], out["v"])


def test_timed_span_is_real_without_profile_and_feeds_calibration():
    """Spans are the single timing source: calibration samples land in the
    stats store with no profile attached."""
    with pd.session(engine="eager", name="cal") as ctx:
        sp = ctx.tracer.timed_span("segment", engine="eager")
        assert sp is not NOOP_SPAN
        sp.finish()
        assert sp.duration > 0
        df = pd.from_arrays({"x": np.arange(32.0)})
        df[df["x"] > 1].compute()
        assert len(ctx.stats_store.runtime_samples.get("eager", ())) >= 1
        assert ctx.metrics.snapshot().get("calibration.runtime_samples",
                                          0) >= 1


def test_profiles_nest_and_detach_cleanly():
    ctx = get_context()
    with profile() as outer:
        ctx.tracer.span("a").finish()
        with profile() as inner:
            ctx.tracer.span("b").finish()
        ctx.tracer.span("c").finish()
    assert {s.name for s in outer.spans} == {"a", "b", "c"}
    assert {s.name for s in inner.spans} == {"b"}


# ---------------------------------------------------------------------------
# Bounded trace logs + structured events.


def test_trace_log_ring_buffer_bounds_and_counts_drops():
    log = TraceLog(limit=3)
    for i in range(10):
        log.append(i)
    assert list(log) == [7, 8, 9]
    assert log.dropped == 7
    unbounded = TraceLog(limit=None)
    unbounded.extend(range(100))
    assert len(unbounded) == 100 and unbounded.dropped == 0


def test_session_trace_limit_bounds_planner_trace():
    with pd.session(engine="auto", trace_limit=5) as ctx:
        df = pd.from_arrays({"x": np.arange(16.0)})
        for _ in range(8):
            df[df["x"] > 1].compute()
        assert len(ctx.planner_trace) <= 5
        assert ctx.planner_trace.dropped > 0
        assert len(ctx.force_log) <= 5


def test_planner_events_are_strings_with_structure():
    with pd.session(engine="auto", name="ev") as ctx:
        df = pd.from_arrays({"x": np.arange(64.0)})
        df[df["x"] > 1].compute()
        seg_lines = [e for e in ctx.planner_trace
                     if getattr(e, "kind", None) == "segment"]
        assert seg_lines
        ev = seg_lines[0]
        assert isinstance(ev, str)              # legacy consumers unbroken
        assert ev.startswith("auto: seg0")
        assert ev.fields["engine"] in ("eager", "streaming", "distributed")
        assert ev.to_dict()["kind"] == "segment"
    ev2 = PlannerEvent("hello", kind="note", n=1)
    assert ev2 == "hello" and ev2.fields == {"n": 1}


def test_fallback_events_counted_per_status():
    from repro.pandas.fallback import record_fallback
    with pd.session(name="fb") as ctx:
        record_fallback("DataFrame.x", (3, 2), "materialize-input")
        record_fallback("DataFrame.y", None, "no-registered-kernel",
                        status="failed")
        snap = ctx.metrics.snapshot()
        assert snap["fallback.served"] == 1
        assert snap["fallback.failed"] == 1
        assert len(ctx.fallback_trace) == 2


# ---------------------------------------------------------------------------
# Exporters.


def test_chrome_trace_export_validates_and_has_complete_events(tmp_path):
    with pd.session(engine="auto", name="chrome"):
        with profile() as prof:
            _corpus_program()
    trace = prof.to_chrome_trace()
    validate_chrome_trace(trace)
    events = trace["traceEvents"]
    x_events = [e for e in events if e["ph"] == "X"]
    assert x_events
    for e in x_events:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert "span_id" in e["args"]
    assert any(e["ph"] == "M" for e in events)       # process metadata
    assert any(e["ph"] == "C" for e in events)       # counter samples
    path = prof.save_chrome_trace(str(tmp_path / "trace.json"))
    reloaded = json.load(open(path))
    validate_chrome_trace(reloaded)


def test_chrome_trace_validation_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "X",
                                               "pid": 1}]})  # no ts/dur
    with pytest.raises(ValueError):
        validate_chrome_trace({})


def test_jsonl_export_round_trips_span_fields(tmp_path):
    with pd.session(engine="auto", name="jsonl"):
        with profile() as prof:
            _corpus_program()
    path = tmp_path / "spans.jsonl"
    n = prof.to_jsonl(str(path))
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert n == len(lines) == len(prof.spans)
    by_id = {s.id: s for s in prof.spans}
    for rec in lines:
        assert rec["name"] == by_id[rec["id"]].name
        assert rec["duration"] >= 0


def test_profile_ring_bounds_span_count():
    ctx = get_context()
    with profile(max_spans=4) as prof:
        for i in range(10):
            ctx.tracer.span(f"s{i}").finish()
    assert len(prof.spans) == 4
    assert prof.dropped == 6
    assert prof.counters.get("spans.dropped") == 6
    assert [s.name for s in prof.spans] == ["s6", "s7", "s8", "s9"]


def test_profile_counts_persist_cache_hits():
    from repro.core import from_arrays
    with pd.session(engine="streaming", name="persist"):
        with profile() as prof:
            df = from_arrays({"x": np.arange(2048.0)}, partition_rows=256)
            df = df[df["x"] > 1]
            df["x"].sum().compute(live_df=[df])    # df live → persisted
            df["x"].mean().compute(live_df=[])     # reuses the cache
    assert prof.counters.get("persist.misses", 0) >= 1
    assert prof.counters.get("persist.hits", 0) >= 1


# ---------------------------------------------------------------------------
# The jit_analyze rename.


def test_core_tracer_shim_warns_and_reexports():
    import importlib
    import sys
    sys.modules.pop("repro.core.tracer", None)
    with pytest.warns(DeprecationWarning, match="repro.core.tracer"):
        mod = importlib.import_module("repro.core.tracer")
    from repro.core import jit_analyze
    assert mod.analyze is jit_analyze.analyze
    assert mod.usecols_hint is jit_analyze.usecols_hint


@pd.analyze
def _analyzed_prog():
    return 1


def test_analyze_emits_span_when_profiled():
    with pd.session(name="an") as ctx:
        with profile() as prof:
            _analyzed_prog()
        spans = prof.find("analyze", mode="function")
        assert spans and "jit_seconds" in spans[0].attrs
        assert ctx.analysis.get("jit_seconds") is not None
