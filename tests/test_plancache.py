"""Plan cache (planner/plancache.py): fingerprint discrimination and
process stability, warm-hit semantics (skip optimize + segment DP, rebind
to fresh sources), data-derived plan state never leaking across sources,
and the session escape hatch."""
import subprocess
import sys

import numpy as np
import pytest

import repro.core as core
import repro.pandas as rpd
from repro.core import expr as E
from repro.core import graph as G
from repro.core.context import LaFPContext, get_context, session
from repro.core.planner.plancache import (CachedPlan, PlanCache, Uncacheable,
                                          cache_key, default_plan_cache,
                                          plan_fingerprint, stats_epoch)


def _source(n=4_000, seed=0, partition_rows=1024, dtype=np.float64):
    rng = np.random.default_rng(seed)
    return core.InMemorySource({
        "fare": rng.uniform(0, 100, n).astype(dtype),
        "vendor": rng.integers(0, 4, n).astype(np.int64),
    }, partition_rows)


def _plan(src):
    scan = G.Scan(src)
    filt = G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(10.0)))
    return [G.GroupByAgg(filt, ("vendor",), {"total": ("fare", "sum")})]


# ---------------------------------------------------------------------------
# Fingerprint discrimination


def test_identical_shapes_collide_across_sources_and_rebuilds():
    ctx = get_context()
    # fresh graphs over different data (different cache_token, same schema)
    fp1 = plan_fingerprint(_plan(_source(seed=0)), ctx)
    fp2 = plan_fingerprint(_plan(_source(seed=1)), ctx)
    fp3 = plan_fingerprint(_plan(_source(seed=0, n=9_000)), ctx)
    assert fp1 == fp2 == fp3


def test_op_kind_and_params_separate():
    ctx = get_context()
    src = _source()
    base = plan_fingerprint(_plan(src), ctx)
    # different predicate constant
    scan = G.Scan(src)
    other = [G.GroupByAgg(
        G.Filter(scan, E.BinOp("gt", E.Col("fare"), E.Lit(20.0))),
        ("vendor",), {"total": ("fare", "sum")})]
    assert plan_fingerprint(other, ctx) != base
    # different op kind in the same slot
    head = [G.GroupByAgg(G.Head(G.Scan(src), 100),
                         ("vendor",), {"total": ("fare", "sum")})]
    assert plan_fingerprint(head, ctx) != base
    # different agg fn
    agg = [G.GroupByAgg(
        G.Filter(G.Scan(src), E.BinOp("gt", E.Col("fare"), E.Lit(10.0))),
        ("vendor",), {"total": ("fare", "mean")})]
    assert plan_fingerprint(agg, ctx) != base


def test_schema_separates():
    ctx = get_context()
    fp64 = plan_fingerprint(_plan(_source(dtype=np.float64)), ctx)
    fp32 = plan_fingerprint(_plan(_source(dtype=np.float32)), ctx)
    assert fp64 != fp32


def test_engine_environment_separates():
    src = _source()
    a = LaFPContext(name="a")
    b = LaFPContext(name="b")
    a.backend = "auto"
    b.backend = "auto"
    b.engine_allowlist = ("eager",)
    assert plan_fingerprint(_plan(src), a) != plan_fingerprint(_plan(src), b)
    c = LaFPContext(name="c")
    c.backend = "streaming"
    assert plan_fingerprint(_plan(src), a) != plan_fingerprint(_plan(src), c)
    # backend options that steer planning separate too
    d = LaFPContext(name="d")
    d.backend = "auto"
    d.backend_options["placement"] = "per_root"
    assert plan_fingerprint(_plan(src), a) != plan_fingerprint(_plan(src), d)


def test_stats_epoch_separates():
    ctx = get_context()
    roots = _plan(_source())
    key0 = cache_key(roots, ctx)
    assert key0 is not None
    # observed cardinality for a node of THIS plan moves the epoch
    ctx.stats_store.record(roots[0].key(), rows=123, nbytes=1968)
    key1 = cache_key(roots, ctx)
    assert key1[0] == key0[0]          # same structural fingerprint
    assert key1[1] != key0[1]          # different stats epoch
    # trusted calibration moves it again
    for _ in range(3):
        ctx.stats_store.record_runtime("eager", 1e6, 0.01)
    key2 = cache_key(roots, ctx)
    assert key2[1] not in (key0[1], key1[1])


def test_fingerprint_stable_across_processes():
    ctx = get_context()
    prog = (
        "import sys, numpy as np\n"
        "sys.path.insert(0, 'src')\n"
        "import repro.core as core\n"
        "from repro.core import expr as E, graph as G\n"
        "from repro.core.context import LaFPContext\n"
        "from repro.core.planner.plancache import plan_fingerprint\n"
        "rng = np.random.default_rng(0)\n"
        "src = core.InMemorySource({'fare': rng.uniform(0, 100, 4000),"
        " 'vendor': rng.integers(0, 4, 4000).astype(np.int64)}, 1024)\n"
        "f = G.Filter(G.Scan(src), E.BinOp('gt', E.Col('fare'),"
        " E.Lit(10.0)))\n"
        "roots = [G.GroupByAgg(f, ('vendor',), {'total': ('fare',"
        " 'sum')})]\n"
        "print(plan_fingerprint(roots, LaFPContext(name='test')))\n")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, check=True, cwd=".")
    here = plan_fingerprint(_plan(_source()), LaFPContext(name="test"))
    assert out.stdout.strip() == here


def test_uncacheable_plans():
    ctx = get_context()
    src = _source()
    # opaque row-wise UDF node
    mr = [G.MapRows(G.Scan(src), lambda t: t)]
    with pytest.raises(Uncacheable):
        plan_fingerprint(mr, ctx)
    assert cache_key(mr, ctx) is None
    # UDF hiding inside an expression
    udf = [G.Assign(G.Scan(src), "x",
                    E.UDF(np.sqrt, (E.Col("fare"),)))]
    assert cache_key(udf, ctx) is None
    # side-effecting sink
    sink = [G.SinkPrint(["x"], [G.Length(G.Scan(src))], None)]
    assert cache_key(sink, ctx) is None


# ---------------------------------------------------------------------------
# Warm-hit semantics


def _compute(src, engine="auto"):
    df = core.read_source(src)
    return (df[df["fare"] > 10.0]
            .groupby("vendor").agg({"total": ("fare", "sum")})
            .compute())


def test_warm_hit_skips_planning_and_matches_cold():
    cache = default_plan_cache()
    with session(engine="auto", engines=("eager", "streaming")) as ctx:
        src = _source()
        cold = _compute(src)
        assert ctx.metrics.counter("plan_cache.misses") == 1
        warm = _compute(src)
        assert ctx.metrics.counter("plan_cache.hits") == 1
        for col in cold.columns:
            np.testing.assert_array_equal(cold[col], warm[col])
            assert cold[col].dtype == warm[col].dtype
        # trace + explain surfacing
        kinds = [getattr(e, "kind", None) for e in ctx.planner_trace]
        assert "plan_cache" in kinds
        report = rpd.explain()
        assert report.runs[0].cached is False
        assert report.runs[1].cached is True
        assert "cached=hit" in report.render()
    assert cache.stats()["hits"] >= 1


def test_new_data_same_shape_hits_and_stays_correct():
    """The headline property: a new source with the same schema hits the
    cached shape, and data-derived plan state (zone-map partition skips)
    from the old data never leaks into the new run."""
    with session(engine="eager") as ctx:
        # source A: fare all below 10 → the filter >50 prunes every
        # partition via zone maps in the cached optimized template
        low = core.InMemorySource(
            {"fare": np.linspace(0.0, 9.0, 4000),
             "vendor": np.arange(4000, dtype=np.int64) % 4}, 1024)
        df = core.read_source(low)
        empty = df[df["fare"] > 50.0].compute()
        assert len(empty["fare"]) == 0
        assert ctx.metrics.counter("plan_cache.misses") == 1
        # source B: same shape, fare up to 100 → must NOT reuse A's skips
        high = core.InMemorySource(
            {"fare": np.linspace(0.0, 100.0, 4000),
             "vendor": np.arange(4000, dtype=np.int64) % 4}, 1024)
        df2 = core.read_source(high)
        out = df2[df2["fare"] > 50.0].compute()
        assert ctx.metrics.counter("plan_cache.hits") == 1
        expected = np.linspace(0.0, 100.0, 4000)
        expected = expected[expected > 50.0]
        np.testing.assert_allclose(np.sort(out["fare"]),
                                   np.sort(expected))


def test_same_data_warm_hit_keeps_pruning():
    with session(engine="eager") as ctx:
        low = core.InMemorySource(
            {"fare": np.linspace(0.0, 9.0, 4000),
             "vendor": np.arange(4000, dtype=np.int64) % 4}, 1024)
        for _ in range(2):
            df = core.read_source(low)
            out = df[df["fare"] > 50.0].compute()
            assert len(out["fare"]) == 0
        assert ctx.metrics.counter("plan_cache.hits") == 1


def test_plan_cache_disabled_escape_hatch():
    with session(engine="eager", plan_cache=False) as ctx:
        src = _source()
        _compute(src)
        _compute(src)
        assert ctx.metrics.counter("plan_cache.hits") == 0
        assert ctx.metrics.counter("plan_cache.misses") == 0
        assert all(getattr(e, "kind", None) != "plan_cache"
                   for e in ctx.planner_trace)


def test_auto_warm_hit_reuses_decisions():
    with session(engine="auto", engines=("eager", "streaming")) as ctx:
        src = _source()
        _compute(src)
        cold_decisions = ctx.planner_decisions
        assert cold_decisions
        _compute(src)
        assert ctx.metrics.counter("plan_cache.hits") == 1
        warm_decisions = ctx.planner_decisions
        assert [d.backend for d in warm_decisions] == \
            [d.backend for d in cold_decisions]
        # decisions are fresh clones, never the cached template's objects
        cold_ids = {n.id for d in cold_decisions for n in d.nodes}
        warm_ids = {n.id for d in warm_decisions for n in d.nodes}
        assert not (cold_ids & warm_ids)


def test_cache_lru_bounded_and_clear():
    cache = PlanCache(max_entries=2)
    ctx = get_context()
    entries = []
    for n in (1000, 2000, 3000):
        roots = _plan(_source(n=n))
        walk = G.walk(roots)
        key = (plan_fingerprint(roots, ctx), f"epoch{n}")
        entries.append(CachedPlan.build(key, walk, roots,
                                        {x.id: x for x in walk}, None, 0.0))
        cache.store(entries[-1])
    assert len(cache) == 2
    assert cache.lookup(entries[0].key) is None      # evicted oldest
    assert cache.lookup(entries[2].key) is not None
    cache.clear()
    assert len(cache) == 0


def test_warm_rebind_rederives_pruned_partitions_from_new_zonemaps(tmp_path):
    """Satellite of the scan-pushdown work: a warm hit binding a *different*
    on-disk source (token mismatch) must re-derive the pruned-partition set
    from the NEW source's zone maps — the partitions the cached template
    skipped for source A are exactly the live ones for a reversed source B,
    and neither run may touch its dead partitions on disk."""
    from repro.core.source import NpzDirectorySource, write_npz_source

    class Spy(NpzDirectorySource):
        def __init__(self, path):
            super().__init__(path)
            self.loaded = []

        def load_partition(self, i, columns=None):
            self.loaded.append(i)
            return super().load_partition(i, columns)

    n, rows, cut = 4000, 512, 3500.0
    asc = np.arange(n, dtype=np.float64)
    write_npz_source(str(tmp_path / "asc"), {"key": asc}, rows)
    write_npz_source(str(tmp_path / "desc"), {"key": asc[::-1].copy()}, rows)
    a, b = Spy(str(tmp_path / "asc")), Spy(str(tmp_path / "desc"))

    def live(src):
        return {pi for pi in range(src.n_partitions)
                if src.partition_meta(pi)["zonemap"]["key"][1] >= cut}

    with session(engine="eager") as ctx:
        ra = core.read_source(a)
        out_a = ra[ra["key"] >= cut].compute()
        assert ctx.metrics.counter("plan_cache.misses") == 1
        rb = core.read_source(b)
        out_b = rb[rb["key"] >= cut].compute()
        assert ctx.metrics.counter("plan_cache.hits") == 1
    np.testing.assert_array_equal(np.sort(np.asarray(out_a["key"])),
                                  np.sort(np.asarray(out_b["key"])))
    # the two sources prune opposite ends — reusing A's skip set on B
    # would read the wrong partitions (and drop live rows)
    assert live(a) and live(b) and live(a) != live(b)
    assert set(a.loaded) <= live(a)
    assert set(b.loaded) <= live(b)
