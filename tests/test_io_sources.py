"""On-disk IO subsystem (repro.io): sidecar-backed Parquet/NPZ sources,
projection+predicate pushdown at the scan layer, zone-map partition
pruning (prune-proof: pruned partitions are never ``load_partition``-ed),
the async partition prefetcher, and the ``read_parquet`` /
``to_parquet_cache`` facade entry points."""
from __future__ import annotations

import json
import os
import time

import numpy as np
import pytest

import repro.core as core
import repro.pandas as rpd
from repro.core.context import session
from repro.core.source import (NpzDirectorySource, encode_strings,
                               write_npz_source)
from repro.io import prefetch_iter
from repro.io import sidecar as SC

ENGINES = ("eager", "streaming", "auto")


def _taxi_arrays(rng, n=4_000):
    vendors = [["acme", "beta", "cabco"][i] for i in rng.integers(0, 3, n)]
    codes, vocab = encode_strings(vendors)
    return ({
        "fare": rng.uniform(-5, 100, n),
        "tip": rng.uniform(0, 20, n),
        "vendor": codes,
        "pickup": (1_577_836_800
                   + rng.integers(0, 366 * 86400, n)).astype(np.int64),
    }, {"vendor": vocab}, ("pickup",))


def _write_source(kind, base, rng, partition_rows=512):
    arrays, dicts, datetimes = _taxi_arrays(rng)
    if kind == "npz":
        return write_npz_source(os.path.join(base, "npz_src"), arrays,
                                partition_rows, dicts=dicts,
                                datetimes=datetimes)
    pytest.importorskip("pyarrow")
    from repro.io.parquet import write_parquet_source
    return write_parquet_source(os.path.join(base, "pq_src"), arrays,
                                partition_rows, dicts=dicts,
                                datetimes=datetimes)


def _canon(res):
    return {k: np.asarray(res[k]) for k in res.columns}


def _assert_identical(a, b):
    a, b = _canon(a), _canon(b)
    assert set(a) == set(b)
    for k in a:
        assert a[k].dtype == b[k].dtype, k
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


class SpyNpz(NpzDirectorySource):
    """NPZ source recording which partitions were actually read."""

    def __init__(self, path):
        super().__init__(path)
        self.loaded: list[int] = []

    def load_partition(self, i, columns=None):
        self.loaded.append(i)
        return super().load_partition(i, columns)


# ---------------------------------------------------------------------------
# Prune proof: partitions outside the predicate's zone-map range are never
# read from disk — the acceptance criterion, per engine.


@pytest.mark.parametrize("engine", ENGINES)
def test_pruned_partitions_never_loaded(engine, tmp_path):
    n, rows = 4096, 512
    key = np.arange(n, dtype=np.float64)
    write_npz_source(str(tmp_path / "d"), {"key": key, "val": key % 7}, rows)
    spy = SpyNpz(str(tmp_path / "d"))
    with session(engine=engine) as ctx:
        df = core.read_source(spy)
        out = df[df["key"] >= 3584.0].compute()
        assert len(out["key"]) == 512
        live = {pi for pi in range(spy.n_partitions)
                if spy.partition_meta(pi)["zonemap"]["key"][1] >= 3584.0}
        assert live != set(range(spy.n_partitions))   # pruning is non-trivial
        assert set(spy.loaded) <= live                # pruned: NEVER loaded
        assert ctx.metrics.counter("io.partitions_pruned") > 0


# ---------------------------------------------------------------------------
# Pushdown differential: session(pushdown=False) is the escape hatch, and
# results must be bit-identical with the pass on or off, per engine and
# per on-disk source kind.


@pytest.mark.parametrize("kind", ("npz", "parquet"))
@pytest.mark.parametrize("engine", ENGINES)
def test_pushdown_on_off_bit_identical(engine, kind, tmp_path):
    src = _write_source(kind, str(tmp_path), np.random.default_rng(0))

    def run(**opts):
        with session(engine=engine, **opts):
            df = core.read_source(src)
            r = df[df["fare"] > 60.0]
            return (r.groupby("vendor")
                    .agg({"m": ("tip", "mean"), "n": ("fare", "count")})
                    .compute())

    _assert_identical(run(pushdown=True), run(pushdown=False))


@pytest.mark.parametrize("kind", ("npz", "parquet"))
def test_pushdown_reads_fewer_bytes(kind, tmp_path):
    # selective filter on a sorted key, all columns in the output: with
    # pushdown+zonemap the dead partitions never leave the disk, so
    # bytes-read drops; full-read (both passes off) pays for everything
    n, rows = 8192, 512
    arrays = {"key": np.arange(n, dtype=np.float64),
              "a": np.random.default_rng(1).random(n),
              "b": np.random.default_rng(2).random(n)}
    if kind == "npz":
        src = write_npz_source(str(tmp_path / "d"), arrays, rows)
    else:
        pytest.importorskip("pyarrow")
        from repro.io.parquet import write_parquet_source
        src = write_parquet_source(str(tmp_path / "d"), arrays, rows)

    def run(**opts):
        with session(engine="streaming", **opts) as ctx:
            df = core.read_source(src)
            r = df[df["key"] >= float(n - rows)]
            vals = (float(r["a"].sum()), float(r["b"].sum()))
            return vals, ctx.metrics.counter("io.bytes_read")

    out_on, bytes_on = run()
    out_off, bytes_off = run(pushdown=False, zonemap=False)
    np.testing.assert_allclose(out_on, out_off, rtol=1e-9)
    assert bytes_on * 2 <= bytes_off, (bytes_on, bytes_off)


# ---------------------------------------------------------------------------
# Sidecar stats: reopening never rescans data; staleness rebuilds; tokens
# are path-stable and cover the sidecar.


def test_sidecar_restores_stats_without_data_rescan(tmp_path, monkeypatch):
    arrays, dicts, datetimes = _taxi_arrays(np.random.default_rng(0), 1024)
    base = str(tmp_path / "d")
    write_npz_source(base, arrays, 256, dicts=dicts, datetimes=datetimes)
    # simulate a pre-sidecar directory: strip stats from _meta.json
    meta_path = os.path.join(base, "_meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    for p in meta["partitions"]:
        p.pop("rows", None)
        p.pop("zonemap", None)
    with open(meta_path, "w") as f:
        json.dump(meta, f)

    def bomb(*a, **k):
        raise AssertionError("reopen rescanned partition data")
    monkeypatch.setattr(np, "load", bomb)
    src = NpzDirectorySource(base)                 # sidecar only — no np.load
    m = src.partition_meta(0)
    assert m["rows"] == 256 and "fare" in m["zonemap"]


def test_sidecar_stale_on_data_change_and_token_moves(tmp_path):
    arrays, dicts, datetimes = _taxi_arrays(np.random.default_rng(0), 1024)
    base = str(tmp_path / "d")
    src = write_npz_source(base, arrays, 256, dicts=dicts,
                           datetimes=datetimes)
    tok = src.cache_token()
    assert NpzDirectorySource(base).cache_token() == tok  # path-stable
    # touching a data file invalidates the recorded (size, mtime_ns) state
    part = os.path.join(base, "part-00000.npz")
    os.utime(part, ns=(time.time_ns(), time.time_ns()))
    files = [os.path.join(base, p["file"]) for p in src._parts]
    assert SC.read_sidecar(base, data_files=files) is None
    # rewriting the sidecar moves the token (mtime component)
    SC.write_sidecar(base, src._parts, data_files=files)
    assert NpzDirectorySource(base).cache_token() != tok


def test_sidecar_stale_on_deleted_data_file(tmp_path):
    # a recorded file deleted from disk must read as stale even though
    # every *surviving* file still matches its recorded state — otherwise
    # the sidecar's partitions reference a missing file
    import glob as _glob
    base = str(tmp_path / "d")
    write_npz_source(base, {"x": np.arange(1024, dtype=np.float64)}, 256)
    os.remove(os.path.join(base, "part-00003.npz"))
    files = sorted(_glob.glob(os.path.join(base, "part-*.npz")))
    assert len(files) == 3
    assert SC.read_sidecar(base, data_files=files) is None


def test_parquet_reopen_after_file_deletion_rebuilds(tmp_path):
    pytest.importorskip("pyarrow")
    from repro.io.parquet import ParquetSource, write_parquet_source
    base = str(tmp_path / "d")
    src = write_parquet_source(base,
                               {"x": np.arange(1024, dtype=np.float64)}, 256)
    assert src.n_partitions == 4
    os.remove(os.path.join(base, "part-00003.parquet"))
    reopened = ParquetSource(base)      # stale sidecar → rebuilt, no crash
    assert reopened.n_partitions == 3
    # a partition referencing a vanished file fails loudly, not with a
    # bare StopIteration swallowed by streaming generators
    with pytest.raises(FileNotFoundError, match="missing"):
        reopened._handle("part-00003.parquet")


# ---------------------------------------------------------------------------
# Externally-written parquet: zone maps must be timezone-independent, and
# nulls rejected with a clear error at the scan boundary.


def test_timestamp_zone_maps_are_utc_under_local_tz(tmp_path, monkeypatch):
    # footer stats decode to naive datetimes representing UTC instants;
    # building zone maps via naive .timestamp() on a non-UTC machine would
    # shift bounds by the UTC offset and mis-prune partitions
    pa = pytest.importorskip("pyarrow")
    import pyarrow.parquet as pq
    from repro.io.parquet import ParquetSource
    monkeypatch.setenv("TZ", "America/New_York")
    time.tzset()
    try:
        lo, hi = 1_577_836_800, 1_577_923_200        # 2020-01-01/02 UTC
        d = tmp_path / "pq"
        d.mkdir()
        ts = pa.array([lo, hi], pa.int64()).cast(pa.timestamp("s"))
        pq.write_table(pa.table({"ts": ts}),
                       str(d / "part-00000.parquet"))
        src = ParquetSource(str(d))                  # no sidecar: footer pass
        assert src.partition_meta(0)["zonemap"]["ts"] == (lo, hi)
        loaded = src.load_partition(0, ["ts"])
        assert loaded["ts"].tolist() == [lo, hi]     # bounds match the data
    finally:
        monkeypatch.undo()
        time.tzset()


def test_parquet_nulls_rejected_with_clear_error(tmp_path):
    pa = pytest.importorskip("pyarrow")
    import pyarrow.parquet as pq
    from repro.io.parquet import ParquetSource
    for name, arr in (("s", pa.array(["a", None, "b"], pa.string())),
                      ("x", pa.array([1, None, 3], pa.int64()))):
        d = tmp_path / f"nulls_{name}"
        d.mkdir()
        pq.write_table(pa.table({name: arr}), str(d / "part-00000.parquet"))
        with pytest.raises(ValueError, match="null"):
            ParquetSource(str(d))


# ---------------------------------------------------------------------------
# Prefetcher: ordering, exception propagation, early-exit shutdown — then
# end-to-end through the streaming backend's Head early-exit.


def test_prefetch_iter_preserves_order_and_counts():
    # slow consumer (20ms) vs fast load (5ms): the worker runs ahead, so
    # every partition EXCEPT the first counts as prefetched — the first is
    # demand-loaded (the consumer is already blocked waiting on it), and
    # on_prefetch must not fire for partitions the consumer requested
    # before their decode finished
    seen, got = [], []

    def load(i):
        time.sleep(0.005)
        return i * i

    for v in prefetch_iter(range(10), load, depth=3,
                           on_prefetch=seen.append):
        time.sleep(0.02)
        got.append(v)
    assert got == [i * i for i in range(10)]
    assert 0 not in seen                      # demand-loaded, not prefetched
    assert 1 <= len(seen) <= 9


def test_prefetch_iter_propagates_exceptions_in_order():
    def load(i):
        if i == 3:
            raise ValueError("boom")
        return i

    out = []
    with pytest.raises(ValueError, match="boom"):
        for v in prefetch_iter(range(6), load, depth=2):
            out.append(v)
    assert out == [0, 1, 2]


def test_prefetch_iter_early_exit_stops_worker():
    import threading
    before = threading.active_count()
    for _ in range(3):
        it = prefetch_iter(range(100), lambda i: i, depth=2)
        for v in it:
            if v == 5:
                break
        it.close()
    time.sleep(0.1)
    assert threading.active_count() <= before + 1


@pytest.mark.parametrize("depth", (0, 2))
def test_streaming_head_early_exit_with_prefetch(depth, tmp_path):
    n, rows = 8192, 256
    src = write_npz_source(str(tmp_path / "d"),
                           {"x": np.arange(n, dtype=np.float64)}, rows)
    with session(engine="streaming", io_prefetch=depth) as ctx:
        df = core.read_source(src)
        out = df.head(10).compute()
        np.testing.assert_array_equal(np.asarray(out["x"]),
                                      np.arange(10, dtype=np.float64))
        loaded = ctx.metrics.counter("io.partitions_loaded")
        assert loaded < n // rows              # early exit: not a full scan
        # prefetched counts decoded-ahead partitions only — a subset of
        # loads, and timing-dependent, so just the invariant here (the
        # deterministic semantics test is below)
        assert ctx.metrics.counter("io.partitions_prefetched") <= loaded


def test_prefetched_counts_only_partitions_decoded_ahead(tmp_path):
    # through the real scan loader: 8 partitions, load slower than nothing
    # but faster than the consumer, so the worker is ahead for every
    # partition except the first — prefetched must land strictly between
    # 1 and partitions_loaded, never equal partitions_loaded (the old bug:
    # every load through the prefetch thread counted as a prefetch)
    from repro.core import graph as G
    from repro.io.scan import iter_scan_partitions

    n, rows = 2048, 256
    base = str(tmp_path / "d")
    write_npz_source(base, {"x": np.arange(n, dtype=np.float64)}, rows)

    class SlowNpz(NpzDirectorySource):
        def load_partition(self, i, columns=None):
            time.sleep(0.005)
            return super().load_partition(i, columns)

    src = SlowNpz(base)
    with session(engine="streaming", io_prefetch=2) as ctx:
        for _ in iter_scan_partitions(G.Scan(src), ctx):
            time.sleep(0.02)
        loaded = ctx.metrics.counter("io.partitions_loaded")
        prefetched = ctx.metrics.counter("io.partitions_prefetched")
        assert loaded == src.n_partitions == 8
        assert 1 <= prefetched < loaded


# ---------------------------------------------------------------------------
# Facade: read_parquet and the read_csv parquet cache.


def _write_csv(path, n=600):
    rng = np.random.default_rng(0)
    with open(path, "w") as f:
        f.write("fare,vendor\n")
        for i in range(n):
            f.write(f"{rng.uniform(0, 100):.4f},v{i % 4}\n")


def test_read_parquet_facade(tmp_path):
    pytest.importorskip("pyarrow")
    src = _write_source("parquet", str(tmp_path), np.random.default_rng(0))
    df = rpd.read_parquet(src.path)
    assert int(df["fare"].count()) == 4_000
    only = rpd.read_parquet(src.path, columns=["fare"])
    assert only.columns == ["fare"]


def test_read_csv_parquet_cache_roundtrip_and_freshness(tmp_path,
                                                       monkeypatch):
    pytest.importorskip("pyarrow")
    csv = str(tmp_path / "t.csv")
    cache = str(tmp_path / "t.pq")
    _write_csv(csv)
    df = rpd.read_csv(csv, to_parquet_cache=cache)
    first = df[df["fare"] > 50.0].groupby("vendor").agg(
        {"n": ("fare", "count")}).compute()
    assert os.path.exists(os.path.join(cache, SC.SIDECAR_NAME))

    # warm: the CSV must not be parsed again
    import repro.pandas.io as fio
    monkeypatch.setattr(fio, "_parse_csv", lambda *a, **k: pytest.fail(
        "warm cache re-parsed the CSV"))
    df2 = rpd.read_csv(csv, to_parquet_cache=cache)
    again = df2[df2["fare"] > 50.0].groupby("vendor").agg(
        {"n": ("fare", "count")}).compute()
    _assert_identical(first, again)
    monkeypatch.undo()

    # stale: appended rows must force a rebuild
    with open(csv, "a") as f:
        f.write("1.0,v0\n")
    df3 = rpd.read_csv(csv, to_parquet_cache=cache)
    assert int(df3["fare"].count()) == 601


def test_read_csv_parquet_cache_stale_on_parse_param_change(tmp_path,
                                                           monkeypatch):
    # dtype/parse_dates are part of the cache identity: a later call with
    # different parse options must rebuild, not silently serve the first
    # call's schema
    pytest.importorskip("pyarrow")
    import repro.pandas.io as fio
    csv = str(tmp_path / "t.csv")
    cache = str(tmp_path / "t.pq")
    _write_csv(csv)
    calls = []
    orig = fio._parse_csv

    def counting(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(fio, "_parse_csv", counting)
    rpd.read_csv(csv, to_parquet_cache=cache)
    assert len(calls) == 1
    rpd.read_csv(csv, to_parquet_cache=cache)          # warm, same params
    assert len(calls) == 1
    df = rpd.read_csv(csv, dtype={"fare": "float32"}, to_parquet_cache=cache)
    assert len(calls) == 2                             # params changed
    assert np.asarray(df.compute()["fare"]).dtype == np.float32
    rpd.read_csv(csv, dtype={"fare": "float32"}, to_parquet_cache=cache)
    assert len(calls) == 2                             # warm under new params
    # the recorded identity covers parse_dates too
    with open(os.path.join(cache, SC.SIDECAR_NAME)) as f:
        payload = json.load(f)
    assert payload["ingest"]["__params__"] == {
        "dtype": {"fare": "<f4"}, "parse_dates": []}
