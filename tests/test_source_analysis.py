"""JIT static analysis tests (paper §2.4, §3.1, §3.5) — the Fig. 3 → Fig. 4
column-selection example and the live-frame analysis."""
from repro.core.source_analysis import analyze_source

PAPER_FIG3 = '''
import lazyfatpandas.pandas as pd
pd.analyze()
df = pd.read_csv("test.csv")
df = df[df["fare_amount"] > 0]
df["day"] = df.pickup_datetime.dt.dayofweek
p_per_day = df.groupby(["day"])["passenger_count"].sum()
print(p_per_day)
'''


def test_paper_fig3_usecols():
    """22 columns → exactly the 3 used (paper Fig. 4)."""
    res = analyze_source(PAPER_FIG3)
    (lineno, cols), = res.usecols.items()
    assert cols == ["fare_amount", "passenger_count", "pickup_datetime"]


def test_whole_frame_print_makes_all_live():
    src = '''
df = read_csv("x.csv")
df = df[df["a"] > 0]
print(df)
'''
    res = analyze_source(src)
    (_, cols), = res.usecols.items()
    assert cols is None          # ALL live → no usecols


def test_head_describe_ignored():
    """Paper §3.1 heuristic: head/info/describe don't make columns live."""
    src = '''
df = read_csv("x.csv")
print(df.head())
print(df.describe())
s = df["a"].sum()
print(f"{s}")
'''
    res = analyze_source(src)
    (_, cols), = res.usecols.items()
    assert cols == ["a"]


def test_reassignment_kills_columns():
    src = '''
df = read_csv("x.csv")
y = df["a"].sum()
df = read_csv("y.csv")
z = df["b"].sum()
print(f"{y} {z}")
'''
    res = analyze_source(src)
    cols_by_line = dict(res.usecols)
    assert sorted(cols_by_line.values()) == [["a"], ["b"]]


def test_branches_union_liveness():
    src = '''
df = read_csv("x.csv")
if flag:
    v = df["a"].mean()
else:
    v = df["b"].mean()
print(f"{v}")
'''
    res = analyze_source(src)
    (_, cols), = res.usecols.items()
    assert cols == ["a", "b"]


def test_loop_liveness():
    src = '''
df = read_csv("x.csv")
total = 0
while total < 10:
    total = total + df["a"].sum()
print(f"{total}")
'''
    res = analyze_source(src)
    (_, cols), = res.usecols.items()
    assert cols == ["a"]


def test_live_frames_at_force_point():
    """Paper §3.5 Fig. 11: live_df=[df] at the mid-program force point."""
    src = '''
df = read_csv("x.csv")
p = df.groupby(["k"])["v"].sum()
plot(p.compute())
avg = df["w"].mean()
print(f"{avg}")
'''
    res = analyze_source(src)
    assert len(res.live_at) == 1
    (_, frames), = res.live_at.items()
    assert "df" in frames


def test_readonly_columns():
    src = '''
df = read_csv("x.csv")
df["b"] = df["a"] * 2
s = df["a"].sum() + df["b"].sum() + df["c"].sum()
print(f"{s}")
'''
    res = analyze_source(src)
    readonly = res.all_used_cols - res.assigned_cols
    assert "a" in readonly and "c" in readonly
    assert "b" not in readonly


def test_derived_frame_liveness_flows_to_source():
    """Paper §3.1 rule 3: df2 derived from df — df2's live cols count."""
    src = '''
df = read_csv("x.csv")
df2 = df[df["a"] > 0]
v = df2["b"].sum()
print(f"{v}")
'''
    res = analyze_source(src)
    (_, cols), = res.usecols.items()
    assert cols == ["a", "b"]


def test_aggregate_kills_identity():
    """Aggregation-derived frames don't propagate ALL back (paper's
    aggregate-kill rule)."""
    src = '''
df = read_csv("x.csv")
agg = df.groupby(["k"])["v"].sum()
print(agg)
'''
    res = analyze_source(src)
    (_, cols), = res.usecols.items()
    assert cols == ["k", "v"]
