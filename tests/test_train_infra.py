"""Training infrastructure: loop convergence, checkpoint/restart, preemption,
cross-mesh resharding, gradient compression, pipeline state."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.compat import shard_map
from repro.configs import get_config
from repro.data.pipeline import (PipelineConfig, TokenPipeline,
                                 synthetic_token_source)
from repro.launch.train import build_state
from repro.models.layers import init_from_spec
from repro.models.transformer import model_spec
from repro.train.loop import LoopConfig, Trainer
from repro.train.optim import OptimConfig, init_opt_state
from repro.train.train_step import TrainConfig, cross_entropy, make_train_step


def _smoke_setup(tmp_path, steps=20, microbatches=1):
    cfg = get_config("llama3_2_3b").smoke()
    tcfg = TrainConfig(optim=OptimConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=steps),
                       microbatches=microbatches)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    src = synthetic_token_source(64, 32, cfg.vocab, seed=1)
    pipe = TokenPipeline(src, PipelineConfig(batch=4, seq=32, prefetch=0))
    state = build_state(cfg)
    loop = LoopConfig(total_steps=steps, ckpt_every=8, log_every=5,
                      ckpt_dir=str(tmp_path / "ck"))
    return cfg, step, pipe, state, loop


def test_loss_decreases(tmp_path):
    cfg, step, pipe, state, loop = _smoke_setup(tmp_path, steps=25)
    tr = Trainer(step, state, iter(pipe), loop, pipeline_state=pipe.state)
    tr.log = lambda m: None
    out = tr.run()
    losses = [m["loss"] for m in tr.metrics_history]
    assert out["steps"] == 25
    assert losses[-1] < losses[0]


def test_checkpoint_resume_continues(tmp_path):
    cfg, step, pipe, state, loop = _smoke_setup(tmp_path, steps=10)
    tr = Trainer(step, state, iter(pipe), loop, pipeline_state=pipe.state)
    tr.log = lambda m: None
    tr.run()
    mgr = CheckpointManager(loop.ckpt_dir)
    assert mgr.latest_step() == 10
    # resume into a new trainer; runs 5 more steps
    loop2 = LoopConfig(total_steps=15, ckpt_every=100,
                       ckpt_dir=loop.ckpt_dir)
    pipe2 = TokenPipeline(pipe.source, pipe.cfg)
    state2 = build_state(cfg, seed=99)     # would diverge unless restored
    tr2 = Trainer(step, state2, iter(pipe2), loop2)
    assert tr2.try_resume()
    assert tr2.step == 10
    out = tr2.run()
    assert out["steps"] == 15
    assert int(tr2.state["opt"]["step"]) == 15


def test_preemption_checkpoint(tmp_path):
    cfg, step, pipe, state, loop = _smoke_setup(tmp_path, steps=1000)
    tr = Trainer(step, state, iter(pipe), loop, pipeline_state=pipe.state)
    tr.log = lambda m: None
    # simulate a preemption signal after a few steps via the data stream
    raw = iter(pipe)

    def limited():
        for i, b in enumerate(raw):
            if i == 7:
                tr._preempted = True     # what the SIGTERM handler sets
            yield b
    tr.data = limited()
    out = tr.run()
    assert out["preempted"]
    mgr = CheckpointManager(loop.ckpt_dir)
    assert mgr.latest_step() == out["steps"]   # final ckpt written


def test_checkpoint_atomicity_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((4,))}
    mgr.save(5, state)
    # fake a partial (crashed) save at a later step: no COMMIT file
    d = tmp_path / "step_000000009"
    (d / "arrays").mkdir(parents=True)
    (d / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 5


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": jnp.ones((2,)) * s})
    assert mgr.all_steps() == [3, 4]


def test_cross_mesh_resharding_restore(tmp_path):
    """Elasticity: save unsharded, restore under a different device layout
    (1-device 'mesh' here; the sharding path is identical at any size)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    state = {"params": {"w": jnp.arange(16.0).reshape(4, 4)}}
    mgr.save(3, state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"params": {"w": NamedSharding(mesh, P("data", None))}}
    step, restored, _ = mgr.restore(shardings=sh)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(16.0).reshape(4, 4))
    assert restored["params"]["w"].sharding == sh["params"]["w"]


def test_pipeline_state_checkpoint_roundtrip(tmp_path):
    cfg, step, pipe, state, loop = _smoke_setup(tmp_path, steps=6)
    tr = Trainer(step, state, iter(pipe), loop, pipeline_state=pipe.state)
    tr.log = lambda m: None
    tr.run()
    mgr = CheckpointManager(loop.ckpt_dir)
    _, _, extras = mgr.restore()
    assert extras["pipeline"]["batch_index"] == pipe.state.batch_index
    assert extras["pipeline"]["epoch"] == pipe.state.epoch


def test_microbatched_step_matches_full_batch():
    """Grad accumulation must be loss/grad-equivalent to the full batch."""
    cfg = get_config("qwen2_5_3b").smoke()
    key = jax.random.PRNGKey(0)
    params = init_from_spec(model_spec(cfg), key)
    batch = {
        "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab),
        "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab),
    }
    outs = {}
    for mb in (1, 2):
        tcfg = TrainConfig(optim=OptimConfig(lr=1e-3, warmup_steps=1,
                                             total_steps=5),
                           microbatches=mb)
        step = make_train_step(cfg, tcfg)
        state = {"params": params, "opt": init_opt_state(params)}
        new_state, m = step(state, batch)
        outs[mb] = new_state["params"]["unembed"]
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(outs[2]),
                               rtol=5e-3, atol=1e-5)


def test_gradient_compression_error_feedback():
    """int8 EF compression: single-step error bounded; residual carries the
    quantization error exactly."""
    from repro.distributed.compression import compress_tree
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(256, 8)) * 1e-3, jnp.float32)}
    deq, res = compress_tree(g, None)
    np.testing.assert_allclose(np.asarray(deq["w"] + res["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-8)
    # relative error of one shot is small
    rel = float(jnp.linalg.norm(deq["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02


def test_compressed_psum_shardmap():
    from functools import partial
    from repro.distributed.compression import compressed_psum
    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)

    @partial(jax.jit)
    def run(x):
        f = shard_map(lambda v: compressed_psum(v[0], "d")[0][None],
                      mesh=mesh, in_specs=jax.sharding.PartitionSpec("d"),
                          out_specs=jax.sharding.PartitionSpec("d"))
        return f(x[None])
    out = run(x)[0]
    # int8 block quantization: error bounded by half a quant step (~scale/2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=0,
                               atol=0.02)


def test_sharded_vocab_ce_matches_gather():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 8, 32)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 32, (2, 8)), jnp.int32)
    a = cross_entropy(logits, labels, "sharded_vocab")
    b = cross_entropy(logits, labels, "gather_logits")
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_ce_label_masking():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    labels = jnp.asarray([[1, 2, -100, -100]], jnp.int32)
    full = cross_entropy(logits[:, :2], labels[:, :2])
    masked = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(full), float(masked), rtol=1e-6)
