"""Rowwise-fusion pass tests: chain collapse + safety guards, the
session(fusion=False) escape hatch, trace/metric/explain surfacing, the
plan-cache environment fingerprint, and fused-vs-sequential execution
through the shared physical operator."""
from __future__ import annotations

import numpy as np
import pytest

import repro.pandas as rpd
from repro.core import get_context
from repro.core import graph as G
from repro.core import physical as X
from repro.core.fuse import fuse_rowwise_chains
from repro.core.optimizer import optimize
from repro.core.planner.plancache import plan_fingerprint


def _frame(rng, n=400):
    return rpd.from_arrays({
        "a": rng.integers(0, 8, n).astype(np.float64),
        "b": rng.random(n),
        "c": rng.integers(0, 3, n).astype(np.float64),
    })


def _chain(df):
    r = df[df["a"] > 2.0]
    r = r.assign(x=r["b"] * 2.0)
    # "aa" makes pandas column order (a, b, aa, x) differ from sorted
    # order — catches the jitted path's dict-pytree key sorting
    r = r.rename(columns={"c": "aa"})
    return r.fillna(0.0)


def _fused_nodes(roots):
    return [n for n in G.walk(roots) if n.op == "fused_rowwise"]


# ---------------------------------------------------------------------------
# Chain collapse + guards


def test_chain_collapses_to_single_fused_node(rng):
    node = _chain(_frame(rng))._node
    roots, _ = fuse_rowwise_chains([node])
    (fused,) = _fused_nodes(roots)
    # members are innermost-first: the filter executes before the assign
    assert [m.op for m in fused.ops] == ["filter", "assign", "rename",
                                        "fillna"]
    assert fused.inputs[0].op == "scan"


def test_single_rowwise_op_is_not_wrapped(rng):
    df = _frame(rng)
    node = df[df["a"] > 2.0]._node
    roots, idmap = fuse_rowwise_chains([node])
    assert not _fused_nodes(roots) and not idmap


def test_persist_mark_breaks_the_chain(rng):
    # a persisted interior node is a planned §3.5 materialization point —
    # absorbing it would make its cached value unaddressable
    df = _frame(rng)
    r = df[df["a"] > 2.0]
    r._node.persist = True
    node = r.assign(x=r["b"] * 2.0).fillna(0.0)._node
    roots, _ = fuse_rowwise_chains([node])
    (fused,) = _fused_nodes(roots)
    assert [m.op for m in fused.ops] == ["assign", "fillna"]
    assert fused.inputs[0].op == "filter" and fused.inputs[0].persist


def test_shared_interior_node_is_not_absorbed(rng):
    # the filter feeds two consumers: only the single-consumer suffix fuses
    df = _frame(rng)
    shared = df[df["a"] > 2.0]
    left = shared.assign(x=shared["b"] * 2.0).fillna(0.0)._node
    right = shared.rename(columns={"c": "cc"})._node
    roots, _ = fuse_rowwise_chains([left, right])
    for fused in _fused_nodes(roots):
        assert "filter" not in [m.op for m in fused.ops]


def test_session_fusion_false_disables_the_pass(rng):
    ctx = get_context()
    ctx.backend_options["fusion"] = False
    roots, _ = optimize([_chain(_frame(rng))._node], ctx)
    assert not _fused_nodes(roots)
    ctx.backend_options["fusion"] = True
    roots, _ = optimize([_chain(_frame(rng))._node], ctx)
    assert _fused_nodes(roots)


# ---------------------------------------------------------------------------
# Surfacing: trace event, metric, explain label, plan-cache fingerprint


def test_fuse_emits_event_and_metric(rng):
    ctx = get_context()
    before = ctx.metrics.counter("fuse.applied")
    optimize([_chain(_frame(rng))._node], ctx)
    events = [ev for ev in ctx.planner_trace
              if getattr(ev, "kind", None) == "fuse"]
    # the leading filter is absorbed into the scan by scan_pushdown, so
    # the fused chain starts at the assign
    assert events and events[-1].fields["ops"][0] == "assign"
    assert ctx.metrics.counter("fuse.applied") == before + 1


def test_explain_renders_fused_label(rng):
    out = _chain(_frame(rng)).compute()
    assert len(out["a"]) > 0
    report = rpd.explain()
    ops = [op for run in report.runs for seg in run.segments
           for op in seg.ops]
    # the filter is pushed into the scan; the remaining rowwise chain fuses
    assert any(op.startswith("fused[assign") for op in ops), ops


def test_fingerprint_covers_fusion_flag_and_kernel_impl(rng):
    ctx = get_context()
    node = _chain(_frame(rng))._node
    base = plan_fingerprint([node], ctx)
    ctx.backend_options["fusion"] = False
    off = plan_fingerprint([node], ctx)
    ctx.backend_options["fusion"] = True
    ctx.backend_options["kernel_impl"] = "pallas"
    pallas = plan_fingerprint([node], ctx)
    assert len({base, off, pallas}) == 3


# ---------------------------------------------------------------------------
# Execution: the fused pass must equal the op-at-a-time members


@pytest.mark.parametrize("xp_name", ("numpy", "jnp"))
def test_fused_execution_matches_sequential(rng, xp_name):
    node = _chain(_frame(rng))._node
    roots, _ = fuse_rowwise_chains([node])
    (fused,) = _fused_nodes(roots)
    cols = {
        "a": rng.integers(0, 8, 300).astype(np.float64),
        "b": rng.random(300),
        "c": rng.integers(0, 3, 300).astype(np.float64),
    }
    cols["b"][::7] = np.nan
    if xp_name == "jnp":
        import jax.numpy as jnp
        table = {k: jnp.asarray(v) for k, v in cols.items()}
    else:
        table = dict(cols)
    got = X.apply_fused_rowwise(table, fused.ops)
    ref = dict(table)
    for m in fused.ops:
        ref = X.rowwise._apply_member(ref, m)
    assert list(got) == list(ref)     # pandas column ORDER, not just set
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   rtol=1e-6)
