"""mistral-nemo-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — 128k ctx, head_dim 128 (≠ d_model/heads, per Nemo)
[hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.configs import ArchConfig
from repro.models.transformer import LayerSpec

ARCH = ArchConfig(
    name="mistral-nemo-12b",
    d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, rope_theta=1_000_000.0,
    group=(LayerSpec("attn", "dense"),), n_groups=40,
    family="dense",
)
