"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408(expert)
vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained
[arXiv:2401.06066; hf].  Layer 0 is a dense FFN (d_ff 10944) per the paper."""
from repro.configs import ArchConfig
from repro.models.transformer import LayerSpec

ARCH = ArchConfig(
    name="deepseek-moe-16b",
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab=102400,
    prelude=(LayerSpec("attn", "dense"),),
    group=(LayerSpec("attn", "moe"),), n_groups=27,
    moe_routed=64, moe_shared=2, moe_top_k=6, moe_d_ff=1408,
    family="moe",
)
