"""Architecture configs (assigned pool) + input shapes.

Each ``configs/<id>.py`` defines ``ARCH = ArchConfig(...)`` with the exact
assigned hyperparameters; ``ArchConfig.smoke()`` derives the reduced same-
family config used by CPU smoke tests.  ``input_specs`` builds the
ShapeDtypeStruct stand-ins for dry-run lowering (no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from ..models.transformer import LayerSpec, cache_shapes


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    prelude: tuple[LayerSpec, ...] = ()
    group: tuple[LayerSpec, ...] = ()
    n_groups: int = 0
    postlude: tuple[LayerSpec, ...] = ()
    modality: str = "text"              # text | embed_in (audio/vlm stub)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    embed_scale: bool = False
    # MoE
    moe_routed: int = 0
    moe_shared: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity: float = 1.25
    # MLA
    kv_lora_rank: int | None = None
    qk_rope_dim: int = 64
    v_head_dim: int | None = None
    # SSM / xLSTM
    xlstm_proj_factor: float = 2.0
    mamba_d_state: int = 16
    ssm_chunk: int = 128
    ssm_scan_dtype: str = "float32"   # "bfloat16": §Perf jamba iteration
    sharding_profile: str = "fsdp_tp"   # dp_tp: replicate params over data
                                        # (small models; kills FSDP gathers)
    # policy
    activation_dtype: Any = jnp.bfloat16
    remat: bool = True
    sub_quadratic: bool = False         # runs long_500k
    family: str = "dense"               # dense|moe|ssm|hybrid|audio|vlm
    attn_impl: str = "chunked"          # flash-style default; "dense" = naive baseline
    kv_chunk: int = 1024

    # -- sub-config helpers -------------------------------------------------
    def attn_config(self, ls: LayerSpec):
        from ..models.attention import AttnConfig
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            qkv_bias=self.qkv_bias, window=ls.window,
            rope_theta=self.rope_theta, kv_lora_rank=self.kv_lora_rank,
            qk_rope_dim=self.qk_rope_dim, v_head_dim=self.v_head_dim,
            attn_impl=self.attn_impl, kv_chunk=self.kv_chunk)

    def moe_config(self):
        from ..models.moe import MoEConfig
        return MoEConfig(d_model=self.d_model, n_routed=self.moe_routed,
                         n_shared=self.moe_shared, top_k=self.moe_top_k,
                         d_ff_expert=self.moe_d_ff,
                         capacity_factor=self.moe_capacity)

    def mamba_config(self):
        from ..models.ssm import MambaConfig
        return MambaConfig(d_model=self.d_model, d_state=self.mamba_d_state,
                           chunk=self.ssm_chunk,
                           scan_dtype=self.ssm_scan_dtype)

    def xlstm_config(self):
        from ..models.xlstm import XLSTMConfig
        return XLSTMConfig(d_model=self.d_model, n_heads=self.n_heads,
                           proj_factor=self.xlstm_proj_factor,
                           chunk=self.ssm_chunk)

    @property
    def n_layers(self) -> int:
        return (len(self.prelude) + self.n_groups * len(self.group)
                + len(self.postlude))

    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts from the spec (embed table
        excluded from both, unembed included — the 6ND convention)."""
        from ..models.transformer import model_spec
        spec = model_spec(self)
        total = active = 0
        for path, (shape, _dt, _ax) in spec.items():
            n = 1
            for d in shape:
                n *= d
            if path == "embed":
                continue
            total += n
            if "/ffn/w_" in path and self.moe_routed:
                active += n * self.moe_top_k // self.moe_routed
            else:
                active += n
        return total, active

    def group_param_count(self) -> int:
        """Active params in ONE scan group (for scan-body FLOPs correction)."""
        from ..models.transformer import model_spec
        spec = model_spec(self)
        active = 0
        for path, (shape, _dt, _ax) in spec.items():
            if not path.startswith("group/"):
                continue
            n = 1
            for d in shape:
                n *= d
            n //= max(self.n_groups, 1)
            if "/ffn/w_" in path and self.moe_routed:
                active += n * self.moe_top_k // self.moe_routed
            else:
                active += n
        return active

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests: same stacking
        pattern, tiny widths."""
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            d_model=64, n_heads=heads, n_kv_heads=kv, head_dim=16,
            d_ff=128 if self.d_ff else 0, vocab=128,
            n_groups=min(self.n_groups, 2),
            prelude=self.prelude[:1], postlude=self.postlude[:1],
            moe_routed=min(self.moe_routed, 8) if self.moe_routed else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_d_ff=32 if self.moe_routed else 0,
            moe_capacity=8.0,    # no drops at smoke scale (decode≡train)
            kv_lora_rank=32 if self.kv_lora_rank else None,
            qk_rope_dim=8 if self.kv_lora_rank else 64,
            v_head_dim=16 if self.v_head_dim else None,
            ssm_chunk=8,
            ssm_scan_dtype="float32",   # exact chunk↔step equivalence
            activation_dtype=jnp.float32, remat=False)


# ---------------------------------------------------------------------------
# Shapes


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k only for sub-quadratic families (DESIGN §4)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("skipped: pure full-attention arch at 524k context "
                       "(assignment skip rule; see DESIGN.md §4)")
    return True, ""


def input_specs(arch: ArchConfig, shape: ShapeConfig,
                cache_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the step function
    that the dry-run lowers — weak-type-correct, shardable, no allocation."""
    B, S = shape.global_batch, shape.seq
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        if arch.modality == "text":
            return {"tokens": tok,
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        return {"embeds": jax.ShapeDtypeStruct((B, S, arch.d_model),
                                               jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if arch.modality == "text":
            return {"tokens": tok}
        return {"embeds": jax.ShapeDtypeStruct((B, S, arch.d_model),
                                               jnp.bfloat16)}
    # decode: one new token against an S-token cache
    new = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)} \
        if arch.modality == "text" else \
        {"embeds": jax.ShapeDtypeStruct((B, 1, arch.d_model), jnp.bfloat16)}
    new["cache"] = cache_shapes(arch, B, S, cache_dtype)
    new["cache_len"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    return new


# ---------------------------------------------------------------------------
# Registry

ARCH_IDS = [
    "musicgen_large", "deepseek_moe_16b", "deepseek_v2_lite_16b",
    "qwen2_5_3b", "mistral_nemo_12b", "gemma3_4b", "llama3_2_3b",
    "phi_3_vision_4_2b", "xlstm_350m", "jamba_v0_1_52b",
]

_ALIASES = {
    "musicgen-large": "musicgen_large",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen2.5-3b": "qwen2_5_3b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "gemma3-4b": "gemma3_4b",
    "llama3.2-3b": "llama3_2_3b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "xlstm-350m": "xlstm_350m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.ARCH


def list_archs() -> list[str]:
    return list(ARCH_IDS)
