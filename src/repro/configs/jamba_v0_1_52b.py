"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba:attention 1:7 [arXiv:2403.19887].

Stacking: 4 groups of 8 (attention at index 4 of each group, MoE on every
other layer).  Sub-quadratic: Mamba state + KV cache only on 4 attention
layers → runs long_500k."""
from repro.configs import ArchConfig
from repro.models.transformer import LayerSpec

_G = (
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("attn", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
)

ARCH = ArchConfig(
    name="jamba-v0.1-52b",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    group=_G, n_groups=4,
    moe_routed=16, moe_shared=0, moe_top_k=2, moe_d_ff=14336,
    ssm_chunk=128,
    ssm_scan_dtype="bfloat16",   # §Perf: halves SSM scan HBM traffic
    sub_quadratic=True, family="hybrid",
)
