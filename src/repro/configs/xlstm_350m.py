"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks [arXiv:2405.04517].

No FFN (the xLSTM blocks contain their own up/down projections).
Sub-quadratic: runs long_500k (O(1)-state decode)."""
from repro.configs import ArchConfig
from repro.models.transformer import LayerSpec

ARCH = ArchConfig(
    name="xlstm-350m",
    d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab=50304,
    group=(LayerSpec("slstm", "none"), LayerSpec("mlstm", "none")),
    n_groups=12,
    xlstm_proj_factor=2.0,
    sub_quadratic=True, family="ssm",
    sharding_profile="dp_tp",   # §Perf: 350M params — FSDP gathers cost more than replication
)
