"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend
[hf:microsoft/Phi-3-vision-128k-instruct].

The CLIP vision tower is a STUB: input_specs feeds precomputed patch
embeddings merged into the token stream (B, S, d_model)."""
from repro.configs import ArchConfig
from repro.models.transformer import LayerSpec

ARCH = ArchConfig(
    name="phi-3-vision-4.2b",
    d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064,
    group=(LayerSpec("attn", "dense"),), n_groups=32,
    modality="embed_in", family="vlm",
)
