"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — QKV bias [hf:Qwen/Qwen2.5-3B]."""
from repro.configs import ArchConfig
from repro.models.transformer import LayerSpec

ARCH = ArchConfig(
    name="qwen2.5-3b",
    d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, vocab=151936, qkv_bias=True, rope_theta=1_000_000.0,
    group=(LayerSpec("attn", "dense"),), n_groups=36,
    family="dense",
)
