"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local(window 1024):global, 128k ctx
[hf:google/gemma-3-4b-pt].

Stacking: 5 groups of (5 local + 1 global) + 4 trailing local layers.
long_500k is SKIPPED: the global layers are quadratic (DESIGN §4)."""
from repro.configs import ArchConfig
from repro.models.transformer import LayerSpec

_LOCAL = LayerSpec("attn", "dense", window=1024)
_GLOBAL = LayerSpec("attn", "dense")

ARCH = ArchConfig(
    name="gemma3-4b",
    d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144, embed_scale=True, rope_theta=1_000_000.0,
    group=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL), n_groups=5,
    postlude=(_LOCAL, _LOCAL, _LOCAL, _LOCAL),
    family="dense",
)
