"""musicgen-large [audio]: 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Modality frontend (EnCodec encoder) is a STUB: input_specs feeds precomputed
frame embeddings (B, S, d_model); the LM head predicts codebook tokens."""
from repro.configs import ArchConfig
from repro.models.transformer import LayerSpec

ARCH = ArchConfig(
    name="musicgen-large",
    d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048,
    group=(LayerSpec("attn", "dense"),), n_groups=48,
    modality="embed_in", family="audio",
)
