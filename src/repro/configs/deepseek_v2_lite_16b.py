"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408(expert)
vocab=102400, MLA kv_lora=512, MoE 64 routed top-6 + 2 shared
[arXiv:2405.04434; hf].

Assignment-spec note (DESIGN §4): the line gives both "64e top-6" and
"160 routed"; we follow the primary spec (64 routed).  Layer 0 dense
(d_ff 10944) per the paper."""
from repro.configs import ArchConfig
from repro.models.transformer import LayerSpec

ARCH = ArchConfig(
    name="deepseek-v2-lite-16b",
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab=102400,
    prelude=(LayerSpec("mla", "dense"),),
    group=(LayerSpec("mla", "moe"),), n_groups=26,
    moe_routed=64, moe_shared=2, moe_top_k=6, moe_d_ff=1408,
    kv_lora_rank=512, qk_rope_dim=64, v_head_dim=128,
    family="moe",
)
