"""AdamW + cosine schedule + global-norm clipping, pure JAX (no optax
dependency).  Optimizer state shards identically to the parameters (ZeRO-3
equivalent under the FSDP rules)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptimConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict[str, Any]:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"mu": zeros(), "nu": zeros(),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: OptimConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, state["step"])
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:                     # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
