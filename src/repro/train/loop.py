"""Training loop with fault tolerance.

* periodic async checkpoints (model + optimizer + data cursor + rng),
* SIGTERM/SIGINT preemption handler → final checkpoint → clean exit,
* resume-from-latest on start (including after simulated failures),
* metrics through the LaFP lazy-sink machinery (host transfers batched like
  lazy print),
* deterministic data order across restarts via the checkpointed cursor.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Iterator

import jax
import numpy as np

from ..ckpt.checkpoint import CheckpointManager
from ..data.pipeline import PipelineState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_ckpt: bool = True


class Trainer:
    def __init__(self, train_step: Callable, init_state: dict,
                 data: Iterator, loop_cfg: LoopConfig,
                 pipeline_state: PipelineState | None = None,
                 log_fn: Callable | None = None):
        self.train_step = train_step
        self.state = init_state
        self.data = data
        self.cfg = loop_cfg
        self.mgr = CheckpointManager(loop_cfg.ckpt_dir, loop_cfg.keep)
        self.pipeline_state = pipeline_state or PipelineState()
        self.log = log_fn or (lambda m: print(m, flush=True))
        self.step = 0
        self._preempted = False
        self.metrics_history: list[dict] = []

    # -- fault tolerance -----------------------------------------------------
    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # non-main thread (tests)

    def try_resume(self) -> bool:
        latest = self.mgr.latest_step()
        if latest is None:
            return False
        step, state, extras = self.mgr.restore(latest)
        self.state = state
        self.step = step
        if "pipeline" in extras:
            self.pipeline_state = PipelineState.from_dict(extras["pipeline"])
        self.log({"event": "resumed", "step": step})
        return True

    def _checkpoint(self, block=False):
        extras = {"pipeline": self.pipeline_state.to_dict()}
        self.mgr.save(self.step, self.state, extras,
                      block=block or not self.cfg.async_ckpt)

    # -- main loop --------------------------------------------------------------
    def run(self) -> dict:
        self._install_signal_handlers()
        t0 = time.perf_counter()
        tokens_seen = 0
        last_loss = None
        for batch in self.data:
            if self.step >= self.cfg.total_steps or self._preempted:
                break
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            self.state, metrics = self.train_step(self.state, batch)
            self.step += 1
            tokens_seen += int(np.prod(batch["labels"].shape))
            if self.step % self.cfg.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = self.step
                m["tokens"] = tokens_seen
                self.metrics_history.append(m)
                self.log(m)
                last_loss = m.get("loss")
            if self.step % self.cfg.ckpt_every == 0:
                self._checkpoint()
        self._checkpoint(block=True)
        self.mgr.wait()
        wall = time.perf_counter() - t0
        return {"steps": self.step, "wall_seconds": wall,
                "tokens": tokens_seen, "final_loss": last_loss,
                "preempted": self._preempted}
