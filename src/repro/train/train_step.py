"""Loss + train step.

Cross-entropy keeps logits **vocab-sharded** (model axis): logsumexp reduces
over the sharded vocab dim with partial sums (GSPMD inserts one small
all-reduce of (B,S) instead of gathering (B,S,V)), and the label logit is a
fused one-hot contraction — the naive gather over a sharded vocab dim would
all-to-all.  ``loss_mode="gather_logits"`` keeps the naive version as the
paper-faithful lazy-framework baseline for §Perf.

Grad accumulation is a `lax.scan` over microbatches so XLA overlaps each
microbatch's reduce-scatter with the next one's compute.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..models.transformer import forward
from .optim import OptimConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optim: OptimConfig = dataclasses.field(default_factory=OptimConfig)
    microbatches: int = 1
    aux_weight: float = 0.01
    loss_mode: str = "sharded_vocab"    # sharded_vocab | gather_logits
    compress_pod_grads: bool = False
    z_loss: float = 0.0


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mode: str = "sharded_vocab", z_loss: float = 0.0):
    """logits (B,T,V) f32-accurate CE; labels (B,T) int32; -100 → masked."""
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    logits = logits.astype(jnp.float32)
    if mode == "gather_logits":
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    else:
        # vocab-sharded-friendly: partial max/sum over V fuse with the matmul
        from ..distributed.sharding import shard_logits
        m = jnp.max(logits, axis=-1)
        lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
        # one-hot must be pinned to the logits' vocab sharding, else GSPMD
        # materializes it V-replicated (33 GB/device at V=128k!)
        onehot = shard_logits(jax.nn.one_hot(safe, logits.shape[-1],
                                             dtype=jnp.bfloat16))
        lab = jnp.sum(logits * onehot.astype(jnp.float32), axis=-1)
    nll = (lse - lab) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
    if z_loss:
        loss = loss + z_loss * jnp.sum(jnp.square(lse) * mask) \
            / jnp.maximum(jnp.sum(mask), 1)
    return loss


def loss_fn(params, cfg, batch: dict, tcfg: TrainConfig):
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    logits, _, aux = forward(params, cfg, inputs, mode="train")
    ce = cross_entropy(logits, batch["labels"], tcfg.loss_mode, tcfg.z_loss)
    return ce + tcfg.aux_weight * aux, {"ce": ce, "aux": aux}


def make_train_step(cfg, tcfg: TrainConfig, grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "residuals"?}.  Microbatching splits the batch
    on dim 0 and scans, accumulating grads in f32.

    ``grad_shardings`` (a pytree of NamedSharding matching params) pins each
    gradient to its parameter's FSDP×TP sharding — without it GSPMD
    all-reduces full-size gradients over the data axis (52 B params → 208
    GB/step on jamba) instead of reduce-scattering to the shards (§Perf
    iteration 1)."""

    def _constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, tcfg)
        return loss, parts, _constrain_grads(grads)

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            def split(x):
                B = x.shape[0]
                mb = tcfg.microbatches
                return x.reshape(mb, B // mb, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                loss, parts, grads = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(lambda g:
                                                g.astype(jnp.float32), grads))
                return acc, loss
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, losses = jax.lax.scan(body, zero, micro)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = jnp.mean(losses)
        else:
            loss, parts, grads = grads_of(params, batch)

        if tcfg.compress_pod_grads:
            from ..distributed.compression import compress_tree
            grads, new_res = compress_tree(grads, state.get("residuals"))
        else:
            new_res = state.get("residuals")

        new_params, new_opt, om = adamw_update(tcfg.optim, params, grads,
                                               state["opt"])
        metrics = {"loss": loss, **om}
        new_state = {"params": new_params, "opt": new_opt}
        if new_res is not None:
            new_state["residuals"] = new_res
        return new_state, metrics

    return train_step


def make_eval_step(cfg, tcfg: TrainConfig):
    def eval_step(params, batch):
        loss, parts = loss_fn(params, cfg, batch, tcfg)
        return {"loss": loss, **parts}
    return eval_step
