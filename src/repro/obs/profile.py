"""`Profile` — what ``with pd.profile() as prof:`` yields.

A profile attaches to the current session's tracer for the duration of the
block, collecting every finished span into a bounded ring plus the counter
deltas accumulated while it was open.  Exporters: ``render()`` (text span
tree), ``to_chrome_trace()`` / ``save_chrome_trace()`` (perfetto), and
``to_jsonl()``.
"""
from __future__ import annotations

import contextlib
import json

from .export import to_chrome_trace, write_jsonl
from .spans import Span

DEFAULT_MAX_SPANS = 65_536

_DETAIL_ATTRS = ("op", "engine", "force_reason", "segment", "rows_in",
                 "rows_out", "bytes_out", "bytes_moved", "peak_bytes",
                 "est_work", "segments", "device_resident", "status",
                 "jit_seconds", "node_id", "payload")


class Profile:
    """Completed-span ring + counter deltas for one profiled block."""

    def __init__(self, session: str = "",
                 max_spans: int = DEFAULT_MAX_SPANS):
        self.session = session
        self.max_spans = max_spans
        self.spans: list[Span] = []          # completion order
        self.dropped = 0
        self.counters: dict[str, int] = {}
        self.gauges: dict[str, float] = {}

    # -- collection (called by Tracer._finish) ------------------------------

    def _add(self, span: Span) -> None:
        self.spans.append(span)
        if self.max_spans and len(self.spans) > self.max_spans:
            excess = len(self.spans) - self.max_spans
            del self.spans[:excess]
            self.dropped += excess

    # -- queries ------------------------------------------------------------

    def find(self, name: str | None = None, **attrs) -> list[Span]:
        """Spans matching a name and/or attribute equality filters."""
        out = []
        for s in self.spans:
            if name is not None and s.name != name:
                continue
            if any(s.attrs.get(k) != v for k, v in attrs.items()):
                continue
            out.append(s)
        return out

    def span_names(self) -> set[str]:
        return {s.name for s in self.spans}

    def total_seconds(self) -> float:
        if not self.spans:
            return 0.0
        return max(s.t1 or s.t0 for s in self.spans) \
            - min(s.t0 for s in self.spans)

    # -- rendering -----------------------------------------------------------

    def render(self) -> str:
        """Human-readable span tree (chronological, indented by parent)."""
        lines = [f"profile session={self.session} spans={len(self.spans)}"
                 + (f" dropped={self.dropped}" if self.dropped else "")]
        ids = {s.id for s in self.spans}
        children: dict[int | None, list[Span]] = {}
        for s in self.spans:
            parent = s.parent_id if s.parent_id in ids else None
            children.setdefault(parent, []).append(s)
        for group in children.values():
            group.sort(key=lambda s: s.t0)

        def emit(span: Span, depth: int) -> None:
            detail = " ".join(
                f"{k}={span.attrs[k]}" for k in _DETAIL_ATTRS
                if k in span.attrs)
            lines.append(f"{'  ' * depth}{span.name} "
                         f"{span.duration * 1e3:.3f}ms"
                         + (f" {detail}" if detail else ""))
            for child in children.get(span.id, ()):
                emit(child, depth + 1)

        for root in children.get(None, ()):
            emit(root, 1)
        if self.counters:
            lines.append("counters: " + " ".join(
                f"{k}={v}" for k, v in sorted(self.counters.items())))
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()

    # -- export --------------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        return to_chrome_trace(self.spans, counters=self.counters,
                               session=self.session)

    def save_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path

    def to_jsonl(self, path: str) -> int:
        return write_jsonl(self.spans, path)


@contextlib.contextmanager
def profile(ctx=None, max_spans: int = DEFAULT_MAX_SPANS):
    """Collect a :class:`Profile` of everything the session executes inside
    the block:

        with pd.profile() as prof:
            pd.analyze()
            ...
        print(prof.render())

    Attaches to the *current* session's tracer (or ``ctx``'s, when given):
    sessions opened inside the block have their own tracers and are not
    captured.  Profiles nest — each sees the spans finished while it was
    open."""
    from repro.core.context import get_context
    ctx = ctx if ctx is not None else get_context()
    tracer = ctx.tracer
    prof = Profile(session=getattr(ctx, "session_name", ""),
                   max_spans=max_spans)
    metrics = getattr(ctx, "metrics", None)
    counters_before = metrics.snapshot() if metrics is not None else {}
    persist_before = dict(getattr(ctx, "persist_stats", {}))
    tracer.attach(prof)
    try:
        yield prof
    finally:
        tracer.detach(prof)
        if metrics is not None:
            prof.counters = metrics.delta(counters_before,
                                          metrics.snapshot())
            prof.gauges = metrics.gauges()
        for key, value in getattr(ctx, "persist_stats", {}).items():
            delta = value - persist_before.get(key, 0)
            if delta:
                prof.counters[f"persist.{key}"] = delta
        if self_dropped := prof.dropped:
            prof.counters["spans.dropped"] = self_dropped
