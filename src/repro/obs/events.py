"""Structured trace events + the bounded trace log.

``ctx.planner_trace`` / ``ctx.fallback_trace`` historically were unbounded
plain lists; a long-lived serving session accumulated entries forever.
:class:`TraceLog` is the drop-in replacement: a ``list`` subclass whose
``append`` evicts the oldest entries past a configurable limit
(``session(trace_limit=...)``), counting what it dropped.

:class:`PlannerEvent` migrates the planner's string trace onto structured
events without breaking a single existing consumer: it *is* a ``str`` (the
legacy rendering — ``"device-resident" in line`` keeps working) carrying a
``kind`` tag and a ``fields`` dict for programmatic access.
"""
from __future__ import annotations

import threading

DEFAULT_TRACE_LIMIT = 10_000


class TraceLog(list):
    """Bounded append-log: keeps the newest ``limit`` entries, counts
    evictions in ``dropped``.  ``limit=None`` (or 0) disables bounding.

    Appends are lock-guarded: the eviction step is a read-modify-write
    (append, then trim) that two racing appenders could interleave into a
    lost ``dropped`` count or an over-limit log.  Sessions are single-owner
    by contract, but facade fallbacks and engine callbacks may append from
    worker threads, so the log itself stays safe."""

    def __init__(self, limit: int | None = DEFAULT_TRACE_LIMIT):
        super().__init__()
        self.limit = limit
        self.dropped = 0
        self._lock = threading.Lock()

    def append(self, item) -> None:
        with self._lock:
            super().append(item)
            if self.limit and len(self) > self.limit:
                excess = len(self) - self.limit
                del self[:excess]
                self.dropped += excess

    def extend(self, items) -> None:
        for item in items:
            self.append(item)


class PlannerEvent(str):
    """A planner-trace entry: a structured event that renders as (and *is*)
    its legacy string form.

    ``kind`` tags the event type (``"segment"``, ``"handoff"``,
    ``"calibration"``, ``"peak-calibration"``, ``"native-fallback"``,
    ``"note"``); ``fields`` holds the typed payload that used to be
    embedded in the string."""

    def __new__(cls, text: str, kind: str = "note", **fields):
        self = super().__new__(cls, text)
        self.kind = kind
        self.fields = fields
        return self

    def to_dict(self) -> dict:
        return {"kind": self.kind, "text": str(self), **self.fields}
