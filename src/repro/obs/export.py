"""Profile exporters: Chrome trace-event JSON (perfetto-compatible), JSONL
span sink, and a schema validator used by tests and CI.

The Chrome trace format is the ``{"traceEvents": [...]}`` object form of
the Trace Event specification: complete events (``ph: "X"``) with
microsecond ``ts``/``dur``, one row per thread, span attributes in
``args``.  Open the file at https://ui.perfetto.dev or
``chrome://tracing``.
"""
from __future__ import annotations

import json
from typing import Iterable

_PHASES = {"X", "B", "E", "i", "I", "C", "M", "b", "e", "n", "s", "t", "f"}


def _display_name(span) -> str:
    op = span.attrs.get("op")
    if span.name == "operator" and op:
        return f"op:{op}"
    if span.name == "segment":
        return f"segment:{span.attrs.get('engine', '?')}"
    return span.name


def to_chrome_trace(spans: Iterable, counters: dict | None = None,
                    session: str = "") -> dict:
    """Chrome trace-event JSON for a span list.  Timestamps are rebased to
    the earliest span so traces start at t=0."""
    spans = list(spans)
    base = min((s.t0 for s in spans), default=0.0)
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
        "args": {"name": f"repro session={session or '?'}"}}]
    for s in spans:
        end = s.t1 if s.t1 is not None else s.t0
        events.append({
            "name": _display_name(s),
            "cat": s.name,
            "ph": "X",
            "ts": (s.t0 - base) * 1e6,
            "dur": max((end - s.t0) * 1e6, 0.001),
            "pid": 1,
            "tid": s.thread_id % 100_000,
            "args": {"span_id": s.id, "parent_id": s.parent_id,
                     **{k: _jsonable(v) for k, v in s.attrs.items()}},
        })
    if counters:
        ts = max((e["ts"] + e.get("dur", 0) for e in events[1:]), default=0)
        events.append({
            "name": "counters", "ph": "C", "ts": ts, "pid": 1, "tid": 0,
            "args": {k: v for k, v in counters.items()
                     if isinstance(v, (int, float))}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def validate_chrome_trace(obj) -> bool:
    """Assert ``obj`` is schema-valid trace-event JSON; raises
    ``ValueError`` with the first violation, returns True when clean."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with a traceEvents list")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for key in ("name", "ph", "pid"):
            if key not in ev:
                raise ValueError(f"event {i} missing required key {key!r}")
        if not isinstance(ev["name"], str):
            raise ValueError(f"event {i} name must be a string")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        if ev["ph"] == "X":
            if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
                raise ValueError(f"event {i} needs numeric ts >= 0")
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i} needs numeric dur >= 0")
    return True


def write_jsonl(spans: Iterable, path: str) -> int:
    """One span per line as JSON; returns the number written."""
    n = 0
    with open(path, "w") as f:
        for s in spans:
            f.write(json.dumps(s.to_dict(), default=str) + "\n")
            n += 1
    return n
