"""Counters and gauges registry — one per session context.

Counters are monotonically increasing event counts (cache hits, fallback
events, calibration samples, shard exchanges); gauges are last-written
values (peak bytes).  ``Profile`` reports the counter *delta* over the
profiled block, so long-lived sessions don't leak history into a profile.

Counter glossary (what the built-in layers emit):

==============================  =============================================
``persist.hits``/``.misses``    §3.5 reuse-cache lookups (from persist_stats)
``plan_cache.hits``             force points served by the plan cache (warm
                                bind, optimize/rewrite/segment-DP skipped)
``plan_cache.misses``           cacheable plans planned cold and stored
``plan_cache.uncacheable``      plans the fingerprint refuses (UDF/MapRows,
                                sinks, materialized/handoff payloads)
``fallback.served``             facade ops served by the fallback protocol
``fallback.failed``             facade ops with no registered kernel
``calibration.runtime_samples`` (work, seconds) samples fed to StatsStore
``calibration.peak_samples``    (est, observed) peak samples fed to StatsStore
``stats.cardinalities``         observed-cardinality records after a run
``exchange.shuffles``           distributed shuffle exchanges (join/sort/…)
``exchange.shards``             shard partitions moved across those shuffles
``distributed.native_fallbacks`` sharded native paths that fell back to eager
``spans.dropped``               spans discarded by a full profile ring
``io.partitions_loaded``        source partitions actually decoded from disk
``io.partitions_pruned``        partitions skipped via zone-map/pushdown
                                pruning (never read)
``io.partitions_prefetched``    partitions decoded ahead of the consumer by
                                the async prefetcher (streaming backend)
``io.bytes_read``               decoded bytes of loaded partitions (the
                                pushdown benchmark's figure of merit)
``io.pushdown_rows_in``/
``io.pushdown_rows_out``        rows entering / surviving pushed-down
                                predicates at the scan layer
==============================  =============================================
"""
from __future__ import annotations

import threading


class MetricsRegistry:
    """Thread-safe named counters + gauges."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, float] = {}

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def counter(self, name: str) -> int:
        return self._counters.get(name, 0)

    def snapshot(self) -> dict[str, int]:
        """Copy of all counters (for delta computation)."""
        with self._lock:
            return dict(self._counters)

    def gauges(self) -> dict[str, float]:
        with self._lock:
            return dict(self._gauges)

    @staticmethod
    def delta(before: dict[str, int], after: dict[str, int]
              ) -> dict[str, int]:
        """Nonzero counter increments between two snapshots."""
        out = {}
        for name, value in after.items():
            d = value - before.get(name, 0)
            if d:
                out[name] = d
        return out
