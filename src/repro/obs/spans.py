"""Spans and tracers — the core of the telemetry subsystem.

A :class:`Span` is one timed region with attributes (rows in/out, bytes
moved, peak memory, engine, …) and a parent link, so force points nest as
``execute → plan → segment → operator / handoff / fallback`` trees.

The :class:`Tracer` lives on the session context (``ctx.tracer``) and is
*disabled* until a :class:`~repro.obs.profile.Profile` attaches.  Disabled
tracing must cost nearly nothing on hot paths, so there are two gates:

* ``tracing_active()`` — one module-global integer check, no context
  lookup.  ``traced_op``-wrapped physical operators test this first and
  call straight through when no profile exists anywhere in the process.
* ``Tracer.span()`` — returns the shared :data:`NOOP_SPAN` when this
  particular session has no attached profile.

``Tracer.timed_span()`` always returns a real span: the runtime uses it
for segment/engine wall time, which feeds the planner's cost calibration
(``StatsStore.record_runtime``) whether or not anyone is profiling — spans
are the *single* timing instrumentation point.
"""
from __future__ import annotations

import functools
import itertools
import threading
import time

_ids = itertools.count(1)

# module-global count of tracers with an attached profile; the process-wide
# fast gate for operator instrumentation (one int check when disabled)
_ACTIVE_TRACERS = 0
_ACTIVE_LOCK = threading.Lock()


def tracing_active() -> bool:
    """True when any session in the process has an attached profile."""
    return _ACTIVE_TRACERS > 0


class Span:
    """One timed region.  Context-manager use finishes the span and hands
    it to the owning tracer's attached profiles."""

    __slots__ = ("id", "parent_id", "name", "t0", "t1", "attrs",
                 "thread_id", "_tracer")

    def __init__(self, name: str, parent_id: int | None = None,
                 attrs: dict | None = None, tracer: "Tracer | None" = None):
        self.id = next(_ids)
        self.parent_id = parent_id
        self.name = name
        self.attrs = dict(attrs) if attrs else {}
        self.thread_id = threading.get_ident()
        self._tracer = tracer
        self.t1: float | None = None
        self.t0 = time.perf_counter()

    @property
    def duration(self) -> float:
        """Wall seconds (to now, for a still-open span)."""
        return (self.t1 if self.t1 is not None else time.perf_counter()) \
            - self.t0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def finish(self) -> "Span":
        if self.t1 is None and self._tracer is not None:
            self._tracer._finish(self)
        elif self.t1 is None:
            self.t1 = time.perf_counter()
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.finish()

    def to_dict(self) -> dict:
        return {"id": self.id, "parent_id": self.parent_id,
                "name": self.name, "t0": self.t0, "t1": self.t1,
                "duration": self.duration, "thread_id": self.thread_id,
                "attrs": dict(self.attrs)}

    def __repr__(self) -> str:
        return (f"Span({self.name!r} #{self.id} {self.duration * 1e3:.3f}ms "
                f"{self.attrs})")


class _NoopSpan:
    """Shared do-nothing span returned on every disabled-tracing path."""

    __slots__ = ()
    id = 0
    parent_id = None
    name = "noop"
    duration = 0.0
    attrs: dict = {}

    def set(self, **attrs):
        return self

    def finish(self):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def __bool__(self):
        return False


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Per-session span factory.  Thread-safe: the open-span stack is
    thread-local, so concurrent sessions (or one session crossing threads)
    never mis-parent spans."""

    def __init__(self, session: str = ""):
        self.session = session
        self._profiles: list = []       # attached Profile sinks
        self._tls = threading.local()

    # -- state -------------------------------------------------------------

    @property
    def enabled(self) -> bool:
        return bool(self._profiles)

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def current_span(self) -> Span | None:
        stack = self._stack()
        return stack[-1] if stack else None

    # -- span creation -----------------------------------------------------

    def span(self, name: str, **attrs) -> Span | _NoopSpan:
        """A span recorded only while a profile is attached; the no-op
        fast path otherwise."""
        if not self._profiles:
            return NOOP_SPAN
        return self._start(name, attrs)

    def timed_span(self, name: str, **attrs) -> Span:
        """A real (self-timing) span regardless of profiling state — for
        sites whose duration feeds calibration, not just profiles."""
        return self._start(name, attrs)

    def event(self, name: str, **attrs) -> Span | _NoopSpan:
        """Zero-duration instant event (recorded only when enabled)."""
        sp = self.span(name, **attrs)
        if sp is not NOOP_SPAN:
            sp.finish()
        return sp

    def _start(self, name: str, attrs: dict) -> Span:
        stack = self._stack()
        parent = stack[-1].id if stack else None
        sp = Span(name, parent_id=parent, attrs=attrs, tracer=self)
        stack.append(sp)
        return sp

    def _finish(self, sp: Span) -> None:
        sp.t1 = time.perf_counter()
        stack = self._stack()
        if stack and stack[-1] is sp:
            stack.pop()
        else:                            # out-of-order finish: best effort
            try:
                stack.remove(sp)
            except ValueError:
                pass
        for prof in tuple(self._profiles):
            prof._add(sp)

    # -- profile attachment ------------------------------------------------

    def attach(self, profile) -> None:
        global _ACTIVE_TRACERS
        with _ACTIVE_LOCK:
            self._profiles.append(profile)
            _ACTIVE_TRACERS += 1

    def detach(self, profile) -> None:
        global _ACTIVE_TRACERS
        with _ACTIVE_LOCK:
            try:
                self._profiles.remove(profile)
            except ValueError:
                return
            _ACTIVE_TRACERS -= 1


# ---------------------------------------------------------------------------
# Hot-path helpers for code without a context in hand (physical operators).


def _current_tracer() -> Tracer | None:
    from repro.core.context import get_context
    return getattr(get_context(), "tracer", None)


def op_span(op: str, **attrs) -> Span | _NoopSpan:
    """Operator span via the current session's tracer; no-op when the
    process has no active profile (one int check) or this session's tracer
    is disabled."""
    if not _ACTIVE_TRACERS:
        return NOOP_SPAN
    tracer = _current_tracer()
    if tracer is None or not tracer._profiles:
        return NOOP_SPAN
    return tracer.span("operator", op=op, **attrs)


def io_span(op: str, tracer: Tracer | None = None, **attrs) -> Span | _NoopSpan:
    """IO-layer span (partition load, prefetch, ingest) with the same
    disabled-cost profile as :func:`op_span`.  Accepts an explicit tracer
    for call sites off the session thread — the prefetch worker passes the
    owning session's tracer, since the context lookup is thread-local."""
    if not _ACTIVE_TRACERS:
        return NOOP_SPAN
    t = tracer if tracer is not None else _current_tracer()
    if t is None or not t._profiles:
        return NOOP_SPAN
    return t.span("io", op=op, **attrs)


def metric_inc(name: str, n: int = 1) -> None:
    """Increment a counter on the current session's metrics registry."""
    from repro.core.context import get_context
    metrics = getattr(get_context(), "metrics", None)
    if metrics is not None:
        metrics.inc(name, n)


def _rows_of(value) -> int | None:
    if isinstance(value, dict):
        if not value:
            return 0
        shape = getattr(next(iter(value.values())), "shape", None)
        return int(shape[0]) if shape else None
    rows = getattr(value, "rows", None)
    if callable(rows) and hasattr(value, "valid"):    # ShardedTable
        try:
            return int(value.rows())
        except Exception:  # noqa: BLE001 — metadata only, never fail the op
            return None
    return None


def _bytes_of(value) -> int | None:
    if isinstance(value, dict):
        return int(sum(int(getattr(c, "nbytes", 0) or 0)
                       for c in value.values()))
    nbytes = getattr(value, "nbytes", None)
    if callable(nbytes):
        try:
            return int(nbytes())
        except Exception:  # noqa: BLE001
            return None
    return int(nbytes) if isinstance(nbytes, (int, float)) else None


rows_of = _rows_of
bytes_of = _bytes_of


def traced_op(op: str):
    """Instrument a physical operator with a per-call span (rows in/out,
    bytes out).  The disabled path is one module-global int check before
    calling straight through; the original is kept on ``__wrapped__`` so
    the observability benchmark can measure a truly uninstrumented
    baseline."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _ACTIVE_TRACERS:
                return fn(*args, **kwargs)
            sp = op_span(op)
            if sp is NOOP_SPAN:
                return fn(*args, **kwargs)
            with sp:
                rows_in = _rows_of(args[0]) if args else None
                if rows_in is not None:
                    sp.attrs["rows_in"] = rows_in
                out = fn(*args, **kwargs)
                rows_out = _rows_of(out)
                if rows_out is not None:
                    sp.attrs["rows_out"] = rows_out
                bytes_out = _bytes_of(out)
                if bytes_out is not None:
                    sp.attrs["bytes_out"] = bytes_out
            return out

        wrapper.__wrapped__ = fn
        return wrapper

    return deco
