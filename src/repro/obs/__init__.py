"""``repro.obs`` — structured telemetry: spans, metrics, profile export.

Every execution layer (JIT analysis, planner, runtime segments, physical
operators, handoffs, fallbacks) emits hierarchical :class:`Span` records
through the session context's :class:`Tracer`.  Tracing is **near-zero-cost
when disabled**: the hot-path gate is a single module-global integer check
(``spans.tracing_active``) and operators receive a shared no-op span — the
``benchmarks/run.py observability`` figure measures and CI bounds the
overhead (< 3% vs an uninstrumented baseline).

User surface (re-exported as ``repro.pandas.profile``):

    with pd.profile() as prof:
        ...plain pandas-style code...
    print(prof.render())            # span tree with durations + attributes
    prof.counters                   # counter deltas for the profiled block
    prof.to_chrome_trace()          # trace-event JSON; open in perfetto
    prof.save_chrome_trace("t.json")

Module map
----------
``spans``    Span / Tracer / no-op fast path / ``traced_op`` decorator
``metrics``  per-session counters + gauges registry
``events``   bounded TraceLog ring + structured PlannerEvent strings
``export``   Chrome trace-event JSON, JSONL sink, schema validation
``profile``  Profile object + ``profile()`` context manager
"""
from __future__ import annotations

from .events import DEFAULT_TRACE_LIMIT, PlannerEvent, TraceLog
from .export import to_chrome_trace, validate_chrome_trace, write_jsonl
from .metrics import MetricsRegistry
from .profile import Profile, profile
from .spans import (NOOP_SPAN, Span, Tracer, metric_inc, op_span, traced_op,
                    tracing_active)

__all__ = [
    "Span", "Tracer", "NOOP_SPAN", "tracing_active", "traced_op", "op_span",
    "metric_inc", "MetricsRegistry", "TraceLog", "PlannerEvent",
    "DEFAULT_TRACE_LIMIT", "to_chrome_trace", "validate_chrome_trace",
    "write_jsonl", "Profile", "profile",
]
