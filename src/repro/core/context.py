"""Session context: engine choice, sink ordering chain, persist cache,
static-analysis hints (the runtime side of the paper's JIT analysis).

Contexts are *session-scoped*: ``get_context()`` returns the top of a
thread-local session stack, falling back to a process-wide default session.
``session(...)`` is the public context manager (re-exported as
``repro.pandas.session``) giving an isolated planner / persist / sink /
stats state; nested sessions stack, and each thread gets its own stack so
concurrent sessions never share mutable state.

Engines are addressed by **string name** (``"eager"``, ``"streaming"``,
``"distributed"``, ``"auto"``, plus anything registered through
``repro.register_engine`` / the ``repro.engines`` entry-point group).
``BackendEngines`` survives as a deprecated ``str``-mixin enum alias layer:
its members compare and hash equal to the plain names, so legacy code
keeps working while new code writes ``session(engine="streaming")``.

Concurrency invariants (the contract the serving tests in
``tests/test_serving.py`` pin down):

* The session stack is **thread-local**: ``get_context()`` in one thread
  never sees another thread's pushed sessions.  A serving worker must push
  its own session (``with session(...)``) — the process-wide default
  context is shared by every thread that never pushed one and is *not*
  synchronized; concurrent work must not run against it.
* Everything hanging off one ``LaFPContext`` (persist cache, stats store,
  traces, run records) is owned by that session; two sessions share no
  mutable state.  Sharing one context across threads is not supported.
* Cross-session shared state is individually synchronized: the engine
  registry (``RLock``), ``MetricsRegistry`` (lock per registry),
  ``TraceLog`` (lock per log), the process-global plan cache
  (``planner.plancache.PlanCache``, lock + immutable entries, fresh node
  clones per hit), and the stats persistence files (``StatsStore.save`` /
  ``load`` append to a log under an ``fcntl`` file lock)."""
from __future__ import annotations

import contextlib
import enum
import threading
import warnings
from typing import Any

from . import graph
from .engines import normalize_engine
from ..obs.events import DEFAULT_TRACE_LIMIT, TraceLog
from ..obs.metrics import MetricsRegistry
from ..obs.spans import Tracer


class BackendEngines(str, enum.Enum):
    """DEPRECATED alias layer for the string-named engine API.

    Members are ``str`` subclasses equal to their engine name, so
    ``BackendEngines.STREAMING == "streaming"`` and either form is accepted
    anywhere an engine is named.  New code should pass the strings; the
    open registry (``repro.register_engine``) admits engines this closed
    enum can never know about."""
    EAGER = "eager"            # device-resident jnp, whole-table (Pandas analogue)
    STREAMING = "streaming"    # host out-of-core, partition-at-a-time (Dask analogue)
    DISTRIBUTED = "distributed"  # shard_map over mesh data axis (Modin/cluster analogue)
    AUTO = "auto"              # cost-based per-force-point choice (planner/)


class LaFPContext:
    def __init__(self, name: str = "default",
                 trace_limit: int | None = DEFAULT_TRACE_LIMIT):
        self.session_name = name
        # telemetry (repro.obs): per-session span tracer (no-op until a
        # profile attaches) + counters/gauges registry.  trace_limit bounds
        # the string/event trace logs below so long-lived serving sessions
        # can't grow without limit.
        self.trace_limit = trace_limit
        self.tracer = Tracer(session=name)
        self.metrics = MetricsRegistry()
        self._backend: str = "eager"
        self.backend_options: dict[str, Any] = {}
        # AUTO candidate allow-list (None → every registered engine)
        self.engine_allowlist: tuple[str, ...] | None = None
        # §3.3 lazy print: chain of sink nodes not yet flushed.
        self.last_sink: graph.SinkPrint | None = None
        self.pending_sinks: list[graph.SinkPrint] = []
        # §3.5 common computation reuse: structural-key → materialized value.
        self.persist_cache: dict[tuple, Any] = {}
        self.persist_stats = {"hits": 0, "misses": 0}
        # JIT static analysis results (source_analysis.py):
        #   usecols:   {(var, lineno) | var: tuple(cols) | None}
        #   live_at:   {lineno: [frame var names]}
        self.analysis: dict[str, Any] = {}
        # registry for f-string escapes (§3.3): uid -> node
        self.scalar_registry: dict[int, graph.Node] = {}
        # live frame tracking: var name -> LazyFrame (filled by analyze())
        self.optimizer_trace: list[str] = TraceLog(trace_limit)
        self.memory_budget: int | None = None   # bytes; chunked engines enforce
        self.last_peak_bytes: int = 0           # metered peak accounting
        self.last_run_peak_bytes: int = 0       # peak of the latest single run
        # engine that produced last_run_peak_bytes (peak-calibration samples
        # are recorded under this stats-store namespace)
        self.last_run_peak_engine: str | None = None
        # cost-based planner (planner/): AUTO plan-choice trace + feedback
        # stats store (observed cardinalities keyed by structural node key,
        # plus per-engine runtime samples for cost calibration).  AUTO
        # placement strategy is per-session via backend_options:
        #   backend_options["placement"] = "operator" (segments, default)
        #                                | "per_root" (PR-1 behaviour)
        self.planner_trace: list[str] = TraceLog(trace_limit)
        from .planner.feedback import StatsStore
        self.stats_store = StatsStore()
        # stats-store persistence: when REPRO_STATS_CACHE_DIR is set (or a
        # session passes stats_path=...), calibration + cardinality feedback
        # is reloaded here and re-saved after every execute, so AUTO
        # calibration survives process restarts (per-context cache file,
        # keyed by session name)
        import os as _os
        cache_dir = _os.environ.get("REPRO_STATS_CACHE_DIR")
        self.stats_path: str | None = (
            _os.path.join(cache_dir, f"{name}.json") if cache_dir else None)
        if self.stats_path:
            self.stats_store.load(self.stats_path)
        self.planner_decisions: list[Any] = []  # last force point's Decisions
        # plan cache (planner/plancache.py): repeated plan shapes skip
        # optimize/rewrite/segment-DP.  Per-session opt-out via
        # session(plan_cache=False); the cache itself is process-global.
        self.plan_cache_enabled = True
        self.last_plan_seconds: float = 0.0     # planning wall of last force point
        # structured per-force-point records (segments, handoffs) consumed
        # by ``repro.core.explain`` — the typed counterpart of the string
        # traces above
        self.run_records: list[Any] = []
        self.print_fn = print                   # patched in tests
        # facade fallback protocol (repro.pandas): every op the lazy layer
        # serves by eager materialization (or fails to serve at all) is
        # recorded here — coverage gaps are measured, not guessed.
        self.fallback_trace: list[Any] = TraceLog(trace_limit)  # FallbackEvents
        # force-point log: why each execute() was triggered (user compute,
        # fallback materialization, repr, flush, …)
        self.force_log: list[str] = TraceLog(trace_limit)
        # metrics
        self.exec_count = 0

    # -- engine choice (string-named; enum members accepted as aliases) -----

    @property
    def backend(self) -> str:
        return self._backend

    @backend.setter
    def backend(self, value) -> None:
        self._backend = normalize_engine(value)

    def reset(self):
        self.__init__(self.session_name, trace_limit=self.trace_limit)

    def sink_chain_add(self, sink: graph.SinkPrint):
        self.last_sink = sink
        self.pending_sinks.append(sink)

    def sinks_flushed(self):
        self.pending_sinks.clear()
        self.last_sink = None

    def report(self):
        """Typed introspection report of everything this session ran so
        far: segments (chosen engine, rejected candidates, costs), handoff
        payloads, fallback events, calibration scales.  See
        ``repro.core.explain``."""
        from .explain import build_report
        return build_report(self)


# ---------------------------------------------------------------------------
# Session stack.  The default session preserves the pre-session global
# behaviour (module-level scripts, benchmarks); pushed sessions shadow it
# per-thread.

_DEFAULT_CTX = LaFPContext()
_CTX = _DEFAULT_CTX  # back-compat alias
_TLS = threading.local()


def _stack() -> list[LaFPContext]:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


def get_context() -> LaFPContext:
    stack = _stack()
    return stack[-1] if stack else _DEFAULT_CTX


def default_context() -> LaFPContext:
    return _DEFAULT_CTX


def push_session(ctx: LaFPContext | None = None) -> LaFPContext:
    ctx = ctx if ctx is not None else LaFPContext(name="session")
    _stack().append(ctx)
    return ctx


def pop_session() -> LaFPContext:
    stack = _stack()
    if not stack:
        raise RuntimeError("pop_session() with no active session")
    return stack.pop()


def session_depth() -> int:
    return len(_stack())


@contextlib.contextmanager
def session(engine: str | BackendEngines | None = None,
            memory_budget: int | None = None,
            name: str = "session",
            stats_path: str | None = None,
            engines: tuple | list | None = None,
            backend: str | BackendEngines | None = None,
            trace_limit: int | None = DEFAULT_TRACE_LIMIT,
            plan_cache: bool = True,
            **backend_options):
    """Isolated execution session: fresh engine choice, persist cache,
    sink chain, stats store (planner feedback + runtime calibration), and
    traces.

        with repro.pandas.session(engine="streaming",
                                  memory_budget=1 << 28) as ctx:
            ...plain pandas-style code...

    ``engine`` names any registered engine (or ``"auto"``); ``backend`` is
    the deprecated alias for it and still accepts ``BackendEngines``
    members.  ``engines`` is an AUTO candidate allow-list — e.g.
    ``session(engine="auto", engines=("eager", "streaming"))`` keeps the
    planner from ever considering other engines for the block.

    Extra keyword options flow into ``ctx.backend_options`` — e.g.
    ``session(engine="auto", placement="per_root")`` selects the legacy
    per-root planner strategy for the block.  IO-layer knobs:
    ``pushdown=False`` disables the scan-pushdown optimizer pass (filters
    stay as plan nodes — the differential-testing escape hatch), and
    ``io_prefetch=N`` sets the async partition-prefetch depth for
    prefetchable on-disk sources (0 disables; default 2).

    ``stats_path`` persists the session's stats store (cardinality feedback
    + runtime/peak calibration samples) to a JSON file: reloaded here,
    re-saved after every execute — AUTO calibration survives process
    restarts.  ``REPRO_STATS_CACHE_DIR`` enables the same per-context
    persistence globally.

    ``plan_cache=False`` opts the session out of the process-global plan
    cache (``repro.core.planner.plancache``): every force point re-plans
    from scratch — the escape hatch the conformance suite uses to prove
    warm-hit results bit-identical to cold plans.

    ``trace_limit`` bounds the session's trace logs (``planner_trace``,
    ``fallback_trace``, ``force_log``, ``optimizer_trace``): the newest
    entries are kept, evictions counted on each log's ``.dropped``.  Pass
    ``None`` (or 0) for unbounded legacy behaviour.

    Pending lazy sinks are flushed on clean exit (so deferred prints inside
    the block don't silently vanish); on exception the session is popped
    unflushed."""
    if backend is not None:
        if engine is not None:
            raise TypeError("pass engine=... or backend=..., not both")
        warnings.warn(
            "session(backend=...) is deprecated; use session(engine=...) "
            "with a string engine name", DeprecationWarning, stacklevel=3)
        engine = backend
    ctx = LaFPContext(name=name, trace_limit=trace_limit)
    if engine is not None:
        ctx.backend = normalize_engine(engine, warn_enum=True)
    ctx.memory_budget = memory_budget
    if engines is not None:
        ctx.engine_allowlist = tuple(
            normalize_engine(e) for e in engines)
    if stats_path is not None:
        ctx.stats_path = stats_path
        ctx.stats_store.load(stats_path)
    ctx.plan_cache_enabled = bool(plan_cache)
    ctx.backend_options.update(backend_options)
    push_session(ctx)
    try:
        yield ctx
        if ctx.last_sink is not None:
            from .runtime import flush
            flush()
    finally:
        pop_session()
