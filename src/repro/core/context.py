"""Session context: backend choice, sink ordering chain, persist cache,
static-analysis hints (the runtime side of the paper's JIT analysis)."""
from __future__ import annotations

import enum
from typing import Any

from . import graph


class BackendEngines(enum.Enum):
    EAGER = "eager"            # device-resident jnp, whole-table (Pandas analogue)
    STREAMING = "streaming"    # host out-of-core, partition-at-a-time (Dask analogue)
    DISTRIBUTED = "distributed"  # shard_map over mesh data axis (Modin/cluster analogue)
    AUTO = "auto"              # cost-based per-force-point choice (planner/)


class LaFPContext:
    def __init__(self):
        self.backend: BackendEngines = BackendEngines.EAGER
        self.backend_options: dict[str, Any] = {}
        # §3.3 lazy print: chain of sink nodes not yet flushed.
        self.last_sink: graph.SinkPrint | None = None
        self.pending_sinks: list[graph.SinkPrint] = []
        # §3.5 common computation reuse: structural-key → materialized value.
        self.persist_cache: dict[tuple, Any] = {}
        self.persist_stats = {"hits": 0, "misses": 0}
        # JIT static analysis results (source_analysis.py):
        #   usecols:   {(var, lineno) | var: tuple(cols) | None}
        #   live_at:   {lineno: [frame var names]}
        self.analysis: dict[str, Any] = {}
        # registry for f-string escapes (§3.3): uid -> node
        self.scalar_registry: dict[int, graph.Node] = {}
        # live frame tracking: var name -> LazyFrame (filled by analyze())
        self.optimizer_trace: list[str] = []
        self.memory_budget: int | None = None   # bytes; streaming backend enforces
        self.last_peak_bytes: int = 0           # streaming backend peak accounting
        # cost-based planner (planner/): AUTO plan-choice trace + feedback
        # stats store (observed cardinalities keyed by structural node key)
        self.planner_trace: list[str] = []
        from .planner.feedback import StatsStore
        self.stats_store = StatsStore()
        self.planner_decisions: list[Any] = []  # last force point's Decisions
        self.print_fn = print                   # patched in tests
        # metrics
        self.exec_count = 0

    def reset(self):
        self.__init__()

    def sink_chain_add(self, sink: graph.SinkPrint):
        self.last_sink = sink
        self.pending_sinks.append(sink)

    def sinks_flushed(self):
        self.pending_sinks.clear()
        self.last_sink = None


_CTX = LaFPContext()


def get_context() -> LaFPContext:
    return _CTX
