"""DEPRECATED shim — this module never was a tracer.

``repro.core.tracer`` held the JIT *static-analysis* entry point
(``analyze()``), a name collision waiting to happen once the repo grew a
real tracing subsystem (``repro.obs``).  The implementation now lives in
``repro.core.jit_analyze``; import from there.  This shim re-exports the
full public surface and warns on import.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.tracer is deprecated (it is the JIT static-analysis entry "
    "point, not a tracer); import repro.core.jit_analyze instead — the "
    "tracing subsystem lives in repro.obs",
    DeprecationWarning, stacklevel=2)

from .jit_analyze import (analyze, live_frames_hint, usecols_hint,  # noqa: E402,F401
                          user_call_lineno, user_frame_locals)

__all__ = ["analyze", "usecols_hint", "live_frames_hint",
           "user_call_lineno", "user_frame_locals"]
