"""Task-graph optimizer (paper §2.6/§3): CSE, predicate pushdown with safe
points, filter fusion, projection pushdown (column selection), zone-map
partition pruning, metadata dtype narrowing.

All rules rebuild the DAG immutably; a node map from original ids to
rewritten nodes is returned so callers can re-bind frames/scalars.

Deviation from the paper (documented): for a multi-parent node whose parents
all carry (different) filters p1..pn, the paper's text pushes p1∧…∧pn below;
the sound combination is p1∨…∨pn (a row failing *all* parents' predicates is
the only kind that can be dropped).  We implement the disjunction.
"""
from __future__ import annotations

from typing import Iterable

from . import expr as E
from . import graph as G
from .context import LaFPContext


# ---------------------------------------------------------------------------
# Rebuild helpers


def _rebuild(roots: list[G.Node], replace: dict[int, G.Node]) -> tuple[list[G.Node], dict[int, G.Node]]:
    """Rebuild DAG applying id→node replacements; returns (new_roots, idmap)."""
    memo: dict[int, G.Node] = {}

    def rec(n: G.Node) -> G.Node:
        if n.id in memo:
            return memo[n.id]
        if n.id in replace:
            out = rec(replace[n.id])
        else:
            new_inputs = [rec(i) for i in n.inputs]
            if all(a is b for a, b in zip(new_inputs, n.inputs)):
                out = n
            else:
                out = G.copy_runtime_flags(n, n.with_inputs(new_inputs))
        memo[n.id] = out
        return out

    new_roots = [rec(r) for r in roots]
    return new_roots, memo


def cse(roots: list[G.Node]) -> tuple[list[G.Node], dict[int, G.Node]]:
    """Merge structurally identical nodes (redundant-computation removal)."""
    by_key: dict[tuple, G.Node] = {}
    memo: dict[int, G.Node] = {}

    def rec(n: G.Node) -> G.Node:
        if n.id in memo:
            return memo[n.id]
        new_inputs = [rec(i) for i in n.inputs]
        if not all(a is b for a, b in zip(new_inputs, n.inputs)):
            cand = G.copy_runtime_flags(n, n.with_inputs(new_inputs))
        else:
            cand = n
        key = cand.key()
        out = by_key.setdefault(key, cand)
        if out is not cand and cand.persist:
            out.persist = True
        memo[n.id] = out
        return out

    new_roots = [rec(r) for r in roots]
    return new_roots, memo


# ---------------------------------------------------------------------------
# Predicate pushdown


_SWAPPABLE = ("assign", "project", "rename", "astype", "fillna",
              "sort_values")


def _can_swap(f: G.Filter, u: G.Node, parents: dict[int, list[G.Node]]) -> bool:
    """Paper §3.2 conditions: (1) mod∩used=∅ (2) row-preserving elementwise
    (3) f is u's only parent."""
    if u.op not in _SWAPPABLE:
        return False
    if G.ALL in u.mod_attrs():
        return False
    if u.mod_attrs() & f.predicate.used_cols():
        return False
    if u.op == "project":
        # predicate must only use projected columns (it does, by construction)
        if not f.predicate.used_cols() <= frozenset(u.columns):
            return False
    if len(parents.get(u.id, [])) != 1:
        return False
    if u.has_side_effects():
        return False
    if u.persist:
        # planned materialization point (§3.5): rewriting u away would lose
        # the cached subexpression future force points expect to reuse
        return False
    return True


def _rename_pred(pred: E.Expr, inv: dict[str, str]) -> E.Expr:
    """Rewrite column refs when pushing a filter below a rename."""
    if isinstance(pred, E.Col):
        return E.Col(inv.get(pred.name, pred.name))
    if isinstance(pred, E.BinOp):
        return E.BinOp(pred.op, _rename_pred(pred.left, inv),
                       _rename_pred(pred.right, inv))
    if isinstance(pred, E.Not):
        return E.Not(_rename_pred(pred.child, inv))
    if isinstance(pred, E.Cast):
        return E.Cast(_rename_pred(pred.child, inv), pred.dtype)
    if isinstance(pred, E.DtField):
        return E.DtField(_rename_pred(pred.child, inv), pred.field)
    if isinstance(pred, E.IsIn):
        return E.IsIn(_rename_pred(pred.child, inv), pred.values)
    return pred


def push_filters(roots: list[G.Node], trace: list[str] | None = None
                 ) -> tuple[list[G.Node], dict[int, G.Node]]:
    """Iterate single-step pushes to fixpoint."""
    total_map: dict[int, G.Node] = {}
    changed = True
    guard = 0
    while changed and guard < 100:
        guard += 1
        changed = False
        parents = G.parents_map(roots)
        for n in G.walk(roots):
            if not isinstance(n, G.Filter):
                continue
            u = n.inputs[0]
            # fuse adjacent filters: Filter(Filter(x,p2),p1) → Filter(x,p1∧p2)
            if isinstance(u, G.Filter) and len(parents.get(u.id, [])) == 1 \
                    and not u.persist:
                fused = G.Filter(u.inputs[0],
                                 E.BinOp("and", u.predicate, n.predicate))
                # output == n's output: carry n's runtime flags
                G.copy_runtime_flags(n, fused)
                roots, m = _rebuild(roots, {n.id: fused})
                total_map.update(m)
                if trace is not None:
                    trace.append(f"fuse_filters #{n.id}+#{u.id}")
                changed = True
                break
            if isinstance(u, G.Join):
                outc: dict[int, frozenset | None] = {}
                for w in G.walk(roots):
                    outc[w.id] = w.out_cols([outc[i.id] for i in w.inputs])
                nr = None if u.persist else _push_into_join(n, u, parents,
                                                            trace, outc)
                if nr is not None:
                    G.copy_runtime_flags(n, nr)
                    roots, m = _rebuild(roots, {n.id: nr})
                    total_map.update(m)
                    changed = True
                    break
                continue
            if not _can_swap(n, u, parents):
                continue
            pred = n.predicate
            if isinstance(u, G.Rename):
                inv = {v: k for k, v in u.mapping.items()}
                pred = _rename_pred(pred, inv)
            new_filter = G.Filter(u.inputs[0], pred)
            # the rewritten top node produces n's (filtered) output, so it
            # inherits n's flags (persist-marked u blocks the swap above)
            new_u = G.copy_runtime_flags(n, u.with_inputs([new_filter]))
            roots, m = _rebuild(roots, {n.id: new_u})
            total_map.update(m)
            if trace is not None:
                trace.append(f"push_filter #{n.id} below {u.op}#{u.id}")
            changed = True
            break
    return roots, total_map


def _push_into_join(f: G.Filter, j: G.Join, parents, trace, outc
                    ) -> G.Node | None:
    """Push a filter into a join side when its columns come wholly from that
    side (beyond-paper; classic relational rule).  Inner joins: both sides;
    left joins: left side only."""
    if len(parents.get(j.id, [])) != 1:
        return None
    used = f.predicate.used_cols()
    lcols = outc.get(j.inputs[0].id)
    rcols = outc.get(j.inputs[1].id)
    sfx_l, sfx_r = j.suffixes
    if any(c.endswith(sfx_l) or c.endswith(sfx_r) for c in used):
        return None  # suffixed col: ambiguous provenance, stay safe
    if lcols is not None and used <= lcols:
        nl = G.Filter(j.inputs[0], f.predicate)
        if trace is not None:
            trace.append(f"push_filter #{f.id} into join left")
        return j.with_inputs([nl, j.inputs[1]])
    if (j.how == "inner" and rcols is not None and used <= rcols
            and not (used & (lcols or frozenset()))):
        nr = G.Filter(j.inputs[1], f.predicate)
        if trace is not None:
            trace.append(f"push_filter #{f.id} into join right")
        return j.with_inputs([j.inputs[0], nr])
    return None


def push_common_parent_filters(roots: list[G.Node], trace=None
                               ) -> tuple[list[G.Node], dict[int, G.Node]]:
    """Paper §3.2 multi-parent case: if *all* parents of u are filters, push
    their disjunction below u (retaining the originals)."""
    parents = G.parents_map(roots)
    for n in G.walk(roots):
        ps = parents.get(n.id, [])
        if len(ps) < 2 or not all(isinstance(p, G.Filter) for p in ps):
            continue
        if n.op not in _SWAPPABLE and n.op != "scan":
            continue
        if isinstance(n, G.Scan):
            continue  # zone-map pruning handles scan-level pruning
        preds = [p.predicate for p in ps]
        disj = preds[0]
        for p in preds[1:]:
            disj = E.BinOp("or", disj, p)
        if n.mod_attrs() & disj.used_cols() or G.ALL in n.mod_attrs():
            continue
        pushed = G.Filter(n.inputs[0], disj)
        new_n = n.with_inputs([pushed])
        if trace is not None:
            trace.append(f"push_disjunction below {n.op}#{n.id}")
        return _rebuild(roots, {n.id: new_n})
    return roots, {}


# ---------------------------------------------------------------------------
# Selectivity-ordered filter fusion (planner-backed, beyond paper)


def order_conjuncts(roots: list[G.Node], ctx: "LaFPContext | None" = None,
                    trace=None) -> tuple[list[G.Node], dict[int, G.Node]]:
    """Reorder each fused filter's conjuncts most-selective-first using the
    planner's selectivity estimates (zone maps / NDVs of the filter's
    input).  Semantically neutral (∧ is commutative); puts the strongest
    pruner first for zone-map checks and keeps fused predicates in a
    deterministic, statistics-ranked order."""
    from .planner.stats import estimate_plan, predicate_selectivity
    try:
        stats = estimate_plan(roots, ctx)
    except Exception:  # noqa: BLE001 — estimation must never break planning
        return roots, {}
    replace: dict[int, G.Node] = {}
    for n in G.walk(roots):
        if not isinstance(n, G.Filter):
            continue
        conj = _conjuncts(n.predicate)
        if len(conj) < 2:
            continue
        child = stats[n.inputs[0].id]
        scored = sorted(
            ((predicate_selectivity(c, child), repr(c.key()), c) for c in conj),
            key=lambda t: (t[0], t[1]))
        ordered = [c for _, _, c in scored]
        if ordered == conj:
            continue
        nf = G.copy_runtime_flags(n, G.Filter(n.inputs[0], E.conjoin(ordered)))
        replace[n.id] = nf
        if trace is not None:
            trace.append(
                f"order_conjuncts #{n.id}: "
                + " ".join(f"{s:.3f}" for s, _, _ in scored))
    if not replace:
        return roots, {}
    return _rebuild(roots, replace)


# ---------------------------------------------------------------------------
# Projection pushdown (column selection, §3.1 at DAG level)


def column_selection(roots: list[G.Node], ctx: LaFPContext | None = None,
                     trace=None) -> tuple[list[G.Node], dict[int, G.Node]]:
    order = G.walk(roots)
    live: dict[int, frozenset | None] = {}
    root_ids = {r.id for r in roots}
    # out_cols per node (forward)
    outc: dict[int, frozenset | None] = {}
    for n in order:
        outc[n.id] = n.out_cols([outc[i.id] for i in n.inputs])
    # roots need all their columns
    for r in roots:
        live[r.id] = outc[r.id]
    # backward: requirement flows from parents to children (union)
    for n in reversed(order):
        if n.persist:
            # persisted results serve FUTURE uses whose columns we may not
            # see in this DAG → keep everything (§3.5 soundness)
            live[n.id] = None
        ln = live.get(n.id, frozenset() if n.id not in root_ids else None)
        reqs = n.required_cols(ln)
        for inp, req in zip(n.inputs, reqs):
            prev = live.get(inp.id)
            if inp.id not in live:
                live[inp.id] = req
            elif prev is None or req is None:
                live[inp.id] = None
            else:
                live[inp.id] = prev | req
    # static-analysis extra columns (future uses beyond this DAG)
    extra: dict[int, frozenset] = {}
    if ctx is not None:
        for sid, cols in ctx.analysis.get("scan_extra_cols", {}).items():
            extra[sid] = frozenset(cols)
    replace: dict[int, G.Node] = {}
    for n in order:
        ln = live.get(n.id)
        # dead-assign elimination: the assigned column is never used
        # downstream → the expression is "not even computed" (paper §2.5)
        if isinstance(n, G.Assign) and ln is not None and n.name not in ln:
            replace[n.id] = n.inputs[0]
            if trace is not None:
                trace.append(f"dead_assign #{n.id} ({n.name}) dropped")
            continue
        # narrow projects to live columns (keep ≥1 to preserve row count)
        if isinstance(n, G.Project) and ln is not None:
            keep = tuple(c for c in n.columns if c in ln)
            if keep and keep != n.columns:
                replace[n.id] = G.Project(n.inputs[0], keep)
                if trace is not None:
                    trace.append(f"narrow_project #{n.id}: "
                                 f"{len(n.columns)}→{len(keep)}")
            continue
        if isinstance(n, G.Scan):
            need = live.get(n.id)
            if need is None:
                continue
            need = frozenset(need) | extra.get(id(n.source), frozenset())
            all_cols = frozenset(n.source.schema.names)
            need = need & all_cols
            current = frozenset(n.columns) if n.columns is not None else all_cols
            if not need:
                # row-count-only consumers (e.g. len): keep one narrow column
                cheapest = min(n.source.schema.columns, key=lambda c: c.itemsize)
                need = frozenset([cheapest.name])
            if need < current:
                ns = G.Scan(n.source, tuple(sorted(need)), n.dtype_overrides,
                            pushdown=n.pushdown)
                ns.skip_partitions = n.skip_partitions
                replace[n.id] = ns
                if trace is not None:
                    trace.append(
                        f"column_selection scan#{n.id}: {len(current)}→{len(need)} cols")
    if not replace:
        return roots, {}
    return _rebuild(roots, replace)


# ---------------------------------------------------------------------------
# Zone-map partition pruning (beyond paper)


def _conjuncts(p: E.Expr) -> list[E.Expr]:
    if isinstance(p, E.BinOp) and p.op == "and":
        return _conjuncts(p.left) + _conjuncts(p.right)
    return [p]


def zone_map_pruning(roots: list[G.Node], trace=None
                     ) -> tuple[list[G.Node], dict[int, G.Node]]:
    """For Filter→(row-preserving ops)→Scan chains, skip partitions whose
    zone maps prove the predicate all-False.  Only predicates over columns
    unmodified along the chain participate."""
    parents = G.parents_map(roots)
    replace: dict[int, G.Node] = {}
    for n in G.walk(roots):
        if not isinstance(n, G.Filter):
            continue
        # walk down through row-preserving unary ops collecting modified cols
        node = n.inputs[0]
        modified: set[str] = set()
        ok = True
        while not isinstance(node, G.Scan):
            if node.op in _SWAPPABLE and len(node.inputs) == 1 \
                    and len(parents.get(node.id, [])) == 1 \
                    and G.ALL not in node.mod_attrs():
                modified |= set(node.mod_attrs())
                if node.op == "rename":
                    ok = False  # name changes: skip for safety
                    break
                node = node.inputs[0]
            else:
                ok = False
                break
        if not ok or not isinstance(node, G.Scan):
            continue
        scan = node
        usable = [c for c in _conjuncts(n.predicate)
                  if isinstance(c, E.BinOp) and not (c.used_cols() & modified)]
        if not usable:
            continue
        skips = set(scan.skip_partitions)
        for pi in range(scan.source.n_partitions):
            zm = scan.source.partition_meta(pi)
            zonemap = zm.get("zonemap", {})
            if not zonemap:
                continue
            if any(c.prune_partition(zonemap) for c in usable):
                skips.add(pi)
        if skips != set(scan.skip_partitions):
            ns = G.Scan(scan.source, scan.columns, scan.dtype_overrides,
                        pushdown=scan.pushdown)
            ns.skip_partitions = frozenset(skips)
            replace[scan.id] = ns
            if trace is not None:
                trace.append(f"zone_map_prune scan#{scan.id}: "
                             f"skip {len(skips)}/{scan.source.n_partitions} partitions")
    if not replace:
        return roots, {}
    return _rebuild(roots, replace)


# ---------------------------------------------------------------------------
# Scan predicate pushdown (beyond paper; the IO-subsystem boundary)


def _has_udf(e: E.Expr) -> bool:
    import dataclasses
    if isinstance(e, E.UDF):
        return True
    if dataclasses.is_dataclass(e):
        for f in dataclasses.fields(e):
            v = getattr(e, f.name)
            for x in (v if isinstance(v, (list, tuple)) else (v,)):
                if isinstance(x, E.Expr) and _has_udf(x):
                    return True
    return False


def scan_pushdown(roots: list[G.Node], trace=None
                  ) -> tuple[list[G.Node], dict[int, G.Node]]:
    """Sink a Filter's conjuncts into the Scan beneath it
    (``Scan.pushdown``), so the source layer evaluates the predicate per
    partition right after decode — the Filter node disappears from the
    plan, and the scan's column set can then shrink to the output
    projection (predicate-only columns are read transiently by the
    loader, never materialized downstream).

    Runs after ``push_filters`` (which lands fused filters directly on
    scans) and after ``zone_map_pruning`` (which needs the Filter
    present); conjuncts that reference UDFs or non-source columns stay in
    a residual Filter.  Sources must opt in via ``supports_pushdown``."""
    parents = G.parents_map(roots)
    replace: dict[int, G.Node] = {}
    scan_map: dict[int, G.Node] = {}
    claimed: set[int] = set()
    for n in G.walk(roots):
        if not isinstance(n, G.Filter):
            continue
        u = n.inputs[0]
        if not isinstance(u, G.Scan) or u.id in claimed:
            continue
        if not getattr(u.source, "supports_pushdown", False):
            continue
        if len(parents.get(u.id, [])) != 1 or u.persist:
            continue
        names = frozenset(u.source.schema.names)
        pushable: list[E.Expr] = []
        residual: list[E.Expr] = []
        for c in _conjuncts(n.predicate):
            if _has_udf(c) or not (c.used_cols() <= names):
                residual.append(c)
            else:
                pushable.append(c)
        if not pushable:
            continue
        merged = list(u.pushdown.conjuncts) if u.pushdown is not None else []
        merged += [c for c in pushable if c not in merged]
        ns = G.Scan(u.source, u.columns, u.dtype_overrides,
                    pushdown=G.ScanPushdown(merged))
        ns.skip_partitions = u.skip_partitions
        if residual:
            out: G.Node = G.Filter(ns, E.conjoin(residual))
        else:
            out = ns
        G.copy_runtime_flags(n, out)
        replace[n.id] = out
        scan_map[u.id] = ns
        claimed.add(u.id)
        if trace is not None:
            trace.append(f"scan_pushdown scan#{u.id}: "
                         f"{len(pushable)} conjuncts sunk"
                         + (f", {len(residual)} residual" if residual else ""))
    if not replace:
        return roots, {}
    roots2, m = _rebuild(roots, replace)
    # the absorbed Scan is never visited by the rebuild walk (its only
    # parent — the Filter — is replaced before its inputs are descended),
    # so record its image explicitly: the composed idmap must track it or
    # the plan cache's rebinding slots keep the stale pushdown-free Scan
    for uid, ns in scan_map.items():
        m.setdefault(uid, ns)
    return roots2, m


# ---------------------------------------------------------------------------
# Metadata dtype narrowing (paper §3.6) — applied to scans of read-only cols


def dtype_narrowing(roots: list[G.Node], ctx: LaFPContext | None,
                    trace=None) -> tuple[list[G.Node], dict[int, G.Node]]:
    import numpy as np
    from .schema import narrow_int_dtype
    readonly = None
    if ctx is not None:
        readonly = ctx.analysis.get("readonly_cols")  # None → analysis absent
    replace = {}
    for n in G.walk(roots):
        if not isinstance(n, G.Scan):
            continue
        overrides = dict(n.dtype_overrides)
        cols = n.columns or n.source.schema.names
        for c in cols:
            cs = n.source.schema.col(c)
            if cs.is_dict or cs.is_datetime or cs.np_dtype.kind != "i":
                continue
            if readonly is not None and c not in readonly:
                continue  # paper's read-only guard
            lo, hi = None, None
            for pi in range(n.source.n_partitions):
                zm = n.source.partition_meta(pi).get("zonemap", {})
                if c not in zm:
                    lo = None
                    break
                plo, phi = zm[c]
                lo = plo if lo is None else min(lo, plo)
                hi = phi if hi is None else max(hi, phi)
            if lo is None:
                continue
            target = narrow_int_dtype(int(lo), int(hi))
            if target.itemsize < cs.np_dtype.itemsize:
                overrides[c] = str(target)
        if overrides != n.dtype_overrides:
            ns = G.Scan(n.source, n.columns, overrides, pushdown=n.pushdown)
            ns.skip_partitions = n.skip_partitions
            replace[n.id] = ns
            if trace is not None:
                trace.append(f"dtype_narrow scan#{n.id}: {overrides}")
    if not replace:
        return roots, {}
    return _rebuild(roots, replace)


def _engines_execute_pushdown(ctx) -> bool:
    """True when every engine this plan could land on declares the
    ``scan_pushdown`` capability.  An engine that does not know about
    ``Scan.pushdown`` (e.g. an externally registered plugin with its own
    scan loader) would silently drop the absorbed filter — so the pass
    only runs when the session engine (or, under AUTO, every candidate)
    opts in."""
    from .engines import AUTO, default_registry
    reg = default_registry()
    engine = str(ctx.backend)
    if engine == AUTO:
        from .planner.select import candidate_engines
        names = candidate_engines(ctx)
    else:
        names = (engine,)
    try:
        return all(getattr(reg.capability_of(n), "scan_pushdown", False)
                   for n in names)
    except Exception:  # noqa: BLE001 — unknown engine: stay conservative
        return False


# ---------------------------------------------------------------------------
# Pipeline


def optimize(roots: list[G.Node], ctx: LaFPContext | None = None,
             enable: Iterable[str] = ("cse", "rewrite", "pushdown",
                                      "selectivity", "columns", "zonemap",
                                      "scan_pushdown", "dtypes", "fuse")
             ) -> tuple[list[G.Node], dict[int, G.Node]]:
    """Run the rule pipeline; returns (new_roots, combined id map)."""
    enable = set(enable)
    trace = ctx.optimizer_trace if ctx is not None else None
    combined: dict[int, G.Node] = {n.id: n for n in G.walk(roots)}

    def absorb(m: dict[int, G.Node]):
        for k in combined:
            cur = combined[k]
            while cur.id in m and m[cur.id] is not cur:
                cur = m[cur.id]
            combined[k] = cur

    if "cse" in enable:
        roots, m = cse(roots)
        absorb(m)
    if "rewrite" in enable and (ctx is None
                                or ctx.backend_options.get("rewrites", True)):
        # pattern rewrites run before pushdown: filter-through-concat and
        # vectorized MapRows expose structure the later passes exploit
        from .rewrite import apply_rewrites
        roots, m, _ = apply_rewrites(roots, ctx, trace=trace)
        absorb(m)
    if "pushdown" in enable:
        roots, m = push_filters(roots, trace)
        absorb(m)
        roots, m = push_common_parent_filters(roots, trace)
        absorb(m)
        roots, m = cse(roots)  # pushdown can expose new sharing
        absorb(m)
    if "selectivity" in enable:
        roots, m = order_conjuncts(roots, ctx, trace)
        absorb(m)
    if "columns" in enable:
        roots, m = column_selection(roots, ctx, trace)
        absorb(m)
    if "zonemap" in enable and (ctx is None
                                or ctx.backend_options.get("zonemap", True)):
        roots, m = zone_map_pruning(roots, trace)
        absorb(m)
    if "scan_pushdown" in enable and (
            ctx is None or (ctx.backend_options.get("pushdown", True)
                            and _engines_execute_pushdown(ctx))):
        roots, m = scan_pushdown(roots, trace)
        absorb(m)
        if m and "columns" in enable:
            # the absorbed Filter's predicate columns are no longer live
            # above the scan — shrink Scan.columns to the output projection
            roots, m = column_selection(roots, ctx, trace)
            absorb(m)
    if "dtypes" in enable:
        roots, m = dtype_narrowing(roots, ctx, trace)
        absorb(m)
    if "fuse" in enable and (ctx is None
                             or ctx.backend_options.get("fusion", True)):
        # runs last: fusion freezes chains, so every structural rewrite
        # must already have happened
        from .fuse import fuse_rowwise_chains
        roots, m = fuse_rowwise_chains(roots, ctx, trace)
        absorb(m)
    return roots, combined
