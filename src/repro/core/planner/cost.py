"""Per-operator, per-engine cost model over ``TableStats``.

Costs are unitless "work" numbers — only comparisons between engines on
the *same* plan matter.  Every engine's constants live in the
``BackendCapability`` it registered with (``repro.core.engines``);
unsupported ops are priced via the fallback penalty plus a gather charge,
mirroring the engines' actual convert-and-delegate fallback paths.

Peak-memory models follow the capability's ``peak_model`` declaration:

* ``"resident"`` — refcounted topological walk: every node's output is
                   resident until its last consumer ran (exactly what a
                   whole-table executor frees).
* ``"chunked"``  — chunk-sized flow for row-wise ops plus pipeline-breaker
                   state: join build sides, group-by partial aggregates,
                   sort materialization, shared-node memoization.
* ``"sharded"``  — resident-model bytes divided across the engine's
                   ``shard_count()`` for all-native segments; the first
                   fallback (or a host-materialized boundary input)
                   gathers the whole table on one host.

Nothing in this module names a concrete engine: candidates, constants, and
model selection all flow from the registry.
"""
from __future__ import annotations

import dataclasses
import math

from .. import graph as G
from ..engines import default_registry
from .stats import TableStats

_LOG_OPS = ("sort_values", "drop_duplicates")  # n log n ops
_BREAKERS = ("sort_values", "groupby_agg", "join", "drop_duplicates",
             "top_k")


@dataclasses.dataclass
class CostEstimate:
    backend: str
    total: float                         # unitless work
    peak_bytes: float                    # estimated resident high-water mark
    per_node: dict[int, float]           # node id -> work contribution
    # pre-calibration peak: ``peak_bytes`` may be rescaled by the measured
    # peak_scale (select._price); calibration samples must pair the *raw*
    # model estimate with the observed peak, or the regression would chase
    # its own output back toward 1
    raw_peak_bytes: float | None = None

    def __repr__(self):
        return (f"<Cost {self.backend} total={self.total:.3g} "
                f"peak={self.peak_bytes / 1e6:.1f}MB>")


def node_work(n: G.Node, stats: dict[int, TableStats], cap) -> float:
    """Estimated work for one operator on one engine (public: the
    operator-granular planner prices nodes individually)."""
    st = stats[n.id]
    in_rows = sum(stats[i.id].rows for i in n.inputs)
    if isinstance(n, G.Scan):
        # price bytes-actually-read: pruned partitions and projected-away
        # columns cost nothing; a pushed-down predicate adds its mask
        # evaluation over every decoded row
        from .stats import scan_read_profile
        prof = scan_read_profile(n)
        if prof is None:
            return st.total_bytes * cap.scan_cost_per_byte
        read_rows, read_bytes = prof
        work = read_bytes * cap.scan_cost_per_byte
        if n.pushdown is not None:
            work += read_rows * cap.row_cost
        return work
    if isinstance(n, (G.Materialized, G.SinkPrint, G.Handoff)):
        return 0.0
    if isinstance(n, G.Join):
        return _join_work(n, stats, cap)
    if isinstance(n, G.FusedRowwise):
        return _fused_work(n, stats, cap)
    rows = max(in_rows, st.rows, 1.0)
    work = rows * cap.row_cost
    if isinstance(n, G.TopK):
        # heap/partial-sort: linear selection over the input, log factor
        # only in the kept k rows — ≪ a full sort's log2(rows)
        work *= max(1.0, math.log2(min(float(n.n), rows) + 2.0))
    elif n.op in _LOG_OPS:
        work *= max(1.0, math.log2(rows + 1))
    native = n.op in cap.native_ops
    if native:
        work /= cap.parallelism
    else:
        in_bytes = sum(stats[i.id].total_bytes for i in n.inputs)
        work = work * cap.fallback_penalty + in_bytes * cap.transfer_cost_per_byte
    return work


# per-member compute discount inside a fused chain: members run in one
# dispatch with no intermediate tables, so each costs a fraction of a
# stand-alone rowwise op
_FUSED_MEMBER_DISCOUNT = 0.25


def _fused_work(n: "G.FusedRowwise", stats: dict[int, TableStats],
                cap) -> float:
    """One pass over the child plus summed (discounted) per-member compute —
    strictly below the op-at-a-time sum for any chain of ≥ 2 members, so
    placement never penalizes a fused segment."""
    in_st = stats[n.inputs[0].id]
    rows = max(in_st.rows, stats[n.id].rows, 1.0)
    work = rows * cap.row_cost * (1.0 + _FUSED_MEMBER_DISCOUNT * len(n.ops))
    if n.op in cap.native_ops:
        return work / cap.parallelism
    return (work * cap.fallback_penalty
            + in_st.total_bytes * cap.transfer_cost_per_byte)


def _join_work(n: G.Join, stats: dict[int, TableStats], cap) -> float:
    """Joins are costed by *build side* (hash-join model): linear probe and
    output plus an n-log-n build on the (right) build side only.  Engines
    with an exchange-based join (``cap.broadcast_join_bytes > 0``) add the
    data movement their strategy implies — replicating the build side when
    it fits the broadcast threshold, an all-to-all shuffle of both sides
    otherwise — so the planner can prefer the exchange engine exactly when
    the build side is small."""
    probe, build = stats[n.inputs[0].id], stats[n.inputs[1].id]
    out_rows = max(stats[n.id].rows, 1.0)
    work = (max(probe.rows, 1.0) + out_rows) * cap.row_cost
    work += (max(build.rows, 1.0) * cap.row_cost
             * max(1.0, math.log2(build.rows + 2)))
    if "join" in cap.native_ops:
        work /= cap.parallelism
        if cap.broadcast_join_bytes:
            if build.total_bytes <= cap.broadcast_join_bytes:
                # broadcast-hash: replicate the small build side
                work += build.total_bytes * cap.transfer_cost_per_byte
            else:
                # shuffle exchange of both sides
                work += ((probe.total_bytes + build.total_bytes)
                         * cap.transfer_cost_per_byte)
    else:
        in_bytes = probe.total_bytes + build.total_bytes
        work = work * cap.fallback_penalty + in_bytes * cap.transfer_cost_per_byte
    return work


def bounded_walk(roots: list[G.Node],
                 boundary: frozenset[int]) -> list[G.Node]:
    """Post-order walk that does not descend past ``boundary`` nodes —
    they are included as leaves (a segment sees its cross-segment inputs
    as already-materialized handoffs)."""
    seen: set[int] = set()
    order: list[G.Node] = []

    def rec(n: G.Node):
        if n.id in seen:
            return
        seen.add(n.id)
        if n.id not in boundary:
            for i in n.inputs:
                rec(i)
        order.append(n)

    for r in roots:
        rec(r)
    return order


def _resident_peak(order, roots, stats) -> float:
    """Replay a whole-table executor's refcounted walk on estimated sizes."""
    refcount: dict[int, int] = {}
    for n in order:
        for i in n.inputs:
            refcount[i.id] = refcount.get(i.id, 0) + 1
    root_ids = {r.id for r in roots}
    resident: dict[int, float] = {}
    peak = 0.0
    for n in order:
        resident[n.id] = stats[n.id].total_bytes
        peak = max(peak, sum(resident.values()))
        for i in n.inputs:
            refcount[i.id] -= 1
            if refcount[i.id] == 0 and i.id not in root_ids:
                resident.pop(i.id, None)
    return peak


_ROWWISE = ("filter", "project", "assign", "rename", "astype", "fillna",
            "map_rows", "head", "fused_rowwise")


def _chunked_peak(order, roots, stats, chunk_rows: int,
                  boundary: frozenset[int] = frozenset()) -> float:
    """Chunked flow + breaker state, as a partition-at-a-time executor
    accounts it.

    Scans stream at *source partition* granularity; row-wise ops keep their
    input's flow size (scaled by their row ratio); everything else
    re-chunks at ``chunk_rows``.  Pipeline breakers add long-lived state.
    ``boundary`` nodes are segment handoffs: their table is fully resident
    host memory for the segment's lifetime and re-streams in chunks.
    """
    parents: dict[int, int] = {}
    for n in order:
        for i in n.inputs:
            parents[i.id] = parents.get(i.id, 0) + 1
    root_ids = {r.id for r in roots}
    state = 0.0                    # long-lived breaker/memo state
    max_flow = 0.0                 # largest transient chunk in flight
    flow_rows: dict[int, float] = {}
    for n in order:
        st = stats[n.id]
        if n.id in boundary:
            state += st.total_bytes
            flow_rows[n.id] = min(float(chunk_rows), st.rows)
            max_flow = max(max_flow, flow_rows[n.id] * st.row_bytes)
            continue
        if isinstance(n, G.Scan):
            fr = 0.0
            for pi in range(n.source.n_partitions):
                if pi in n.skip_partitions:
                    continue
                fr = max(fr, float(n.source.partition_meta(pi).get(
                    "rows", chunk_rows)))
            fr = fr or min(float(chunk_rows), st.rows)
        elif n.op in _ROWWISE and n.inputs:
            in_st = stats[n.inputs[0].id]
            ratio = st.rows / in_st.rows if in_st.rows else 1.0
            fr = flow_rows[n.inputs[0].id] * min(1.0, ratio)
        else:
            fr = min(float(chunk_rows), st.rows)
        flow_rows[n.id] = fr
        max_flow = max(max_flow, fr * st.row_bytes)
        if parents.get(n.id, 0) > 1:
            state += st.total_bytes      # shared nodes are memoized in full
            continue
        if isinstance(n, G.Join):
            state += stats[n.inputs[1].id].total_bytes   # build side held
        elif isinstance(n, G.SortValues):
            state += stats[n.inputs[0].id].total_bytes   # materializes input
        elif isinstance(n, (G.GroupByAgg, G.DropDuplicates)):
            state += st.total_bytes                      # partials ≈ output
        elif isinstance(n, G.TopK):
            state += st.total_bytes                      # best-k accumulator
        elif n.id in root_ids and st.rows:
            state += st.total_bytes                      # root materialized
    return state + max_flow


def plan_cost(roots: list[G.Node], stats: dict[int, TableStats],
              kind, chunk_rows: int = 1 << 16,
              n_shards: int | None = None,
              boundary: frozenset[int] = frozenset(),
              sharded_boundary: frozenset[int] = frozenset()) -> CostEstimate:
    """Price an optimized plan (or one planner segment) on one engine.

    ``kind`` is an engine name (registry key).  ``boundary`` marks
    cross-segment inputs: they are priced as already-materialized handoff
    leaves (no work; resident bytes).  ``sharded_boundary`` names the
    subset whose handoff payload arrives device-resident (same-engine
    producer → consumer for a ``keeps_device_payloads`` engine): those
    cost no re-shard and keep the segment's sharded peak."""
    cap = default_registry().capability_of(kind)
    order = bounded_walk(roots, boundary)
    # a sharded-model segment fed by *host* handoffs runs its ops on the
    # gathered host table (single-host fallback), not across shards;
    # device-resident (sharded) handoffs keep it sharded
    host_boundary = boundary - sharded_boundary
    unsharded = cap.peak_model == "sharded" and bool(host_boundary)
    per_node: dict[int, float] = {}
    total = cap.startup_cost
    for n in order:
        if n.id in boundary:
            w = 0.0
        else:
            w = node_work(n, stats, cap)
            if unsharded and n.op in cap.native_ops:
                w *= cap.parallelism
        per_node[n.id] = w
        total += w
    if cap.peak_model == "chunked":
        peak = _chunked_peak(order, roots, stats, chunk_rows, boundary)
    else:
        peak = _resident_peak(order, roots, stats)
        if cap.peak_model == "sharded":
            if n_shards is None:
                n_shards = cap.shard_count() if cap.shard_count else 1
            # host-handoff-fed segments start from a host-resident table
            # (the runtime hands the engine a plain dict, not shards), so
            # only segments whose inputs are scans or sharded handoffs and
            # whose ops are all native earn the sharded peak
            if not host_boundary and all(n.op in cap.native_ops
                                         for n in order):
                peak /= max(1, n_shards)
            # else: first fallback gathers on one host → full-peak estimate
    return CostEstimate(cap.name, total, peak, per_node)


def transfer_cost(bytes_: float, from_cap, to_cap) -> float:
    """Work charged for materializing a segment boundary: the producer
    gathers/host-normalizes its output and the consumer re-ingests it, plus
    the consumer's fixed startup (a new engine spins up per segment)."""
    per_byte = from_cap.transfer_cost_per_byte + to_cap.transfer_cost_per_byte
    return bytes_ * max(per_byte, 0.25) + to_cap.startup_cost
