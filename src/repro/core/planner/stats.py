"""Statistics layer: per-source and per-node cardinality/width estimation.

Leaf stats are derived from metadata the engine already maintains —
partition metas (rows), zone maps (min/max), dict vocabularies (exact NDV)
— and propagated through the DAG.  Nothing here touches data; estimation
is pure metadata arithmetic, cheap enough to run at every force point.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

from .. import expr as E
from .. import graph as G

# Fallback selectivities when no metadata applies (classic System R knobs).
DEFAULT_SELECTIVITY = 1.0 / 3.0
DEFAULT_EQ_SELECTIVITY = 0.1
MIN_SELECTIVITY = 1e-4


@dataclasses.dataclass
class TableStats:
    """Estimated shape of one operator's output."""
    rows: float
    col_bytes: dict[str, float]           # per-column bytes per row
    ndv: dict[str, float]                 # per-column distinct-count estimate
    zonemap: dict[str, tuple]             # col -> (min, max) over all rows
    exact: bool = False                   # True when taken from feedback/meta

    @property
    def row_bytes(self) -> float:
        return sum(self.col_bytes.values()) or 8.0

    @property
    def total_bytes(self) -> float:
        return self.rows * self.row_bytes

    def col_ndv(self, name: str) -> float:
        """NDV estimate for a column, capped by the row count."""
        v = self.ndv.get(name)
        if v is None:
            v = math.sqrt(self.rows) if self.rows > 0 else 1.0
        return max(1.0, min(v, self.rows or 1.0))

    def scaled(self, selectivity: float) -> "TableStats":
        sel = max(MIN_SELECTIVITY, min(1.0, selectivity))
        return TableStats(
            rows=self.rows * sel,
            col_bytes=dict(self.col_bytes),
            ndv={c: max(1.0, v * sel) for c, v in self.ndv.items()},
            zonemap=dict(self.zonemap),
        )


def source_stats(source, columns=None, skip_partitions=frozenset()) -> TableStats:
    """Leaf statistics from partition metas + zone maps + dict vocabularies."""
    names = tuple(columns) if columns is not None else source.schema.names
    rows = 0
    zonemap: dict[str, tuple] = {}
    metas_ok = True
    for pi in range(source.n_partitions):
        if pi in skip_partitions:
            continue
        meta = source.partition_meta(pi)
        if "rows" not in meta:
            metas_ok = False
            break
        rows += meta["rows"]
        for c, (lo, hi) in meta.get("zonemap", {}).items():
            if c not in names:
                continue
            if c in zonemap:
                plo, phi = zonemap[c]
                zonemap[c] = (min(plo, lo), max(phi, hi))
            else:
                zonemap[c] = (lo, hi)
    if not metas_ok:
        rows = 1 << 20  # unknown source size: assume big, plan conservatively
    col_bytes = {}
    ndv = {}
    for c in names:
        cs = source.schema.col(c)
        col_bytes[c] = float(cs.itemsize)
        est = source.column_ndv(c) if hasattr(source, "column_ndv") else None
        if est is None and c in zonemap and cs.np_dtype.kind in "iu":
            lo, hi = zonemap[c]
            est = hi - lo + 1
        if est is not None:
            ndv[c] = float(min(est, rows or 1))
    return TableStats(rows=float(rows), col_bytes=col_bytes, ndv=ndv,
                      zonemap=zonemap, exact=metas_ok)


# ---------------------------------------------------------------------------
# Selectivity estimation


def _range_fraction(lo: float, hi: float, cut: float, side: str) -> float:
    """Fraction of a uniform [lo, hi] column passing ``col <side> cut``."""
    if hi <= lo:
        # degenerate zone: all rows equal lo
        passes = {"lt": lo < cut, "le": lo <= cut,
                  "gt": lo > cut, "ge": lo >= cut}[side]
        return 1.0 if passes else MIN_SELECTIVITY
    frac = (cut - lo) / (hi - lo)
    if side in ("gt", "ge"):
        frac = 1.0 - frac
    return max(MIN_SELECTIVITY, min(1.0, frac))


_FLIP = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le"}


def predicate_selectivity(pred: E.Expr, stats: TableStats) -> float:
    """Estimated fraction of rows passing ``pred`` on a table with ``stats``.

    Range predicates interpolate against the merged zone map (uniformity
    assumption); equality uses 1/NDV; boolean combinators compose assuming
    independence.  Falls back to System-R-style constants.
    """
    if isinstance(pred, E.Not):
        return max(MIN_SELECTIVITY, 1.0 - predicate_selectivity(pred.child, stats))
    if isinstance(pred, E.IsIn):
        if isinstance(pred.child, E.Col):
            ndv = stats.col_ndv(pred.child.name)
            return max(MIN_SELECTIVITY, min(1.0, len(pred.values) / ndv))
        return DEFAULT_EQ_SELECTIVITY
    if not isinstance(pred, E.BinOp):
        return DEFAULT_SELECTIVITY
    if pred.op == "and":
        return max(MIN_SELECTIVITY,
                   predicate_selectivity(pred.left, stats)
                   * predicate_selectivity(pred.right, stats))
    if pred.op == "or":
        sl = predicate_selectivity(pred.left, stats)
        sr = predicate_selectivity(pred.right, stats)
        return min(1.0, sl + sr - sl * sr)
    if pred.op in ("lt", "le", "gt", "ge"):
        # normalize to col-vs-constant using interval bounds
        side, left, right = pred.op, pred.left, pred.right
        if isinstance(right, E.Col) and not isinstance(left, E.Col):
            side, left, right = _FLIP[side], right, left
        lb = left.bounds(stats.zonemap)
        rb = right.bounds(stats.zonemap)
        if lb is not None and rb is not None:
            (llo, lhi), (rlo, rhi) = lb, rb
            cut = (rlo + rhi) / 2.0
            return _range_fraction(llo, lhi, cut, side)
        return DEFAULT_SELECTIVITY
    if pred.op == "eq":
        for side in (pred.left, pred.right):
            if isinstance(side, E.Col):
                return max(MIN_SELECTIVITY, min(1.0, 1.0 / stats.col_ndv(side.name)))
        return DEFAULT_EQ_SELECTIVITY
    if pred.op == "ne":
        for side in (pred.left, pred.right):
            if isinstance(side, E.Col):
                return max(MIN_SELECTIVITY,
                           1.0 - min(1.0, 1.0 / stats.col_ndv(side.name)))
        return 1.0 - DEFAULT_EQ_SELECTIVITY
    return DEFAULT_SELECTIVITY


# ---------------------------------------------------------------------------
# Per-node propagation


def _table_stats_of(table: Mapping) -> TableStats:
    import numpy as np
    rows = 0
    col_bytes = {}
    for k, v in table.items():
        arr = np.asarray(v)
        rows = int(arr.shape[0]) if arr.ndim else 0
        col_bytes[k] = float(arr.dtype.itemsize)
    return TableStats(rows=float(rows), col_bytes=col_bytes, ndv={},
                      zonemap={}, exact=True)


def scan_read_profile(n: "G.Scan") -> tuple[float, float] | None:
    """``(rows, bytes)`` the scan will actually read: rows over *unpruned*
    partitions × the width of the read column set (output projection ∪
    pushed-down predicate columns).  ``None`` when partition metas lack
    row counts — callers fall back to whole-table size."""
    rows = 0
    for pi in range(n.source.n_partitions):
        if pi in n.skip_partitions:
            continue
        meta = n.source.partition_meta(pi)
        if "rows" not in meta:
            return None
        rows += meta["rows"]
    names = n.columns if n.columns is not None else n.source.schema.names
    read = set(names)
    if n.pushdown is not None:
        read |= {c for c in n.pushdown.used_cols()
                 if c in n.source.schema.names}
    width = sum(n.source.schema.col(c).itemsize for c in read)
    return float(rows), float(rows * width)


def scan_read_bytes(n: "G.Scan") -> float | None:
    prof = scan_read_profile(n)
    return prof[1] if prof is not None else None


def estimate_node(n: G.Node, child_stats: list[TableStats]) -> TableStats:
    """One-step propagation of TableStats through an operator."""
    if isinstance(n, G.Scan):
        st = source_stats(n.source, n.columns, n.skip_partitions)
        if n.pushdown is not None:
            # the pushed-down predicate filters rows at load time, so the
            # scan's *output* carries the filter's selectivity
            st = st.scaled(predicate_selectivity(n.pushdown.predicate, st))
        return st
    if isinstance(n, G.Materialized):
        return _table_stats_of(n.table)
    if isinstance(n, G.Handoff):
        if isinstance(n.value, dict):
            return _table_stats_of(n.value)
        return TableStats(rows=0.0, col_bytes={}, ndv={}, zonemap={},
                          exact=True)
    if isinstance(n, (G.Reduce, G.Length)):
        return TableStats(rows=0.0, col_bytes={}, ndv={}, zonemap={})
    if isinstance(n, G.SinkPrint):
        return TableStats(rows=0.0, col_bytes={}, ndv={}, zonemap={})
    c = child_stats[0] if child_stats else TableStats(0.0, {}, {}, {})
    if isinstance(n, G.FusedRowwise):
        st = c
        for m in n.ops:          # fold member estimates innermost-first
            st = estimate_node(m, [st])
        return st
    if isinstance(n, G.Filter):
        return c.scaled(predicate_selectivity(n.predicate, c))
    if isinstance(n, G.Project):
        return TableStats(
            rows=c.rows,
            col_bytes={k: c.col_bytes.get(k, 8.0) for k in n.columns},
            ndv={k: v for k, v in c.ndv.items() if k in n.columns},
            zonemap={k: v for k, v in c.zonemap.items() if k in n.columns})
    if isinstance(n, G.Assign):
        out = TableStats(c.rows, dict(c.col_bytes), dict(c.ndv), dict(c.zonemap))
        out.col_bytes[n.name] = 8.0
        b = n.expr.bounds(c.zonemap)
        if b is not None:
            out.zonemap[n.name] = b
        else:
            out.zonemap.pop(n.name, None)
        out.ndv.pop(n.name, None)
        return out
    if isinstance(n, G.Rename):
        m = n.mapping
        return TableStats(
            rows=c.rows,
            col_bytes={m.get(k, k): v for k, v in c.col_bytes.items()},
            ndv={m.get(k, k): v for k, v in c.ndv.items()},
            zonemap={m.get(k, k): v for k, v in c.zonemap.items()})
    if isinstance(n, G.AsType):
        import numpy as np
        out = TableStats(c.rows, dict(c.col_bytes), dict(c.ndv), dict(c.zonemap))
        for col, dt in n.dtypes.items():
            out.col_bytes[col] = float(np.dtype(dt).itemsize)
        return out
    if isinstance(n, G.FillNa):
        return c
    if isinstance(n, G.SortValues):
        return c
    if isinstance(n, G.DropDuplicates):
        cols = n.subset or tuple(c.col_bytes)
        distinct = 1.0
        for col in cols:
            distinct *= c.col_ndv(col)
            if distinct >= c.rows:
                break
        return TableStats(rows=min(c.rows, distinct),
                          col_bytes=dict(c.col_bytes), ndv=dict(c.ndv),
                          zonemap=dict(c.zonemap))
    if isinstance(n, (G.Head, G.TopK)):
        return TableStats(rows=min(float(n.n), c.rows),
                          col_bytes=dict(c.col_bytes), ndv=dict(c.ndv),
                          zonemap=dict(c.zonemap))
    if isinstance(n, G.MapRows):
        return TableStats(rows=c.rows, col_bytes=dict(c.col_bytes),
                          ndv={}, zonemap={})
    if isinstance(n, G.GroupByAgg):
        groups = 1.0
        for k in n.keys:
            groups *= c.col_ndv(k)
            if groups >= c.rows:
                break
        groups = max(1.0, min(groups, c.rows or 1.0))
        col_bytes = {k: c.col_bytes.get(k, 8.0) for k in n.keys}
        for out_name in n.aggs:
            col_bytes[out_name] = 8.0
        ndv = {k: min(c.col_ndv(k), groups) for k in n.keys}
        zonemap = {k: v for k, v in c.zonemap.items() if k in n.keys}
        return TableStats(rows=groups, col_bytes=col_bytes, ndv=ndv,
                          zonemap=zonemap)
    if isinstance(n, G.Join):
        l, r = child_stats
        key_ndv = 1.0
        for k in n.on:
            key_ndv *= max(l.col_ndv(k), r.col_ndv(k))
        key_ndv = max(1.0, key_ndv)
        rows = l.rows * r.rows / key_ndv
        if n.how == "left":
            rows = max(rows, l.rows)
        col_bytes = dict(l.col_bytes)
        for k, v in r.col_bytes.items():
            if k in col_bytes and k not in n.on:
                col_bytes[k + n.suffixes[0]] = col_bytes.pop(k)
                col_bytes[k + n.suffixes[1]] = v
            elif k not in col_bytes:
                col_bytes[k] = v
        ndv = {**r.ndv, **l.ndv}
        zonemap = {**r.zonemap, **l.zonemap}
        return TableStats(rows=rows, col_bytes=col_bytes, ndv=ndv,
                          zonemap=zonemap)
    if isinstance(n, G.Concat):
        rows = sum(s.rows for s in child_stats)
        cols: dict[str, float] = {}
        for s in child_stats:
            for k, v in s.col_bytes.items():
                cols[k] = max(cols.get(k, 0.0), v)
        ndv: dict[str, float] = {}
        for s in child_stats:
            for k, v in s.ndv.items():
                ndv[k] = ndv.get(k, 0.0) + v
        return TableStats(rows=rows, col_bytes=cols, ndv=ndv, zonemap={})
    # unknown operator: pass through conservatively
    return c


def estimate_plan(roots: list[G.Node], ctx=None) -> dict[int, TableStats]:
    """TableStats per node id for the whole DAG (post-order walk).

    When ``ctx.stats_store`` holds observed cardinalities for a node's
    structural key (feedback loop), the observation overrides the estimate
    — repeated plans converge to actual row counts.
    """
    store = getattr(ctx, "stats_store", None) if ctx is not None else None
    out: dict[int, TableStats] = {}
    for n in G.walk(roots):
        est = estimate_node(n, [out[i.id] for i in n.inputs])
        if store is not None:
            obs = store.lookup(_safe_key(n))
            if obs is not None and est.rows > 0:
                ratio = obs["rows"] / est.rows if est.rows else 1.0
                est = TableStats(rows=float(obs["rows"]),
                                 col_bytes=dict(est.col_bytes),
                                 ndv={c: max(1.0, v * min(1.0, ratio))
                                      for c, v in est.ndv.items()},
                                 zonemap=dict(est.zonemap), exact=True)
            elif obs is not None:
                est = TableStats(rows=float(obs["rows"]),
                                 col_bytes=dict(est.col_bytes),
                                 ndv=dict(est.ndv), zonemap=dict(est.zonemap),
                                 exact=True)
        out[n.id] = est
    return out


def _safe_key(n: G.Node):
    try:
        return n.key()
    except Exception:  # side-effect nodes key fine; belt and braces
        return ("id", n.id)
