"""Plan cache: repeated plan *shapes* skip optimize/rewrite/segment-DP.

Serving workloads re-run the same program shape over fresh data (new day's
file, next request's in-memory frame).  Re-planning from scratch at every
force point re-pays JIT analysis amortization: CSE, pattern rewrites,
pushdown, the column/zone-map/dtype passes and — under AUTO — the segment
DP.  This module caches the *optimized* plan keyed by a structural
fingerprint and rebinds it to fresh sources on a hit.

Cache key = ``(plan_fingerprint, stats_epoch)``:

* ``plan_fingerprint`` — graph shape + op kinds/params + source
  schema/dtypes + engine environment (engine choice, allow-list, candidate
  set, placement strategy, chunk size, rewrites flag, memory budget).
  Source ``cache_token``s are deliberately **excluded** so the same program
  shape over new data still hits.  Built only from process-stable values —
  never ``id()`` or object ``repr`` — so fingerprints agree across
  processes.
* ``stats_epoch`` — a content digest of everything the cost planner would
  read for this plan from the session's ``StatsStore`` (bucketed
  calibration scales + observed per-node cardinalities).  New feedback
  changes the epoch, so a stale placement is re-planned instead of reused;
  identical stats views (e.g. two fresh sessions) share entries.

Plans containing opaque or side-effecting nodes (``MapRows``, UDF
expressions, ``SinkPrint``, ``Materialized``, ``Handoff``) are
**uncacheable**: their semantics or payloads are not captured by a
structural fingerprint.  They take the normal cold path and are counted
under ``plan_cache.uncacheable``.

Rebinding rules (``CachedPlan.bind``): the cached template is cloned with
fresh node ids; each template scan is pointed at the new plan's source.
When the new source's ``cache_token`` differs from the one the template
was optimized against, *data-derived* plan state is dropped — zone-map
``skip_partitions`` reset and optimizer dtype-narrowing overrides replaced
by the new scan's own — because those were proven against the old data.
Schema-derived state (column pruning) is kept; the fingerprint already
guarantees equal schemas.
"""
from __future__ import annotations

import hashlib
import math
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any

from .. import expr as E
from .. import graph as G


class Uncacheable(Exception):
    """Raised while fingerprinting a plan that must not be cached."""


# -- structural fingerprint --------------------------------------------------

def _expr_fp(e) -> tuple:
    """Expr fingerprint = its structural key, after proving no UDF hides
    anywhere in the tree (``UDF.key()`` leaks ``id(fn)`` — neither stable
    nor a faithful identity for closures)."""
    _check_no_udf(e)
    return e.key()


def _check_no_udf(e) -> None:
    if isinstance(e, E.UDF):
        raise Uncacheable("udf expression")
    import dataclasses as _dc
    if _dc.is_dataclass(e):
        for f in _dc.fields(e):
            v = getattr(e, f.name)
            if isinstance(v, E.Expr):
                _check_no_udf(v)
            elif isinstance(v, tuple):
                for item in v:
                    if isinstance(item, E.Expr):
                        _check_no_udf(item)


def _schema_fp(source) -> tuple:
    return tuple((c.name, str(c.np_dtype), c.is_dict, c.is_datetime)
                 for c in source.schema.columns)


def _scan_fp(n: G.Scan) -> tuple:
    # NO cache_token here — that is the whole point of the cache: the same
    # shape over new data (new token) must still hit.  Source *identity*
    # beyond shape is covered by the source class + schema here and by the
    # bind-time token comparison (which drops data-derived state on
    # mismatch); pushed-down predicates are part of the shape.
    pd_fp = (tuple(_expr_fp(c) for c in n.pushdown.conjuncts)
             if n.pushdown is not None else None)
    return ("scan", type(n.source).__name__, n.columns,
            tuple(sorted(n.dtype_overrides.items())),
            tuple(sorted(n.skip_partitions)), pd_fp, _schema_fp(n.source))


_NODE_FP = {
    "scan": _scan_fp,
    "project": lambda n: ("project", n.columns),
    "filter": lambda n: ("filter", _expr_fp(n.predicate)),
    "assign": lambda n: ("assign", n.name, _expr_fp(n.expr)),
    "rename": lambda n: ("rename", tuple(sorted(n.mapping.items()))),
    "astype": lambda n: ("astype", tuple(sorted(n.dtypes.items()))),
    "fillna": lambda n: ("fillna", repr(n.value), n.columns),
    "sort_values": lambda n: ("sort", n.by, repr(n.ascending)),
    "drop_duplicates": lambda n: ("dropdup", n.subset),
    "head": lambda n: ("head", n.n),
    "top_k": lambda n: ("topk", n.by, n.n, repr(n.ascending), n.mode),
    "groupby_agg": lambda n: ("gb", n.keys, tuple(sorted(n.aggs.items()))),
    "join": lambda n: ("join", n.on, n.how, tuple(n.suffixes)),
    "concat": lambda n: ("concat", len(n.inputs)),
    "reduce": lambda n: ("reduce", n.column, n.fn),
    "length": lambda n: ("length",),
    "fused_rowwise": lambda n: (
        ("fused",) + tuple(_NODE_FP[m.op](m) for m in n.ops)),
    # map_rows / sink_print / materialized / handoff deliberately absent:
    # opaque code, side effects, or embedded payloads → uncacheable.
}


def _env_fp(ctx) -> tuple:
    """Planning environment: everything besides the graph that steers
    optimize() / plan_placement() output."""
    from ..engines import AUTO
    engine = str(ctx.backend)
    allow = (tuple(sorted(ctx.engine_allowlist))
             if ctx.engine_allowlist else None)
    if engine == AUTO:
        from .select import candidate_engines
        cands = tuple(candidate_engines(ctx))
    else:
        cands = (engine,)
    opts = ctx.backend_options
    return ("env", engine, allow, cands,
            str(opts.get("placement", "operator")),
            int(opts.get("chunk_rows", 1 << 16)),
            bool(opts.get("rewrites", True)),
            bool(opts.get("fusion", True)),
            bool(opts.get("pushdown", True)),
            bool(opts.get("zonemap", True)),
            str(opts.get("kernel_impl", "auto")),
            ctx.memory_budget)


def plan_fingerprint(roots: list[G.Node], ctx, walk=None) -> str:
    """Process-stable structural fingerprint of a plan + its planning
    environment.  Raises :class:`Uncacheable` for plans that must not be
    cached."""
    nodes = walk if walk is not None else G.walk(roots)
    idx = {n.id: i for i, n in enumerate(nodes)}
    parts = []
    for n in nodes:
        fp = _NODE_FP.get(n.op)
        if fp is None:
            raise Uncacheable(f"op {n.op!r}")
        parts.append(fp(n) + (tuple(idx[i.id] for i in n.inputs),))
    root_idx = tuple(idx[r.id] for r in roots)
    blob = repr((tuple(parts), root_idx, _env_fp(ctx))).encode()
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


# -- stats epoch -------------------------------------------------------------

def _bucket_scale(scale: float) -> int:
    """Half-octave bucket: small calibration jitter keeps the epoch stable,
    a real shift (≥ ~1.4×) re-plans."""
    return round(math.log2(scale) * 2)


def _bucket_rows(rows: float) -> float:
    return float(f"{rows:.2g}") if rows > 0 else 0.0


def stats_epoch(roots: list[G.Node], ctx, walk=None) -> str:
    """Digest of the planner-visible ``StatsStore`` state *for this plan*:
    bucketed runtime/peak calibration scales plus the observed cardinality
    (bucketed rows) of every plan node the store knows.  This is the
    "stats epoch" component of the cache key — when feedback that could
    change placement arrives, the epoch moves and the shape re-plans."""
    store = getattr(ctx, "stats_store", None)
    if store is None:
        return "nostats"
    nodes = walk if walk is not None else G.walk(roots)
    cal = tuple(sorted((b, _bucket_scale(s))
                       for b, s in store.calibration().items()))
    pcal = tuple(sorted((b, _bucket_scale(s))
                        for b, s in store.peak_calibration().items()))
    obs = []
    for i, n in enumerate(nodes):
        try:
            o = store.lookup(n.key())
        except Exception:  # noqa: BLE001 — side-effect nodes key on id
            o = None
        if o:
            obs.append((i, _bucket_rows(o.get("rows", 0.0))))
    blob = repr((cal, pcal, tuple(obs))).encode()
    return hashlib.blake2b(blob, digest_size=8).hexdigest()


def cache_key(roots: list[G.Node], ctx, walk=None):
    """``(fingerprint, epoch)`` for a cacheable plan, else ``None``."""
    nodes = walk if walk is not None else G.walk(roots)
    try:
        fp = plan_fingerprint(roots, ctx, walk=nodes)
    except Uncacheable:
        return None
    return fp, stats_epoch(roots, ctx, walk=nodes)


# -- cached plans ------------------------------------------------------------

def _token(source):
    tok = getattr(source, "cache_token", None)
    return tok() if callable(tok) else ("mem", id(source))


@dataclass
class CachedPlan:
    """One cached optimized plan: the post-optimize template, the original→
    optimized image list (re-creating ``optimize``'s idmap on bind), scan
    rebinding slots, and — under AUTO — the segment decisions."""
    key: tuple
    template_roots: list = field(default_factory=list)
    images: list = field(default_factory=list)       # orig walk idx → template node
    scan_bindings: dict = field(default_factory=dict)  # template scan id → orig walk idx
    source_tokens: dict = field(default_factory=dict)  # orig walk idx → cache_token
    decisions: Any = None                            # list[Decision] | None
    plan_seconds: float = 0.0                        # cold planning cost it saves

    @classmethod
    def build(cls, key, orig_walk, opt_roots, idmap, decisions,
              plan_seconds) -> "CachedPlan | None":
        images = [idmap.get(n.id, n) for n in orig_walk]
        src_slots = {id(n.source): i for i, n in enumerate(orig_walk)
                     if isinstance(n, G.Scan)}
        scan_bindings: dict[int, int] = {}
        source_tokens: dict[int, Any] = {}
        for t in G.walk(opt_roots):
            if isinstance(t, G.Scan):
                oi = src_slots.get(id(t.source))
                if oi is None:      # optimizer invented a source? don't cache
                    return None
                scan_bindings[t.id] = oi
                source_tokens[oi] = _token(t.source)
        return cls(key=key, template_roots=list(opt_roots), images=images,
                   scan_bindings=scan_bindings, source_tokens=source_tokens,
                   decisions=decisions, plan_seconds=plan_seconds)

    def bind(self, new_walk: list[G.Node]):
        """Clone the template against the new plan's sources.  Returns
        ``(opt_roots, idmap, decisions|None)`` or ``None`` when the plan
        cannot be bound (caller falls back to cold planning)."""
        if len(new_walk) != len(self.images):
            return None
        memo: dict[int, G.Node] = {}

        def clone(t: G.Node) -> G.Node:
            out = memo.get(t.id)
            if out is not None:
                return out
            if isinstance(t, G.Scan):
                oi = self.scan_bindings[t.id]
                new_scan = new_walk[oi]
                src = new_scan.source
                out = None
                if _token(src) == self.source_tokens[oi]:
                    # same data: data-derived plan state (zone-map skips,
                    # dtype narrowing) is still proven — keep it
                    out = G.Scan(src, t.columns, t.dtype_overrides,
                                 pushdown=t.pushdown)
                    out.skip_partitions = t.skip_partitions
                else:
                    # fresh data: keep schema-derived pruning (columns,
                    # pushed-down predicate — its semantics don't depend on
                    # data), drop data-derived state.  The template's
                    # skip_partitions were proven against the *old*
                    # source's zone maps; carrying them over would
                    # silently drop live partitions of the new data, so
                    # re-derive the prune set from the pushed-down
                    # conjuncts against the new source's partition metas.
                    out = G.Scan(src, t.columns,
                                 dict(new_scan.dtype_overrides),
                                 pushdown=t.pushdown)
                    skips = set(new_scan.skip_partitions)
                    if t.pushdown is not None:
                        usable = [c for c in t.pushdown.conjuncts
                                  if isinstance(c, E.BinOp)]
                        if usable:
                            for pi in range(src.n_partitions):
                                zm = src.partition_meta(pi).get(
                                    "zonemap", {})
                                if zm and any(c.prune_partition(zm)
                                              for c in usable):
                                    skips.add(pi)
                    out.skip_partitions = frozenset(skips)
            else:
                out = t.with_inputs([clone(i) for i in t.inputs])
            memo[t.id] = out
            return out

        try:
            opt_roots = [clone(r) for r in self.template_roots]
            idmap = {n.id: clone(img)
                     for n, img in zip(new_walk, self.images)}
            decisions = None
            if self.decisions is not None:
                import dataclasses as _dc
                decisions = [
                    _dc.replace(d,
                                roots=[clone(r) for r in d.roots],
                                nodes=[clone(n) for n in d.nodes],
                                boundary=[clone(b) for b in d.boundary])
                    for d in self.decisions]
        except (KeyError, IndexError, AttributeError, AssertionError):
            return None
        return opt_roots, idmap, decisions


class PlanCache:
    """Process-global, thread-safe LRU of :class:`CachedPlan`.

    Thread-safety invariant: all map access happens under ``_lock``;
    entries are immutable after ``store`` and ``bind`` clones fresh nodes
    per call, so concurrent sessions never share mutable plan state."""

    def __init__(self, max_entries: int = 128):
        self._lock = threading.Lock()
        self._entries: OrderedDict[tuple, CachedPlan] = OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.uncacheable = 0
        self.hit_plan_seconds = 0.0     # total wall spent binding on hits
        self.miss_plan_seconds = 0.0    # total wall spent planning on misses

    def lookup(self, key) -> CachedPlan | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
            return entry

    def store(self, entry: CachedPlan | None) -> None:
        if entry is None:
            return
        with self._lock:
            self._entries[entry.key] = entry
            self._entries.move_to_end(entry.key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)

    def record_hit(self, seconds: float) -> None:
        with self._lock:
            self.hits += 1
            self.hit_plan_seconds += seconds

    def record_miss(self, seconds: float) -> None:
        with self._lock:
            self.misses += 1
            self.miss_plan_seconds += seconds

    def record_uncacheable(self) -> None:
        with self._lock:
            self.uncacheable += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.uncacheable = 0
            self.hit_plan_seconds = self.miss_plan_seconds = 0.0

    def __len__(self):
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "uncacheable": self.uncacheable,
                "hit_rate": (self.hits / total) if total else 0.0,
                "mean_hit_plan_seconds": (
                    self.hit_plan_seconds / self.hits if self.hits else 0.0),
                "mean_miss_plan_seconds": (
                    self.miss_plan_seconds / self.misses
                    if self.misses else 0.0),
            }


_DEFAULT_CACHE = PlanCache()


def default_plan_cache() -> PlanCache:
    """The process-global plan cache shared by every session (sessions hit
    each other's entries by design — the key carries the full planning
    environment and stats epoch, so sharing is sound)."""
    return _DEFAULT_CACHE
