"""Feedback loop: record actual cardinalities after execution and feed them
back into estimation (the paper's "runtime optimization" leg).

Observations are keyed by each node's *structural* key, so a re-built plan
with the same shape (the common case for scripted/repeated workloads) hits
the store even though node ids differ.  ``estimate_plan`` consults the
store and overrides a-priori estimates with observed row counts.

The store is JSON-persistable (``save``/``load``): cardinalities are keyed
by the ``repr`` of the structural key — deterministic across processes for
disk-backed sources (``Source.cache_token``) — and runtime/peak calibration
samples are keyed by backend name, so AUTO calibration survives restarts
(``LaFPContext.stats_path`` / ``REPRO_STATS_CACHE_DIR``).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

from .. import graph as G


# a backend's cost scale is trusted only after this many observed runs —
# a single noisy measurement must not flip placement
MIN_RUNTIME_SAMPLES = 3
_MAX_RUNTIME_SAMPLES = 64
# same floor for peak-estimate calibration (observed vs estimated peaks)
MIN_PEAK_SAMPLES = MIN_RUNTIME_SAMPLES
_MAX_PEAK_SAMPLES = 64


def _least_squares_scale(samples) -> float | None:
    """Regression through the origin: observed = scale * estimated."""
    num = sum(e * o for e, o in samples)
    den = sum(e * e for e, _o in samples)
    if den <= 0 or num <= 0:
        return None
    return num / den


class StatsStore:
    """Bounded store of observed per-node cardinalities, backend peaks, and
    per-backend (estimated, observed) samples used to calibrate the cost
    model's ``BackendCapability`` constants — both *work* (estimated work →
    wall seconds) and *peak* (estimated peak bytes → metered peak bytes)."""

    def __init__(self, max_entries: int = 4096):
        # keyed by repr(structural key): deterministic, JSON-serializable,
        # and stable across processes for path-token sources
        self.observed: dict[str, dict[str, float]] = {}
        self.backend_peaks: dict[str, int] = {}
        self.runtime_samples: dict[str, list[tuple[float, float]]] = {}
        self.peak_samples: dict[str, list[tuple[float, float]]] = {}
        self.max_entries = max_entries

    @staticmethod
    def _k(key) -> str:
        return key if isinstance(key, str) else repr(key)

    def record(self, key, rows: int, nbytes: int) -> None:
        k = self._k(key)
        if len(self.observed) >= self.max_entries and k not in self.observed:
            # drop the oldest insertion (dict preserves order)
            self.observed.pop(next(iter(self.observed)))
        self.observed[k] = {"rows": float(rows), "nbytes": float(nbytes)}

    def lookup(self, key) -> dict[str, float] | None:
        return self.observed.get(self._k(key))

    def record_peak(self, backend: str, peak_bytes: int,
                    est_peak: float | None = None) -> None:
        """One observed peak.  With ``est_peak`` (the cost model's a-priori
        estimate for the same run) it also becomes a calibration sample."""
        self.backend_peaks[backend] = max(
            self.backend_peaks.get(backend, 0), int(peak_bytes))
        if est_peak is not None and est_peak > 0 and peak_bytes > 0:
            samples = self.peak_samples.setdefault(backend, [])
            samples.append((float(est_peak), float(peak_bytes)))
            if len(samples) > _MAX_PEAK_SAMPLES:
                del samples[0]

    # -- runtime calibration (measured, not guessed, cost constants) --------

    def record_runtime(self, backend: str, est_work: float,
                       seconds: float) -> None:
        """One observed execution: the plan's estimated (uncalibrated) work
        on ``backend`` and the wall seconds it actually took."""
        if est_work <= 0 or seconds < 0:
            return
        samples = self.runtime_samples.setdefault(backend, [])
        samples.append((float(est_work), float(seconds)))
        if len(samples) > _MAX_RUNTIME_SAMPLES:
            del samples[0]

    def cost_scale(self, backend: str) -> float | None:
        """Calibrated seconds-per-work-unit for ``backend``: least-squares
        regression through the origin over the recorded (work, seconds)
        samples.  None until ``MIN_RUNTIME_SAMPLES`` runs were observed."""
        samples = self.runtime_samples.get(backend, ())
        if len(samples) < MIN_RUNTIME_SAMPLES:
            return None
        return _least_squares_scale(samples)

    def calibration(self) -> dict[str, float]:
        """All backends with a trusted calibrated scale."""
        out = {}
        for backend in self.runtime_samples:
            scale = self.cost_scale(backend)
            if scale is not None:
                out[backend] = scale
        return out

    # -- peak calibration (observed peaks recalibrate peak estimates) -------

    def peak_scale(self, backend: str) -> float | None:
        """Calibrated observed-per-estimated-peak ratio, regressed the same
        way runtimes calibrate work constants.  None until
        ``MIN_PEAK_SAMPLES`` metered runs were observed."""
        samples = self.peak_samples.get(backend, ())
        if len(samples) < MIN_PEAK_SAMPLES:
            return None
        return _least_squares_scale(samples)

    def peak_calibration(self) -> dict[str, float]:
        out = {}
        for backend in self.peak_samples:
            scale = self.peak_scale(backend)
            if scale is not None:
                out[backend] = scale
        return out

    def __len__(self):
        return len(self.observed)

    # -- persistence (AUTO calibration survives process restarts) -----------

    def to_json(self) -> dict:
        return {
            "observed": self.observed,
            "backend_peaks": self.backend_peaks,
            "runtime_samples": {b: [list(s) for s in ss]
                                for b, ss in self.runtime_samples.items()},
            "peak_samples": {b: [list(s) for s in ss]
                             for b, ss in self.peak_samples.items()},
        }

    def merge_json(self, data: dict) -> None:
        for k, v in data.get("observed", {}).items():
            self.record(k, v.get("rows", 0.0), v.get("nbytes", 0.0))
        for b, p in data.get("backend_peaks", {}).items():
            self.backend_peaks[b] = max(self.backend_peaks.get(b, 0), int(p))
        for b, ss in data.get("runtime_samples", {}).items():
            for est, sec in ss:
                self.record_runtime(b, est, sec)
        for b, ss in data.get("peak_samples", {}).items():
            for est, obs in ss:
                self.record_peak(b, obs, est_peak=est)

    def save(self, path: str) -> None:
        """Atomic write; best-effort (a read-only cache dir never breaks
        execution)."""
        try:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                       prefix=".stats-", suffix=".json")
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json(), f)
            os.replace(tmp, path)
        except OSError:
            pass

    def load(self, path: str) -> bool:
        try:
            with open(path) as f:
                self.merge_json(json.load(f))
            return True
        except (OSError, ValueError):
            return False


def _rows_nbytes(value: Any) -> tuple[int, int] | None:
    """(rows, nbytes) of a materialized table value; None for scalars."""
    gather = getattr(value, "rows", None)
    if callable(gather) and hasattr(value, "valid"):     # ShardedTable
        return value.rows(), value.nbytes()
    if not isinstance(value, dict):
        return None
    rows = 0
    nbytes = 0
    for v in value.values():
        shape = getattr(v, "shape", None)
        if shape:
            rows = int(shape[0])
        nbytes += int(getattr(v, "nbytes", 0))
    return rows, nbytes


def record_execution(roots: list[G.Node], results: dict[int, Any],
                     ctx, backend_name: str | None = None) -> int:
    """Write actual cardinalities of materialized results (and any persisted
    intermediates) into ``ctx.stats_store``.  Returns entries recorded."""
    store = getattr(ctx, "stats_store", None)
    if store is None:
        return 0
    recorded = 0
    for n in G.walk(roots):
        val = results.get(n.id)
        if val is None and n.persist:
            key = getattr(n, "cache_key", None) or n.key()
            val = ctx.persist_cache.get(key)
        if val is None:
            continue
        rn = _rows_nbytes(val)
        if rn is None:
            continue
        if isinstance(n, (G.SinkPrint, G.Materialized, G.Handoff)):
            continue
        store.record(n.key(), rn[0], rn[1])
        recorded += 1
    if recorded:
        metrics = getattr(ctx, "metrics", None)
        if metrics is not None:
            metrics.inc("stats.cardinalities", recorded)
    # engines that meter their own peak (MemoryMeter, device-buffer
    # accounting) announce it via ctx.last_run_peak_engine — record *this
    # run's* peak under that engine's namespace (the session-cumulative
    # ctx.last_peak_bytes may belong to a different engine's earlier run)
    peak_engine = getattr(ctx, "last_run_peak_engine", None)
    run_peak = getattr(ctx, "last_run_peak_bytes", 0)
    if peak_engine and run_peak and backend_name \
            and peak_engine in str(backend_name).split("+"):
        store.record_peak(peak_engine, run_peak)
    return recorded
