"""Feedback loop: record actual cardinalities after execution and feed them
back into estimation (the paper's "runtime optimization" leg).

Observations are keyed by each node's *structural* key, so a re-built plan
with the same shape (the common case for scripted/repeated workloads) hits
the store even though node ids differ.  ``estimate_plan`` consults the
store and overrides a-priori estimates with observed row counts.

The store is JSON-persistable (``save``/``load``): cardinalities are keyed
by the ``repr`` of the structural key — deterministic across processes for
disk-backed sources (``Source.cache_token``) — and runtime/peak calibration
samples are keyed by backend name, so AUTO calibration survives restarts
(``LaFPContext.stats_path`` / ``REPRO_STATS_CACHE_DIR``).

Persistence is **process-safe**: ``save`` appends only the *delta* recorded
since the last flush as one JSON line to ``<path>.log`` under an ``fcntl``
file lock (``<path>.lock``), and compacts base + log into a fresh base file
(atomic ``os.replace``) when the log grows — so concurrent sessions and
processes sharing one stats path interleave appends instead of overwriting
each other, and a reader never sees a torn file.  In-memory mutation is
lock-guarded for multi-threaded serving.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
import threading
from typing import Any

from .. import graph as G

try:
    import fcntl
    _HAVE_FLOCK = True
except ImportError:                      # non-POSIX: best-effort, no lock
    _HAVE_FLOCK = False

# compact <path>.log into the base file once it passes this size
_COMPACT_LOG_BYTES = 1 << 18


@contextlib.contextmanager
def _file_lock(lock_path: str, shared: bool = False):
    """Advisory inter-process lock (``flock``).  Writers take it exclusive
    (append + compaction are serialized); readers take it shared (a read
    never overlaps a compaction's replace/truncate pair)."""
    if not _HAVE_FLOCK:
        yield None
        return
    f = open(lock_path, "a+")
    try:
        fcntl.flock(f, fcntl.LOCK_SH if shared else fcntl.LOCK_EX)
        yield f
    finally:
        fcntl.flock(f, fcntl.LOCK_UN)
        f.close()


# a backend's cost scale is trusted only after this many observed runs —
# a single noisy measurement must not flip placement
MIN_RUNTIME_SAMPLES = 3
_MAX_RUNTIME_SAMPLES = 64
# same floor for peak-estimate calibration (observed vs estimated peaks)
MIN_PEAK_SAMPLES = MIN_RUNTIME_SAMPLES
_MAX_PEAK_SAMPLES = 64


def _least_squares_scale(samples) -> float | None:
    """Regression through the origin: observed = scale * estimated."""
    num = sum(e * o for e, o in samples)
    den = sum(e * e for e, _o in samples)
    if den <= 0 or num <= 0:
        return None
    return num / den


class StatsStore:
    """Bounded store of observed per-node cardinalities, backend peaks, and
    per-backend (estimated, observed) samples used to calibrate the cost
    model's ``BackendCapability`` constants — both *work* (estimated work →
    wall seconds) and *peak* (estimated peak bytes → metered peak bytes)."""

    def __init__(self, max_entries: int = 4096):
        # keyed by repr(structural key): deterministic, JSON-serializable,
        # and stable across processes for path-token sources
        self.observed: dict[str, dict[str, float]] = {}
        self.backend_peaks: dict[str, int] = {}
        self.runtime_samples: dict[str, list[tuple[float, float]]] = {}
        self.peak_samples: dict[str, list[tuple[float, float]]] = {}
        self.max_entries = max_entries
        # concurrency: mutation and aggregate reads are lock-guarded so
        # multi-threaded sessions sharing a store (serving) never tear it
        self._lock = threading.RLock()
        # delta recorded since the last save() — what gets appended to the
        # on-disk log.  Data merged *from* disk (load) must not re-enter
        # the pending delta or every process would re-append what it read.
        self._pending = _empty_delta()
        self._suspend_pending = False

    @staticmethod
    def _k(key) -> str:
        return key if isinstance(key, str) else repr(key)

    def record(self, key, rows: int, nbytes: int) -> None:
        k = self._k(key)
        with self._lock:
            if (len(self.observed) >= self.max_entries
                    and k not in self.observed):
                # drop the oldest insertion (dict preserves order)
                self.observed.pop(next(iter(self.observed)))
            entry = {"rows": float(rows), "nbytes": float(nbytes)}
            self.observed[k] = entry
            if not self._suspend_pending:
                self._pending["observed"][k] = dict(entry)

    def lookup(self, key) -> dict[str, float] | None:
        return self.observed.get(self._k(key))

    def record_peak(self, backend: str, peak_bytes: int,
                    est_peak: float | None = None) -> None:
        """One observed peak.  With ``est_peak`` (the cost model's a-priori
        estimate for the same run) it also becomes a calibration sample."""
        with self._lock:
            self.backend_peaks[backend] = max(
                self.backend_peaks.get(backend, 0), int(peak_bytes))
            if not self._suspend_pending:
                self._pending["backend_peaks"][backend] = \
                    self.backend_peaks[backend]
            if est_peak is not None and est_peak > 0 and peak_bytes > 0:
                samples = self.peak_samples.setdefault(backend, [])
                samples.append((float(est_peak), float(peak_bytes)))
                if len(samples) > _MAX_PEAK_SAMPLES:
                    del samples[0]
                if not self._suspend_pending:
                    self._pending["peak_samples"].setdefault(
                        backend, []).append([float(est_peak),
                                             float(peak_bytes)])

    # -- runtime calibration (measured, not guessed, cost constants) --------

    def record_runtime(self, backend: str, est_work: float,
                       seconds: float) -> None:
        """One observed execution: the plan's estimated (uncalibrated) work
        on ``backend`` and the wall seconds it actually took."""
        if est_work <= 0 or seconds < 0:
            return
        with self._lock:
            samples = self.runtime_samples.setdefault(backend, [])
            samples.append((float(est_work), float(seconds)))
            if len(samples) > _MAX_RUNTIME_SAMPLES:
                del samples[0]
            if not self._suspend_pending:
                self._pending["runtime_samples"].setdefault(
                    backend, []).append([float(est_work), float(seconds)])

    def cost_scale(self, backend: str) -> float | None:
        """Calibrated seconds-per-work-unit for ``backend``: least-squares
        regression through the origin over the recorded (work, seconds)
        samples.  None until ``MIN_RUNTIME_SAMPLES`` runs were observed."""
        with self._lock:
            samples = self.runtime_samples.get(backend, ())
            if len(samples) < MIN_RUNTIME_SAMPLES:
                return None
            return _least_squares_scale(samples)

    def calibration(self) -> dict[str, float]:
        """All backends with a trusted calibrated scale."""
        out = {}
        with self._lock:
            for backend in tuple(self.runtime_samples):
                scale = self.cost_scale(backend)
                if scale is not None:
                    out[backend] = scale
        return out

    # -- peak calibration (observed peaks recalibrate peak estimates) -------

    def peak_scale(self, backend: str) -> float | None:
        """Calibrated observed-per-estimated-peak ratio, regressed the same
        way runtimes calibrate work constants.  None until
        ``MIN_PEAK_SAMPLES`` metered runs were observed."""
        with self._lock:
            samples = self.peak_samples.get(backend, ())
            if len(samples) < MIN_PEAK_SAMPLES:
                return None
            return _least_squares_scale(samples)

    def peak_calibration(self) -> dict[str, float]:
        out = {}
        with self._lock:
            for backend in tuple(self.peak_samples):
                scale = self.peak_scale(backend)
                if scale is not None:
                    out[backend] = scale
        return out

    def __len__(self):
        return len(self.observed)

    # -- persistence (AUTO calibration survives process restarts) -----------

    def to_json(self) -> dict:
        with self._lock:
            return {
                "observed": {k: dict(v) for k, v in self.observed.items()},
                "backend_peaks": dict(self.backend_peaks),
                "runtime_samples": {
                    b: [list(s) for s in ss]
                    for b, ss in self.runtime_samples.items()},
                "peak_samples": {
                    b: [list(s) for s in ss]
                    for b, ss in self.peak_samples.items()},
            }

    def merge_json(self, data: dict) -> None:
        for k, v in data.get("observed", {}).items():
            self.record(k, v.get("rows", 0.0), v.get("nbytes", 0.0))
        for b, p in data.get("backend_peaks", {}).items():
            with self._lock:
                self.backend_peaks[b] = max(self.backend_peaks.get(b, 0),
                                            int(p))
        for b, ss in data.get("runtime_samples", {}).items():
            for est, sec in ss:
                self.record_runtime(b, est, sec)
        for b, ss in data.get("peak_samples", {}).items():
            for est, obs in ss:
                self.record_peak(b, obs, est_peak=est)

    def _take_pending(self) -> dict | None:
        with self._lock:
            if not any(self._pending.values()):
                return None
            delta, self._pending = self._pending, _empty_delta()
            return delta

    def _requeue(self, delta: dict) -> None:
        """Put an unflushed delta back (save failed) so the next save
        retries it instead of silently dropping it from disk."""
        with self._lock:
            self._pending["observed"] = {**delta["observed"],
                                         **self._pending["observed"]}
            for b, p in delta["backend_peaks"].items():
                cur = self._pending["backend_peaks"].get(b, 0)
                self._pending["backend_peaks"][b] = max(cur, p)
            for field in ("runtime_samples", "peak_samples"):
                for b, ss in delta[field].items():
                    self._pending[field][b] = \
                        ss + self._pending[field].get(b, [])

    def save(self, path: str) -> None:
        """Append the delta since the last save as one JSON line to
        ``<path>.log`` under the file lock; compact into the base file when
        the log grows.  Best-effort (a read-only cache dir never breaks
        execution)."""
        delta = self._take_pending()
        try:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            log = path + ".log"
            with _file_lock(path + ".lock"):
                if delta is not None:
                    with open(log, "a") as f:
                        f.write(json.dumps(delta) + "\n")
                    delta = None
                try:
                    log_size = os.path.getsize(log)
                except OSError:
                    log_size = 0
                if log_size > _COMPACT_LOG_BYTES or not os.path.exists(path):
                    _compact_locked(path, self.max_entries)
        except OSError:
            if delta is not None:
                self._requeue(delta)

    def compact(self, path: str) -> None:
        """Merge base + append-log into a fresh base file (atomic replace)
        and truncate the log, under the exclusive file lock."""
        try:
            with _file_lock(path + ".lock"):
                _compact_locked(path, self.max_entries)
        except OSError:
            pass

    def load(self, path: str) -> bool:
        """Merge the persisted base file plus any not-yet-compacted log
        lines.  Takes the file lock shared, so a load never observes a
        compaction's replace/truncate mid-flight.  Loaded data does not
        re-enter the pending delta (it is already on disk)."""
        found = False
        try:
            with _file_lock(path + ".lock", shared=True):
                with self._lock:
                    self._suspend_pending = True
                    try:
                        try:
                            with open(path) as f:
                                self.merge_json(json.load(f))
                            found = True
                        except (OSError, ValueError):
                            pass
                        try:
                            with open(path + ".log") as f:
                                for line in f:
                                    line = line.strip()
                                    if not line:
                                        continue
                                    try:
                                        self.merge_json(json.loads(line))
                                    except ValueError:
                                        continue  # torn tail (lockless writer)
                                    found = True
                        except OSError:
                            pass
                    finally:
                        self._suspend_pending = False
        except OSError:
            return False
        return found


def _empty_delta() -> dict:
    return {"observed": {}, "backend_peaks": {},
            "runtime_samples": {}, "peak_samples": {}}


def _compact_locked(path: str, max_entries: int) -> None:
    """Merge base + log → fresh base (atomic replace), truncate log.
    Caller holds the exclusive file lock."""
    merged = StatsStore(max_entries=max_entries)
    merged._suspend_pending = True
    try:
        with open(path) as f:
            merged.merge_json(json.load(f))
    except (OSError, ValueError):
        pass
    log = path + ".log"
    try:
        with open(log) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    merged.merge_json(json.loads(line))
                except ValueError:
                    continue
    except OSError:
        pass
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=".stats-", suffix=".json")
    with os.fdopen(fd, "w") as f:
        json.dump(merged.to_json(), f)
    os.replace(tmp, path)
    with open(log, "w"):
        pass


def _rows_nbytes(value: Any) -> tuple[int, int] | None:
    """(rows, nbytes) of a materialized table value; None for scalars."""
    gather = getattr(value, "rows", None)
    if callable(gather) and hasattr(value, "valid"):     # ShardedTable
        return value.rows(), value.nbytes()
    if not isinstance(value, dict):
        return None
    rows = 0
    nbytes = 0
    for v in value.values():
        shape = getattr(v, "shape", None)
        if shape:
            rows = int(shape[0])
        nbytes += int(getattr(v, "nbytes", 0))
    return rows, nbytes


def record_execution(roots: list[G.Node], results: dict[int, Any],
                     ctx, backend_name: str | None = None) -> int:
    """Write actual cardinalities of materialized results (and any persisted
    intermediates) into ``ctx.stats_store``.  Returns entries recorded."""
    store = getattr(ctx, "stats_store", None)
    if store is None:
        return 0
    recorded = 0
    for n in G.walk(roots):
        val = results.get(n.id)
        if val is None and n.persist:
            key = getattr(n, "cache_key", None) or n.key()
            val = ctx.persist_cache.get(key)
        if val is None:
            continue
        rn = _rows_nbytes(val)
        if rn is None:
            continue
        if isinstance(n, (G.SinkPrint, G.Materialized, G.Handoff)):
            continue
        store.record(n.key(), rn[0], rn[1])
        recorded += 1
    if recorded:
        metrics = getattr(ctx, "metrics", None)
        if metrics is not None:
            metrics.inc("stats.cardinalities", recorded)
    # engines that meter their own peak (MemoryMeter, device-buffer
    # accounting) announce it via ctx.last_run_peak_engine — record *this
    # run's* peak under that engine's namespace (the session-cumulative
    # ctx.last_peak_bytes may belong to a different engine's earlier run)
    peak_engine = getattr(ctx, "last_run_peak_engine", None)
    run_peak = getattr(ctx, "last_run_peak_bytes", 0)
    if peak_engine and run_peak and backend_name \
            and peak_engine in str(backend_name).split("+"):
        store.record_peak(peak_engine, run_peak)
    return recorded
