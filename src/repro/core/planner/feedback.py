"""Feedback loop: record actual cardinalities after execution and feed them
back into estimation (the paper's "runtime optimization" leg).

Observations are keyed by each node's *structural* key, so a re-built plan
with the same shape (the common case for scripted/repeated workloads) hits
the store even though node ids differ.  ``estimate_plan`` consults the
store and overrides a-priori estimates with observed row counts.
"""
from __future__ import annotations

from typing import Any

from .. import graph as G


# a backend's cost scale is trusted only after this many observed runs —
# a single noisy measurement must not flip placement
MIN_RUNTIME_SAMPLES = 3
_MAX_RUNTIME_SAMPLES = 64


class StatsStore:
    """Bounded store of observed per-node cardinalities, backend peaks, and
    per-backend (estimated work, wall seconds) runtime samples used to
    calibrate the cost model's ``BackendCapability`` constants."""

    def __init__(self, max_entries: int = 4096):
        self.observed: dict[tuple, dict[str, float]] = {}
        self.backend_peaks: dict[str, int] = {}
        self.runtime_samples: dict[str, list[tuple[float, float]]] = {}
        self.max_entries = max_entries

    def record(self, key: tuple, rows: int, nbytes: int) -> None:
        if len(self.observed) >= self.max_entries and key not in self.observed:
            # drop the oldest insertion (dict preserves order)
            self.observed.pop(next(iter(self.observed)))
        self.observed[key] = {"rows": float(rows), "nbytes": float(nbytes)}

    def lookup(self, key: tuple) -> dict[str, float] | None:
        return self.observed.get(key)

    def record_peak(self, backend: str, peak_bytes: int) -> None:
        self.backend_peaks[backend] = max(
            self.backend_peaks.get(backend, 0), int(peak_bytes))

    # -- runtime calibration (measured, not guessed, cost constants) --------

    def record_runtime(self, backend: str, est_work: float,
                       seconds: float) -> None:
        """One observed execution: the plan's estimated (uncalibrated) work
        on ``backend`` and the wall seconds it actually took."""
        if est_work <= 0 or seconds < 0:
            return
        samples = self.runtime_samples.setdefault(backend, [])
        samples.append((float(est_work), float(seconds)))
        if len(samples) > _MAX_RUNTIME_SAMPLES:
            del samples[0]

    def cost_scale(self, backend: str) -> float | None:
        """Calibrated seconds-per-work-unit for ``backend``: least-squares
        regression through the origin over the recorded (work, seconds)
        samples.  None until ``MIN_RUNTIME_SAMPLES`` runs were observed."""
        samples = self.runtime_samples.get(backend, ())
        if len(samples) < MIN_RUNTIME_SAMPLES:
            return None
        num = sum(w * s for w, s in samples)
        den = sum(w * w for w, s in samples)
        if den <= 0 or num <= 0:
            return None
        return num / den

    def calibration(self) -> dict[str, float]:
        """All backends with a trusted calibrated scale."""
        out = {}
        for backend in self.runtime_samples:
            scale = self.cost_scale(backend)
            if scale is not None:
                out[backend] = scale
        return out

    def __len__(self):
        return len(self.observed)


def _rows_nbytes(value: Any) -> tuple[int, int] | None:
    """(rows, nbytes) of a materialized table value; None for scalars."""
    if not isinstance(value, dict):
        return None
    rows = 0
    nbytes = 0
    for v in value.values():
        shape = getattr(v, "shape", None)
        if shape:
            rows = int(shape[0])
        nbytes += int(getattr(v, "nbytes", 0))
    return rows, nbytes


def record_execution(roots: list[G.Node], results: dict[int, Any],
                     ctx, backend_name: str | None = None) -> int:
    """Write actual cardinalities of materialized results (and any persisted
    intermediates) into ``ctx.stats_store``.  Returns entries recorded."""
    store = getattr(ctx, "stats_store", None)
    if store is None:
        return 0
    recorded = 0
    for n in G.walk(roots):
        val = results.get(n.id)
        if val is None and n.persist:
            key = getattr(n, "cache_key", None) or n.key()
            val = ctx.persist_cache.get(key)
        if val is None:
            continue
        rn = _rows_nbytes(val)
        if rn is None:
            continue
        if isinstance(n, (G.SinkPrint, G.Materialized, G.Handoff)):
            continue
        store.record(n.key(), rn[0], rn[1])
        recorded += 1
    if backend_name and "streaming" in backend_name and ctx.last_peak_bytes:
        store.record_peak("streaming", ctx.last_peak_bytes)
    return recorded
