"""Cost-based adaptive planner (beyond-paper subsystem).

The paper's headline claim is that a lazy dataframe system "allows the
choice of the best-suited backend for an application based on factors such
as data size" — this package is that choice, made mechanical.  It turns the
manual engine knob into ``"auto"``: at every force point the runtime
estimates the plan, prices it per registered engine, and dispatches to the
cheapest engine whose footprint fits the memory budget.

Candidates, capabilities, cost constants, and calibration namespaces all
flow from the open engine registry (``repro.core.engines``): nothing in
this package names a concrete engine, so engines added at runtime via
``repro.register_engine`` (or the ``repro.engines`` entry-point group) are
planned, priced, and calibrated exactly like the in-tree ones.

Design record
=============

Four layers, each usable on its own:

``stats``
    Cardinality/width estimation.  Leaf statistics come from what the
    engine already maintains for free: partition metas (row counts), zone
    maps (per-partition min/max), and dictionary vocabularies (exact NDV
    for encoded string columns).  ``estimate_plan`` propagates a
    ``TableStats`` (rows, per-column byte widths, NDVs, merged zone map)
    through every DAG node.  ``Filter`` nodes use selectivity estimation:
    range predicates interpolate against the zone map, equality predicates
    use 1/NDV, conjunction multiplies, disjunction adds (inclusion–
    exclusion).  Joins use the classic |L|·|R|/max(ndv_L, ndv_R) rule;
    group-bys cap output rows at the key-NDV product.

``cost``
    A per-operator, per-engine cost function over those stats.  Engines
    publish a ``BackendCapability`` descriptor at registration: supported
    ops, startup overhead, per-byte scan cost, per-row compute cost,
    effective parallelism, transfer cost, and a fallback penalty so ops an
    engine must gather-and-delegate are priced in rather than forbidden.
    ``plan_cost`` also simulates peak memory per the capability's declared
    ``peak_model``: the resident model replays a refcounted topological
    walk; the chunked model charges chunk-sized flow plus pipeline-breaker
    state (join build sides, group-by partials, sort materialization); the
    sharded model divides resident bytes across shards until the first
    fallback gathers.

``select``
    ``"auto"`` resolution: operator-granular hybrid placement.
    ``plan_placement`` prices every operator on every candidate engine and
    partitions the DAG into engine *segments* via a min-cut style dynamic
    program with an explicit transfer charge at cut edges (the cost of
    materializing a boundary and re-ingesting it in the next engine).  Each
    segment then picks the cheapest calibrated engine whose estimated peak
    fits ``ctx.memory_budget`` (falling back to the lowest-footprint engine
    when nothing fits, flagged ``feasible=False``); engines the model
    cannot price are rejected with the recorded reason, never silently
    dropped.  Segments execute in topological order chained by
    ``graph.Handoff`` pipe breakers.  The PR-1 per-root-subtree strategy
    remains selectable via ``ctx.backend_options["placement"]="per_root"``.
    Every segment appends a human-readable line to ``ctx.planner_trace``
    ("plan-choice trace") and a typed ``Decision.candidates`` record
    (rendered by ``repro.core.explain`` / ``pd.explain()``):
      auto: seg0 root#7 ops=3 -> engineA cost=1.2e+05 peak=3.1MB cal=x1 (...)

``feedback``
    The paper's "runtime optimization" leg, twice over.  After execution
    the runtime records actual cardinalities/bytes into ``ctx.stats_store``
    keyed by each node's *structural* key, plus per-engine observed peaks
    — the next estimate of the same (sub)plan overrides the a-priori guess.
    Every run additionally records an (estimated work, wall seconds) sample
    per engine; once ``MIN_RUNTIME_SAMPLES`` accumulate, ``cost_scale``
    regresses (least squares through the origin) the engine's
    seconds-per-work-unit and the selector compares *calibrated* costs, so
    cost constants converge to measured values on this machine.

``plancache``
    Serving-path amortization: the optimized plan (and its segment
    decisions) cached under a structural fingerprint + stats epoch, so a
    repeated plan shape skips optimize/rewrite/segment-DP entirely and
    rebinds cached segments to fresh sources.  Source ``cache_token``s are
    deliberately excluded from the key — the same program over new data
    hits.

The planner never changes results — only where they are computed.  It
reads the optimized DAG (after pushdown/pruning), so its stats reflect
what will actually run.
"""
from .cost import CostEstimate, node_work, plan_cost, transfer_cost
from .feedback import MIN_RUNTIME_SAMPLES, StatsStore, record_execution
from .plancache import (PlanCache, Uncacheable, cache_key,
                        default_plan_cache, plan_fingerprint, stats_epoch)
from .select import (Decision, calibration_scales, candidate_engines,
                     plan_placement)
from .stats import TableStats, estimate_plan, predicate_selectivity, source_stats

__all__ = [
    "CostEstimate", "plan_cost", "node_work", "transfer_cost",
    "StatsStore", "record_execution", "MIN_RUNTIME_SAMPLES",
    "Decision", "plan_placement", "calibration_scales", "candidate_engines",
    "TableStats", "estimate_plan", "predicate_selectivity", "source_stats",
    "PlanCache", "Uncacheable", "cache_key", "default_plan_cache",
    "plan_fingerprint", "stats_epoch",
]
