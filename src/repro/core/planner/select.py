"""AUTO engine selection: operator-granular hybrid placement with
runtime-calibrated costs.

The optimized DAG is partitioned into engine *segments* — connected groups
of operators assigned to one engine — by a min-cut style dynamic program
over per-node per-engine costs with an explicit transfer charge for
materializing at segment boundaries (``cost.transfer_cost``).  Segments
execute in topological order; values crossing a boundary are materialized
to host and re-enter the next segment as ``graph.Handoff`` leaves
(``runtime._dispatch`` chains them).

Candidate engines come from the open registry (``repro.core.engines``):
every registered engine — in-tree or plug-in — is priced by its declared
``BackendCapability``, and ``ctx.engine_allowlist`` (``session(engines=
(...,))``) restricts the candidate set per session.  Calibration keys and
stats-store namespaces are the engines' registry names, so a runtime-
registered engine calibrates exactly like a built-in.

Costs are calibrated: once ``ctx.stats_store`` holds enough observed
(estimated-work, wall-seconds) samples for an engine
(``feedback.MIN_RUNTIME_SAMPLES``), its cost constants are scaled by the
regressed seconds-per-work-unit, so repeated workloads converge to measured
— not guessed — constants.

The plan-choice trace (``ctx.planner_trace``) records one line per segment
(engine names are whatever the registry holds):

    auto: seg0 root#12 ops=4 -> engineA cost=2.1e+05 peak=3.4MB cal=x1 |
    engineB 5.0e+05/0.3MB, engineC 8.7e+05/0.9MB

Read it as: segment 0 (4 operators, output node 12) dispatched to engineA
with calibrated work 2.1e5 and estimated peak 3.4 MB; rejected candidates
follow with their work/peak.  ``budget!`` marks candidates rejected for
exceeding ``ctx.memory_budget``; ``pricing-failed:`` marks candidates the
cost model could not price (with the reason — never silently dropped).
Segments with cross-segment inputs append ``handoff<-#id`` markers; at
execution time ``runtime.execute_segments`` adds one line per boundary
value kept device-resident (``payload=ShardedTable``), and when peak
calibration is active an ``auto: peak-calibration`` summary precedes the
segments.  The same information is available as typed records through
``repro.core.explain`` (``Decision.candidates`` feeds it).

``ctx.backend_options["placement"]`` selects the strategy: ``"operator"``
(default, segments) or ``"per_root"`` (the PR-1 behaviour: one choice per
root subtree; kept for regret comparisons in
``benchmarks/run.py backend_selection``).
"""
from __future__ import annotations

import dataclasses

from .. import graph as G
from ..engines import default_registry
from .cost import CostEstimate, node_work, plan_cost, transfer_cost
from .stats import estimate_plan


def candidate_engines(ctx=None) -> tuple[str, ...]:
    """Engine names the planner may choose from: every registered engine,
    filtered by the session's allow-list when one is set.

    An allow-list that matches *no* registered engine is an error, not a
    silent fall-through — otherwise a typo'd ``session(engines=(...))``
    would dispatch to exactly the engines the user tried to exclude."""
    from ..engines import UnknownEngineError
    names = default_registry().names()
    allow = getattr(ctx, "engine_allowlist", None) if ctx is not None else None
    if allow:
        allowed = tuple(n for n in names if n in allow)
        if not allowed:
            raise UnknownEngineError(
                f"engine allow-list {tuple(allow)!r} matches no registered "
                f"engine; registered engines: {list(names)}")
        return allowed
    return names


@dataclasses.dataclass
class Decision:
    """One planner segment: a connected group of operators dispatched to one
    engine.  ``roots`` are the segment's outputs (nodes consumed by other
    segments, or plan roots); ``nodes`` is every operator the segment runs;
    ``boundary`` lists cross-segment inputs that arrive as handoffs.
    ``candidates`` holds one structured record per priced engine (chosen
    and rejected alike) — the typed source for ``pd.explain()``."""
    roots: list                          # segment output nodes
    backend: str                         # engine name (registry key)
    cost: CostEstimate
    rejected: dict[str, str]             # engine name -> reason string
    nodes: list = dataclasses.field(default_factory=list)
    boundary: list = dataclasses.field(default_factory=list)
    feasible: bool = True                # est. peak fits ctx.memory_budget
    scale: float = 1.0                   # calibrated sec/work for the engine
    # engine name -> {"work", "peak_bytes", "over_budget", "chosen",
    #                 "reason"} (work/peak None when pricing failed)
    candidates: dict[str, dict] = dataclasses.field(default_factory=dict)


def _caps(cands: tuple[str, ...]):
    reg = default_registry()
    return {kind: reg.capability_of(kind) for kind in cands}


def calibration_scales(ctx, cands: tuple[str, ...] | None = None
                       ) -> dict[str, float]:
    """Per-engine cost multipliers regressed from observed runtimes.

    Engines with enough samples get their measured seconds-per-work-unit;
    engines not yet observed get the median of the known scales (so all
    candidates stay comparable); with no observations at all, every scale
    is 1.0 and costs compare raw — exactly the uncalibrated model."""
    cands = cands if cands is not None else candidate_engines(ctx)
    store = getattr(ctx, "stats_store", None)
    known = store.calibration() if store is not None else {}
    if not known:
        return {kind: 1.0 for kind in cands}
    ordered = sorted(known.values())
    default = ordered[len(ordered) // 2]
    return {kind: known.get(kind, default) for kind in cands}


def _price(roots: list[G.Node], boundary_ids: frozenset[int], stats,
           budget, chunk_rows, scales, cands,
           preferred: str | None = None,
           peak_scales: dict[str, float] | None = None,
           sharded_boundary: frozenset[int] = frozenset()) -> Decision:
    """Price one segment on every candidate engine and decide.

    An engine the cost model cannot price is *not* silently dropped: the
    failure reason is recorded in ``Decision.rejected``.  ``preferred``
    (the min-cut assignment) wins when it is budget-feasible; otherwise the
    cheapest calibrated feasible candidate; if nothing fits the budget, the
    smallest-footprint engine survives and ``feasible=False``.

    ``peak_scales`` are the measured observed/estimated peak ratios
    (``StatsStore.peak_scale``): candidate peak estimates are recalibrated
    by them before the budget check, the same way runtime scales calibrate
    work.  ``sharded_boundary`` marks handoff inputs arriving as
    device-resident payloads (only meaningful for candidates whose
    capability ``keeps_device_payloads``)."""
    caps = _caps(cands)
    costs: dict[str, CostEstimate] = {}
    rejected: dict[str, str] = {}
    cand_records: dict[str, dict] = {}
    for kind in cands:
        try:
            sb = (sharded_boundary if caps[kind].keeps_device_payloads
                  else frozenset())
            costs[kind] = plan_cost(roots, stats, kind, chunk_rows,
                                    boundary=boundary_ids,
                                    sharded_boundary=sb)
            costs[kind].raw_peak_bytes = costs[kind].peak_bytes
            ps = (peak_scales or {}).get(caps[kind].name)
            if ps is not None:
                costs[kind].peak_bytes *= ps     # calibrated peak estimate
        except Exception as e:  # noqa: BLE001 — reason recorded, not dropped
            reason = (f"{caps[kind].name} pricing-failed: "
                      f"{type(e).__name__}: {e}")
            rejected[caps[kind].name] = reason
            cand_records[caps[kind].name] = {
                "work": None, "peak_bytes": None, "over_budget": False,
                "chosen": False, "reason": reason}
    if not costs:
        raise RuntimeError(
            f"no engine could price this plan: {rejected}")
    feasible = {k: c for k, c in costs.items()
                if budget is None or c.peak_bytes <= budget}
    ok = True
    if preferred in feasible:
        best = preferred
    elif feasible:
        best = min(feasible, key=lambda k: costs[k].total * scales[k])
    else:
        # nothing fits: take the smallest-footprint engine (a chunked-model
        # engine is the usual survivor) and let the meter arbitrate
        best = min(costs, key=lambda k: costs[k].peak_bytes)
        ok = False
    for k, c in costs.items():
        over = budget is not None and c.peak_bytes > budget
        cand_records[c.backend] = {
            "work": c.total * scales[k], "peak_bytes": c.peak_bytes,
            "over_budget": over, "chosen": k is best,
            "reason": "" if k is best else (
                f"{c.backend} {c.total * scales[k]:.3g}"
                f"/{c.peak_bytes / 1e6:.1f}MB" + (" budget!" if over else ""))}
        if k is best:
            continue
        rejected[c.backend] = cand_records[c.backend]["reason"]
    return Decision(list(roots), best, costs[best], rejected,
                    feasible=ok, scale=scales[best],
                    candidates=cand_records)


# ---------------------------------------------------------------------------
# Per-root placement (PR-1 behaviour, kept for regret comparison)


def _per_root_placement(roots, stats, budget, chunk_rows, scales, cands,
                        peak_scales=None):
    per_root = [_price([r], frozenset(), stats, budget, chunk_rows, scales,
                       cands, peak_scales=peak_scales)
                for r in roots]
    # group same-engine decisions (first-appearance order; safe — at most
    # one root carries the ordered sink chain)
    merged: list[Decision] = []
    by_backend: dict[str, Decision] = {}
    for d in per_root:
        prev = by_backend.get(d.backend)
        if prev is not None:
            prev.roots.extend(d.roots)
            prev.cost = CostEstimate(
                prev.cost.backend, prev.cost.total + d.cost.total,
                max(prev.cost.peak_bytes, d.cost.peak_bytes),
                {**prev.cost.per_node, **d.cost.per_node},
                raw_peak_bytes=max(
                    prev.cost.raw_peak_bytes or prev.cost.peak_bytes,
                    d.cost.raw_peak_bytes or d.cost.peak_bytes))
            prev.feasible = prev.feasible and d.feasible
        else:
            by_backend[d.backend] = d
            merged.append(d)
    if len(merged) > 1:
        seen: dict[int, int] = {}
        overlap = False
        for gi, d in enumerate(merged):
            for n in G.walk(d.roots):
                if seen.setdefault(n.id, gi) != gi:
                    overlap = True
                    break
            if overlap:
                break
        if overlap:
            # subtrees assigned to different engines share nodes — hybrid
            # per-root placement would run the shared work once per group,
            # so fall back to a single whole-plan choice
            merged = [_price(roots, frozenset(), stats, budget, chunk_rows,
                             scales, cands, peak_scales=peak_scales)]
    for d in merged:
        d.nodes = G.walk(d.roots)
    return merged


# ---------------------------------------------------------------------------
# Operator-granular placement (min-cut DP + acyclic segment formation)


def _assign_operators(order, roots, stats, scales, caps):
    """Min-cut style assignment: bottom-up DP minimizing calibrated node
    work plus transfer charges at engine-boundary edges.  Multi-parent
    nodes (and roots that are also consumed elsewhere) are fixed at their
    own subtree optimum so shared work is priced exactly once.  Returns
    (assignment node-id -> engine name, pricing-failure reasons)."""
    errors: dict[str, str] = {}
    w: dict[int, dict[str, float]] = {}
    for n in order:
        w[n.id] = {}
        for kind, cap in caps.items():
            try:
                # amortize the engine's fixed startup over the plan so the
                # per-node DP sees the same constant plan_cost charges once
                # per segment (extra segments pay it again via transfer)
                w[n.id][kind] = (node_work(n, stats, cap)
                                 + cap.startup_cost / len(order)) * scales[kind]
            except Exception as e:  # noqa: BLE001 — reason surfaces in trace
                errors.setdefault(cap.name, (
                    f"{cap.name} pricing-failed: {type(e).__name__}: {e}"))
        if not w[n.id]:
            raise RuntimeError(f"no engine can price node {n!r}: {errors}")

    parents: dict[int, int] = {}
    for n in order:
        for i in n.inputs:
            parents[i.id] = parents.get(i.id, 0) + 1
    for r in roots:
        parents[r.id] = parents.get(r.id, 0) + 1   # the caller consumes roots

    def _transfer(child, b_from, b_to):
        work = transfer_cost(stats[child.id].total_bytes,
                             caps[b_from], caps[b_to])
        return work * 0.5 * (scales[b_from] + scales[b_to])

    dp: dict[int, dict[str, float]] = {}
    choice: dict[int, dict[str, dict[int, str]]] = {}
    fixed: dict[int, str] = {}
    for n in order:
        dp[n.id] = {}
        choice[n.id] = {}
        for b in w[n.id]:
            tot = w[n.id][b]
            ch: dict[int, str] = {}
            for i in n.inputs:
                if i.id in fixed:
                    bi = fixed[i.id]
                    tot += 0.0 if bi == b else _transfer(i, bi, b)
                    ch[i.id] = bi
                else:
                    best_b, best_c = None, float("inf")
                    for bi, ci in dp[i.id].items():
                        c = ci + (0.0 if bi == b else _transfer(i, bi, b))
                        if c < best_c:
                            best_c, best_b = c, bi
                    tot += best_c
                    ch[i.id] = best_b
            dp[n.id][b] = tot
            choice[n.id][b] = ch
        if parents.get(n.id, 0) > 1:
            fixed[n.id] = min(dp[n.id], key=dp[n.id].get)

    assign: dict[int, str] = dict(fixed)

    def backtrack(n: G.Node, b: str):
        for i in n.inputs:
            bi = choice[n.id][b][i.id]
            if i.id not in assign:
                assign[i.id] = bi
                backtrack(i, bi)
            elif i.id in fixed and i.id not in _expanded:
                _expanded.add(i.id)
                backtrack(i, assign[i.id])

    _expanded: set[int] = set()
    for r in roots:
        if r.id not in assign:
            assign[r.id] = min(dp[r.id], key=dp[r.id].get)
        if r.id not in _expanded:
            _expanded.add(r.id)
            backtrack(r, assign[r.id])
    return assign, errors


def _form_segments(order, assign):
    """Group same-engine connected operators into segments, keeping the
    segment graph acyclic: a node may join an input's segment only if no
    other input segment transitively depends on it."""
    seg_of: dict[int, int] = {}
    seg_nodes: list[list[G.Node]] = []
    seg_backend: list[str] = []
    seg_deps: list[set[int]] = []        # direct segment dependencies

    def depends_on(s: int, t: int) -> bool:
        """True if segment s (transitively) depends on segment t."""
        stack, seen = [s], set()
        while stack:
            x = stack.pop()
            if x == t:
                return True
            if x in seen:
                continue
            seen.add(x)
            stack.extend(seg_deps[x])
        return False

    for n in order:
        b = assign[n.id]
        joined = None
        for i in n.inputs:
            s = seg_of[i.id]
            if seg_backend[s] != b:
                continue
            if any(seg_of[j.id] != s and depends_on(seg_of[j.id], s)
                   for j in n.inputs):
                continue                 # joining would create a cycle
            joined = s
            break
        if joined is None:
            joined = len(seg_nodes)
            seg_nodes.append([])
            seg_backend.append(b)
            seg_deps.append(set())
        seg_of[n.id] = joined
        seg_nodes[joined].append(n)
        for i in n.inputs:
            s = seg_of[i.id]
            if s != joined:
                seg_deps[joined].add(s)
    return seg_of, seg_nodes, seg_backend, seg_deps


def _topo_segments(seg_nodes, seg_deps):
    """Topological order of segments (producers before consumers)."""
    remaining = {s: set(d) for s, d in enumerate(seg_deps)}
    out: list[int] = []
    ready = [s for s, d in remaining.items() if not d]
    while ready:
        s = min(ready)                    # deterministic order
        ready.remove(s)
        out.append(s)
        for t, deps in remaining.items():
            if s in deps:
                deps.discard(s)
                if not deps and t not in out and t not in ready:
                    ready.append(t)
    assert len(out) == len(seg_nodes), "segment graph has a cycle"
    return out


def _operator_placement(roots, stats, budget, chunk_rows, scales, cands,
                        peak_scales=None):
    order = G.walk(roots)
    caps = _caps(cands)
    try:
        assign, errors = _assign_operators(order, roots, stats, scales, caps)
    except RuntimeError:
        # some operator priced on no engine: whole-plan choice decides
        return [_price(roots, frozenset(), stats, budget, chunk_rows,
                       scales, cands, peak_scales=peak_scales)]
    seg_of, seg_nodes, seg_backend, seg_deps = _form_segments(order, assign)
    root_ids = {r.id for r in roots}
    consumed_outside: dict[int, bool] = {}
    consumer_backends: dict[int, set] = {}
    for n in order:
        for i in n.inputs:
            if seg_of[i.id] != seg_of[n.id]:
                consumed_outside[i.id] = True
                consumer_backends.setdefault(i.id, set()).add(assign[n.id])
    # a cross-segment value stays device-resident iff its producing engine
    # keeps device payloads and *every* consumer (and no final root) runs
    # the same engine — mirroring runtime.execute_segments' keep rule
    device_resident = {
        nid for nid, bs in consumer_backends.items()
        if caps[assign[nid]].keeps_device_payloads
        and nid not in root_ids
        and all(b == assign[nid] for b in bs)}
    decisions: list[Decision] = []
    for s in _topo_segments(seg_nodes, seg_deps):
        nodes = seg_nodes[s]
        node_ids = {n.id for n in nodes}
        outputs = [n for n in nodes
                   if consumed_outside.get(n.id) or n.id in root_ids]
        boundary = []
        seen_b: set[int] = set()
        for n in nodes:
            for i in n.inputs:
                if i.id not in node_ids and i.id not in seen_b:
                    seen_b.add(i.id)
                    boundary.append(i)
        sharded_b = (frozenset(seen_b & device_resident)
                     if caps[seg_backend[s]].keeps_device_payloads
                     else frozenset())
        d = _price(outputs, frozenset(seen_b), stats, budget, chunk_rows,
                   scales, cands, preferred=seg_backend[s],
                   peak_scales=peak_scales, sharded_boundary=sharded_b)
        d.nodes = nodes
        d.boundary = boundary
        # per-node pricing failures excluded an engine from the assignment
        # DP — surface them over the generic segment-level rejection
        d.rejected.update({k: v for k, v in errors.items()
                           if k != d.cost.backend})
        for k, v in errors.items():
            if k != d.cost.backend and k not in d.candidates:
                d.candidates[k] = {
                    "work": None, "peak_bytes": None, "over_budget": False,
                    "chosen": False, "reason": v}
        decisions.append(d)
    return decisions


# ---------------------------------------------------------------------------
# Entry point


def plan_placement(roots: list[G.Node], ctx) -> list[Decision]:
    """Partition the optimized plan into engine segments (topological
    order).  ``ctx.backend_options["placement"]`` picks the strategy:
    operator-granular segments (default) or the legacy per-root-subtree
    hybrid.  Candidates come from the engine registry, filtered by the
    session allow-list."""
    stats = estimate_plan(roots, ctx)
    budget = ctx.memory_budget
    chunk_rows = ctx.backend_options.get("chunk_rows", 1 << 16)
    cands = candidate_engines(ctx)
    scales = calibration_scales(ctx, cands)
    store = getattr(ctx, "stats_store", None)
    peak_scales = store.peak_calibration() if store is not None else {}
    mode = ctx.backend_options.get("placement", "operator")
    if mode == "per_root":
        decisions = _per_root_placement(roots, stats, budget, chunk_rows,
                                        scales, cands, peak_scales)
    else:
        decisions = _operator_placement(roots, stats, budget, chunk_rows,
                                        scales, cands, peak_scales)
    # only genuinely measured engines appear in the calibration line —
    # unmeasured candidates are priced at the median of the known scales,
    # and printing that default as if profiled would mislead debugging
    from ...obs.events import PlannerEvent
    measured = store.calibration() if store is not None else {}
    if measured:
        ctx.planner_trace.append(PlannerEvent(
            "auto: calibration " + " ".join(
                f"{name}={v:.3g}s/w" for name, v in sorted(measured.items())),
            kind="calibration", scales=dict(measured)))
    if peak_scales:
        ctx.planner_trace.append(PlannerEvent(
            "auto: peak-calibration " + " ".join(
                f"{name}=x{v:.3g}" for name, v in sorted(peak_scales.items())),
            kind="peak-calibration", scales=dict(peak_scales)))
    for si, d in enumerate(decisions):
        ids = ",".join(f"#{r.id}" for r in d.roots)
        alts = ", ".join(d.rejected.values()) or "-"
        hand = ("".join(f" handoff<-#{b.id}" for b in d.boundary)
                if d.boundary else "")
        cal = f"cal=x{d.scale:.3g}"
        if measured and d.cost.backend not in measured:
            cal += "(default)"
        ctx.planner_trace.append(PlannerEvent(
            f"auto: seg{si} root{ids} ops={len(d.nodes)} -> {d.cost.backend} "
            f"cost={d.cost.total * d.scale:.3g} "
            f"peak={d.cost.peak_bytes / 1e6:.1f}MB {cal}"
            f"{hand} | {alts}",
            kind="segment", segment=si, engine=str(d.cost.backend),
            cost=d.cost.total * d.scale, peak_bytes=d.cost.peak_bytes,
            root_ids=tuple(r.id for r in d.roots),
            boundary=tuple(b.id for b in d.boundary)))
    return decisions
