"""AUTO backend selection: cost every candidate engine, respect the memory
budget, dispatch to the cheapest — per root subtree (hybrid placement).

The plan-choice trace (``ctx.planner_trace``) records one line per decision:

    auto: root#12 -> eager cost=2.1e+05 peak=3.4MB | streaming 5.0e+05/0.3MB,
    distributed 8.7e+05/0.9MB

Read it as: subtree rooted at node 12 dispatched to eager with estimated
work 2.1e5 and estimated peak 3.4 MB; the rejected candidates follow with
their work/peak.  ``budget!`` marks candidates rejected for exceeding
``ctx.memory_budget``.
"""
from __future__ import annotations

import dataclasses

from .. import graph as G
from ..context import BackendEngines
from .cost import CostEstimate, plan_cost
from .stats import estimate_plan

CANDIDATES = (BackendEngines.EAGER, BackendEngines.STREAMING,
              BackendEngines.DISTRIBUTED)


@dataclasses.dataclass
class Decision:
    roots: list                          # root nodes assigned to this engine
    backend: BackendEngines
    cost: CostEstimate
    rejected: dict[str, str]             # backend name -> reason string


def _choose(roots: list[G.Node], stats, budget, chunk_rows) -> Decision:
    costs: dict[BackendEngines, CostEstimate] = {}
    for kind in CANDIDATES:
        try:
            costs[kind] = plan_cost(roots, stats, kind, chunk_rows)
        except Exception:  # noqa: BLE001 — a backend we can't price is skipped
            continue
    feasible = {k: c for k, c in costs.items()
                if budget is None or c.peak_bytes <= budget}
    rejected: dict[str, str] = {}
    if feasible:
        best = min(feasible, key=lambda k: costs[k].total)
    else:
        # nothing fits: take the smallest-footprint engine (streaming's
        # chunked model is the usual survivor) and let the meter arbitrate
        best = min(costs, key=lambda k: costs[k].peak_bytes)
    for k, c in costs.items():
        if k is best:
            continue
        over = budget is not None and c.peak_bytes > budget
        rejected[c.backend] = (
            f"{c.backend} {c.total:.3g}/{c.peak_bytes / 1e6:.1f}MB"
            + (" budget!" if over else ""))
    return Decision(list(roots), best, costs[best], rejected)


def plan_placement(roots: list[G.Node], ctx) -> list[Decision]:
    """Partition ``roots`` into per-backend execution groups.

    Each root subtree is costed independently (hybrid placement — branches
    of very different sizes may land on different engines); all roots
    choosing the same engine form one dispatch group (each backend's
    executor then memoizes shared work within the group).  When subtrees
    assigned to *different* engines overlap, hybrid placement would
    execute the shared nodes once per group — in that case we fall back
    to a single whole-plan choice instead.
    """
    stats = estimate_plan(roots, ctx)
    budget = ctx.memory_budget
    chunk_rows = ctx.backend_options.get("chunk_rows", 1 << 16)
    per_root = [_choose([r], stats, budget, chunk_rows) for r in roots]
    # group same-backend decisions (first-appearance order; safe — at most
    # one root carries the ordered sink chain)
    merged: list[Decision] = []
    by_backend: dict[BackendEngines, Decision] = {}
    for d in per_root:
        prev = by_backend.get(d.backend)
        if prev is not None:
            prev.roots.extend(d.roots)
            prev.cost = CostEstimate(
                prev.cost.backend, prev.cost.total + d.cost.total,
                max(prev.cost.peak_bytes, d.cost.peak_bytes),
                {**prev.cost.per_node, **d.cost.per_node})
        else:
            by_backend[d.backend] = d
            merged.append(d)
    if len(merged) > 1:
        seen: dict[int, int] = {}
        overlap = False
        for gi, d in enumerate(merged):
            for n in G.walk(d.roots):
                if seen.setdefault(n.id, gi) != gi:
                    overlap = True
                    break
            if overlap:
                break
        if overlap:
            merged = [_choose(roots, stats, budget, chunk_rows)]
    for d in merged:
        ids = ",".join(f"#{r.id}" for r in d.roots)
        alts = ", ".join(d.rejected.values()) or "-"
        ctx.planner_trace.append(
            f"auto: root{ids} -> {d.cost.backend} cost={d.cost.total:.3g} "
            f"peak={d.cost.peak_bytes / 1e6:.1f}MB | {alts}")
    return merged
