"""Typed plan/run introspection: ``pd.explain()`` / ``session.report()``.

Before this module, the only way to see what AUTO did was to grep the raw
``ctx.planner_trace`` / ``ctx.fallback_trace`` strings.  ``explain``
unifies that into structured, typed records:

* :class:`SegmentRecord` — one planner segment: the chosen engine, every
  priced candidate (:class:`CandidateRecord`, chosen and rejected alike,
  with calibrated work / estimated peak / over-budget flag / reason), the
  operators it runs, and the boundary handoffs feeding it.
* :class:`HandoffRecord` — one cross-segment value: payload kind
  (``table`` / scalar type / ``ShardedTable``), whether it stayed
  device-resident, producer and consumer engines.
* :class:`FallbackRecord` — one facade fallback event (op, shape, reason,
  served/failed status).
* :class:`CalibrationRecord` — one engine's runtime/peak calibration state
  (regressed scales + sample counts).
* :class:`RunRecord` — one force point: why it fired, the requested
  engine, the engines that executed, its segments and handoffs.
* :class:`ExplainReport` — the whole story; ``render()`` (also
  ``str(report)``) produces a stable, human-readable text plan, and
  ``to_dict()`` a JSON-serializable form (the CI golden artifact).

Two entry points:

* ``explain()`` / ``explain(None)`` — report everything the current
  session ran so far (every segment, handoff, fallback event, and
  calibration scale).
* ``explain(frame)`` — *plan-only*: run the optimizer and the planner on a
  lazy frame without executing it, and report the would-be segment
  placement with full candidate pricing.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class CandidateRecord:
    """One engine priced for one segment (chosen or rejected)."""
    engine: str
    chosen: bool
    work: float | None                  # calibrated work; None → pricing failed
    peak_bytes: float | None
    over_budget: bool
    reason: str                         # "" for the chosen engine


@dataclasses.dataclass(frozen=True)
class SegmentRecord:
    """One planner segment (or the whole plan, for fixed-engine runs)."""
    index: int
    engine: str
    root_ids: tuple[int, ...]
    ops: tuple[str, ...]
    work: float | None
    peak_bytes: float | None
    scale: float
    feasible: bool
    candidates: tuple[CandidateRecord, ...]
    handoff_in: tuple[int, ...]         # boundary node ids feeding this segment
    # telemetry linkage: id of the span that timed this segment's execution
    # (repro.obs) — None for plan-only reports (explain(frame))
    span_id: int | None = None


@dataclasses.dataclass(frozen=True)
class HandoffRecord:
    """One value crossing a segment boundary."""
    node_id: int
    segment: int
    payload_kind: str                   # "table" | "ShardedTable" | scalar type
    device_resident: bool
    producer: str
    consumers: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class FallbackRecord:
    op: str
    shape: tuple | None
    reason: str
    status: str                         # "fallback" (served) | "failed"


@dataclasses.dataclass(frozen=True)
class CalibrationRecord:
    engine: str
    cost_scale: float | None            # seconds per work unit (None: untrusted)
    peak_scale: float | None            # observed / estimated peak ratio
    runtime_samples: int
    peak_samples: int


@dataclasses.dataclass(frozen=True)
class RewriteRecord:
    """One fired plan rewrite (repro.core.rewrite): which rule replaced
    which node, and the estimated whole-plan work delta (negative =
    cheaper; None when pricing was unavailable)."""
    rule: str
    before_id: int
    before_op: str
    after_id: int
    after_op: str
    detail: str = ""
    cost_delta: float | None = None


@dataclasses.dataclass(frozen=True)
class DiagnosticRecord:
    """One pre-execution linter diagnostic (repro.lint), keyed to the user
    program's source line."""
    line: int
    col: int
    kind: str                           # e.g. "fallback.materialize"
    message: str
    symbol: str = ""
    level: str = "info"                 # "info" | "warn"


@dataclasses.dataclass(frozen=True)
class RunRecord:
    """One force point (``execute()`` call)."""
    index: int
    force_reason: str
    engine: str                         # requested engine ("auto" or fixed)
    executed: tuple[str, ...]           # engines that actually ran
    segments: tuple[SegmentRecord, ...]
    handoffs: tuple[HandoffRecord, ...]
    rewrites: tuple[RewriteRecord, ...] = ()
    cached: bool = False                # plan served from the plan cache


@dataclasses.dataclass(frozen=True)
class ExplainReport:
    session: str
    engine: str                         # session engine at report time
    runs: tuple[RunRecord, ...]
    fallbacks: tuple[FallbackRecord, ...]
    calibration: tuple[CalibrationRecord, ...]
    diagnostics: tuple[DiagnosticRecord, ...] = ()
    # cumulative ``io.*`` counters at report time (partitions loaded /
    # pruned / prefetched, bytes read, pushdown row accounting)
    io_counters: dict[str, int] = dataclasses.field(default_factory=dict)

    # -- rendering ----------------------------------------------------------

    def render(self) -> str:
        """Stable text plan: one block per run, one line per segment,
        indented candidate/handoff detail."""
        lines = [f"plan session={self.session} engine={self.engine} "
                 f"runs={len(self.runs)}"]
        for run in self.runs:
            lines.append(
                f"run {run.index} ({run.force_reason}): {run.engine}"
                f" -> {'+'.join(run.executed) or '-'}"
                f"{' cached=hit' if run.cached else ''}")
            for rw in run.rewrites:
                delta = ("" if rw.cost_delta is None
                         else f" Δwork={rw.cost_delta:+.3g}")
                det = f" ({rw.detail})" if rw.detail else ""
                lines.append(
                    f"  rewrite {rw.rule}: {rw.before_op}#{rw.before_id}"
                    f" -> {rw.after_op}#{rw.after_id}{det}{delta}")
            for seg in run.segments:
                hand = ("".join(f" handoff<-#{b}" for b in seg.handoff_in)
                        if seg.handoff_in else "")
                work = "-" if seg.work is None else f"{seg.work:.3g}"
                peak = ("-" if seg.peak_bytes is None
                        else f"{seg.peak_bytes / 1e6:.1f}MB")
                span = (f" span=#{seg.span_id}"
                        if seg.span_id is not None else "")
                lines.append(
                    f"  seg{seg.index} -> {seg.engine} ops={len(seg.ops)} "
                    f"[{','.join(seg.ops)}] work={work} peak={peak} "
                    f"cal=x{seg.scale:.3g}"
                    f"{'' if seg.feasible else ' infeasible!'}{hand}{span}")
                for c in seg.candidates:
                    if c.chosen:
                        continue
                    cw = "-" if c.work is None else f"{c.work:.3g}"
                    cp = ("-" if c.peak_bytes is None
                          else f"{c.peak_bytes / 1e6:.1f}MB")
                    flag = " budget!" if c.over_budget else ""
                    reason = (f" ({c.reason})"
                              if c.work is None and c.reason else "")
                    lines.append(
                        f"    rejected {c.engine}: {cw}/{cp}{flag}{reason}")
            for h in run.handoffs:
                res = "device-resident" if h.device_resident else "host"
                lines.append(
                    f"  handoff #{h.node_id} seg{h.segment} "
                    f"payload={h.payload_kind} {res} "
                    f"{h.producer}->{'+'.join(h.consumers)}")
        if self.fallbacks:
            lines.append(f"fallbacks: {len(self.fallbacks)}")
            for f in self.fallbacks:
                shape = "x".join(map(str, f.shape)) if f.shape else "?"
                lines.append(f"  {f.status}: {f.op} [{shape}] {f.reason}")
        if self.diagnostics:
            lines.append(f"diagnostics: {len(self.diagnostics)}")
            for d in self.diagnostics:
                lines.append(f"  {d.level} L{d.line}: [{d.kind}] {d.message}")
        if self.calibration:
            parts = []
            for c in self.calibration:
                bit = f"{c.engine}"
                if c.cost_scale is not None:
                    bit += f"={c.cost_scale:.3g}s/w"
                if c.peak_scale is not None:
                    bit += f" peak=x{c.peak_scale:.3g}"
                bit += f" (n={c.runtime_samples}/{c.peak_samples})"
                parts.append(bit)
            lines.append("calibration: " + "; ".join(parts))
        if self.io_counters:
            parts = []
            for k, v in sorted(self.io_counters.items()):
                short = k.split(".", 1)[1]
                parts.append(f"{short}={v / 1e6:.1f}MB" if short == "bytes_read"
                             else f"{short}={v}")
            lines.append("io: " + " ".join(parts))
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()

    def to_dict(self) -> dict:
        """JSON-serializable form (uploaded as a CI artifact)."""
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Record construction


def _op_label(n) -> str:
    """Operator label for plan rendering: fused segments expand their
    member ops — ``fused[filter,assign,...]`` — so a plan reader sees what
    the single node executes; scans carrying pushdown state render it —
    ``scan[cols=3,pred=2,pruned 4/16]`` — so a reader sees what never
    leaves the disk."""
    if n.op == "fused_rowwise":
        return "fused[" + ",".join(m.op for m in n.ops) + "]"
    if n.op == "scan":
        bits = []
        if n.columns is not None:
            bits.append(f"cols={len(n.columns)}")
        pushdown = getattr(n, "pushdown", None)
        if pushdown is not None:
            bits.append(f"pred={len(pushdown.conjuncts)}")
        if n.skip_partitions:
            total = getattr(n.source, "n_partitions", "?")
            bits.append(f"pruned {len(n.skip_partitions)}/{total}")
        if bits:
            return "scan[" + ",".join(bits) + "]"
    return n.op


def _candidate_records(candidates: dict[str, dict]
                       ) -> tuple[CandidateRecord, ...]:
    out = []
    for name, rec in candidates.items():
        out.append(CandidateRecord(
            engine=name, chosen=bool(rec.get("chosen")),
            work=rec.get("work"), peak_bytes=rec.get("peak_bytes"),
            over_budget=bool(rec.get("over_budget")),
            reason=rec.get("reason", "")))
    # chosen first, then alphabetical — stable regardless of registry order
    out.sort(key=lambda c: (not c.chosen, c.engine))
    return tuple(out)


def segment_records(decisions, span_ids: dict[int, int] | None = None
                    ) -> tuple[SegmentRecord, ...]:
    """Typed segments from planner ``Decision`` objects; ``span_ids`` maps
    segment index → telemetry span id for executed (not plan-only) runs."""
    span_ids = span_ids or {}
    segs = []
    for si, d in enumerate(decisions):
        segs.append(SegmentRecord(
            index=si,
            engine=str(d.backend),
            root_ids=tuple(r.id for r in d.roots),
            ops=tuple(_op_label(n) for n in d.nodes),
            work=d.cost.total,
            peak_bytes=d.cost.peak_bytes,
            scale=d.scale,
            feasible=d.feasible,
            candidates=_candidate_records(getattr(d, "candidates", {}) or {}),
            handoff_in=tuple(b.id for b in d.boundary),
            span_id=span_ids.get(si)))
    return tuple(segs)


def _drain_rewrites(ctx) -> tuple[RewriteRecord, ...]:
    """Consume the rewrite events the optimizer queued for this force
    point (``ctx._pending_rewrites``, filled by ``rewrite.apply_rewrites``)."""
    pending = getattr(ctx, "_pending_rewrites", None)
    if not pending:
        return ()
    out = tuple(RewriteRecord(
        rule=ev.rule, before_id=ev.before_id, before_op=ev.before_op,
        after_id=ev.after_id, after_op=ev.after_op, detail=ev.detail,
        cost_delta=ev.cost_delta) for ev in pending)
    pending.clear()
    return out


def record_run(ctx, force_reason: str, backend_name: str, opt_roots) -> None:
    """Append one typed RunRecord to ``ctx.run_records`` (called by
    ``runtime.execute`` after every force point)."""
    decisions = getattr(ctx, "planner_decisions", None) or []
    handoff_dicts = getattr(ctx, "_last_handoff_events", None) or []
    ctx._last_handoff_events = []
    span_ids = getattr(ctx, "_last_segment_spans", None) or {}
    ctx._last_segment_spans = {}
    if decisions:
        segments = segment_records(decisions, span_ids)
    else:
        # fixed-engine run: one synthetic segment listing the plan's ops
        from . import graph as G
        segments = (SegmentRecord(
            index=0, engine=str(backend_name),
            root_ids=tuple(r.id for r in opt_roots),
            ops=tuple(_op_label(n) for n in G.walk(opt_roots)),
            work=None, peak_bytes=None, scale=1.0, feasible=True,
            candidates=(), handoff_in=(), span_id=span_ids.get(0)),)
    handoffs = tuple(HandoffRecord(**h) for h in handoff_dicts)
    records = getattr(ctx, "run_records", None)
    if records is None:
        records = ctx.run_records = []
    records.append(RunRecord(
        index=len(records),
        force_reason=force_reason,
        engine=str(ctx.backend),
        executed=tuple(str(backend_name).split("+")),
        segments=segments,
        handoffs=handoffs,
        rewrites=_drain_rewrites(ctx),
        cached=bool(getattr(ctx, "_last_plan_cached", False))))
    if len(records) > 1024:              # bound long-lived sessions
        del records[: len(records) - 1024]


def _fallback_records(ctx) -> tuple[FallbackRecord, ...]:
    out = []
    for ev in getattr(ctx, "fallback_trace", ()):
        out.append(FallbackRecord(
            op=getattr(ev, "op", "?"),
            shape=getattr(ev, "shape", None),
            reason=getattr(ev, "reason", ""),
            status=getattr(ev, "status", "fallback")))
    return tuple(out)


def _calibration_records(ctx) -> tuple[CalibrationRecord, ...]:
    store = getattr(ctx, "stats_store", None)
    if store is None:
        return ()
    engines = sorted(set(store.runtime_samples) | set(store.peak_samples))
    out = []
    for name in engines:
        out.append(CalibrationRecord(
            engine=name,
            cost_scale=store.cost_scale(name),
            peak_scale=store.peak_scale(name),
            runtime_samples=len(store.runtime_samples.get(name, ())),
            peak_samples=len(store.peak_samples.get(name, ()))))
    return tuple(out)


def _diagnostic_records(ctx) -> tuple[DiagnosticRecord, ...]:
    """Linter diagnostics ``pd.analyze()`` attached to ``ctx.analysis``."""
    diags = (getattr(ctx, "analysis", None) or {}).get("diagnostics") or ()
    out = []
    for d in diags:
        out.append(DiagnosticRecord(
            line=getattr(d, "line", 0), col=getattr(d, "col", 0),
            kind=getattr(d, "kind", "?"), message=getattr(d, "message", ""),
            symbol=getattr(d, "symbol", ""),
            level=getattr(d, "level", "info")))
    return tuple(out)


def _io_counter_snapshot(ctx) -> dict[str, int]:
    metrics = getattr(ctx, "metrics", None)
    if metrics is None:
        return {}
    return {k: v for k, v in metrics.snapshot().items()
            if k.startswith("io.")}


def build_report(ctx) -> ExplainReport:
    """Typed report of everything ``ctx`` ran so far."""
    return ExplainReport(
        session=getattr(ctx, "session_name", "?"),
        engine=str(ctx.backend),
        runs=tuple(getattr(ctx, "run_records", ()) or ()),
        fallbacks=_fallback_records(ctx),
        calibration=_calibration_records(ctx),
        diagnostics=_diagnostic_records(ctx),
        io_counters=_io_counter_snapshot(ctx))


def explain(obj=None, ctx=None) -> ExplainReport:
    """Structured plan/run introspection.

    ``explain()`` reports the current session's history: every force
    point's segments (chosen engine + rejected candidates + costs),
    handoff payload kinds, fallback events, and calibration scales.

    ``explain(frame)`` plans a lazy frame **without executing it**: the
    optimizer and the cost-based planner run, and the report contains the
    would-be placement (one planned run, no handoffs/fallbacks)."""
    from .context import get_context
    ctx = ctx if ctx is not None else get_context()
    if obj is None:
        return build_report(ctx)
    node = getattr(obj, "_node", None)
    if node is None and hasattr(obj, "frame"):      # LazyColumn
        node = getattr(obj.frame, "_node", None)
    if node is None:
        node = obj
    from .optimizer import optimize
    from .planner.select import plan_placement
    saved_trace = ctx.planner_trace
    ctx.planner_trace = []
    try:
        roots, _ = optimize([node], ctx)
        decisions = plan_placement(roots, ctx)
    finally:
        ctx.planner_trace = saved_trace
    run = RunRecord(
        index=0, force_reason="explain", engine=str(ctx.backend),
        executed=(), segments=segment_records(decisions), handoffs=(),
        rewrites=_drain_rewrites(ctx))
    return ExplainReport(
        session=getattr(ctx, "session_name", "?"),
        engine=str(ctx.backend),
        runs=(run,),
        fallbacks=(),
        calibration=_calibration_records(ctx),
        diagnostics=_diagnostic_records(ctx))
