"""`analyze()` — Just-in-Time static analysis entry point (paper §2.4).

Two forms, both using reflection to find the program source (paper Fig. 5):

* ``pd.analyze()`` as the first statement of a script — inspects the calling
  module's source, runs the `ast` analyses, and installs the results in the
  context.  Because our API is already lazy, no textual rewrite is needed:
  the "rewritten program" is the original program executing against hints
  (usecols at read sites, live_df at force sites) looked up by call-site
  line number — semantically identical to the paper's injected arguments.

* ``@analyze`` on a function — analyzes the function body and installs hints
  before invoking it.

(Formerly ``repro.core.tracer`` — renamed because it is the static-analysis
entry point, not a tracer; ``repro.obs`` is the tracing subsystem.  The old
module remains as a deprecation shim.)
"""
from __future__ import annotations

import functools
import inspect
import sys
import textwrap
import time

from .context import get_context
from .source_analysis import analyze_source

# Frames from any engine-internal package are skipped when reflecting on the
# user program: the core layers and the repro.pandas facade both re-export
# analyze()/read_* entry points.
_INTERNAL_PREFIXES = ("repro.core", "repro.pandas")


def _is_internal(module_name: str) -> bool:
    return module_name.startswith(_INTERNAL_PREFIXES)


def _install_lazy_builtins(globs: dict):
    """The paper's program rewriter substitutes print/len with their lazy
    sink-building versions.  For a script (``__main__``) we do the same at
    analyze() time by rebinding the caller module's globals — this is what
    makes the facade a true two-line change (no third import for lazy
    print)."""
    from . import func as lazy_func
    if "print" not in globs:
        globs["print"] = lazy_func.print
    if "len" not in globs:
        globs["len"] = lazy_func.len


def analyze(fn=None):
    if fn is None:
        # script mode: reflect on the caller; analysis is installed in the
        # *current session's* context (session-scoped, not process-global)
        ctx = get_context()
        frame = sys._getframe(1)
        # skip facade/shim frames if called via repro.pandas / repro.core.lazy
        while frame and _is_internal(frame.f_globals.get("__name__", "")):
            frame = frame.f_back
        if frame.f_globals.get("__name__") == "__main__":
            _install_lazy_builtins(frame.f_globals)
        try:
            source = inspect.getsource(sys.modules[frame.f_globals["__name__"]])
        except Exception:
            try:
                with open(frame.f_code.co_filename) as f:
                    source = f.read()
            except Exception:
                ctx.analysis = {}
                return None
        with ctx.tracer.span("analyze", mode="script") as sp:
            t0 = time.perf_counter()
            res = analyze_source(source)
            ctx.analysis = res.as_context_dict()
            _attach_diagnostics(ctx, source)
            jit = time.perf_counter() - t0
            ctx.analysis["jit_seconds"] = jit
            sp.set(jit_seconds=jit)
        return res

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        # look up the context at call time: the function may run inside a
        # session() block created after decoration
        ctx = get_context()
        with ctx.tracer.span("analyze", mode="function") as sp:
            t0 = time.perf_counter()
            try:
                # getsourcelines (not getsource): the hints are keyed by the
                # *file* line numbers the call-site reflection reports, so a
                # function defined mid-file must have its analysis shifted by
                # its starting line; dedent handles nested/indented defs
                # (whose raw source is a SyntaxError to ast.parse).
                lines, start = inspect.getsourcelines(fn)
                source = textwrap.dedent("".join(lines))
                res = analyze_source(source)
                ctx.analysis = res.as_context_dict()
                offset = start - 1
                if offset:
                    ctx.analysis["usecols"] = {
                        ln + offset: v
                        for ln, v in ctx.analysis["usecols"].items()}
                    ctx.analysis["live_at"] = {
                        ln + offset: v
                        for ln, v in ctx.analysis["live_at"].items()}
                _attach_diagnostics(ctx, source, offset)
            except (OSError, TypeError, SyntaxError):
                ctx.analysis = {}
            jit = time.perf_counter() - t0
            ctx.analysis["jit_seconds"] = jit
            sp.set(jit_seconds=jit)
        return fn(*args, **kwargs)

    return wrapped


def _attach_diagnostics(ctx, source: str, offset: int = 0) -> None:
    """Run the pre-execution linter (repro.lint) over the analyzed program
    and attach the findings — surfaced by ``pd.explain()`` and, when the
    session is verbose, printed eagerly.  Linting is advisory: any failure
    leaves the analysis usable."""
    try:
        from ..lint import lint_source
        ctx.analysis["diagnostics"] = lint_source(source, offset=offset)
    except Exception:  # noqa: BLE001 — the linter must never break analyze()
        ctx.analysis["diagnostics"] = []


def user_call_lineno() -> int | None:
    """Line number of the nearest stack frame outside repro.core — the
    call-site key for static-analysis hints."""
    frame = sys._getframe(1)
    while frame is not None:
        mod = frame.f_globals.get("__name__", "")
        if not _is_internal(mod):
            return frame.f_lineno
        frame = frame.f_back
    return None


def user_frame_locals() -> dict:
    frame = sys._getframe(1)
    while frame is not None:
        mod = frame.f_globals.get("__name__", "")
        if not _is_internal(mod):
            return frame.f_locals
        frame = frame.f_back
    return {}


def usecols_hint() -> list[str] | None:
    """usecols for the read_* call currently executing, if analysis has one."""
    ctx = get_context()
    usecols = ctx.analysis.get("usecols") if ctx.analysis else None
    if not usecols:
        return None
    lineno = user_call_lineno()
    return usecols.get(lineno) if lineno is not None else None


def live_frames_hint() -> list | None:
    """live_df for the force point currently executing (paper §3.5)."""
    from .lazyframe import LazyFrame
    ctx = get_context()
    live_at = ctx.analysis.get("live_at") if ctx.analysis else None
    if not live_at:
        return None
    lineno = user_call_lineno()
    if lineno is None or lineno not in live_at:
        return None
    names = live_at[lineno]
    local = user_frame_locals()
    frames = [local[n] for n in names
              if isinstance(local.get(n), LazyFrame)]
    return frames or None
