# The paper's primary contribution: the Lazy Fat Pandas engine in JAX —
# lazy task-graph construction (graph, lazyframe), JIT static analysis
# (tracer, source_analysis), DAG optimization (optimizer, liveness), lazy
# sinks (sinks, func), metadata (metadata), and pluggable backends
# (backends.eager / backends.streaming / backends.distributed).
from .context import (BackendEngines, default_context, get_context,
                      pop_session, push_session, session)
from .lazyframe import LazyFrame, Result, from_arrays, read_npz, read_source
from .runtime import execute, flush
from .source import InMemorySource, NpzDirectorySource, encode_strings, write_npz_source
from .tracer import analyze

__all__ = [
    "BackendEngines", "get_context", "default_context", "session",
    "push_session", "pop_session", "LazyFrame", "Result", "from_arrays",
    "read_npz", "read_source", "execute", "flush", "InMemorySource",
    "NpzDirectorySource", "encode_strings", "write_npz_source", "analyze",
]
