# The paper's primary contribution: the Lazy Fat Pandas engine in JAX —
# lazy task-graph construction (graph, lazyframe), JIT static analysis
# (jit_analyze, source_analysis), DAG optimization (optimizer, liveness), lazy
# sinks (sinks, func), metadata (metadata), and pluggable string-named
# engines (engines registry + backends.eager/streaming/distributed,
# extensible via repro.register_engine / the repro.engines entry-point
# group).
from .context import (BackendEngines, default_context, get_context,
                      pop_session, push_session, session)
from .engines import (BackendCapability, create_engine, engine_names,
                      get_capability, register_engine, unregister_engine)
from .explain import ExplainReport, explain
from .lazyframe import LazyFrame, Result, from_arrays, read_npz, read_source
from .runtime import execute, flush
from .source import InMemorySource, NpzDirectorySource, encode_strings, write_npz_source
from .jit_analyze import analyze

__all__ = [
    "BackendEngines", "get_context", "default_context", "session",
    "push_session", "pop_session", "LazyFrame", "Result", "from_arrays",
    "read_npz", "read_source", "execute", "flush", "InMemorySource",
    "NpzDirectorySource", "encode_strings", "write_npz_source", "analyze",
    "register_engine", "unregister_engine", "engine_names",
    "get_capability", "create_engine", "BackendCapability",
    "explain", "ExplainReport",
]
