"""Metadata store (paper §3.6): per-source types + statistics.

Stats are computed once (a "background task" in the paper; here an explicit
``compute_metadata`` call or on first use), keyed by source identity and
modification time, and feed three optimizations: dtype narrowing, category
(dictionary) candidates, and backend choice by estimated in-memory size.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Mapping

import numpy as np

from .source import Source


@dataclasses.dataclass
class ColumnStats:
    dtype: str
    min: float | None = None
    max: float | None = None
    distinct_est: int | None = None
    null_frac: float = 0.0

    def narrowable(self) -> str | None:
        from .schema import narrow_int_dtype
        if self.min is None or not np.dtype(self.dtype).kind == "i":
            return None
        t = narrow_int_dtype(int(self.min), int(self.max))
        return str(t) if t.itemsize < np.dtype(self.dtype).itemsize else None

    def category_candidate(self, rows: int, threshold: float = 0.01) -> bool:
        """Few distinct values → dictionary/category encode (paper §3.6)."""
        return (self.distinct_est is not None and rows > 0
                and self.distinct_est <= max(64, threshold * rows))


@dataclasses.dataclass
class SourceMetadata:
    rows: int
    row_bytes: int
    columns: dict[str, ColumnStats]
    computed_at: float = dataclasses.field(default_factory=time.time)
    mtime: float | None = None

    def estimated_bytes(self) -> int:
        return self.rows * self.row_bytes

    def fits_in(self, budget_bytes: int) -> bool:
        return self.estimated_bytes() <= budget_bytes


_STORE: dict[int, SourceMetadata] = {}


def compute_metadata(source: Source, sample_partitions: int | None = None
                     ) -> SourceMetadata:
    """Scan (a sample of) partitions for stats.  Types come from the schema;
    min/max/distinct come from data (paper: 'statistics can be computed from
    a sample')."""
    n = source.n_partitions
    take = range(n) if sample_partitions is None else range(
        min(n, sample_partitions))
    stats: dict[str, ColumnStats] = {}
    rows = 0
    sampled_rows = 0
    for pi in take:
        part = source.load_partition(pi)
        pr = len(next(iter(part.values()))) if part else 0
        sampled_rows += pr
        for cname, arr in part.items():
            cs = stats.get(cname)
            if cs is None:
                cs = stats[cname] = ColumnStats(dtype=str(arr.dtype))
            if arr.dtype.kind in "ifu" and arr.size:
                amin, amax = float(arr.min()), float(arr.max())
                cs.min = amin if cs.min is None else min(cs.min, amin)
                cs.max = amax if cs.max is None else max(cs.max, amax)
                if arr.dtype.kind == "f":
                    cs.null_frac = float(np.isnan(arr).mean())
            uniq = np.unique(arr[: 65536])
            cs.distinct_est = max(cs.distinct_est or 0, int(uniq.shape[0]))
    # total rows from partition meta when sampled
    total = source.total_rows()
    rows = total if total is not None else sampled_rows
    row_bytes = source.schema.row_bytes()
    mtime = None
    path = getattr(source, "path", None)
    if path and os.path.exists(path):
        mtime = os.path.getmtime(path)
    md = SourceMetadata(rows=rows, row_bytes=row_bytes, columns=stats,
                        mtime=mtime)
    _STORE[id(source)] = md
    return md


def get_metadata(source: Source) -> SourceMetadata | None:
    md = _STORE.get(id(source))
    if md is None:
        return None
    path = getattr(source, "path", None)
    if path and md.mtime is not None and os.path.exists(path):
        if os.path.getmtime(path) > md.mtime:   # stale (paper's mtime check)
            del _STORE[id(source)]
            return None
    return md


def choose_backend(source: Source, available_bytes: int) -> str:
    """Cost-based backend choice sketch (paper future work, implemented):
    a whole-table ("resident"/"sharded" peak model) engine when the table
    fits comfortably, the first out-of-core ("chunked") engine otherwise.
    Candidates come from the engine registry — an out-of-tree engine with a
    chunked peak model is eligible without edits here.  Returns the engine
    *name*."""
    from .engines import default_registry
    md = get_metadata(source) or compute_metadata(source, sample_partitions=1)
    reg = default_registry()
    names = reg.names()
    resident = [n for n in names
                if reg.capability_of(n).peak_model != "chunked"]
    chunked = [n for n in names
               if reg.capability_of(n).peak_model == "chunked"]
    if md.estimated_bytes() * 2 <= available_bytes and resident:
        # the paper's sketch wants the local in-memory engine, not a
        # cluster dispatch: startup cost is the registry-generic proxy
        return min(resident,
                   key=lambda n: reg.capability_of(n).startup_cost)
    return chunked[0] if chunked else names[0]


def dtype_overrides_for(source: Source,
                        readonly_cols: set[str] | None) -> Mapping[str, str]:
    md = get_metadata(source)
    if md is None:
        return {}
    out = {}
    for cname, cs in md.columns.items():
        if readonly_cols is not None and cname not in readonly_cols:
            continue
        t = cs.narrowable()
        if t:
            out[cname] = t
    return out
