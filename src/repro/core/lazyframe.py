"""LazyFrame / LazyColumn / LazyScalar — the plain-Pandas-shaped lazy API
(paper §2.5).  Every call builds a task-graph node; nothing executes until a
force point (materialize / external call / flush)."""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import numpy as np

from . import expr as E
from . import graph as G
from .context import get_context
from .source import InMemorySource, Source


def _to_expr(v) -> E.Expr:
    if isinstance(v, LazyColumn):
        return v.expr
    if isinstance(v, E.Expr):
        return v
    return E.Lit(v)


class DtAccessor:
    def __init__(self, col: "LazyColumn"):
        self._col = col

    def __getattr__(self, field):
        if field.startswith("_"):
            raise AttributeError(field)
        if field in E._DT_FIELDS:
            return LazyColumn(self._col.frame, E.DtField(self._col.expr, field))
        # facade fallback protocol: unknown dt fields run through the
        # numpy-level kernel table as a wrapped UDF, recorded per session.
        from repro.pandas.fallback import dt_fallback
        return dt_fallback(self._col, field)


class LazyColumn:
    """A column-valued expression over a frame (no new DAG node until used)."""

    def __init__(self, frame: "LazyFrame", expr_: E.Expr):
        self.frame = frame
        self.expr = expr_

    # arithmetic / comparison build Expr trees
    def _bin(self, op, other, reflect=False):
        l, r = self.expr, _to_expr(other)
        if reflect:
            l, r = r, l
        return LazyColumn(self.frame, E.BinOp(op, l, r))

    def __add__(self, o): return self._bin("add", o)
    def __radd__(self, o): return self._bin("add", o, True)
    def __sub__(self, o): return self._bin("sub", o)
    def __rsub__(self, o): return self._bin("sub", o, True)
    def __mul__(self, o): return self._bin("mul", o)
    def __rmul__(self, o): return self._bin("mul", o, True)
    def __truediv__(self, o): return self._bin("truediv", o)
    def __rtruediv__(self, o): return self._bin("truediv", o, True)
    def __floordiv__(self, o): return self._bin("floordiv", o)
    def __mod__(self, o): return self._bin("mod", o)
    def __eq__(self, o): return self._bin("eq", o)      # type: ignore[override]
    def __ne__(self, o): return self._bin("ne", o)      # type: ignore[override]
    def __lt__(self, o): return self._bin("lt", o)
    def __le__(self, o): return self._bin("le", o)
    def __gt__(self, o): return self._bin("gt", o)
    def __ge__(self, o): return self._bin("ge", o)
    def __and__(self, o): return self._bin("and", o)
    def __or__(self, o): return self._bin("or", o)
    def __invert__(self): return LazyColumn(self.frame, E.Not(self.expr))
    def __hash__(self):
        return id(self)

    def isin(self, values):
        return LazyColumn(self.frame, E.IsIn(self.expr, tuple(values)))

    def clip(self, lower=None, upper=None):
        if lower is None and upper is None:
            return LazyColumn(self.frame, self.expr)
        return LazyColumn(self.frame, E.Clip(self.expr, lower, upper))

    def round(self, decimals=0):
        return LazyColumn(self.frame, E.Round(self.expr, int(decimals)))

    def astype(self, dtype):
        return LazyColumn(self.frame, E.Cast(self.expr, str(np.dtype(dtype))))

    def apply(self, fn):
        return LazyColumn(self.frame, E.UDF(fn, (self.expr,)))

    def fillna(self, value):
        def _fill(a, v=value):
            if getattr(a, "dtype", None) is not None and a.dtype.kind == "f":
                import jax.numpy as jnp
                xp = jnp if not isinstance(a, np.ndarray) else np
                return xp.where(xp.isnan(a), xp.asarray(v, dtype=a.dtype), a)
            return a
        return LazyColumn(self.frame, E.UDF(_fill, (self.expr,), name="fillna"))

    @property
    def dt(self):
        return DtAccessor(self)

    @property
    def str(self):
        return StrAccessor(self)

    def __getattr__(self, name):
        # Only reached when normal lookup fails: pandas Series methods the
        # lazy layer doesn't implement natively go through the fallback
        # kernel table (repro.pandas) instead of raising AttributeError.
        if name.startswith("_") or name in ("frame", "expr"):
            raise AttributeError(name)
        from repro.pandas.fallback import series_fallback
        return series_fallback(self, name)

    def to_numpy(self):
        return np.asarray(self.compute(force_reason="Series.to_numpy"))

    @property
    def values(self):
        return self.to_numpy()

    # reductions → LazyScalar
    def _reduce(self, fn):
        node = self.frame._node_for_expr_column(self.expr)
        name = node._col_name
        return LazyScalar(G.Reduce(node._inner, name, fn))

    def sum(self): return self._reduce("sum")
    def mean(self): return self._reduce("mean")
    def min(self): return self._reduce("min")
    def max(self): return self._reduce("max")
    def count(self): return self._reduce("count")
    def nunique(self): return self._reduce("nunique")
    def median(self): return self._reduce("median")

    def compute(self, live_df=None, force_reason="Series.compute"):
        node = self.frame._node_for_expr_column(self.expr)
        res = _execute([node._inner], live_df, force_reason)[0]
        return res[node._col_name]

    def head(self, n=5):
        node = self.frame._node_for_expr_column(self.expr)
        return LazyFrame(G.Head(node._inner, n), source_vocab=self.frame._vocab)


class StrAccessor:
    """Dict-encoded string ops: equality/isin against vocab (TPU adaptation —
    comparisons happen on int32 codes).  Predicates over the vocab itself
    (contains / startswith / endswith / match-by-callable) stay lazy: the
    string work happens once on the (small) vocabulary, the per-row work is
    an integer isin on the codes."""

    def __init__(self, col: LazyColumn):
        self._col = col

    def _codes_for(self, values):
        vocab = self._col.frame._vocab_for(self._col.expr)
        idx = {v: i for i, v in enumerate(vocab)}
        return [idx[v] for v in values if v in idx]

    def _vocab_predicate(self, pred):
        vocab = self._col.frame._vocab_for(self._col.expr)
        codes = tuple(i for i, v in enumerate(vocab) if pred(v))
        if not codes:
            return LazyColumn(self._col.frame,
                              E.BinOp("lt", self._col.expr, E.Lit(0)))
        return LazyColumn(self._col.frame, E.IsIn(self._col.expr, codes))

    def contains(self, pat):
        return self._vocab_predicate(lambda v: pat in v)

    def startswith(self, pat):
        return self._vocab_predicate(lambda v: v.startswith(pat))

    def endswith(self, pat):
        return self._vocab_predicate(lambda v: v.endswith(pat))

    def __getattr__(self, name):
        if name.startswith("_"):
            raise AttributeError(name)
        from repro.pandas.fallback import str_fallback
        return str_fallback(self._col, name)

    def eq(self, value):
        codes = self._codes_for([value])
        if not codes:
            return LazyColumn(self._col.frame,
                              E.BinOp("lt", self._col.expr, E.Lit(0)))  # all-False
        return LazyColumn(self._col.frame,
                          E.BinOp("eq", self._col.expr, E.Lit(codes[0])))

    def isin(self, values):
        codes = self._codes_for(values)
        if not codes:
            return LazyColumn(self._col.frame,
                              E.BinOp("lt", self._col.expr, E.Lit(0)))
        return LazyColumn(self._col.frame, E.IsIn(self._col.expr, tuple(codes)))


class _BoundNode:
    def __init__(self, inner: G.Node, col_name: str):
        self._inner = inner
        self._col_name = col_name


class LazyScalar:
    """Lazy scalar (len(), .mean(), …).  Supports deferred f-string printing
    via the escape-marker mechanism of paper §3.3."""

    ESC = "\x00LAFP:"

    def __init__(self, node: G.Node):
        self.node = node
        get_context().scalar_registry[node.id] = node

    def compute(self, live_df=None, force_reason="scalar.compute"):
        return _execute([self.node], live_df, force_reason)[0]

    def __format__(self, spec):
        return f"{self.ESC}{self.node.id}\x00"

    def __str__(self):
        return self.__format__("")

    def __float__(self):
        return float(self.compute())

    def __int__(self):
        return int(self.compute())


class GroupBy:
    def __init__(self, frame: "LazyFrame", keys: Sequence[str]):
        self.frame = frame
        self.keys = [keys] if isinstance(keys, str) else list(keys)

    def __getitem__(self, col):
        return GroupByColumn(self, col)

    def agg(self, spec: Mapping[str, tuple[str, str]]):
        node = G.GroupByAgg(self.frame._node, self.keys, dict(spec))
        return LazyFrame(node, source_vocab=self.frame._vocab)

    def size(self):
        return self.agg({"size": (None, "count")})

    def __getattr__(self, name):
        if name.startswith("_") or name in ("frame", "keys"):
            raise AttributeError(name)
        cols = self.frame._known_columns()
        if cols is not None and name in cols:
            return GroupByColumn(self, name)   # gb.col.sum() sugar
        from repro.pandas.fallback import groupby_fallback
        return groupby_fallback(self, None, name)


class GroupByColumn:
    def __init__(self, gb: GroupBy, col: str):
        self.gb = gb
        self.col = col

    def _agg(self, fn):
        return self.gb.agg({self.col: (self.col, fn)})

    def sum(self): return self._agg("sum")
    def mean(self): return self._agg("mean")
    def min(self): return self._agg("min")
    def max(self): return self._agg("max")
    def count(self): return self._agg("count")
    def nunique(self): return self._agg("nunique")

    def __getattr__(self, name):
        if name.startswith("_") or name in ("gb", "col"):
            raise AttributeError(name)
        from repro.pandas.fallback import groupby_fallback
        return groupby_fallback(self.gb, self.col, name)


class LazyFrame:
    """The Fat DataFrame.  Wraps a DAG node; assignment mutates the binding
    (pandas semantics), each op adds a node (lazy semantics)."""

    def __init__(self, node: G.Node, source_vocab: Mapping[str, list] | None = None):
        self.__dict__["_node"] = node
        self.__dict__["_vocab"] = dict(source_vocab or {})

    # -- column access ------------------------------------------------------
    def __getitem__(self, key):
        if isinstance(key, str):
            return LazyColumn(self, E.Col(key))
        if isinstance(key, list):
            return LazyFrame(G.Project(self._node, key), source_vocab=self._vocab)
        if isinstance(key, LazyColumn):
            return LazyFrame(G.Filter(self._node, key.expr), source_vocab=self._vocab)
        raise TypeError(f"cannot index LazyFrame with {type(key)}")

    def __getattr__(self, name):
        if name.startswith("_"):
            raise AttributeError(name)
        cols = self._known_columns()
        if cols is None or name in cols:
            return LazyColumn(self, E.Col(name))
        # Not a column of this frame: route through the fallback protocol
        # (repro.pandas kernel table) instead of building a doomed Col ref.
        from repro.pandas.fallback import frame_fallback
        return frame_fallback(self, name)

    def __setitem__(self, key: str, value):
        self.__dict__["_node"] = G.Assign(self._node, key, _to_expr(value))

    def __setattr__(self, key, value):
        if key.startswith("_"):
            self.__dict__[key] = value
        else:
            self[key] = value

    # -- pandas-shaped metadata ----------------------------------------------
    def _known_columns(self) -> frozenset[str] | None:
        """Output column set, propagated bottom-up through the DAG via
        ``Node.out_cols`` (None = statically unknown, e.g. past a MapRows).
        Memoized per node (nodes are immutable), so repeated attribute
        access stays O(1) amortized instead of O(graph)."""
        node = self._node
        if "_colset" in node.__dict__:
            return node.__dict__["_colset"]
        for n in G.walk([node]):
            if "_colset" in n.__dict__:
                continue
            n.__dict__["_colset"] = n.out_cols(
                [i.__dict__["_colset"] for i in n.inputs])
        return node.__dict__["_colset"]

    def _ordered_columns(self) -> list[str] | None:
        """Output columns in pandas order (source schema order + append
        order), or None when statically unknown.  Memoized like
        ``_known_columns``."""
        node = self._node
        if "_colorder" in node.__dict__:
            return node.__dict__["_colorder"]
        for n in G.walk([node]):
            if "_colorder" in n.__dict__:
                continue
            n.__dict__["_colorder"] = _ordered_out(
                n, [i.__dict__["_colorder"] for i in n.inputs])
        return node.__dict__["_colorder"]

    @property
    def columns(self) -> list[str]:
        ordered = self._ordered_columns()
        if ordered is not None:
            return list(ordered)
        cols = self._known_columns()
        if cols is not None:
            return sorted(cols)
        res = self.head(0).compute(force_reason="columns-property")
        return list(res.columns)

    @property
    def shape(self) -> tuple[int, int]:
        from repro.pandas.fallback import record_fallback
        ncols = len(self.columns)
        n = int(_execute([G.Length(self._node)], None, "shape-property")[0])
        record_fallback("DataFrame.shape", (n, ncols), "property-force")
        return (n, ncols)

    # -- pandas-shaped ops ----------------------------------------------------
    def copy(self, deep=True):
        # nodes are immutable; a copy is just a new binding on the same DAG
        return LazyFrame(self._node, source_vocab=self._vocab)

    def drop(self, labels=None, columns=None, axis=1):
        dropped = columns if columns is not None else labels
        if dropped is None:
            raise TypeError("drop requires `columns` (or labels with axis=1)")
        dropped = [dropped] if isinstance(dropped, str) else list(dropped)
        cols = self._ordered_columns()
        if cols is None:
            known = self._known_columns()
            if known is None:
                from repro.pandas.fallback import frame_fallback
                return frame_fallback(self, "drop")(columns=dropped)
            cols = sorted(known)
        keep = [c for c in cols if c not in dropped]
        return LazyFrame(G.Project(self._node, keep), source_vocab=self._vocab)

    def assign(self, **kwargs):
        node = self._node
        for k, v in kwargs.items():
            node = G.Assign(node, k, _to_expr(v))
        return LazyFrame(node, source_vocab=self._vocab)

    def rename(self, columns: Mapping[str, str]):
        return LazyFrame(G.Rename(self._node, columns), source_vocab=self._vocab)

    def astype(self, dtypes):
        if isinstance(dtypes, str):
            raise TypeError("astype requires {col: dtype}")
        return LazyFrame(G.AsType(self._node, {k: str(np.dtype(v))
                                               for k, v in dtypes.items()}),
                         source_vocab=self._vocab)

    def fillna(self, value):
        return LazyFrame(G.FillNa(self._node, value), source_vocab=self._vocab)

    def sort_values(self, by, ascending=True):
        by = [by] if isinstance(by, str) else list(by)
        return LazyFrame(G.SortValues(self._node, by, ascending),
                         source_vocab=self._vocab)

    def drop_duplicates(self, subset=None):
        subset = tuple(subset) if subset is not None else None
        return LazyFrame(G.DropDuplicates(self._node, subset),
                         source_vocab=self._vocab)

    def head(self, n=5):
        return LazyFrame(G.Head(self._node, n), source_vocab=self._vocab)

    def nlargest(self, n, columns):
        by = [columns] if isinstance(columns, str) else list(columns)
        return LazyFrame(G.TopK(self._node, by, n, ascending=False,
                                mode="select"), source_vocab=self._vocab)

    def nsmallest(self, n, columns):
        by = [columns] if isinstance(columns, str) else list(columns)
        return LazyFrame(G.TopK(self._node, by, n, ascending=True,
                                mode="select"), source_vocab=self._vocab)

    def groupby(self, keys):
        return GroupBy(self, keys)

    def merge(self, other: "LazyFrame", on, how="inner", suffixes=("_x", "_y")):
        on = [on] if isinstance(on, str) else list(on)
        vocab = {**other._vocab, **self._vocab}
        return LazyFrame(G.Join(self._node, other._node, on, how, suffixes),
                         source_vocab=vocab)

    def apply_rows(self, fn, name="udf"):
        """Whole-frame UDF escape hatch (pushdown barrier)."""
        return LazyFrame(G.MapRows(self._node, fn, name), source_vocab=self._vocab)

    def describe(self):
        # Paper §3.1 heuristic: describe/info/head don't make columns live;
        # handled in the optimizer — here it's a plain reduce-per-column sink.
        return LazyFrame(G.Head(self._node, 0), source_vocab=self._vocab)

    # -- force points ---------------------------------------------------------
    def compute(self, live_df=None, force_reason="compute"):
        """Force materialization (paper compute()).  ``live_df`` is the
        §3.5 live-frame hint — normally injected by analyze()."""
        return _execute([self._node], live_df, force_reason)[0]

    def materialize(self, live_df=None):
        return self.compute(live_df)

    def to_numpy_table(self, live_df=None):
        res = self.compute(live_df)
        return {k: np.asarray(v) for k, v in res.columns.items()}

    def __len__(self):
        return int(_execute([G.Length(self._node)], None, "len")[0])

    # -- helpers ---------------------------------------------------------------
    def _node_for_expr_column(self, expr_: E.Expr) -> _BoundNode:
        """Bind an expression to a concrete (node, column-name) pair, adding
        an Assign for composed expressions."""
        if isinstance(expr_, E.Col):
            return _BoundNode(self._node, expr_.name)
        name = f"__expr_{abs(hash(expr_.key())) % (1 << 30)}"
        return _BoundNode(G.Assign(self._node, name, expr_), name)

    def _vocab_for(self, expr_: E.Expr) -> list:
        if isinstance(expr_, E.Col) and expr_.name in self._vocab:
            return self._vocab[expr_.name]
        raise KeyError("no vocab for expression (str ops need a dict-encoded "
                       f"source column): {expr_}")

    def __repr__(self):
        # repr is a force point (pandas semantics: printing a frame shows
        # data).  Fall back to the structural repr if execution fails so
        # debugging a broken graph never raises from repr itself.
        try:
            return repr(self.compute(force_reason="repr"))
        except Exception:   # noqa: BLE001
            return f"LazyFrame({self._node!r})"


def _ordered_out(n: G.Node, ins: list[list | None]) -> list | None:
    """Ordered-column analogue of ``Node.out_cols``: output column *order*
    (pandas: source schema order, appends at the end), None = unknown."""
    if isinstance(n, G.Scan):
        return list(n.columns) if n.columns is not None \
            else list(n.source.schema.names)
    if isinstance(n, G.Project):
        return list(n.columns)
    if isinstance(n, G.Assign):
        c = ins[0]
        if c is None:
            return None
        return c if n.name in c else c + [n.name]
    if isinstance(n, G.Rename):
        c = ins[0]
        return None if c is None else [n.mapping.get(x, x) for x in c]
    if isinstance(n, G.GroupByAgg):
        return list(n.keys) + [k for k in n.aggs if k not in n.keys]
    if isinstance(n, G.Join):
        l, r = ins
        if l is None or r is None:
            return None
        overlap = (set(l) & set(r)) - set(n.on)
        out = [x + n.suffixes[0] if x in overlap else x for x in l]
        out += [x + n.suffixes[1] if x in overlap else x
                for x in r if x not in n.on]
        return out
    if isinstance(n, G.Concat):
        if any(c is None for c in ins):
            return None
        common = set(ins[0])
        for c in ins[1:]:
            common &= set(c)
        return [x for x in ins[0] if x in common]
    if isinstance(n, G.Materialized):
        return list(n.table.keys())
    if isinstance(n, (G.Reduce, G.Length, G.SinkPrint)):
        return []
    if isinstance(n, G.MapRows):
        return None
    # row-preserving pass-through (Filter, AsType, FillNa, SortValues,
    # DropDuplicates, Head)
    return ins[0] if ins else None


class Result:
    """Materialized frame: dict of arrays + vocab decoding for display."""

    def __init__(self, columns: Mapping[str, Any], vocab=None):
        self.columns = dict(columns)
        self.vocab = dict(vocab or {})

    def rows(self) -> int:
        for v in self.columns.values():
            return int(v.shape[0])
        return 0

    def __getitem__(self, k):
        return self.columns[k]

    def decode(self, col: str):
        codes = np.asarray(self.columns[col])
        vocab = self.vocab[col]
        return np.asarray([vocab[c] for c in codes], dtype=object)

    def __repr__(self):
        n = self.rows()
        cols = ", ".join(f"{k}:{getattr(v, 'dtype', '?')}"
                         for k, v in self.columns.items())
        lines = [f"<Result {n} rows [{cols}]>"]
        show = min(n, 10)
        names = list(self.columns)
        lines.append(" | ".join(f"{x:>12}" for x in names))
        for i in range(show):
            vals = []
            for c in names:
                v = self.columns[c][i]
                if c in self.vocab:
                    v = self.vocab[c][int(v)]
                vals.append(f"{v!s:>12.12}")
            lines.append(" | ".join(vals))
        if n > show:
            lines.append(f"... ({n - show} more rows)")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Constructors ("pd." namespace functions)


def read_source(source: Source) -> LazyFrame:
    return LazyFrame(G.Scan(source), source_vocab=source.dicts)


def from_arrays(arrays: Mapping[str, np.ndarray], partition_rows: int = 1 << 16,
                dicts=None, datetimes=(), name="mem") -> LazyFrame:
    src = InMemorySource(arrays, partition_rows, dicts, datetimes, name)
    return read_source(src)


def read_npz(path: str) -> LazyFrame:
    from .source import NpzDirectorySource
    return read_source(NpzDirectorySource(path))


# ---------------------------------------------------------------------------
# Execution entry (shared by frames/scalars/sinks)


def _execute(roots: list[G.Node], live_df=None,
             force_reason: str | None = None) -> list[Any]:
    from .runtime import execute  # late import: runtime pulls optimizer+backends
    return execute(roots, live_df, force_reason)
