"""Operator DAG ("task graph", paper §2.5).

Nodes are immutable logical operators; edges point child → parent implicitly
via each node's ``inputs`` tuple (data flows inputs → node; the paper draws
dependency edges the other way, same information).  Structural keys enable
CSE; ``mod_attrs`` / ``used_attrs`` per node drive pushdown safety (§3.2);
``out_cols`` propagation drives projection pushdown / column selection
(§3.1).
"""
from __future__ import annotations

import itertools
from typing import Any, Iterable, Mapping, Sequence

from .expr import Expr, conjoin

_ids = itertools.count()

ALL = "<ALL>"  # sentinel: all columns of a frame


class Node:
    """Base logical operator."""
    op: str = "?"

    def __init__(self, inputs: Sequence["Node"]):
        self.id = next(_ids)
        self.inputs: tuple[Node, ...] = tuple(inputs)
        # runtime fields (paper §2.6 executor):
        self.result: Any = None          # materialized value, cleared by refcount
        self.persist: bool = False       # §3.5 common-computation-reuse mark

    # -- attributes for optimizer ------------------------------------------
    def used_attrs(self) -> frozenset[str]:
        """Input columns this operator reads (beyond pass-through)."""
        return frozenset()

    def mod_attrs(self) -> frozenset[str]:
        """Columns this operator modifies or computes."""
        return frozenset()

    def preserves_rows(self) -> bool:
        """True if output rows are exactly input rows (1:1, same order) —
        precondition (2) of paper §3.2 for swapping with a filter."""
        return False

    def has_side_effects(self) -> bool:
        return False

    def out_cols(self, in_cols: Sequence[frozenset[str] | None]) -> frozenset[str] | None:
        """Output column set given input column sets (None = unknown)."""
        return in_cols[0] if in_cols else None

    def required_cols(self, live: frozenset[str] | None) -> list[frozenset[str] | None]:
        """Columns needed from each input so that `live` output columns can
        be produced. None = all columns."""
        return [None for _ in self.inputs]

    # -- identity -----------------------------------------------------------
    def key(self) -> tuple:
        """Structural key for CSE. Nodes with side effects key on id."""
        raise NotImplementedError

    def with_inputs(self, inputs: Sequence["Node"]) -> "Node":
        """Clone with new inputs (rewrites preserve node params)."""
        raise NotImplementedError

    def __repr__(self):
        return f"{self.op}#{self.id}({', '.join(str(i.id) for i in self.inputs)})"


# ---------------------------------------------------------------------------
# Sources


class ScanPushdown:
    """Filter conjuncts sunk into a :class:`Scan` (scan-level predicate
    pushdown, ``repro.io``).  The scan's loader evaluates the ANDed
    conjuncts on each decoded partition and keeps only passing rows, so
    filtered rows never reach the engine — and partitions the conjuncts
    prove all-False are never read at all (``skip_partitions``).

    Immutable; part of the scan's structural identity (``Scan.key`` and the
    plan-cache fingerprint both cover the conjunct keys)."""

    __slots__ = ("conjuncts",)

    def __init__(self, conjuncts: Sequence[Expr]):
        self.conjuncts: tuple[Expr, ...] = tuple(conjuncts)

    @property
    def predicate(self) -> Expr:
        return conjoin(list(self.conjuncts))

    def used_cols(self) -> frozenset[str]:
        out: frozenset[str] = frozenset()
        for c in self.conjuncts:
            out |= c.used_cols()
        return out

    def key(self) -> tuple:
        return ("pushdown",) + tuple(c.key() for c in self.conjuncts)

    def __repr__(self):
        return f"ScanPushdown({len(self.conjuncts)} conjuncts)"


class Scan(Node):
    """Read a partitioned columnar source. ``columns=None`` → all columns.

    Column selection (§3.1) rewrites ``columns``; zone-map pruning (beyond
    paper) fills ``skip_partitions`` at plan time; the scan-pushdown pass
    (``repro.io``) sinks filter conjuncts into ``pushdown`` so rows are
    dropped at decode time and proven-empty partitions are never read."""
    op = "scan"

    def __init__(self, source, columns: tuple[str, ...] | None = None,
                 dtype_overrides: Mapping[str, str] | None = None,
                 pushdown: ScanPushdown | None = None):
        super().__init__([])
        self.source = source
        self.columns = tuple(columns) if columns is not None else None
        self.dtype_overrides = dict(dtype_overrides or {})
        self.skip_partitions: frozenset[int] = frozenset()
        self.pushdown = pushdown

    def used_attrs(self):
        return self.pushdown.used_cols() if self.pushdown is not None \
            else frozenset()

    def out_cols(self, in_cols):
        if self.columns is not None:
            return frozenset(self.columns)
        return frozenset(self.source.schema.names)

    def key(self):
        token = getattr(self.source, "cache_token", None)
        token = token() if callable(token) else id(self.source)
        return ("scan", token, self.columns,
                tuple(sorted(self.dtype_overrides.items())),
                self.skip_partitions,
                self.pushdown.key() if self.pushdown is not None else None)

    def with_inputs(self, inputs):
        assert not inputs
        n = Scan(self.source, self.columns, self.dtype_overrides,
                 pushdown=self.pushdown)
        n.skip_partitions = self.skip_partitions
        return n


# ---------------------------------------------------------------------------
# Row-preserving unary ops


class Project(Node):
    op = "project"

    def __init__(self, child: Node, columns: Sequence[str]):
        super().__init__([child])
        self.columns = tuple(columns)

    def used_attrs(self):
        return frozenset(self.columns)

    def preserves_rows(self):
        return True

    def out_cols(self, in_cols):
        return frozenset(self.columns)

    def required_cols(self, live):
        return [frozenset(self.columns)]

    def key(self):
        return ("project", self.columns, self.inputs[0].key())

    def with_inputs(self, inputs):
        return Project(inputs[0], self.columns)


class Filter(Node):
    op = "filter"

    def __init__(self, child: Node, predicate: Expr):
        super().__init__([child])
        self.predicate = predicate

    def used_attrs(self):
        return self.predicate.used_cols()

    def preserves_rows(self):
        return False  # drops rows (but keeps columns)

    def out_cols(self, in_cols):
        return in_cols[0]

    def required_cols(self, live):
        if live is None:
            return [None]
        return [live | self.predicate.used_cols()]

    def key(self):
        return ("filter", self.predicate.key(), self.inputs[0].key())

    def with_inputs(self, inputs):
        return Filter(inputs[0], self.predicate)


class Assign(Node):
    """df[name] = expr  (adds or replaces a column)."""
    op = "assign"

    def __init__(self, child: Node, name: str, expr: Expr):
        super().__init__([child])
        self.name = name
        self.expr = expr

    def used_attrs(self):
        return self.expr.used_cols()

    def mod_attrs(self):
        return frozenset([self.name])

    def preserves_rows(self):
        return True

    def out_cols(self, in_cols):
        c = in_cols[0]
        return None if c is None else c | {self.name}

    def required_cols(self, live):
        if live is None:
            return [None]
        need = (live - {self.name}) | (self.expr.used_cols() if self.name in live else frozenset())
        return [need]

    def key(self):
        return ("assign", self.name, self.expr.key(), self.inputs[0].key())

    def with_inputs(self, inputs):
        return Assign(inputs[0], self.name, self.expr)


class Rename(Node):
    op = "rename"

    def __init__(self, child: Node, mapping: Mapping[str, str]):
        super().__init__([child])
        self.mapping = dict(mapping)

    def used_attrs(self):
        return frozenset(self.mapping.keys())

    def mod_attrs(self):
        return frozenset(self.mapping.values())

    def preserves_rows(self):
        return True

    def out_cols(self, in_cols):
        c = in_cols[0]
        if c is None:
            return None
        return frozenset(self.mapping.get(n, n) for n in c)

    def required_cols(self, live):
        if live is None:
            return [None]
        inv = {v: k for k, v in self.mapping.items()}
        return [frozenset(inv.get(n, n) for n in live)]

    def key(self):
        return ("rename", tuple(sorted(self.mapping.items())), self.inputs[0].key())

    def with_inputs(self, inputs):
        return Rename(inputs[0], self.mapping)


class AsType(Node):
    op = "astype"

    def __init__(self, child: Node, dtypes: Mapping[str, str]):
        super().__init__([child])
        self.dtypes = dict(dtypes)

    def used_attrs(self):
        return frozenset(self.dtypes.keys())

    def mod_attrs(self):
        return frozenset(self.dtypes.keys())

    def preserves_rows(self):
        return True

    def required_cols(self, live):
        if live is None:
            return [None]
        return [live]

    def key(self):
        return ("astype", tuple(sorted(self.dtypes.items())), self.inputs[0].key())

    def with_inputs(self, inputs):
        return AsType(inputs[0], self.dtypes)


class FillNa(Node):
    op = "fillna"

    def __init__(self, child: Node, value, columns: tuple[str, ...] | None = None):
        super().__init__([child])
        self.value = value
        self.columns = columns

    def used_attrs(self):
        return frozenset(self.columns or ())

    def mod_attrs(self):
        # unknown columns when columns=None → report nothing modified is
        # unsafe; report ALL via used/mod at optimizer level (handled there).
        return frozenset(self.columns) if self.columns else frozenset([ALL])

    def preserves_rows(self):
        return True

    def key(self):
        return ("fillna", repr(self.value), self.columns, self.inputs[0].key())

    def with_inputs(self, inputs):
        return FillNa(inputs[0], self.value, self.columns)


class SortValues(Node):
    """Row-permuting but set-preserving: filters commute with stable sort."""
    op = "sort_values"

    def __init__(self, child: Node, by: Sequence[str], ascending: bool = True):
        super().__init__([child])
        self.by = tuple(by)
        self.ascending = ascending

    def used_attrs(self):
        return frozenset(self.by)

    def preserves_rows(self):
        return True  # for filter-swap purposes: 1:1 rows, values unchanged

    def required_cols(self, live):
        if live is None:
            return [None]
        return [live | frozenset(self.by)]

    def key(self):
        return ("sort", self.by, self.ascending, self.inputs[0].key())

    def with_inputs(self, inputs):
        return SortValues(inputs[0], self.by, self.ascending)


class DropDuplicates(Node):
    op = "drop_duplicates"

    def __init__(self, child: Node, subset: tuple[str, ...] | None = None):
        super().__init__([child])
        self.subset = subset

    def used_attrs(self):
        return frozenset(self.subset or ())

    def preserves_rows(self):
        return False

    def required_cols(self, live):
        if live is None or self.subset is None:
            return [None]
        return [live | frozenset(self.subset)]

    def key(self):
        return ("dropdup", self.subset, self.inputs[0].key())

    def with_inputs(self, inputs):
        return DropDuplicates(inputs[0], self.subset)


class Head(Node):
    op = "head"

    def __init__(self, child: Node, n: int):
        super().__init__([child])
        self.n = n

    def preserves_rows(self):
        return False

    def key(self):
        return ("head", self.n, self.inputs[0].key())

    def with_inputs(self, inputs):
        return Head(inputs[0], self.n)


class TopK(Node):
    """First ``n`` rows of the stable sort by ``by`` — a partial sort that
    never materializes the full permutation.  Produced by the rewrite pass
    (``sort_values(by).head(n)``) and by native ``nlargest``/``nsmallest``
    lowering; the planner prices it ≪ a full sort.

    ``mode`` pins the tie/NaN semantics: ``"sort"`` is exactly
    ``SortValues(by, ascending) → Head(n)`` (NaN keys travel with the sort,
    descending reverses tie order); ``"select"`` is pandas
    ``nlargest``/``nsmallest`` (NaN keys dropped, ties keep first
    occurrence)."""
    op = "top_k"

    def __init__(self, child: Node, by: Sequence[str], n: int,
                 ascending: bool = True, mode: str = "sort"):
        super().__init__([child])
        self.by = tuple(by)
        self.n = int(n)
        self.ascending = ascending
        self.mode = mode

    def used_attrs(self):
        return frozenset(self.by)

    def preserves_rows(self):
        return False

    def required_cols(self, live):
        if live is None:
            return [None]
        return [live | frozenset(self.by)]

    def key(self):
        return ("topk", self.by, self.n, self.ascending, self.mode,
                self.inputs[0].key())

    def with_inputs(self, inputs):
        return TopK(inputs[0], self.by, self.n, self.ascending, self.mode)


class MapRows(Node):
    """Opaque row-wise UDF over the whole frame (pushdown barrier: unknown
    mod/used attrs, paper §3.2 'operators whose semantics are not known')."""
    op = "map_rows"

    def __init__(self, child: Node, fn, name="udf"):
        super().__init__([child])
        self.fn = fn
        self.name = name

    def mod_attrs(self):
        return frozenset([ALL])

    def used_attrs(self):
        return frozenset([ALL])

    def preserves_rows(self):
        return True

    def out_cols(self, in_cols):
        return None

    def key(self):
        return ("maprows", id(self.fn), self.inputs[0].key())

    def with_inputs(self, inputs):
        return MapRows(inputs[0], self.fn, self.name)


class FusedRowwise(Node):
    """Maximal single-consumer chain of rowwise ops collapsed into one
    physical pass (``core.fuse``; Dask's low-level ``fuse`` analogue).

    ``ops`` holds the member nodes innermost-first.  Each member is kept as
    a parameter template: execution rebinds it to the running table, so its
    own ``inputs`` edge is never followed.  The chain is one device dispatch
    on the jnp path and one chunk-loop body on the streaming path — no
    intermediate tables between members."""
    op = "fused_rowwise"

    def __init__(self, child: Node, ops: Sequence[Node]):
        super().__init__([child])
        self.ops = tuple(ops)

    def used_attrs(self):
        used: set[str] = set()
        produced: set[str] = set()
        for m in self.ops:
            used |= set(m.used_attrs()) - produced
            produced |= set(m.mod_attrs())
        return frozenset(used)

    def mod_attrs(self):
        out: set[str] = set()
        for m in self.ops:
            out |= set(m.mod_attrs())
        return frozenset(out)

    def preserves_rows(self):
        return all(m.preserves_rows() for m in self.ops)

    def out_cols(self, in_cols):
        c = in_cols[0] if in_cols else None
        for m in self.ops:
            c = m.out_cols([c])
        return c

    def required_cols(self, live):
        for m in reversed(self.ops):
            live = m.required_cols(live)[0]
        return [live]

    def key(self):
        # member keys minus their child component (every rowwise key ends
        # with the child key), then the real child key once
        return (("fused",) + tuple(m.key()[:-1] for m in self.ops)
                + (self.inputs[0].key(),))

    def with_inputs(self, inputs):
        return FusedRowwise(inputs[0], self.ops)


# ---------------------------------------------------------------------------
# Row-count-changing / multi-input ops


class GroupByAgg(Node):
    """groupby(keys).agg({out_name: (col, fn)}) — fn ∈ sum|mean|count|min|max.

    Aggregates kill all columns except keys and agg outputs (paper §3.1)."""
    op = "groupby_agg"

    def __init__(self, child: Node, keys: Sequence[str],
                 aggs: Mapping[str, tuple[str, str]]):
        super().__init__([child])
        self.keys = tuple(keys)
        self.aggs = dict(aggs)

    def used_attrs(self):
        used = set(self.keys)
        for (col, _fn) in self.aggs.values():
            if col is not None:
                used.add(col)
        return frozenset(used)

    def mod_attrs(self):
        return frozenset(self.aggs.keys())

    def out_cols(self, in_cols):
        return frozenset(self.keys) | frozenset(self.aggs.keys())

    def required_cols(self, live):
        return [self.used_attrs()]

    def key(self):
        return ("gb", self.keys, tuple(sorted(self.aggs.items())), self.inputs[0].key())

    def with_inputs(self, inputs):
        return GroupByAgg(inputs[0], self.keys, self.aggs)


class Join(Node):
    op = "join"

    def __init__(self, left: Node, right: Node, on: Sequence[str],
                 how: str = "inner", suffixes=("_x", "_y")):
        super().__init__([left, right])
        self.on = tuple(on)
        self.how = how
        self.suffixes = suffixes

    def used_attrs(self):
        return frozenset(self.on)

    def out_cols(self, in_cols):
        l, r = in_cols
        if l is None or r is None:
            return None
        out = set(self.on)
        overlap = (l & r) - set(self.on)
        for n in l - set(self.on):
            out.add(n + self.suffixes[0] if n in overlap else n)
        for n in r - set(self.on):
            out.add(n + self.suffixes[1] if n in overlap else n)
        return frozenset(out)

    def required_cols(self, live):
        if live is None:
            return [None, None]
        # strip suffixes conservatively
        base = set(self.on)
        for n in live:
            for s in self.suffixes:
                if n.endswith(s):
                    base.add(n[: -len(s)])
            base.add(n)
        return [frozenset(base), frozenset(base)]

    def key(self):
        return ("join", self.on, self.how, self.suffixes,
                self.inputs[0].key(), self.inputs[1].key())

    def with_inputs(self, inputs):
        return Join(inputs[0], inputs[1], self.on, self.how, self.suffixes)


class Concat(Node):
    op = "concat"

    def __init__(self, children: Sequence[Node]):
        super().__init__(children)

    def out_cols(self, in_cols):
        out = None
        for c in in_cols:
            if c is None:
                return None
            out = c if out is None else (out & c)
        return out

    def required_cols(self, live):
        return [live for _ in self.inputs]

    def key(self):
        return ("concat",) + tuple(i.key() for i in self.inputs)

    def with_inputs(self, inputs):
        return Concat(inputs)


# ---------------------------------------------------------------------------
# Reductions → scalars


class Reduce(Node):
    """Column reduction to a scalar:
    mean/sum/min/max/count/nunique/median."""
    op = "reduce"

    def __init__(self, child: Node, column: str | None, fn: str):
        super().__init__([child])
        self.column = column
        self.fn = fn

    def used_attrs(self):
        return frozenset([self.column]) if self.column else frozenset()

    def out_cols(self, in_cols):
        return frozenset()

    def required_cols(self, live):
        return [frozenset([self.column]) if self.column else frozenset()]

    def key(self):
        return ("reduce", self.column, self.fn, self.inputs[0].key())

    def with_inputs(self, inputs):
        return Reduce(inputs[0], self.column, self.fn)


class Length(Node):
    """Lazy len(df) (paper §3.3: lazyfatpandas.func.len)."""
    op = "length"

    def __init__(self, child: Node):
        super().__init__([child])

    def out_cols(self, in_cols):
        return frozenset()

    def required_cols(self, live):
        return [frozenset()]  # any single column suffices; backend handles

    def key(self):
        return ("length", self.inputs[0].key())

    def with_inputs(self, inputs):
        return Length(inputs[0])


# ---------------------------------------------------------------------------
# Sinks (lazy print, §3.3)


class SinkPrint(Node):
    """Lazy print. ``parts`` is a list of str | Node; an extra ordering input
    edge to the previous sink keeps output order (paper Fig. 9)."""
    op = "sink_print"

    def __init__(self, parts: Sequence[Any], data_inputs: Sequence[Node],
                 prev_sink: "SinkPrint | None"):
        inputs = list(data_inputs) + ([prev_sink] if prev_sink is not None else [])
        super().__init__(inputs)
        self.parts = list(parts)
        self.n_data = len(data_inputs)

    def has_side_effects(self):
        return True

    def key(self):
        return ("sink_print", self.id)  # side effects: never CSE'd

    def with_inputs(self, inputs):
        data = inputs[: self.n_data]
        prev = inputs[self.n_data] if len(inputs) > self.n_data else None
        n = SinkPrint(self.parts, data, prev)
        return n


class Materialized(Node):
    """A cached (persisted) result substituted into the graph before
    optimization (§3.5 reuse).  Keys on the *logical* key of the node it
    replaces, so CSE and pushdown treat it as that subexpression."""
    op = "materialized"

    def __init__(self, table, logical_key: tuple):
        super().__init__([])
        self.table = table
        self._key = logical_key

    def out_cols(self, in_cols):
        return frozenset(self.table.keys())

    def key(self):
        return self._key

    def with_inputs(self, inputs):
        return self


class Handoff(Node):
    """Pipe breaker between planner segments (operator-granular hybrid
    placement).  The producing segment's engine has already materialized
    ``value`` — a host table (dict of numpy columns), a scalar, or, for
    distributed→distributed chains, a device-resident
    ``physical.ShardedTable`` that never round-trips through host memory —
    and the consuming segment's engine treats this node as a pre-computed
    leaf.  Keys on the logical key of the node it replaces so persist/CSE
    machinery sees the original subexpression."""
    op = "handoff"

    def __init__(self, value, logical_key: tuple, producer: str = "?"):
        super().__init__([])
        self.value = value
        self.producer = producer            # backend name that produced it
        self._key = logical_key

    def out_cols(self, in_cols):
        if isinstance(self.value, dict):
            return frozenset(self.value.keys())
        cols = getattr(self.value, "cols", None)   # ShardedTable payload
        if isinstance(cols, dict):
            return frozenset(cols.keys())
        return frozenset()

    def key(self):
        return self._key

    def with_inputs(self, inputs):
        return self


# ---------------------------------------------------------------------------
# Runtime-flag carrying (rewrites must not lose executor state)


def copy_runtime_flags(src: Node, dst: Node) -> Node:
    """Carry runtime fields (persist mark, cached result, cache key) from a
    node to its rewritten clone.  ``with_inputs`` clones get fresh defaults;
    every rewrite path must route through this so marks survive."""
    if dst is src:
        return dst
    dst.persist = src.persist
    dst.result = src.result
    if hasattr(src, "cache_key"):
        dst.cache_key = src.cache_key
    return dst


# ---------------------------------------------------------------------------
# Traversals


def walk(roots: Iterable[Node]) -> list[Node]:
    """Post-order (inputs before node), deduped."""
    seen: dict[int, Node] = {}
    order: list[Node] = []

    def rec(n: Node):
        if n.id in seen:
            return
        seen[n.id] = n
        for i in n.inputs:
            rec(i)
        order.append(n)

    for r in roots:
        rec(r)
    return order


def parents_map(roots: Iterable[Node]) -> dict[int, list[Node]]:
    out: dict[int, list[Node]] = {}
    for n in walk(roots):
        for i in n.inputs:
            out.setdefault(i.id, []).append(n)
        out.setdefault(n.id, out.get(n.id, []))
    return out
