"""Column/table schemas and dtype lattice for the LaFP engine.

TPU adaptation note: strings never reach the device — a string column is
dictionary-encoded at the source (int32 codes + host-side vocab), which is
the paper's `category` optimization (§3.6) made mandatory.  Datetimes are
int64 epoch seconds; `.dt` accessors are integer arithmetic on the device.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# DTypes

_NARROW_ORDER_INT = [np.int8, np.int16, np.int32, np.int64]
_NARROW_ORDER_FLOAT = [np.float32, np.float64]

DATETIME = "datetime64[s]"  # stored as int64 epoch seconds on device


@dataclasses.dataclass(frozen=True)
class ColumnSchema:
    name: str
    dtype: str                      # numpy dtype string, or 'dict' for encoded strings
    is_dict: bool = False           # dictionary-encoded string column
    dict_size: int | None = None    # vocab size when is_dict
    is_datetime: bool = False       # int64 epoch seconds

    @property
    def np_dtype(self) -> np.dtype:
        if self.is_dict:
            return np.dtype(np.int32)
        if self.is_datetime:
            return np.dtype(np.int64)
        return np.dtype(self.dtype)

    @property
    def itemsize(self) -> int:
        return self.np_dtype.itemsize


@dataclasses.dataclass(frozen=True)
class TableSchema:
    columns: tuple[ColumnSchema, ...]

    def __post_init__(self):
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate column names: {names}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    def col(self, name: str) -> ColumnSchema:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)

    def __contains__(self, name: str) -> bool:
        return any(c.name == name for c in self.columns)

    def select(self, names: Sequence[str]) -> "TableSchema":
        return TableSchema(tuple(self.col(n) for n in names))

    def with_column(self, col: ColumnSchema) -> "TableSchema":
        cols = tuple(c for c in self.columns if c.name != col.name)
        return TableSchema(cols + (col,))

    def drop(self, names: Sequence[str]) -> "TableSchema":
        drop = set(names)
        return TableSchema(tuple(c for c in self.columns if c.name not in drop))

    def row_bytes(self) -> int:
        return sum(c.itemsize for c in self.columns)


def narrow_int_dtype(lo: int, hi: int) -> np.dtype:
    """Smallest signed integer dtype that holds [lo, hi] (paper §3.6 dtype
    narrowing from metadata)."""
    for dt in _NARROW_ORDER_INT:
        info = np.iinfo(dt)
        if info.min <= lo and hi <= info.max:
            return np.dtype(dt)
    return np.dtype(np.int64)


def infer_schema(arrays: Mapping[str, np.ndarray],
                 dicts: Mapping[str, Sequence[str]] | None = None,
                 datetimes: Sequence[str] = ()) -> TableSchema:
    dicts = dicts or {}
    cols = []
    for name, arr in arrays.items():
        if name in dicts:
            cols.append(ColumnSchema(name, "dict", is_dict=True,
                                     dict_size=len(dicts[name])))
        elif name in datetimes:
            cols.append(ColumnSchema(name, DATETIME, is_datetime=True))
        else:
            cols.append(ColumnSchema(name, str(arr.dtype)))
    return TableSchema(tuple(cols))
