"""Pattern-rewrite engine over the logical DAG (Dias-style, PAPERS.md:
"Dias: Dynamic Rewriting of Pandas Code").

A :class:`RewriteRule` recognizes an expensive idiom as a local node
pattern, checks the same safety conditions the optimizer's swap rules use
(single parent, no persist mark, no side effects), and produces a cheaper
equivalent subgraph.  :func:`apply_rewrites` drives the rule set to
fixpoint with the optimizer's immutable ``_rebuild`` machinery, emitting a
structured :class:`RewriteEvent` per fired rule — into the optimizer
trace (as a ``PlannerEvent`` with ``kind="rewrite"``), the
``rewrite.applied`` metric, and the pending-record list ``pd.explain()``
drains into ``RewriteRecord`` entries.

Every rule must be *semantics-preserving under this engine's operators*
(not merely pandas-plausible): the differential conformance suite runs
with rewrites on and off (``session(rewrites=False)``) and the results
must be identical.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Protocol, runtime_checkable

from .. import graph as G


@runtime_checkable
class RewriteRule(Protocol):
    """One idiom rewrite.  ``match`` is the structural pattern test,
    ``guard`` the safety conditions (parents/persist/side effects), and
    ``apply`` builds the replacement subgraph — returning ``None`` to
    decline after a deeper look (e.g. a UDF that fails to vectorize)."""

    name: str
    summary: str                        # one-liner, reused by the linter

    def match(self, n: G.Node) -> bool: ...

    def guard(self, n: G.Node, parents: dict[int, list[G.Node]]) -> bool: ...

    def apply(self, n: G.Node) -> G.Node | None: ...


@dataclasses.dataclass(frozen=True)
class RewriteEvent:
    """One fired rewrite: rule name, replaced/replacement node identity,
    and the whole-plan estimated work delta (negative = cheaper; None when
    pricing failed)."""
    rule: str
    before_id: int
    before_op: str
    after_id: int
    after_op: str
    detail: str = ""
    cost_delta: float | None = None

    def __str__(self):
        delta = ("" if self.cost_delta is None
                 else f" Δwork={self.cost_delta:+.3g}")
        det = f" ({self.detail})" if self.detail else ""
        return (f"rewrite {self.rule}: {self.before_op}#{self.before_id}"
                f" -> {self.after_op}#{self.after_id}{det}{delta}")


def consumed_ok(inner: G.Node, parents: dict[int, list[G.Node]]) -> bool:
    """Safety for a node a rewrite absorbs (it disappears from the plan):
    it must have exactly one parent (others still need its output), no
    persist mark (a planned §3.5 materialization point), and no side
    effects — the same conditions as the optimizer's ``_can_swap``."""
    return (len(parents.get(inner.id, [])) == 1
            and not inner.persist
            and not inner.has_side_effects())


def _plan_work(roots: list[G.Node], ctx) -> float | None:
    """Whole-plan estimated work on the reference capability — only the
    *delta* across one rewrite is meaningful.  Pricing failures (exotic
    sources, missing stats) return None; they must never block a rewrite."""
    try:
        from ..engines import default_registry
        from ..planner.cost import node_work
        from ..planner.stats import estimate_plan
        cap = default_registry().capability_of("eager")
        stats = estimate_plan(roots, ctx)
        return sum(node_work(n, stats, cap) for n in G.walk(roots))
    except Exception:  # noqa: BLE001 — costing is advisory
        return None


def _emit(ctx, trace, ev: RewriteEvent) -> None:
    if trace is not None:
        from ...obs.events import PlannerEvent
        trace.append(PlannerEvent(str(ev), kind="rewrite",
                                  **dataclasses.asdict(ev)))
    if ctx is None:
        return
    metrics = getattr(ctx, "metrics", None)
    if metrics is not None:
        metrics.inc("rewrite.applied")
    pending = getattr(ctx, "_pending_rewrites", None)
    if pending is None:
        pending = ctx._pending_rewrites = []
    pending.append(ev)


def default_rules() -> tuple[RewriteRule, ...]:
    from .rules import DEFAULT_RULES
    return DEFAULT_RULES


def apply_rewrites(roots: list[G.Node], ctx=None,
                   rules: Iterable[RewriteRule] | None = None,
                   trace: list | None = None
                   ) -> tuple[list[G.Node], dict[int, G.Node],
                              list[RewriteEvent]]:
    """Drive ``rules`` to fixpoint over the DAG.

    Returns ``(new_roots, idmap, events)``; the idmap composes with the
    optimizer's combined map exactly like every other pass.  One rule
    fires per iteration (the DAG is rebuilt and parents recomputed before
    the next), and the iteration guard bounds pathological rule sets the
    same way ``push_filters`` bounds itself."""
    from ..optimizer import _rebuild
    rules = tuple(rules) if rules is not None else default_rules()
    total_map: dict[int, G.Node] = {}
    events: list[RewriteEvent] = []
    changed = True
    guard = 0
    while changed and guard < 100:
        guard += 1
        changed = False
        parents = G.parents_map(roots)
        for r in roots:
            # a root is externally consumed: count that as a parent so
            # consumed_ok never lets a rule absorb it out of the plan
            parents.setdefault(r.id, []).append(r)
        for n in G.walk(roots):
            for rule in rules:
                if not rule.match(n) or not rule.guard(n, parents):
                    continue
                repl = rule.apply(n)
                if repl is None:
                    continue
                G.copy_runtime_flags(n, repl)
                before = _plan_work(roots, ctx)
                roots, m = _rebuild(roots, {n.id: repl})
                total_map.update(m)
                after = _plan_work(roots, ctx)
                delta = (after - before
                         if before is not None and after is not None
                         else None)
                detail = getattr(rule, "describe", lambda *_: "")(n, repl)
                ev = RewriteEvent(rule=rule.name, before_id=n.id,
                                  before_op=n.op, after_id=repl.id,
                                  after_op=repl.op, detail=detail,
                                  cost_delta=delta)
                events.append(ev)
                _emit(ctx, trace, ev)
                changed = True
                break
            if changed:
                break
    return roots, total_map, events
