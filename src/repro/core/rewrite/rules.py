"""The built-in rewrite rules.

Each rule documents *why* its rewrite is exact under this engine's
operator semantics — the conformance suite enforces it differentially
(rewrites on vs ``session(rewrites=False)``).
"""
from __future__ import annotations

import numpy as np

from .. import expr as E
from .. import graph as G
from .engine import consumed_ok


class SortHeadToTopK:
    """``sort_values(by).head(n)`` → ``TopK(by, n, mode="sort")``.

    Exact by construction: TopK's sort mode is *defined* as the first n
    rows of the stable sort (descending = reversed-stable, NaN travels
    with the sort), and ``apply_top_k`` reproduces that ordering while
    only materializing the k survivors."""

    name = "sort_head_to_top_k"
    summary = ("sort_values().head(n) runs as a top-k selection "
               "(no full sort)")

    def match(self, n: G.Node) -> bool:
        return isinstance(n, G.Head) and isinstance(n.inputs[0], G.SortValues)

    def guard(self, n: G.Node, parents) -> bool:
        u = n.inputs[0]
        return consumed_ok(u, parents) and isinstance(u.ascending, bool)

    def apply(self, n: G.Head) -> G.Node:
        u = n.inputs[0]
        return G.TopK(u.inputs[0], u.by, n.n, u.ascending, mode="sort")

    def describe(self, n, repl) -> str:
        u = n.inputs[0]
        return f"by={list(u.by)} n={n.n} ascending={u.ascending}"


class DedupBeforeSort:
    """``sort_values(by, ascending=True).drop_duplicates()`` →
    ``drop_duplicates().sort_values(by)`` — sort only the survivors.

    Exact only for whole-row dedup (``subset=None``) under an *ascending*
    stable sort: duplicates are fully identical rows, so the kept first
    occurrences are value-identical and their relative order (earliest
    input occurrence per class) is preserved by the stable sort on either
    side.  A descending sort breaks the commute — ``apply_sort`` reverses
    equal-key runs, so sort-first keeps the *latest* physical copy and
    shifts its tie position — and ``subset=...`` changes which row of a
    group survives, so both are guarded out."""

    name = "dedup_before_sort"
    summary = ("drop_duplicates() after an ascending sort runs before it "
               "(sort only the unique rows)")

    def match(self, n: G.Node) -> bool:
        return (isinstance(n, G.DropDuplicates)
                and isinstance(n.inputs[0], G.SortValues))

    def guard(self, n: G.DropDuplicates, parents) -> bool:
        u = n.inputs[0]
        return (n.subset is None and u.ascending is True
                and consumed_ok(u, parents))

    def apply(self, n: G.DropDuplicates) -> G.Node:
        u = n.inputs[0]
        dedup = G.DropDuplicates(u.inputs[0], None)
        return G.SortValues(dedup, u.by, u.ascending)

    def describe(self, n, repl) -> str:
        return f"by={list(n.inputs[0].by)}"


class FilterThroughConcat:
    """``Filter(Concat(xs))`` → ``Concat([Filter(x) for x in xs])``.

    Exact: ``apply_concat`` preserves per-input row order and filtering is
    row-local, so filtering each leg before concatenation yields the same
    rows in the same order.  Unblocks the §3.2 pushdown pass — the pushed
    copies keep descending toward each leg's scan (zone-map pruning,
    column selection), which ``push_filters`` alone never does because
    Concat is multi-input."""

    name = "filter_through_concat"
    summary = "filters push through concat into each input branch"

    def match(self, n: G.Node) -> bool:
        return isinstance(n, G.Filter) and isinstance(n.inputs[0], G.Concat)

    def guard(self, n: G.Filter, parents) -> bool:
        return consumed_ok(n.inputs[0], parents)

    def apply(self, n: G.Filter) -> G.Node:
        u = n.inputs[0]
        return G.Concat([G.Filter(c, n.predicate) for c in u.inputs])

    def describe(self, n, repl) -> str:
        return f"{len(n.inputs[0].inputs)} branches"


# ---------------------------------------------------------------------------
# MapRows vectorization: symbolic tracing of the whole-table UDF.


class _NotVectorizable(Exception):
    pass


class _SymCol:
    """Symbolic column: records the expression a UDF builds instead of
    computing it.  Any operation outside the native ``Expr`` algebra
    raises (attribute access, truthiness, unsupported operands), which
    aborts the trace — the UDF then simply stays a ``MapRows`` barrier."""

    __slots__ = ("expr",)

    def __init__(self, expr: E.Expr):
        self.expr = expr

    @staticmethod
    def _lift(other) -> E.Expr:
        if isinstance(other, _SymCol):
            return other.expr
        if isinstance(other, (bool, int, float)):
            return E.Lit(other)
        if isinstance(other, (np.bool_, np.integer, np.floating)):
            return E.Lit(other.item())
        raise _NotVectorizable(f"unsupported operand {type(other).__name__}")

    def __invert__(self):
        return _SymCol(E.Not(self.expr))

    def __neg__(self):
        return _SymCol(E.BinOp("sub", E.Lit(0), self.expr))

    def __bool__(self):
        raise _NotVectorizable("data-dependent control flow")

    def __iter__(self):
        raise _NotVectorizable("iteration over a column")

    __hash__ = object.__hash__

    def clip(self, lower=None, upper=None):
        return _SymCol(E.Clip(self.expr, lower, upper))

    def round(self, decimals=0):
        return _SymCol(E.Round(self.expr, int(decimals)))

    def astype(self, dtype):
        return _SymCol(E.Cast(self.expr, str(np.dtype(dtype))))


def _sym_binop(op: str, reflected: bool = False):
    def method(self, other):
        try:
            rhs = _SymCol._lift(other)
        except _NotVectorizable:
            return NotImplemented
        left, right = (rhs, self.expr) if reflected else (self.expr, rhs)
        return _SymCol(E.BinOp(op, left, right))
    return method


for _op, _magic in (("add", "add"), ("sub", "sub"), ("mul", "mul"),
                    ("truediv", "truediv"), ("floordiv", "floordiv"),
                    ("mod", "mod"), ("and", "and"), ("or", "or")):
    setattr(_SymCol, f"__{_magic}__", _sym_binop(_op))
    setattr(_SymCol, f"__r{_magic}__", _sym_binop(_op, reflected=True))
for _op, _magic in (("eq", "eq"), ("ne", "ne"), ("lt", "lt"), ("le", "le"),
                    ("gt", "gt"), ("ge", "ge")):
    setattr(_SymCol, f"__{_magic}__", _sym_binop(_op))


def _trace_udf(fn, cols: list[str]) -> dict[str, E.Expr] | None:
    """Run ``fn`` once on symbolic columns.  Returns ``{out_col: expr}``
    when every output is expressible in the native algebra, else None.
    Like any tracing JIT, a non-pure UDF observes the trace — acceptable
    because a UDF relying on side effects is not vectorizable anyway and
    almost always aborts the trace at its first non-algebraic operation."""
    sym = {c: _SymCol(E.Col(c)) for c in cols}
    try:
        out = fn(dict(sym))
    except Exception:  # noqa: BLE001 — any failure just declines the rewrite
        return None
    if not isinstance(out, dict) or not out:
        return None
    exprs: dict[str, E.Expr] = {}
    for k, v in out.items():
        if not isinstance(k, str):
            return None
        if isinstance(v, _SymCol):
            exprs[k] = v.expr
        elif isinstance(v, (bool, int, float)):
            exprs[k] = E.Lit(v)
        else:
            return None
    return exprs


class MapRowsVectorize:
    """Vectorizable ``MapRows`` UDFs lift into native ``Assign`` chains.

    The UDF is traced symbolically; when every output column is a native
    expression over the *input* columns, the barrier node becomes
    ``Assign*``/``Project``/``Rename`` — pushdown, column selection and
    zone maps all see through it.  Outputs land in fresh temp columns
    first (trace exprs only reference input columns, so no assign can
    clobber another's operand — e.g. a UDF swapping two columns), then a
    Project fixes the output set/order and a Rename restores the UDF's
    output names."""

    name = "map_rows_vectorize"
    summary = ("vectorizable row-UDFs lift into native column expressions "
               "(unblocks pushdown)")

    def match(self, n: G.Node) -> bool:
        return isinstance(n, G.MapRows)

    def guard(self, n: G.MapRows, parents) -> bool:
        return callable(n.fn)

    def apply(self, n: G.MapRows) -> G.Node | None:
        cols = _ordered_cols(n.inputs[0])
        if cols is None:
            return None
        exprs = _trace_udf(n.fn, cols)
        if exprs is None:
            return None
        node: G.Node = n.inputs[0]
        select: list[str] = []
        mapping: dict[str, str] = {}
        for i, (k, ex) in enumerate(exprs.items()):
            if isinstance(ex, E.Col) and ex.name == k:
                select.append(k)            # untouched passthrough column
                continue
            tmp = f"__vec_{i}_{k}"
            node = G.Assign(node, tmp, ex)
            select.append(tmp)
            mapping[tmp] = k
        node = G.Project(node, select)
        if mapping:
            node = G.Rename(node, mapping)
        return node

    def describe(self, n, repl) -> str:
        return f"udf={n.name!r}"


def _ordered_cols(node: G.Node) -> list[str] | None:
    """Statically-known output column order of a subgraph (None when a
    barrier below makes it unknowable)."""
    from ..lazyframe import _ordered_out
    memo: dict[int, list | None] = {}

    def rec(n: G.Node) -> list | None:
        if n.id not in memo:
            memo[n.id] = _ordered_out(n, [rec(i) for i in n.inputs])
        return memo[n.id]

    return rec(node)


DEFAULT_RULES = (SortHeadToTopK(), DedupBeforeSort(), MapRowsVectorize(),
                 FilterThroughConcat())
