"""Rule-based plan-rewrite engine (see engine.py for the driver)."""
from .engine import (RewriteEvent, RewriteRule, apply_rewrites,
                     consumed_ok, default_rules)
from .rules import (DEFAULT_RULES, DedupBeforeSort, FilterThroughConcat,
                    MapRowsVectorize, SortHeadToTopK)

__all__ = [
    "RewriteEvent", "RewriteRule", "apply_rewrites", "consumed_ok",
    "default_rules", "DEFAULT_RULES", "DedupBeforeSort",
    "FilterThroughConcat", "MapRowsVectorize", "SortHeadToTopK",
]
