"""Streaming backend: partition-at-a-time, out-of-core host execution (the
Dask analogue), with deterministic memory accounting.

The DAG is executed as pull-based partition streams.  Row-preserving ops map
over partitions; pipeline breakers (group-by, reductions, sort, join build
side, distinct) hold bounded combiner state — group-by uses partial
aggregation + combine (``physical.partial_aggs``), so memory scales with
the number of groups, not rows.  ``Head`` short-circuits the stream.

Nodes with multiple consumers are materialized once and re-streamed (and
accounted); persist-marked nodes go to the context cache (paper §3.5 — this
is what produced the paper's 2.3× memory / 13× speed trade-off, reproduced
in benchmarks/ablation_persist.py).
"""
from __future__ import annotations

from typing import Any, Iterator

import numpy as np

from .. import physical as X
from .. import graph as G
from ..context import LaFPContext
from . import MemoryMeter

Table = dict

_STREAM_ROWWISE = ("filter", "project", "assign", "rename", "astype",
                   "fillna", "map_rows", "fused_rowwise")


def _part_stream_from_table(table: Table, chunk: int) -> Iterator[Table]:
    rows = X.table_rows(table)
    if rows == 0:
        yield table
        return
    for lo in range(0, rows, chunk):
        yield {k: v[lo:lo + chunk] for k, v in table.items()}


class StreamingBackend:
    name = "streaming"

    def __init__(self, chunk_rows: int = 1 << 16):
        self.chunk_rows = chunk_rows

    # ------------------------------------------------------------------
    def execute(self, roots: list[G.Node], ctx: LaFPContext) -> dict[int, Any]:
        meter = MemoryMeter(ctx.memory_budget)
        parents = G.parents_map(roots)
        shared_ids = {nid for nid, ps in parents.items() if len(ps) > 1}
        memo: dict[int, Any] = {}       # materialized tables for shared nodes
        results: dict[int, Any] = {}
        self._meter = meter
        self._ctx = ctx
        self._shared = shared_ids
        self._memo = memo
        self._value_memo: dict[int, Any] = {}
        self._parents = parents
        for r in roots:
            results[r.id] = self._collect_value(r)
        # accumulate across force points (reset() clears) so program-level
        # peaks are visible to the benchmarks; the per-run peak feeds the
        # planner's peak-estimate calibration (feedback.record_peak samples)
        ctx.last_peak_bytes = max(ctx.last_peak_bytes, meter.peak)
        ctx.last_run_peak_bytes = meter.peak
        ctx.last_run_peak_engine = self.name
        return results

    # ------------------------------------------------------------------
    def _cached(self, n: G.Node):
        key = getattr(n, "cache_key", None) or n.key()
        if not isinstance(n, G.SinkPrint) and key in self._ctx.persist_cache:
            self._ctx.persist_stats["hits"] += 1
            return self._ctx.persist_cache[key]
        return None

    def _maybe_persist(self, n: G.Node, table: Table):
        if n.persist and not isinstance(n, (G.SinkPrint, G.Materialized)):
            self._ctx.persist_stats["misses"] += 1
            key = getattr(n, "cache_key", None) or n.key()
            self._ctx.persist_cache[key] = table
            self._meter.alloc(X.table_nbytes(table), f"persist:{n.op}#{n.id}")

    def stream(self, n: G.Node) -> Iterator[Table]:
        """Yield partitions of n's output. Caller must consume fully."""
        cached = self._cached(n)
        if cached is not None and isinstance(cached, dict):
            yield from _part_stream_from_table(cached, self.chunk_rows)
            return
        if n.id in self._memo:
            yield from _part_stream_from_table(self._memo[n.id], self.chunk_rows)
            return
        if n.id in self._shared or n.persist:
            table = self._materialize(n)
            yield from _part_stream_from_table(table, self.chunk_rows)
            return
        yield from self._stream_fresh(n)

    def _stream_fresh(self, n: G.Node) -> Iterator[Table]:
        meter = self._meter
        if isinstance(n, G.Handoff):
            v = X.handoff_value(n)
            if not isinstance(v, dict):
                raise RuntimeError(f"cannot stream scalar handoff #{n.id}")
            yield from _part_stream_from_table(v, self.chunk_rows)
            return
        if isinstance(n, G.Materialized):
            yield from _part_stream_from_table(n.table, self.chunk_rows)
            return
        if isinstance(n, G.Scan):
            # shared pushdown-aware loader (repro.io): projection ∪
            # predicate columns read, pushed-down conjuncts applied per
            # partition, async prefetch for prefetchable sources; yields a
            # 0-row schema-bearing table when everything is pruned
            from repro.io.scan import iter_scan_partitions
            for part in iter_scan_partitions(n, ctx=self._ctx):
                nb = X.table_nbytes(part)
                meter.alloc(nb, f"scan#{n.id}")
                yield part
                meter.free(nb)
            return
        if n.op in _STREAM_ROWWISE:
            for part in self.stream(n.inputs[0]):
                out = self._rowwise(n, part)
                nb = X.table_nbytes(out)
                meter.alloc(nb, f"{n.op}#{n.id}")
                yield out
                meter.free(nb)
            return
        if isinstance(n, G.Head):
            got = 0
            for part in self.stream(n.inputs[0]):
                take = min(n.n - got, X.table_rows(part))
                # always yield (0-row parts keep the schema downstream)
                yield {k: v[:take] for k, v in part.items()}
                got += take
                if got >= n.n:
                    break  # early exit: upstream generators are abandoned
            return
        if isinstance(n, G.Concat):
            for child in n.inputs:
                yield from self.stream(child)
            return
        if isinstance(n, G.Join):
            build = self._materialize(n.inputs[1])     # build side held
            nb = X.table_nbytes(build)
            meter.alloc(nb, f"join_build#{n.id}")
            for part in self.stream(n.inputs[0]):
                out = X.apply_join(part, build, n.on, n.how, n.suffixes)
                ob = X.table_nbytes(out)
                meter.alloc(ob, f"join_probe#{n.id}")
                yield out
                meter.free(ob)
            meter.free(nb)
            return
        if isinstance(n, G.DropDuplicates):
            # incremental distinct: `seen` holds deduped rows so far; since
            # apply_drop_duplicates keeps first occurrences in order, the new
            # unique rows of each chunk are the tail beyond len(seen).
            seen: Table | None = None
            cols = list(n.subset) if n.subset else None
            yielded = False
            for part in self.stream(n.inputs[0]):
                merged = part if seen is None else {
                    k: np.concatenate([seen[k], part[k]]) for k in seen}
                out_all = X.apply_drop_duplicates(merged, cols or list(merged))
                prev_rows = X.table_rows(seen) if seen is not None else 0
                if X.table_rows(out_all) > prev_rows:
                    yielded = True
                    yield {k: v[prev_rows:] for k, v in out_all.items()}
                prev_bytes = X.table_nbytes(seen) if seen is not None else 0
                seen = out_all
                meter.alloc(max(0, X.table_nbytes(seen) - prev_bytes),
                            f"distinct#{n.id}")
            if not yielded and seen is not None:
                yield {k: v[:0] for k, v in seen.items()}  # keep schema
            return
        # group-by / sort / reduce et al. produce single-partition output
        value = self._collect_value(n)
        if isinstance(value, dict):
            yield from _part_stream_from_table(value, self.chunk_rows)
        else:
            raise RuntimeError(f"cannot stream scalar node {n.op}")

    def _rowwise(self, n: G.Node, part: Table) -> Table:
        if isinstance(n, G.Filter):
            return X.apply_filter(part, n.predicate)
        if isinstance(n, G.Project):
            return X.apply_project(part, n.columns)
        if isinstance(n, G.Assign):
            return X.apply_assign(part, n.name, n.expr)
        if isinstance(n, G.Rename):
            return X.apply_rename(part, n.mapping)
        if isinstance(n, G.AsType):
            return X.apply_astype(part, n.dtypes)
        if isinstance(n, G.FillNa):
            return X.apply_fillna(part, n.value, n.columns)
        if isinstance(n, G.MapRows):
            return X.apply_map_rows(part, n.fn)
        if isinstance(n, G.FusedRowwise):
            # one chunk-loop body: the whole member chain per partition
            return X.apply_fused_rowwise(
                part, n.ops, self._ctx.backend_options.get("kernel_impl"))
        raise NotImplementedError(n.op)

    def _materialize(self, n: G.Node) -> Table:
        cached = self._cached(n)
        if cached is not None and isinstance(cached, dict):
            return cached
        if n.id in self._memo:
            return self._memo[n.id]
        parts = list(self._stream_fresh(n))
        table = (X.apply_concat(parts) if len(parts) > 1 else
                 (parts[0] if parts else {}))
        self._meter.alloc(X.table_nbytes(table), f"materialize:{n.op}#{n.id}")
        if n.id in self._shared:
            self._memo[n.id] = table
        self._maybe_persist(n, table)
        return table

    # ------------------------------------------------------------------
    def _collect_value(self, n: G.Node) -> Any:
        meter = self._meter
        if n.id in self._value_memo:
            return self._value_memo[n.id]
        out = self._collect_value_inner(n)
        self._value_memo[n.id] = out
        return out

    def _collect_value_inner(self, n: G.Node) -> Any:
        meter = self._meter
        if isinstance(n, G.Handoff):
            return X.handoff_value(n)
        cached = self._cached(n)
        if cached is not None:
            return cached
        if isinstance(n, G.SinkPrint):
            # ordering edge (last input) forces the prior sink to print first
            if len(n.inputs) > n.n_data:
                self._collect_value(n.inputs[n.n_data])
            vals = [self._collect_value(i) for i in n.inputs[: n.n_data]]
            from ..sinks import render_sink
            render_sink(n, vals, self._ctx)
            return None
        if isinstance(n, G.Length):
            child = n.inputs[0]
            # fast path: pure scan → metadata row counts, no IO.  A scan
            # with a pushed-down predicate filters rows at load time, so
            # metadata counts would overcount — stream it instead.
            if isinstance(child, G.Scan) and child.pushdown is None:
                total = 0
                metas_ok = True
                for pi in range(child.source.n_partitions):
                    if pi in child.skip_partitions:
                        continue
                    m = child.source.partition_meta(pi)
                    if "rows" not in m:
                        metas_ok = False
                        break
                    total += m["rows"]
                if metas_ok:
                    return total
            return sum(X.table_rows(p) for p in self.stream(child))
        if isinstance(n, G.Reduce):
            return self._reduce_streaming(n)
        if isinstance(n, G.GroupByAgg):
            partial_spec = X.partial_aggs(n.aggs)
            partials = []
            for part in self.stream(n.inputs[0]):
                p = X.apply_groupby_agg(part, n.keys, partial_spec)
                meter.alloc(X.table_nbytes(p), f"gb_partial#{n.id}")
                partials.append(p)
            if not partials:
                return {k: np.zeros(0) for k in list(n.keys) + list(n.aggs)}
            out = X.combine_partials(n.keys, partials, n.aggs)
            for p in partials:
                meter.free(X.table_nbytes(p))
            self._maybe_persist(n, out)
            return out
        if isinstance(n, G.SortValues):
            table = self._materialize_for_breaker(n.inputs[0], f"sort#{n.id}")
            out = X.apply_sort(table, n.by, n.ascending)
            self._maybe_persist(n, out)
            return out
        if isinstance(n, G.TopK):
            return self._topk_streaming(n)
        # generic: materialize the stream
        table = self._materialize(n)
        return table

    def _topk_streaming(self, n: G.TopK) -> Table:
        """Bounded top-k: hold at most ~n rows plus one chunk, never the
        whole input.  An explicit global row position is appended as the
        least-significant sort key so cross-chunk tie order is exactly the
        whole-table kernel's (stable = position order); for the pandas
        ``nlargest`` mode the position is negated so descending keys still
        keep first occurrences."""
        meter = self._meter
        pos_col = "__topk_pos__"
        sign = -1 if (n.mode == "select" and not n.ascending) else 1
        best: Table | None = None
        offset = 0
        for part in self.stream(n.inputs[0]):
            rows = X.table_rows(part)
            part = dict(part)
            part[pos_col] = sign * np.arange(offset, offset + rows,
                                             dtype=np.int64)
            offset += rows
            merged = part if best is None else {
                k: np.concatenate([best[k], part[k]]) for k in best}
            prev = X.table_nbytes(best) if best is not None else 0
            best = X.apply_top_k(merged, tuple(n.by) + (pos_col,), n.n,
                                 n.ascending, n.mode)
            meter.alloc(max(0, X.table_nbytes(best) - prev), f"topk#{n.id}")
        if best is None:
            return {}
        best.pop(pos_col, None)
        self._maybe_persist(n, best)
        return best

    def _materialize_for_breaker(self, child: G.Node, where: str) -> Table:
        parts = list(self.stream(child))
        table = X.apply_concat(parts) if len(parts) > 1 else (
            parts[0] if parts else {})
        self._meter.alloc(X.table_nbytes(table), where)
        return table

    def _reduce_streaming(self, n: G.Reduce):
        fn = n.fn
        if fn == "mean":
            s, c = 0.0, 0
            for part in self.stream(n.inputs[0]):
                v = np.asarray(part[n.column], dtype=np.float64)
                s += float(v.sum())
                c += v.shape[0]
            return s / max(c, 1)
        if fn == "nunique":
            uniq = None
            for part in self.stream(n.inputs[0]):
                u = np.unique(np.asarray(part[n.column]))
                uniq = u if uniq is None else np.unique(np.concatenate([uniq, u]))
                self._meter.alloc(0, f"nunique#{n.id}")
            return int(uniq.shape[0]) if uniq is not None else 0
        if fn == "count":
            return sum(X.table_rows(p) for p in self.stream(n.inputs[0]))
        if fn == "median":
            # not decomposable into bounded partials: materialize the one
            # column over the stream (accounted), then nanmedian (pandas
            # skipna semantics, matching physical.apply_reduce)
            parts = [np.asarray(p[n.column]) for p in self.stream(n.inputs[0])]
            col = np.concatenate(parts) if parts else np.zeros(0)
            self._meter.alloc(int(col.nbytes), f"median#{n.id}")
            out = float(np.nanmedian(col)) if col.size else float("nan")
            self._meter.free(int(col.nbytes))
            return out
        acc = None
        for part in self.stream(n.inputs[0]):
            v = np.asarray(part[n.column])
            if v.size == 0:
                continue
            x = {"sum": v.sum, "min": v.min, "max": v.max}[fn]()
            if acc is None:
                acc = x
            else:
                acc = {"sum": np.add, "min": np.minimum, "max": np.maximum}[fn](acc, x)
        return acc
