"""Distributed backend: shard_map execution over the mesh ``data`` axis (the
Modin/cluster analogue of paper §2.6).

Physical model: each source partition group is padded to a fixed per-shard
row count and stacked to ``(n_shards, rows)`` with a validity mask
(``physical.ShardedTable``).  Row-wise ops and mask updates run inside a
single jit+shard_map program per pipeline stage; reductions and group-bys
compute shard-local partials and combine with ``jax.lax.psum`` over the data
axis.  Group-by keys must be dictionary-coded / small-domain ints (the
metadata store guarantees this for category columns), giving a dense
``segment_sum`` of size G per shard — the same layout the MXU group-by
kernel uses on TPU.

Join, sort, and distinct are *native* (``physical.sharded``): broadcast-hash
join for small unique-key build sides (device-resident, shape-preserving),
shuffle-by-dict-code join / sort / distinct otherwise, all producing
device-resident ``ShardedTable`` outputs.  Only genuinely unsupported cases
(non-integer keys, unbounded key domains, exotic ``how=``) fall back to the
eager kernel — mirroring the paper's "convert to Pandas, run, convert back"
fallback for unsupported Dask ops.

Segment handoffs: ``execute(..., keep_sharded=...)`` lets the runtime keep
named roots device-resident, so distributed→distributed segment chains pass
``ShardedTable`` payloads through ``graph.Handoff`` without a host gather;
incoming sharded handoffs are consumed in place.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ...compat import shard_map
from .. import graph as G
from .. import physical as X
from ..context import LaFPContext
from ..physical.sharded import ShardedTable
from .eager import EagerBackend

_DIST_OPS = ("scan", "filter", "project", "assign", "rename", "astype",
             "fillna", "fused_rowwise")


def _default_mesh() -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(len(devs)), ("data",))


class DistributedBackend:
    name = "distributed"
    supports_device_handoff = True

    def __init__(self, mesh: Mesh | None = None, axis: str = "data"):
        self.mesh = mesh or _default_mesh()
        self.axis = axis
        self._fallback = EagerBackend()

    # -- planning: greatest distributable subgraphs -------------------------
    def execute(self, roots: list[G.Node], ctx: LaFPContext,
                keep_sharded: frozenset[int] = frozenset()) -> dict[int, Any]:
        """Evaluate ``roots``.  Results are host values except for root ids
        in ``keep_sharded``, whose ``ShardedTable`` stays device-resident —
        the runtime requests this for distributed→distributed handoffs."""
        self._ctx = ctx
        results: dict[int, Any] = {}
        memo: dict[int, Any] = {}        # shared: CSE'd subtrees run once
        for r in roots:
            v = self._eval(r, memo)
            if isinstance(v, ShardedTable) and r.id not in keep_sharded:
                # ShardedTable is internal representation; callers (runtime
                # _wrap, host segment handoffs) expect host tables
                v = v.gather()
            results[r.id] = v
        return results

    def _eval(self, n: G.Node, memo: dict[int, Any]) -> Any:
        if n.id in memo:
            return memo[n.id]
        key = getattr(n, "cache_key", None) or n.key()
        if not isinstance(n, G.SinkPrint) and key in self._ctx.persist_cache:
            self._ctx.persist_stats["hits"] += 1
            memo[n.id] = self._ctx.persist_cache[key]
            return memo[n.id]
        out = self._eval_inner(n, memo)
        if n.persist and not isinstance(n, (G.SinkPrint, G.Materialized)):
            val = out.gather() if isinstance(out, ShardedTable) else out
            self._ctx.persist_cache[key] = val
            self._ctx.persist_stats["misses"] += 1
            out = val
        memo[n.id] = out
        return out

    def _eval_inner(self, n: G.Node, memo) -> Any:
        if isinstance(n, G.Handoff):
            v = n.value
            if isinstance(v, ShardedTable):
                if v.n_shards == self._n_shards():
                    return v                  # device-resident, no re-shard
                return X.shard_host_table(v.gather(), self.mesh, self.axis)
            return X.handoff_value(n)
        if isinstance(n, G.Materialized):
            return dict(n.table)
        if isinstance(n, G.SinkPrint):
            if len(n.inputs) > n.n_data:
                self._eval(n.inputs[n.n_data], memo)
            vals = []
            for i in n.inputs[: n.n_data]:
                v = self._eval(i, memo)
                vals.append(v.gather() if isinstance(v, ShardedTable) else v)
            from ..sinks import render_sink
            render_sink(n, vals, self._ctx)
            return None
        if isinstance(n, G.Scan):
            return self._load_sharded(n)
        if n.op in _DIST_OPS:
            child = self._eval(n.inputs[0], memo)
            if isinstance(child, ShardedTable):
                try:
                    return self._rowwise_sharded(n, child)
                except Exception as e:  # noqa: BLE001 — e.g. host-numpy UDF
                    # exprs that cannot be jit-traced: gather and delegate
                    # like any other unsupported op — but never silently
                    # (a genuine native-kernel bug must stay visible)
                    from ...obs.events import PlannerEvent
                    from ...obs.spans import metric_inc
                    self._ctx.planner_trace.append(PlannerEvent(
                        f"distributed: {n.op}#{n.id} native path failed, "
                        f"falling back ({type(e).__name__}: {e})",
                        kind="native-fallback", op=n.op, node_id=n.id,
                        error=type(e).__name__))
                    metric_inc("distributed.native_fallbacks")
                    return self._fallback_node(n, [child])
            return self._fallback_node(n, [child])
        if isinstance(n, G.Reduce):
            child = self._eval(n.inputs[0], memo)
            if isinstance(child, ShardedTable) and n.fn in ("sum", "mean",
                                                            "count", "min", "max"):
                return self._reduce_sharded(n, child)
            return self._fallback_node(n, [child])
        if isinstance(n, G.Length):
            child = self._eval(n.inputs[0], memo)
            if isinstance(child, ShardedTable):
                return int(jnp.sum(child.valid))
            return self._fallback_node(n, [child])
        if isinstance(n, G.GroupByAgg):
            child = self._eval(n.inputs[0], memo)
            if isinstance(child, ShardedTable):
                dense = self._try_groupby_sharded(n, child)
                if dense is not None:
                    return dense
            return self._fallback_node(
                n, [child.gather() if isinstance(child, ShardedTable) else child])
        if isinstance(n, G.Join):
            left = self._eval(n.inputs[0], memo)
            right = self._eval(n.inputs[1], memo)
            if isinstance(left, ShardedTable):
                build = right.gather() if isinstance(right, ShardedTable) else right
                if isinstance(build, dict):
                    out = X.sharded_join(left, build, n.on, n.how, n.suffixes,
                                         self.mesh, self.axis)
                    if out is not None:
                        return out
            return self._fallback_node(n, [left, right])
        if isinstance(n, G.SortValues):
            child = self._eval(n.inputs[0], memo)
            if isinstance(child, ShardedTable):
                out = X.sharded_sort(child, n.by, n.ascending,
                                     self.mesh, self.axis)
                if out is not None:
                    return out
            return self._fallback_node(n, [child])
        if isinstance(n, G.DropDuplicates):
            child = self._eval(n.inputs[0], memo)
            if isinstance(child, ShardedTable):
                out = X.sharded_distinct(child, n.subset, self.mesh, self.axis)
                if out is not None:
                    return out
            return self._fallback_node(n, [child])
        if isinstance(n, G.Head):
            child = self._eval(n.inputs[0], memo)
            if isinstance(child, ShardedTable) and n.n >= 0:
                # native head: serve from the leading shard(s) by masking —
                # no gather, no re-shard (physical.sharded_head).  Negative
                # n (pandas all-but-last-n) takes the host fallback.
                return X.sharded_head(child, n.n)
            return self._fallback_node(n, [child])
        # fallback for concat/maprows and unsupported native cases
        vals = []
        for i in n.inputs:
            v = self._eval(i, memo)
            vals.append(v.gather() if isinstance(v, ShardedTable) else v)
        return self._fallback_node(n, vals)

    def _fallback_node(self, n: G.Node, vals: list[Any]):
        vals = [v.gather() if isinstance(v, ShardedTable) else v for v in vals]
        return self._fallback.eval_node(n, vals, self._ctx)

    # -- sharded physical ops -------------------------------------------------
    def _n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    def _load_sharded(self, n: G.Scan) -> ShardedTable:
        # shared pushdown-aware loader (repro.io): per-partition column
        # projection + pushed-down predicate, io.* accounting
        from repro.io.scan import (empty_scan_table, load_scan_partition,
                                   scan_partition_indices)
        ctx = self._ctx
        metrics = getattr(ctx, "metrics", None)
        tracer = getattr(ctx, "tracer", None)
        if metrics is not None and n.skip_partitions:
            metrics.inc("io.partitions_pruned", len(n.skip_partitions))
        parts = [load_scan_partition(n, pi, metrics=metrics, tracer=tracer)
                 for pi in scan_partition_indices(n)]
        if not parts:
            parts = [empty_scan_table(n)]
        full = {c: np.concatenate([p[c] for p in parts]) for c in parts[0]}
        return X.shard_host_table(full, self.mesh, self.axis)

    def _rowwise_sharded(self, n: G.Node, t: ShardedTable) -> ShardedTable:
        if isinstance(n, G.Filter):
            pred = n.predicate

            @partial(jax.jit)
            def upd(cols, valid):
                mask = pred.evaluate(cols)
                return valid & mask

            valid = upd(t.cols, t.valid)
            return ShardedTable(dict(t.cols), valid)
        if isinstance(n, G.Project):
            return ShardedTable({c: t.cols[c] for c in n.columns}, t.valid)
        if isinstance(n, G.Assign):
            expr = n.expr

            @partial(jax.jit)
            def mk(cols):
                return expr.evaluate(cols)

            val = mk(t.cols)
            if getattr(val, "ndim", 0) != 2:
                val = jnp.broadcast_to(val, t.valid.shape)
            out = dict(t.cols)
            out[n.name] = val
            return ShardedTable(out, t.valid)
        if isinstance(n, G.Rename):
            return ShardedTable({n.mapping.get(c, c): v
                                 for c, v in t.cols.items()}, t.valid)
        if isinstance(n, G.AsType):
            out = dict(t.cols)
            for c, dt in n.dtypes.items():
                out[c] = out[c].astype(dt)
            return ShardedTable(out, t.valid)
        if isinstance(n, G.FillNa):
            out = dict(t.cols)
            for c in (n.columns or list(out)):
                arr = out[c]
                if arr.dtype.kind == "f":
                    out[c] = jnp.where(jnp.isnan(arr),
                                       jnp.asarray(n.value, arr.dtype), arr)
            return ShardedTable(out, t.valid)
        if isinstance(n, G.FusedRowwise):
            # members reuse the per-op sharded paths above; the validity
            # mask plays the deferred-filter role, so no compaction needed
            out = t
            for m in n.ops:
                out = self._rowwise_sharded(m, out)
            return out
        raise NotImplementedError(n.op)

    def _reduce_sharded(self, n: G.Reduce, t: ShardedTable):
        fn = n.fn
        mesh, axis = self.mesh, self.axis

        col = t.cols[n.column] if n.column else None
        valid = t.valid

        @partial(jax.jit)
        def run(col, valid):
            def local(col, valid):
                v = valid
                if fn == "count":
                    r = jnp.sum(v, dtype=jnp.int32)
                elif fn == "sum":
                    r = jnp.sum(jnp.where(v, col, 0))
                elif fn == "mean":
                    s = jnp.sum(jnp.where(v, col.astype(jnp.float32), 0.0))
                    c = jnp.sum(v, dtype=jnp.float32)
                    r = jnp.stack([s, c])
                elif fn == "min":
                    r = jnp.min(jnp.where(v, col, jnp.inf if col.dtype.kind == "f"
                                          else jnp.iinfo(col.dtype).max))
                elif fn == "max":
                    r = jnp.max(jnp.where(v, col, -jnp.inf if col.dtype.kind == "f"
                                          else jnp.iinfo(col.dtype).min))
                return r

            f = shard_map(
                lambda c, v: _psum_combine(fn, local(c[0], v[0]), axis),
                mesh=mesh,
                in_specs=(P(axis), P(axis)),
                out_specs=P())
            if col is None:
                zero = jnp.zeros_like(valid, dtype=jnp.int32)
                return f(zero, valid)
            return f(col, valid)

        out = run(col if col is not None else None, valid)
        if fn == "mean":
            return float(out[0] / jnp.maximum(out[1], 1))
        if fn == "count":
            return int(out)
        return out

    def _try_groupby_sharded(self, n: G.GroupByAgg, t: ShardedTable):
        """Dense group-by when the key domain is small & known (dict codes)."""
        if len(n.keys) != 1:
            return None
        key = n.keys[0]
        karr = t.cols.get(key)
        if karr is None or karr.dtype.kind not in "iu":
            return None
        kmax = int(jnp.max(jnp.where(t.valid, karr, 0)))
        G_dom = kmax + 1
        if G_dom > 1 << 16:
            return None
        mesh, axis = self.mesh, self.axis
        fns = {out: fn for out, (_c, fn) in n.aggs.items()}
        if not set(fns.values()) <= {"sum", "count", "mean", "min", "max"}:
            return None
        cols_needed = {c for (c, _fn) in n.aggs.values() if c is not None}
        value_cols = {c: t.cols[c] for c in cols_needed}

        @partial(jax.jit, static_argnames=("gdom",))
        def run(karr, valid, vals, gdom):
            def local(k, v, vals):
                k = jnp.where(v, k, gdom)  # invalid rows to overflow bucket
                outs = {}
                cnt = jax.ops.segment_sum(v.astype(jnp.float32), k, gdom + 1)
                for out_name, (c, fn) in n.aggs.items():
                    if fn == "count":
                        outs[out_name] = cnt
                    elif fn in ("sum", "mean"):
                        s = jax.ops.segment_sum(
                            jnp.where(v, vals[c].astype(jnp.float32), 0.0), k,
                            gdom + 1)
                        outs[out_name] = jnp.stack([s, cnt]) if fn == "mean" else s
                    elif fn == "min":
                        big = jnp.asarray(jnp.inf, jnp.float32)
                        x = jnp.where(v, vals[c].astype(jnp.float32), big)
                        outs[out_name] = jax.ops.segment_min(x, k, gdom + 1)
                    elif fn == "max":
                        x = jnp.where(v, vals[c].astype(jnp.float32), -jnp.inf)
                        outs[out_name] = jax.ops.segment_max(x, k, gdom + 1)
                outs["__count"] = cnt
                return outs

            def shard_fn(k, v, *vlist):
                vals_d = {name: arr[0] for name, arr in
                          zip(sorted(value_cols), vlist)}
                outs = local(k[0], v[0], vals_d)
                comb = {}
                for name, arr in outs.items():
                    fn = fns.get(name, "count" if name == "__count" else "sum")
                    comb[name] = _psum_combine(
                        "min" if fn == "min" else ("max" if fn == "max" else "sum"),
                        arr, axis)
                return comb

            return shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P(axis), P(axis)) + tuple(P(axis) for _ in value_cols),
                out_specs=P())(karr, valid,
                               *[vals[c] for c in sorted(value_cols)])

        vals = {c: value_cols[c] for c in sorted(value_cols)}
        outs = run(karr, t.valid, vals, G_dom)
        present = np.asarray(outs["__count"][:G_dom]) > 0
        groups = np.nonzero(present)[0]
        result = {key: groups.astype(np.asarray(karr).dtype)}
        for out_name, (_c, fn) in n.aggs.items():
            arr = outs[out_name]
            if fn == "mean":
                s, c = np.asarray(arr[0][:G_dom]), np.asarray(arr[1][:G_dom])
                result[out_name] = (s / np.maximum(c, 1))[groups]
            elif fn == "count":
                result[out_name] = np.asarray(arr[:G_dom]).astype(np.int64)[groups]
            else:
                result[out_name] = np.asarray(arr[:G_dom])[groups]
        return result


def _psum_combine(fn: str, arr, axis: str):
    if fn == "min":
        return jax.lax.pmin(arr, axis)
    if fn == "max":
        return jax.lax.pmax(arr, axis)
    return jax.lax.psum(arr, axis)
