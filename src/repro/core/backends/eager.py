"""Eager backend: whole-table execution on the default JAX device.

Faithful to paper §2.6: topological execution with in-degree refcounting so a
node's result is freed as soon as its last consumer has run; persist-marked
nodes go to the context cache instead of being freed.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from .. import physical as X
from .. import graph as G
from ..context import LaFPContext


class EagerBackend:
    name = "eager"

    def __init__(self, device_arrays: bool = True):
        self.device_arrays = device_arrays

    # -- node evaluation ------------------------------------------------------
    def _load_scan(self, n: G.Scan, ctx: LaFPContext | None = None):
        # shared pushdown-aware loader (repro.io): per-partition column
        # projection + pushed-down predicate, io.* accounting
        from repro.io.scan import (empty_scan_table, load_scan_partition,
                                   scan_partition_indices)
        metrics = getattr(ctx, "metrics", None)
        tracer = getattr(ctx, "tracer", None)
        if metrics is not None and n.skip_partitions:
            metrics.inc("io.partitions_pruned", len(n.skip_partitions))
        parts = [load_scan_partition(n, pi, metrics=metrics, tracer=tracer)
                 for pi in scan_partition_indices(n)]
        if not parts:
            return empty_scan_table(n)
        table = {c: np.concatenate([p[c] for p in parts]) for c in parts[0]}
        if self.device_arrays:
            table = X.to_jax(table)
        return table

    def eval_node(self, n: G.Node, vals: list[Any], ctx: LaFPContext):
        if isinstance(n, G.Handoff):
            return X.handoff_value(n, self.device_arrays)
        if isinstance(n, G.Materialized):
            return (X.to_jax(n.table) if self.device_arrays else n.table)
        if isinstance(n, G.Scan):
            return self._load_scan(n, ctx)
        if isinstance(n, G.Filter):
            return X.apply_filter(vals[0], n.predicate)
        if isinstance(n, G.Project):
            return X.apply_project(vals[0], n.columns)
        if isinstance(n, G.Assign):
            return X.apply_assign(vals[0], n.name, n.expr)
        if isinstance(n, G.Rename):
            return X.apply_rename(vals[0], n.mapping)
        if isinstance(n, G.AsType):
            return X.apply_astype(vals[0], n.dtypes)
        if isinstance(n, G.FillNa):
            return X.apply_fillna(vals[0], n.value, n.columns)
        if isinstance(n, G.FusedRowwise):
            return X.apply_fused_rowwise(
                vals[0], n.ops, ctx.backend_options.get("kernel_impl"))
        if isinstance(n, G.SortValues):
            return X.apply_sort(vals[0], n.by, n.ascending)
        if isinstance(n, G.DropDuplicates):
            return X.apply_drop_duplicates(vals[0], n.subset)
        if isinstance(n, G.Head):
            return X.apply_head(vals[0], n.n)
        if isinstance(n, G.TopK):
            return X.apply_top_k(vals[0], n.by, n.n, n.ascending, n.mode)
        if isinstance(n, G.MapRows):
            return X.apply_map_rows(vals[0], n.fn)
        if isinstance(n, G.GroupByAgg):
            return X.apply_groupby_agg(vals[0], n.keys, n.aggs)
        if isinstance(n, G.Join):
            return X.apply_join(vals[0], vals[1], n.on, n.how, n.suffixes)
        if isinstance(n, G.Concat):
            return X.apply_concat(vals)
        if isinstance(n, G.Reduce):
            return X.apply_reduce(vals[0], n.column, n.fn)
        if isinstance(n, G.Length):
            return X.table_rows(vals[0])
        if isinstance(n, G.SinkPrint):
            return self._run_sink(n, vals, ctx)
        raise NotImplementedError(f"eager: {n.op}")

    def _run_sink(self, n: G.SinkPrint, vals, ctx: LaFPContext):
        from ..sinks import render_sink
        render_sink(n, vals[: n.n_data], ctx)
        return None

    # -- driver ----------------------------------------------------------------
    @staticmethod
    def _value_nbytes(val) -> int:
        """Device-buffer size of one node result (tables only — scalars and
        sinks are negligible)."""
        if isinstance(val, dict):
            return int(X.table_nbytes(val))
        nb = getattr(val, "nbytes", None)
        return int(nb) if isinstance(nb, (int, float)) else 0

    def execute(self, roots: list[G.Node], ctx: LaFPContext) -> dict[int, Any]:
        order = G.walk(roots)
        refcount: dict[int, int] = {}
        for n in order:
            for i in n.inputs:
                refcount[i.id] = refcount.get(i.id, 0) + 1
        root_ids = {r.id for r in roots}
        results: dict[int, Any] = {}
        # deterministic peak metering: resident device-buffer bytes through
        # the refcounted walk — feeds the planner's peak-estimate
        # calibration (StatsStore.record_peak), which before only got
        # samples from the streaming MemoryMeter
        current = peak = 0
        for n in order:
            vals = [results[i.id] for i in n.inputs]
            results[n.id] = self.eval_node(n, vals, ctx)
            current += self._value_nbytes(results[n.id])
            peak = max(peak, current)
            if n.persist and not isinstance(n, (G.SinkPrint, G.Materialized)):
                ctx.persist_stats["misses"] += 1
                key = getattr(n, "cache_key", None) or n.key()
                val = results[n.id]
                if isinstance(val, dict):
                    val = X.to_numpy(val)      # cache host-side
                ctx.persist_cache[key] = val
            # paper §2.6: free inputs whose consumers are all done
            for i in n.inputs:
                refcount[i.id] -= 1
                if refcount[i.id] == 0 and i.id not in root_ids:
                    if not i.persist:
                        current -= self._value_nbytes(results[i.id])
                        results[i.id] = None  # allow GC; keep slot for roots
        ctx.last_run_peak_bytes = peak
        ctx.last_run_peak_engine = self.name
        ctx.last_peak_bytes = max(ctx.last_peak_bytes, peak)
        return {rid: results.get(rid) for rid in root_ids}
