"""Pluggable execution backends (paper §2.6).

* eager       — whole-table, device-resident jnp (the Pandas analogue)
* streaming   — partition-at-a-time host execution, bounded memory, out-of-
                core (the Dask analogue)
* distributed — shard_map over the mesh data axis (the Modin/cluster
                analogue); unsupported ops fall back to eager, mirroring the
                paper's convert-to-Pandas fallback.
"""
from __future__ import annotations

from ..context import BackendEngines


class MemoryBudgetExceeded(RuntimeError):
    def __init__(self, needed: int, budget: int, where: str):
        super().__init__(
            f"memory budget exceeded at {where}: needs {needed/1e6:.1f} MB, "
            f"budget {budget/1e6:.1f} MB")
        self.needed = needed
        self.budget = budget


class MemoryMeter:
    """Deterministic memory accounting for the streaming backend — lets the
    benchmark reproduce the paper's OOM behaviour (Fig. 12) without actually
    exhausting RAM."""

    def __init__(self, budget: int | None):
        self.budget = budget
        self.current = 0
        self.peak = 0

    def alloc(self, nbytes: int, where: str = "?"):
        self.current += int(nbytes)
        self.peak = max(self.peak, self.current)
        if self.budget is not None and self.current > self.budget:
            raise MemoryBudgetExceeded(self.current, self.budget, where)

    def free(self, nbytes: int):
        self.current -= int(nbytes)


def get_backend(kind: BackendEngines, **options):
    if kind == BackendEngines.EAGER:
        from .eager import EagerBackend
        return EagerBackend(**options)
    if kind == BackendEngines.STREAMING:
        from .streaming import StreamingBackend
        return StreamingBackend(**options)
    if kind == BackendEngines.DISTRIBUTED:
        from .distributed import DistributedBackend
        return DistributedBackend(**options)
    raise ValueError(kind)
