"""Pluggable execution backends (paper §2.6).

* eager       — whole-table, device-resident jnp (the Pandas analogue)
* streaming   — partition-at-a-time host execution, bounded memory, out-of-
                core (the Dask analogue)
* distributed — shard_map over the mesh data axis (the Modin/cluster
                analogue); unsupported ops fall back to eager, mirroring the
                paper's convert-to-Pandas fallback.
"""
from __future__ import annotations

import dataclasses

from ..context import BackendEngines
from ..physical.sharded import BROADCAST_BUILD_BYTES


# ---------------------------------------------------------------------------
# Capability registry (planner-facing).  Each backend publishes what it can
# run natively and the constant factors of its cost model; ops outside
# ``native_ops`` are executed via the backend's fallback path and priced with
# ``fallback_penalty`` (+ a gather/transfer charge) by the planner.

_ALL_OPS = frozenset({
    "scan", "materialized", "filter", "project", "assign", "rename",
    "astype", "fillna", "sort_values", "drop_duplicates", "head",
    "map_rows", "groupby_agg", "join", "concat", "reduce", "length",
    "sink_print",
})


@dataclasses.dataclass(frozen=True)
class BackendCapability:
    name: str
    native_ops: frozenset               # ops with a first-class implementation
    startup_cost: float                 # fixed per-force-point dispatch cost
    scan_cost_per_byte: float           # reading source bytes
    row_cost: float                     # per-row per-operator compute
    parallelism: float                  # effective divisor on row work
    transfer_cost_per_byte: float       # host<->device / gather movement
    fallback_penalty: float             # multiplier for non-native ops
    streams_partitions: bool            # True → peak memory is chunk-scaled
    # joins are costed by *build side*: builds at or below this many bytes
    # replicate cheaply (broadcast-hash); larger builds pay an all-to-all
    # shuffle of both sides.  0.0 → the engine has no exchange-based join
    # (its join is a plain local hash join, no extra movement charge).
    broadcast_join_bytes: float = 0.0


CAPABILITIES: dict[BackendEngines, BackendCapability] = {
    BackendEngines.EAGER: BackendCapability(
        name="eager", native_ops=_ALL_OPS,
        startup_cost=1e3, scan_cost_per_byte=1.0, row_cost=1.0,
        parallelism=4.0, transfer_cost_per_byte=0.5, fallback_penalty=1.0,
        streams_partitions=False),
    BackendEngines.STREAMING: BackendCapability(
        name="streaming", native_ops=_ALL_OPS,
        startup_cost=2e3, scan_cost_per_byte=1.5, row_cost=2.0,
        parallelism=1.0, transfer_cost_per_byte=0.0, fallback_penalty=1.0,
        streams_partitions=True),
    BackendEngines.DISTRIBUTED: BackendCapability(
        name="distributed",
        native_ops=frozenset({"scan", "materialized", "filter", "project",
                              "assign", "rename", "astype", "fillna",
                              "reduce", "length", "groupby_agg", "join",
                              "sort_values", "drop_duplicates",
                              "sink_print"}),
        # scan models parallel partition ingest across shard workers (cheaper
        # per byte than eager's single-device load), paid for by the highest
        # fixed startup: distributed only wins once tables are large enough
        # to amortize mesh dispatch.  Runtime calibration corrects both.
        startup_cost=8e4, scan_cost_per_byte=0.6, row_cost=1.0,
        parallelism=8.0, transfer_cost_per_byte=2.0, fallback_penalty=3.0,
        streams_partitions=False,
        broadcast_join_bytes=float(BROADCAST_BUILD_BYTES)),
}


def capabilities(kind: BackendEngines) -> BackendCapability:
    return CAPABILITIES[kind]


class MemoryBudgetExceeded(RuntimeError):
    def __init__(self, needed: int, budget: int, where: str):
        super().__init__(
            f"memory budget exceeded at {where}: needs {needed/1e6:.1f} MB, "
            f"budget {budget/1e6:.1f} MB")
        self.needed = needed
        self.budget = budget


class MemoryMeter:
    """Deterministic memory accounting for the streaming backend — lets the
    benchmark reproduce the paper's OOM behaviour (Fig. 12) without actually
    exhausting RAM."""

    def __init__(self, budget: int | None):
        self.budget = budget
        self.current = 0
        self.peak = 0

    def alloc(self, nbytes: int, where: str = "?"):
        self.current += int(nbytes)
        self.peak = max(self.peak, self.current)
        if self.budget is not None and self.current > self.budget:
            raise MemoryBudgetExceeded(self.current, self.budget, where)

    def free(self, nbytes: int):
        self.current -= int(nbytes)


def backend_class(kind: BackendEngines):
    if kind == BackendEngines.AUTO:
        raise ValueError(
            "BackendEngines.AUTO is resolved by the planner at force points "
            "(repro.core.planner.select.plan_placement); it is not a "
            "physical backend")
    if kind == BackendEngines.EAGER:
        from .eager import EagerBackend
        return EagerBackend
    if kind == BackendEngines.STREAMING:
        from .streaming import StreamingBackend
        return StreamingBackend
    if kind == BackendEngines.DISTRIBUTED:
        from .distributed import DistributedBackend
        return DistributedBackend
    raise ValueError(kind)


def get_backend(kind: BackendEngines, **options):
    return backend_class(kind)(**options)
