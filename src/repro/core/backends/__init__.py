"""In-tree execution engines (paper §2.6), registered with the open
engine registry (``repro.core.engines``):

* eager       — whole-table, device-resident jnp (the Pandas analogue)
* streaming   — partition-at-a-time host execution, bounded memory, out-of-
                core (the Dask analogue)
* distributed — shard_map over the mesh data axis (the Modin/cluster
                analogue); unsupported ops fall back to eager, mirroring the
                paper's convert-to-Pandas fallback.

Importing this package registers all three under their string names; the
planner derives its candidate set, capabilities, cost constants, and
calibration namespaces from the registry, so out-of-tree engines added via
``repro.register_engine`` (or the ``repro.engines`` entry-point group) are
planned exactly like these.
"""
from __future__ import annotations

from ..engines import (ALL_OPS as _ALL_OPS, BackendCapability,
                       default_registry, normalize_engine)


class MemoryBudgetExceeded(RuntimeError):
    def __init__(self, needed: int, budget: int, where: str):
        super().__init__(
            f"memory budget exceeded at {where}: needs {needed/1e6:.1f} MB, "
            f"budget {budget/1e6:.1f} MB")
        self.needed = needed
        self.budget = budget


class MemoryMeter:
    """Deterministic memory accounting for the streaming backend — lets the
    benchmark reproduce the paper's OOM behaviour (Fig. 12) without actually
    exhausting RAM."""

    def __init__(self, budget: int | None):
        self.budget = budget
        self.current = 0
        self.peak = 0

    def alloc(self, nbytes: int, where: str = "?"):
        self.current += int(nbytes)
        self.peak = max(self.peak, self.current)
        if self.budget is not None and self.current > self.budget:
            raise MemoryBudgetExceeded(self.current, self.budget, where)

    def free(self, nbytes: int):
        self.current -= int(nbytes)


# ---------------------------------------------------------------------------
# Registration.  The engine classes themselves are the factories — the
# registry filters construction options against their signatures.  (The
# imports sit below MemoryMeter on purpose: streaming imports it back from
# this partially-initialized package.)

from .eager import EagerBackend          # noqa: E402
from .streaming import StreamingBackend  # noqa: E402
from .distributed import DistributedBackend  # noqa: E402


def _device_count() -> int:
    try:
        import jax
        return max(1, len(jax.devices()))
    except Exception:  # noqa: BLE001 — planning must never crash
        return 1


def _broadcast_build_bytes() -> float:
    from ..physical.sharded import BROADCAST_BUILD_BYTES
    return float(BROADCAST_BUILD_BYTES)


_REG = default_registry()

_REG.register("eager", EagerBackend, BackendCapability(
    name="eager", native_ops=_ALL_OPS,
    startup_cost=1e3, scan_cost_per_byte=1.0, row_cost=1.0,
    parallelism=4.0, transfer_cost_per_byte=0.5, fallback_penalty=1.0,
    peak_model="resident", scan_pushdown=True),
    source="builtin", replace=True)

_REG.register("streaming", StreamingBackend, BackendCapability(
    name="streaming", native_ops=_ALL_OPS,
    startup_cost=2e3, scan_cost_per_byte=1.5, row_cost=2.0,
    parallelism=1.0, transfer_cost_per_byte=0.0, fallback_penalty=1.0,
    peak_model="chunked", scan_pushdown=True),
    source="builtin", replace=True)

_REG.register("distributed", DistributedBackend, BackendCapability(
    name="distributed",
    native_ops=frozenset({"scan", "materialized", "filter", "project",
                          "assign", "rename", "astype", "fillna",
                          "fused_rowwise", "reduce", "length",
                          "groupby_agg", "join", "sort_values",
                          "drop_duplicates", "head", "sink_print"}),
    # scan models parallel partition ingest across shard workers (cheaper
    # per byte than eager's single-device load), paid for by the highest
    # fixed startup: distributed only wins once tables are large enough
    # to amortize mesh dispatch.  Runtime calibration corrects both.
    startup_cost=8e4, scan_cost_per_byte=0.6, row_cost=1.0,
    parallelism=8.0, transfer_cost_per_byte=2.0, fallback_penalty=3.0,
    peak_model="sharded",
    broadcast_join_bytes=_broadcast_build_bytes(),
    keeps_device_payloads=True, scan_pushdown=True,
    shard_count=_device_count), source="builtin", replace=True)


# ---------------------------------------------------------------------------
# Back-compat surface.  ``CAPABILITIES`` is the registry's live capability
# dict (string-keyed; ``BackendEngines`` members hash/compare equal to the
# names, so legacy enum-keyed lookups — and test monkeypatching — work
# unchanged).

CAPABILITIES = _REG.capabilities


def capabilities(kind) -> BackendCapability:
    return _REG.capability_of(kind)


def backend_class(kind):
    """Deprecated: engine factory lookup by name (kept for callers that
    expect a constructor)."""
    kind = normalize_engine(kind)
    if kind == "auto":
        _REG.create(kind)           # raises the explanatory ValueError
    return _REG.spec(kind).factory


def get_backend(kind, **options):
    return _REG.create(kind, options)
