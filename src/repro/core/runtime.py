"""Execution orchestration: optimize → plan persists → dispatch to backend →
flush sinks in order (paper §2.6).
"""
from __future__ import annotations

from typing import Any

from . import graph as G
from .context import get_context
from .liveness import apply_persist_marks, evict_dead_entries, plan_persists
from .optimizer import optimize


def _live_nodes_from(live_df) -> list[G.Node]:
    if not live_df:
        return []
    nodes = []
    for f in live_df:
        node = getattr(f, "_node", None)
        nodes.append(node if node is not None else f)
    return nodes


def execute(roots: list[G.Node], live_df=None,
            force_reason: str | None = None) -> list[Any]:
    """Force computation of ``roots``.  Any pending lazy sinks are chained in
    front (paper §3.4: forced computation processes pending prints first, in
    order).  Returns materialized values for ``roots``.

    ``force_reason`` labels the force point in ``ctx.force_log`` (user
    compute, len, repr, facade fallback materialization, flush, …) so the
    measured fallback protocol can attribute every execution."""
    ctx = get_context()
    ctx.exec_count += 1
    ctx.force_log.append(force_reason or "compute")
    live_nodes = _live_nodes_from(live_df)

    all_roots = list(roots)
    sink_roots: list[G.Node] = []
    if ctx.last_sink is not None:
        sink_roots = [ctx.last_sink]
        all_roots = sink_roots + all_roots

    # §3.5 reuse: substitute cached subexpressions BEFORE optimization so
    # physical rewrites (column narrowing, dead-assign elimination) can't
    # change the lookup key.
    if ctx.persist_cache:
        from .optimizer import _rebuild
        replace = {}
        for n in G.walk(all_roots):
            if isinstance(n, G.Materialized) or isinstance(n, G.SinkPrint):
                continue
            hit = ctx.persist_cache.get(n.key())
            if hit is not None and isinstance(hit, dict):
                ctx.persist_stats["hits"] += 1
                replace[n.id] = G.Materialized(hit, n.key())
        if replace:
            all_roots, sub_map = _rebuild(all_roots, replace)
            live_nodes = [sub_map.get(n.id, n) for n in live_nodes]
            roots = [sub_map.get(n.id, n) for n in roots]
            if sink_roots:
                sink_roots = [all_roots[0]]

    persist_ids = plan_persists(all_roots, live_nodes)
    apply_persist_marks(all_roots, persist_ids)
    logical_keys = {n.id: n.key() for n in G.walk(all_roots)}

    opt_roots, idmap = optimize(all_roots, ctx)
    # re-mark persists on the rewritten nodes; store under the LOGICAL key
    for old_id in persist_ids:
        if old_id in idmap:
            idmap[old_id].persist = True
            idmap[old_id].cache_key = logical_keys[old_id]

    results, backend_name = _dispatch(opt_roots, ctx)

    # planner feedback (§ runtime optimization): observed cardinalities
    # recalibrate future estimates for repeated plans
    from .planner.feedback import record_execution
    record_execution(opt_roots, results, ctx, backend_name)

    if sink_roots:
        ctx.sinks_flushed()
    # eviction compares LOGICAL keys — use the pre-optimization live nodes
    evict_dead_entries(ctx, live_nodes)

    out = []
    for r in roots:
        rn = idmap.get(r.id, r)
        out.append(_wrap(rn, results[rn.id]))
    return out


def flush():
    """Execute all pending lazy sinks (pd.flush(), paper §3.3)."""
    ctx = get_context()
    if ctx.last_sink is None:
        return
    execute([], None, "flush")


def _wrap(node: G.Node, value):
    from .lazyframe import Result
    if isinstance(node, (G.Reduce, G.Length, G.SinkPrint)):
        return value
    vocab = _collect_vocab(node)
    return Result(value, vocab)


def _collect_vocab(node: G.Node):
    vocab = {}
    for n in G.walk([node]):
        if isinstance(n, G.Scan):
            vocab.update(n.source.dicts)
    return vocab


def _dispatch(opt_roots, ctx):
    """Run the optimized plan: fixed backend, or cost-based AUTO placement
    (plan → select → dispatch, possibly hybrid across root subtrees)."""
    from .backends import get_backend
    from .context import BackendEngines
    if ctx.backend != BackendEngines.AUTO:
        backend = get_backend(ctx.backend, **ctx.backend_options)
        return backend.execute(opt_roots, ctx), backend.name
    from .planner.select import plan_placement
    decisions = plan_placement(opt_roots, ctx)
    ctx.planner_decisions = decisions
    results = {}
    names = []
    for d in decisions:
        try:
            backend = get_backend(d.backend, **ctx.backend_options)
        except TypeError:
            # options meant for another engine (AUTO may pick any)
            backend = get_backend(d.backend)
        results.update(backend.execute(d.roots, ctx))
        names.append(backend.name)
    return results, "+".join(names) or "auto"
