"""Execution orchestration: optimize → plan persists → dispatch to engine →
flush sinks in order (paper §2.6).

Engines are addressed through the open registry (``repro.core.engines``) by
string name; nothing here knows a concrete engine.  Every force point also
appends a typed run record (segments + handoff payloads) consumed by
``repro.core.explain`` / ``pd.explain()``.
"""
from __future__ import annotations

from time import perf_counter
from typing import Any

from . import graph as G
from .context import get_context
from .engines import AUTO, create_engine
from .liveness import apply_persist_marks, evict_dead_entries, plan_persists
from .optimizer import optimize


def _live_nodes_from(live_df) -> list[G.Node]:
    if not live_df:
        return []
    nodes = []
    for f in live_df:
        node = getattr(f, "_node", None)
        nodes.append(node if node is not None else f)
    return nodes


def execute(roots: list[G.Node], live_df=None,
            force_reason: str | None = None) -> list[Any]:
    """Force computation of ``roots``.  Any pending lazy sinks are chained in
    front (paper §3.4: forced computation processes pending prints first, in
    order).  Returns materialized values for ``roots``.

    ``force_reason`` labels the force point in ``ctx.force_log`` (user
    compute, len, repr, facade fallback materialization, flush, …) so the
    measured fallback protocol can attribute every execution."""
    ctx = get_context()
    ctx.exec_count += 1
    ctx.force_log.append(force_reason or "compute")
    live_nodes = _live_nodes_from(live_df)

    # "execute" is the root telemetry span of one force point; a no-op
    # unless a profile is attached to this session's tracer (pd.profile()).
    with ctx.tracer.span("execute", force_reason=force_reason or "compute",
                         engine=ctx.backend) as exec_span:
        all_roots = list(roots)
        sink_roots: list[G.Node] = []
        if ctx.last_sink is not None:
            sink_roots = [ctx.last_sink]
            all_roots = sink_roots + all_roots

        # §3.5 reuse: substitute cached subexpressions BEFORE optimization so
        # physical rewrites (column narrowing, dead-assign elimination) can't
        # change the lookup key.
        if ctx.persist_cache:
            from .optimizer import _rebuild
            replace = {}
            for n in G.walk(all_roots):
                if isinstance(n, G.Materialized) or isinstance(n, G.SinkPrint):
                    continue
                hit = ctx.persist_cache.get(n.key())
                if hit is not None and isinstance(hit, dict):
                    ctx.persist_stats["hits"] += 1
                    replace[n.id] = G.Materialized(hit, n.key())
            if replace:
                all_roots, sub_map = _rebuild(all_roots, replace)
                live_nodes = [sub_map.get(n.id, n) for n in live_nodes]
                roots = [sub_map.get(n.id, n) for n in roots]
                if sink_roots:
                    sink_roots = [all_roots[0]]

        persist_ids = plan_persists(all_roots, live_nodes)
        apply_persist_marks(all_roots, persist_ids)
        walk_nodes = G.walk(all_roots)
        logical_keys = {n.id: n.key() for n in walk_nodes}

        # -- plan cache: a repeated plan shape skips optimize/rewrite and
        # (under AUTO) the segment DP entirely, rebinding the cached
        # optimized plan to this run's sources (planner/plancache.py)
        from .planner import plancache as PC
        cache = (PC.default_plan_cache()
                 if getattr(ctx, "plan_cache_enabled", True) else None)
        ckey = None
        bound = None
        t_plan0 = perf_counter()
        if cache is not None:
            ckey = PC.cache_key(all_roots, ctx, walk=walk_nodes)
            if ckey is None:
                cache.record_uncacheable()
                ctx.metrics.inc("plan_cache.uncacheable")
            else:
                entry = cache.lookup(ckey)
                if entry is not None:
                    bound = entry.bind(walk_nodes)

        ctx._cached_decisions = None
        ctx._place_seconds = 0.0
        plan_cached = bound is not None
        if plan_cached:
            opt_roots, idmap, ctx._cached_decisions = bound
            bind_seconds = perf_counter() - t_plan0
            cache.record_hit(bind_seconds)
            ctx.metrics.inc("plan_cache.hits")
            ctx.last_plan_seconds = bind_seconds
            from ..obs.events import PlannerEvent
            ctx.planner_trace.append(PlannerEvent(
                f"plan-cache: hit fp={ckey[0][:12]} epoch={ckey[1][:8]} "
                f"bind={bind_seconds * 1e3:.2f}ms",
                kind="plan_cache", status="hit",
                fingerprint=ckey[0], epoch=ckey[1],
                bind_seconds=bind_seconds))
        else:
            t_opt0 = perf_counter()
            opt_roots, idmap = optimize(all_roots, ctx)
            ctx._opt_seconds = perf_counter() - t_opt0
        ctx._last_plan_cached = plan_cached
        # re-mark persists on the rewritten nodes; store under the LOGICAL key
        for old_id in persist_ids:
            if old_id in idmap:
                idmap[old_id].persist = True
                idmap[old_id].cache_key = logical_keys[old_id]

        results, backend_name = _dispatch(opt_roots, ctx)
        exec_span.set(executed=backend_name)

        if not plan_cached:
            ctx.last_plan_seconds = ctx._opt_seconds + ctx._place_seconds
        if cache is not None and ckey is not None and not plan_cached:
            plan_seconds = ctx.last_plan_seconds
            decisions = (list(ctx.planner_decisions)
                         if ctx.backend == AUTO else None)
            cache.store(PC.CachedPlan.build(
                ckey, walk_nodes, opt_roots, idmap, decisions, plan_seconds))
            cache.record_miss(plan_seconds)
            ctx.metrics.inc("plan_cache.misses")
            from ..obs.events import PlannerEvent
            ctx.planner_trace.append(PlannerEvent(
                f"plan-cache: miss fp={ckey[0][:12]} epoch={ckey[1][:8]} "
                f"plan={plan_seconds * 1e3:.2f}ms",
                kind="plan_cache", status="miss",
                fingerprint=ckey[0], epoch=ckey[1],
                plan_seconds=plan_seconds))

        # planner feedback (§ runtime optimization): observed cardinalities
        # recalibrate future estimates for repeated plans
        from .planner.feedback import record_execution
        record_execution(opt_roots, results, ctx, backend_name)
        # typed run record (segments + handoffs) for pd.explain()
        from .explain import record_run
        record_run(ctx, force_reason or "compute", backend_name, opt_roots)
        if getattr(ctx, "stats_path", None):
            ctx.stats_store.save(ctx.stats_path)

    if sink_roots:
        ctx.sinks_flushed()
    # eviction compares LOGICAL keys — use the pre-optimization live nodes
    evict_dead_entries(ctx, live_nodes)

    out = []
    for r in roots:
        rn = idmap.get(r.id, r)
        out.append(_wrap(rn, results[rn.id]))
    return out


def flush():
    """Execute all pending lazy sinks (pd.flush(), paper §3.3)."""
    ctx = get_context()
    if ctx.last_sink is None:
        return
    execute([], None, "flush")


def _wrap(node: G.Node, value):
    from .lazyframe import Result
    if isinstance(node, (G.Reduce, G.Length, G.SinkPrint)):
        return value
    vocab = _collect_vocab(node)
    return Result(value, vocab)


def _collect_vocab(node: G.Node):
    vocab = {}
    for n in G.walk([node]):
        if isinstance(n, G.Scan):
            vocab.update(n.source.dicts)
    return vocab


def _dispatch(opt_roots, ctx):
    """Run the optimized plan: fixed engine, or cost-based AUTO placement
    (plan → select → chain engine segments through Handoff pipe breakers).

    Spans are the single timing instrumentation point: every engine run
    executes inside a ``timed_span`` whose duration feeds the planner's
    cost calibration (``StatsStore.record_runtime``) — and, when a profile
    is attached, lands in the profile's span tree."""
    engine = ctx.backend
    if engine != AUTO:
        backend = create_engine(engine, ctx.backend_options)
        ctx.planner_decisions = []
        with ctx.tracer.timed_span("segment", engine=backend.name,
                                   segment=0) as sp:
            results = backend.execute(opt_roots, ctx)
        ctx._last_segment_spans = {0: sp.id}
        _record_runtime_sample(opt_roots, ctx, engine, backend.name, sp)
        return results, backend.name
    decisions = getattr(ctx, "_cached_decisions", None)
    if decisions is None:
        from .planner.select import plan_placement
        t_place0 = perf_counter()
        with ctx.tracer.span("plan", engine=AUTO) as psp:
            decisions = plan_placement(opt_roots, ctx)
            psp.set(segments=len(decisions))
        ctx._place_seconds = perf_counter() - t_place0
    ctx.planner_decisions = decisions
    return execute_segments(decisions, ctx,
                            final_root_ids={r.id for r in opt_roots})


def execute_segments(decisions, ctx, final_root_ids=frozenset()):
    """Run planner segments in topological order, chaining boundary values
    through ``Handoff`` leaves.

    Boundary payloads are host-normalized (the transfer the cost model
    charges) — except when the producing segment *and every consumer* of a
    value run on the same engine and that engine keeps device-resident
    payloads (``supports_device_handoff``): then the payload stays on
    device and the consuming segment uses it in place, so same-engine
    chains never re-materialize from host.  Each kept payload is recorded
    in ``ctx.planner_trace`` (``payload=<type>``) and as a typed handoff
    event for ``pd.explain()``.

    ``final_root_ids`` are plan roots the caller will unwrap: those are
    always gathered to host values."""
    from . import physical as X
    from ..obs.events import PlannerEvent
    from ..obs.spans import bytes_of
    results: dict[int, object] = {}
    names: list[str] = []
    produced: dict[int, object] = {}     # original node id -> handoff payload
    handoff_events: list[dict] = []
    segment_spans: dict[int, int] = {}   # segment index -> span id
    tracer = ctx.tracer
    # who consumes each cross-segment value, by engine
    consumers: dict[int, set] = {}
    for d in decisions:
        for b in d.boundary:
            consumers.setdefault(b.id, set()).add(d.backend)
    for si, d in enumerate(decisions):
        backend = create_engine(d.backend, ctx.backend_options)
        seg_roots = _segment_subgraph(d, produced)
        device_resident: set[int] = set()
        if getattr(backend, "supports_device_handoff", False):
            device_resident = {
                orig.id for orig in d.roots
                if orig.id not in final_root_ids
                and consumers.get(orig.id)
                and all(c == d.backend for c in consumers[orig.id])}
        keep = frozenset(new.id for orig, new in zip(d.roots, seg_roots)
                         if orig.id in device_resident)
        with tracer.timed_span("segment", engine=backend.name, segment=si,
                               est_work=d.cost.total) as sp:
            if keep:
                vals = backend.execute(seg_roots, ctx, keep_sharded=keep)
            else:
                vals = backend.execute(seg_roots, ctx)
        segment_spans[si] = sp.id
        raw_est_peak = (d.cost.raw_peak_bytes
                        if d.cost.raw_peak_bytes is not None
                        else d.cost.peak_bytes)
        _record_calibration(ctx, backend.name, d.cost.total,
                            raw_est_peak, sp)
        for orig, new in zip(d.roots, seg_roots):
            v = vals[new.id]
            results[orig.id] = v
            is_boundary = bool(consumers.get(orig.id))
            if orig.id in device_resident:
                produced[orig.id] = v        # device payload, stays resident
                ctx.planner_trace.append(PlannerEvent(
                    f"auto: handoff #{orig.id} seg{si} "
                    f"payload={type(v).__name__} device-resident "
                    f"({d.cost.backend}->{d.cost.backend})",
                    kind="handoff", node_id=orig.id, segment=si,
                    payload=type(v).__name__, device_resident=True,
                    producer=str(d.cost.backend)))
                tracer.event("handoff", node_id=orig.id, segment=si,
                             device_resident=True, bytes_moved=0,
                             payload=type(v).__name__)
            elif is_boundary:
                with tracer.span("handoff", node_id=orig.id, segment=si,
                                 device_resident=False) as hsp:
                    produced[orig.id] = X.to_host_value(v)
                    if hsp:
                        hsp.set(
                            bytes_moved=bytes_of(produced[orig.id]),
                            payload=type(produced[orig.id]).__name__)
            else:
                produced[orig.id] = X.to_host_value(v)
            if is_boundary:
                payload = produced[orig.id]
                handoff_events.append({
                    "node_id": orig.id, "segment": si,
                    "payload_kind": ("table" if isinstance(payload, dict)
                                     else type(payload).__name__),
                    "device_resident": orig.id in device_resident,
                    "producer": d.cost.backend,
                    "consumers": tuple(sorted(consumers[orig.id]))})
        if backend.name not in names:
            names.append(backend.name)
    ctx._last_handoff_events = handoff_events
    ctx._last_segment_spans = segment_spans
    return results, "+".join(names) or AUTO


def _segment_subgraph(d, produced: dict[int, object]) -> list[G.Node]:
    """Rebuild one planner segment for execution: inputs living in other
    segments are replaced by ``Handoff`` leaves carrying the value the
    producing segment already materialized."""
    if not d.boundary:
        return list(d.roots)
    seg_ids = {n.id for n in d.nodes}
    memo: dict[int, G.Node] = {}

    def rec(n: G.Node) -> G.Node:
        if n.id in memo:
            return memo[n.id]
        if n.id not in seg_ids:
            key = getattr(n, "cache_key", None)
            if key is None:
                try:
                    key = n.key()
                except Exception:  # noqa: BLE001 — side-effect nodes key on id
                    key = ("handoff", n.id)
            out = G.Handoff(produced[n.id], key, producer=n.op)
        else:
            new_inputs = [rec(i) for i in n.inputs]
            if all(a is b for a, b in zip(new_inputs, n.inputs)):
                out = n
            else:
                out = G.copy_runtime_flags(n, n.with_inputs(new_inputs))
        memo[n.id] = out
        return out

    return [rec(r) for r in d.roots]


def _record_calibration(ctx, backend_name: str, est_total, raw_est_peak,
                        span) -> None:
    """THE single feed into ``StatsStore``: pair a finished engine span's
    wall time with the plan's estimated work (runtime calibration), and —
    when the engine metered its own peak — the observed peak with the
    estimated one (peak calibration)."""
    store = getattr(ctx, "stats_store", None)
    if store is None:
        return
    metrics = getattr(ctx, "metrics", None)
    store.record_runtime(backend_name, est_total, span.duration)
    if metrics is not None:
        metrics.inc("calibration.runtime_samples")
    observed_peak = getattr(ctx, "last_run_peak_bytes", 0)
    if (observed_peak and getattr(ctx, "last_run_peak_engine", None)
            == backend_name):
        span.set(peak_bytes=observed_peak)
        store.record_peak(backend_name, observed_peak, est_peak=raw_est_peak)
        if metrics is not None:
            metrics.inc("calibration.peak_samples")


def _record_runtime_sample(opt_roots, ctx, kind, backend_name: str,
                           span) -> None:
    """Calibration sample for a fixed-engine run: estimate the plan's work
    with the a-priori cost model and pair it with the span's wall time.
    Best-effort — estimation failures never affect execution."""
    store = getattr(ctx, "stats_store", None)
    if store is None:
        return
    # once an engine is well-sampled, only refresh every 8th force point —
    # plan estimation is metadata arithmetic, but sessions with many tiny
    # fixed-engine force points shouldn't pay it each time
    samples = store.runtime_samples.get(backend_name, ())
    if len(samples) >= 16 and ctx.exec_count % 8:
        return
    try:
        from .planner.cost import plan_cost
        from .planner.stats import estimate_plan
        stats = estimate_plan(opt_roots, ctx)
        est = plan_cost(opt_roots, stats, kind,
                        ctx.backend_options.get("chunk_rows", 1 << 16))
        span.set(est_work=est.total)
        _record_calibration(ctx, backend_name, est.total, est.peak_bytes,
                            span)
    except Exception:  # noqa: BLE001 — calibration is advisory
        pass
