"""Open engine registry: the pluggable-backend core of the facade.

The paper's pitch is that the two-line facade lets the programmer *choose
the backend* per workload; PolyFrame argues dataframe scaling should be
retargetable to new engines rather than baked into one.  This module makes
that concrete: engines are **string-named** entries in a process-wide
registry, each described by a :class:`BackendCapability` the planner prices
against — so adding a fourth engine means registering it, not editing the
planner.

Three ways an engine enters the registry:

* **built-in** — ``repro.core.backends`` registers the in-tree engines on
  import (the registry bootstraps that import lazily);
* **runtime** — ``repro.register_engine(name, factory, capability)`` from
  any code, e.g. a notebook or a test;
* **entry points** — installed distributions exposing the
  ``repro.engines`` entry-point group are loaded on first registry use;
  each entry point must resolve to a zero-argument callable that performs
  its own ``register_engine`` call.

The ``Engine`` runtime protocol is intentionally small:

* ``name`` — the registry key, also the stats-store / calibration
  namespace (``StatsStore.record_runtime(name, ...)``);
* ``execute(roots, ctx)`` — evaluate a list of ``graph.Node`` roots to
  ``{node_id: value}`` host values (tables are ``dict[str, ndarray]``);
* ``execute(roots, ctx, keep_sharded=...)`` — only for engines that set
  ``supports_device_handoff = True`` (capability flag
  ``keeps_device_payloads``): roots named in ``keep_sharded`` may stay
  device-resident and flow to the next same-engine segment through
  ``graph.Handoff`` without a host round-trip.

``"auto"`` is a reserved name: it is resolved by the cost-based planner,
never constructed.
"""
from __future__ import annotations

import dataclasses
import inspect
import threading
import warnings
from typing import Any, Callable, Protocol, runtime_checkable

AUTO = "auto"

# every operator the task graph can contain; engines declare the subset
# they run natively (the rest is priced via the fallback penalty)
ALL_OPS = frozenset({
    "scan", "materialized", "filter", "project", "assign", "rename",
    "astype", "fillna", "fused_rowwise", "sort_values", "drop_duplicates",
    "head", "top_k", "map_rows", "groupby_agg", "join", "concat", "reduce",
    "length", "sink_print",
})


@dataclasses.dataclass(frozen=True)
class BackendCapability:
    """Planner-facing self-description of one engine.

    ``peak_model`` names the peak-memory model the cost layer applies:

    * ``"resident"`` — whole-table execution; peak follows a refcounted
      topological walk of estimated output sizes.
    * ``"chunked"``  — partition-at-a-time execution; peak is chunk-sized
      flow plus pipeline-breaker state.
    * ``"sharded"``  — resident peak divided across ``shard_count()``
      shards while every operator is native and no host-materialized
      boundary forces a single-host gather.
    """
    name: str
    native_ops: frozenset               # ops with a first-class implementation
    startup_cost: float                 # fixed per-force-point dispatch cost
    scan_cost_per_byte: float           # reading source bytes
    row_cost: float                     # per-row per-operator compute
    parallelism: float                  # effective divisor on row work
    transfer_cost_per_byte: float       # host<->device / gather movement
    fallback_penalty: float             # multiplier for non-native ops
    peak_model: str = "resident"        # "resident" | "chunked" | "sharded"
    # joins are costed by *build side*: builds at or below this many bytes
    # replicate cheaply (broadcast-hash); larger builds pay an all-to-all
    # shuffle of both sides.  0.0 → the engine has no exchange-based join.
    broadcast_join_bytes: float = 0.0
    # True → the engine can hand ``Handoff`` payloads to a same-engine
    # consumer segment device-resident (no host gather at the boundary)
    keeps_device_payloads: bool = False
    # True → the engine executes ``Scan.pushdown`` (pushed-down filter
    # conjuncts evaluated at load time — e.g. via the shared
    # ``repro.io.scan`` loader).  The optimizer only sinks predicates into
    # scans when every engine the plan could land on declares this;
    # engines that ignore the attribute would silently drop the filter.
    scan_pushdown: bool = False
    # shard count used by the "sharded" peak model (None → 1)
    shard_count: Callable[[], int] | None = None

    @property
    def streams_partitions(self) -> bool:
        """Deprecated alias for ``peak_model == "chunked"``."""
        return self.peak_model == "chunked"


@runtime_checkable
class Engine(Protocol):
    """Runtime protocol every registered engine factory must produce."""

    name: str

    def execute(self, roots: list, ctx) -> dict[int, Any]:
        ...


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    name: str
    factory: Callable[..., Any]         # class or callable returning an Engine
    capability: BackendCapability
    source: str = "registered"          # "builtin" | "registered" | "entry-point"


class UnknownEngineError(ValueError):
    pass


def normalize_engine(value, *, warn_enum: bool = False) -> str | None:
    """Engine argument → canonical string name.

    Accepts plain strings (the redesigned API) and, as a deprecated alias
    layer, ``BackendEngines`` members (a ``str``-mixin enum whose ``value``
    is the engine name)."""
    if value is None:
        return None
    import enum
    if isinstance(value, enum.Enum):
        if warn_enum:
            warnings.warn(
                "BackendEngines members are deprecated; pass engine name "
                f"strings instead (engine={value.value!r})",
                DeprecationWarning, stacklevel=3)
        value = value.value
    if not isinstance(value, str):
        raise TypeError(
            "engine must be a string name (or a deprecated BackendEngines "
            f"member), got {value!r}")
    return value.lower()


class EngineRegistry:
    """Process-wide registry of named engines.

    ``capabilities`` is a live, string-keyed dict — the planner reads it on
    every pricing call, so tests may patch entries in place."""

    def __init__(self):
        self._specs: dict[str, EngineSpec] = {}
        self.capabilities: dict[str, BackendCapability] = {}
        self._lock = threading.RLock()
        self._bootstrapped = False
        self._bootstrapping = False
        self._entry_points_loaded = False
        self._loading_entry_points = False

    # -- population ---------------------------------------------------------

    def register(self, name: str, factory: Callable[..., Any],
                 capability: BackendCapability, *,
                 source: str = "registered", replace: bool = False) -> EngineSpec:
        name = normalize_engine(name)
        if name == AUTO:
            raise ValueError(
                f"{AUTO!r} is reserved for the cost-based planner")
        if capability.name != name:
            capability = dataclasses.replace(capability, name=name)
        if self._loading_entry_points and source == "registered":
            source = "entry-point"
        with self._lock:
            if name in self._specs and not replace:
                raise ValueError(
                    f"engine {name!r} is already registered "
                    "(pass replace=True to override)")
            spec = EngineSpec(name, factory, capability, source)
            self._specs[name] = spec
            self.capabilities[name] = capability
            return spec

    def unregister(self, name: str) -> None:
        name = normalize_engine(name)
        with self._lock:
            self._specs.pop(name, None)
            self.capabilities.pop(name, None)

    def _bootstrap(self) -> None:
        if self._bootstrapped:
            return
        with self._lock:
            # flag flips only AFTER the import completes: a second thread
            # must block on the lock until the built-ins exist, not sail
            # through the fast path into an empty registry.  The separate
            # in-progress flag breaks same-thread re-entrancy (the backends
            # import can call back into the registry under this RLock).
            if self._bootstrapped or self._bootstrapping:
                return
            self._bootstrapping = True
            try:
                import repro.core.backends  # noqa: F401 — registers built-ins
                self.load_entry_points()
            finally:
                self._bootstrapping = False
            self._bootstrapped = True

    def load_entry_points(self) -> None:
        """Discover installed plug-in engines (``repro.engines`` group).
        Each entry point resolves to a zero-arg callable that registers
        itself.  A broken plug-in warns; it never breaks the host."""
        if self._entry_points_loaded:
            return
        self._entry_points_loaded = True
        try:
            from importlib.metadata import entry_points
            eps = entry_points()
            group = (eps.select(group="repro.engines")
                     if hasattr(eps, "select")
                     else eps.get("repro.engines", []))
        except Exception:  # noqa: BLE001 — discovery is best-effort
            return
        self._loading_entry_points = True
        try:
            for ep in group:
                try:
                    hook = ep.load()
                    if callable(hook):
                        hook()
                except Exception as e:  # noqa: BLE001 — plug-in bug, not ours
                    warnings.warn(
                        f"failed to load engine plug-in {ep.name!r}: "
                        f"{type(e).__name__}: {e}", RuntimeWarning)
        finally:
            self._loading_entry_points = False

    # -- lookup -------------------------------------------------------------

    def names(self) -> tuple[str, ...]:
        self._bootstrap()
        return tuple(self._specs)

    def spec(self, name) -> EngineSpec:
        self._bootstrap()
        name = normalize_engine(name)
        try:
            return self._specs[name]
        except KeyError:
            raise UnknownEngineError(
                f"unknown engine {name!r}; registered engines: "
                f"{list(self._specs)}") from None

    def capability_of(self, name) -> BackendCapability:
        self._bootstrap()
        name = normalize_engine(name)
        try:
            return self.capabilities[name]
        except KeyError:
            raise UnknownEngineError(
                f"unknown engine {name!r}; registered engines: "
                f"{list(self.capabilities)}") from None

    def create(self, name, options: dict | None = None):
        """Instantiate an engine, passing only the options its factory
        accepts (session ``backend_options`` mix per-engine knobs with
        planner-level ones — a factory must neither crash on foreign keys
        nor lose its own)."""
        name = normalize_engine(name)
        if name == AUTO:
            raise ValueError(
                f"{AUTO!r} is resolved by the planner at force points "
                "(repro.core.planner.select.plan_placement); it is not a "
                "physical engine")
        spec = self.spec(name)
        factory = spec.factory
        options = options or {}
        if not options:
            return factory()
        target = factory.__init__ if inspect.isclass(factory) else factory
        try:
            params = inspect.signature(target).parameters
        except (TypeError, ValueError):      # C callables without signatures
            return factory()
        if any(p.kind == inspect.Parameter.VAR_KEYWORD
               for p in params.values()):
            return factory(**options)
        return factory(**{k: v for k, v in options.items() if k in params})


_REGISTRY = EngineRegistry()


def default_registry() -> EngineRegistry:
    return _REGISTRY


# -- module-level convenience API (re-exported as ``repro.register_engine``
# and from ``repro.pandas``) -------------------------------------------------


def register_engine(name: str, factory: Callable[..., Any],
                    capability: BackendCapability, *,
                    replace: bool = False) -> EngineSpec:
    """Register a new execution engine under ``name``.

        repro.register_engine(
            "pool", PoolEngine,
            BackendCapability(name="pool", native_ops=..., ...))

    After registration the engine is addressable everywhere an engine name
    is accepted — ``pd.session(engine="pool")``, ``pd.BACKEND_ENGINE =
    "pool"`` — and it becomes an AUTO candidate priced (and runtime-
    calibrated) like the built-ins."""
    return _REGISTRY.register(name, factory, capability, replace=replace)


def unregister_engine(name: str) -> None:
    _REGISTRY.unregister(name)


def engine_names() -> tuple[str, ...]:
    """Registered engine names, in registration order."""
    return _REGISTRY.names()


def get_capability(name) -> BackendCapability:
    return _REGISTRY.capability_of(name)


def create_engine(name, options: dict | None = None):
    return _REGISTRY.create(name, options)
