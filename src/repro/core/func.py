"""lazyfatpandas.func analogue (paper §3.3): lazy print / lazy len / flush.

``from repro.core.func import print`` shadows the builtin with the lazy
version; non-lazy arguments pass straight through to the real print at flush
time, in program order.
"""
from __future__ import annotations

import builtins

from .context import get_context
from .runtime import flush as _flush
from .sinks import make_print

_builtin_print = builtins.print
_builtin_len = builtins.len


def print(*args, **kwargs):  # noqa: A001 — deliberate shadow
    """Lazy print: adds a sink node to the task graph (ordering edge keeps
    output order); computation is deferred until a force point or flush()."""
    make_print(args, get_context())
    return None


def len(obj):  # noqa: A001
    from . import graph as G
    from .lazyframe import LazyFrame, LazyScalar
    if isinstance(obj, LazyFrame):
        return LazyScalar(G.Length(obj._node))
    return _builtin_len(obj)


def flush():
    """Force all pending lazy sinks (pd.flush(), inserted automatically at
    program end by the paper's rewriter; we expose it and also flush at
    interpreter exit)."""
    _flush()


# auto-flush at interpreter exit so user programs don't lose output
import atexit  # noqa: E402

atexit.register(_flush)
