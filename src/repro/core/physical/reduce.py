"""Whole-column reductions to scalars, plus the partial forms the streaming
backend combines across partitions."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from .table import Table, table_rows, xp_of
from ...obs.spans import traced_op


@traced_op("reduce")
def apply_reduce(table: Table, column: str | None, fn: str):
    xp = xp_of(table)
    if fn == "count":
        return table_rows(table) if column is None else int(table[column].shape[0])
    vals = table[column]
    if xp is jnp and vals.dtype.kind in "iub" and vals.dtype.itemsize < 4:
        vals = vals.astype(jnp.int32)   # widen: no int8 accumulation
    if fn == "sum":
        return xp.sum(vals)
    if fn == "mean":
        return xp.mean(vals.astype(xp.float64 if xp is np else jnp.float32))
    if fn == "min":
        return xp.min(vals)
    if fn == "max":
        return xp.max(vals)
    if fn == "nunique":
        return int(xp.unique(vals).shape[0])
    if fn == "median":
        # pandas skipna semantics; float64 on host like mean (jnp computes
        # in its native f32 precision)
        if vals.shape[0] == 0:
            return float("nan")
        if xp is np:
            return float(np.nanmedian(vals.astype(np.float64)))
        return jnp.nanmedian(vals.astype(jnp.float32))
    raise ValueError(fn)


REDUCE_PARTIAL = {
    "sum": ("sum", lambda xs, xp: xp.sum(xp.asarray(xs))),
    "min": ("min", lambda xs, xp: xp.min(xp.asarray(xs))),
    "max": ("max", lambda xs, xp: xp.max(xp.asarray(xs))),
    "count": ("count", lambda xs, xp: int(np.sum(xs))),
}
