"""ShardedTable — the distributed backend's binding of the table protocol —
plus *native* distributed join, sort, and distinct.

Physical model: columns are ``(n_shards, rows)`` device-sharded arrays over
the mesh ``data`` axis with a validity mask (fixed per-shard row count so
shapes stay static for XLA).

Native operators (previously eager fallbacks):

* join — **broadcast-hash** when the build side is small with unique keys:
  the build table is replicated, the probe side binary-searches the sorted
  build key codes entirely on device, and the output keeps the probe's
  shard layout (shape-preserving: validity-mask update + payload gather).
  Otherwise **shuffle-by-dict-code**: both sides are exchanged so equal key
  codes co-locate (``code % n_shards``), each shard runs the host hash-join
  kernel on its bucket, and an order-restoring exchange by probe row id
  reproduces the exact pandas (probe-order) output.
* sort — range partition by sampled splitters on the primary key, local
  stable lexsort per shard; shard-major gather order is globally sorted.
* distinct — shuffle by key code so duplicates co-locate, local keep-first
  by global row id, order-restoring exchange.

The exchanges are host-mediated here (on a CPU mesh every shard is
host-backed anyway); on a real multi-host mesh they correspond to all-to-all
collectives.  Native paths require integer (dictionary-coded) key columns —
the metadata store guarantees this for category columns; anything else
returns ``None`` and the caller falls back to the eager kernel.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .join import apply_join
from .sort import apply_drop_duplicates
from ...obs.spans import metric_inc, traced_op

# build sides at or below this many bytes replicate to every shard
# (broadcast-hash join); larger builds go through the shuffle exchange
BROADCAST_BUILD_BYTES = 4 << 20

_ROWID = "__lafp_rowid"


class ShardedTable:
    """(n_shards, rows) column arrays + validity mask, device-sharded."""

    def __init__(self, cols: dict[str, jax.Array], valid: jax.Array):
        self.cols = cols
        self.valid = valid  # (n_shards, rows) bool

    @property
    def n_shards(self) -> int:
        return int(self.valid.shape[0])

    def rows(self) -> int:
        """Valid (unpadded) row count across all shards."""
        return int(jnp.sum(self.valid))

    def nbytes(self) -> int:
        return sum(int(v.nbytes) for v in self.cols.values())

    def gather(self) -> dict[str, np.ndarray]:
        mask = np.asarray(self.valid).reshape(-1)
        return {k: np.asarray(v).reshape(-1)[mask] for k, v in self.cols.items()}


@traced_op("sharded_head")
def sharded_head(t: ShardedTable, n: int) -> ShardedTable:
    """Native distributed ``head(n)``: keep the first ``n`` valid rows in
    partition-major order by masking — no gather, no re-shard.

    Row order is the flattened ``(shard, row)`` order (how
    ``shard_host_table`` laid the table out), so a global running count of
    valid rows identifies exactly the leading-shard prefix; trailing shards
    end up fully masked and the table stays device-resident and
    shape-preserving for downstream sharded operators."""
    flat = jnp.cumsum(t.valid.reshape(-1).astype(jnp.int32))
    keep = (flat <= n).reshape(t.valid.shape) & t.valid
    return ShardedTable(dict(t.cols), keep)


# ---------------------------------------------------------------------------
# Host <-> shard layout


@traced_op("shard_host_table")
def shard_host_table(full: dict[str, np.ndarray], mesh, axis: str
                     ) -> ShardedTable:
    """Pad a host table to a fixed per-shard row count and device-shard it."""
    S = mesh.shape[axis]
    rows = len(next(iter(full.values()))) if full else 0
    per = -(-max(rows, 1) // S)
    pad = S * per - rows
    valid = np.arange(S * per) < rows
    sharding = NamedSharding(mesh, P(axis))
    cols = {}
    for c, v in full.items():
        v = np.asarray(v)
        vp = np.concatenate([v, np.zeros(pad, v.dtype)]) if pad else v
        cols[c] = jax.device_put(vp.reshape(S, per), sharding)
    vmask = jax.device_put(valid.reshape(S, per), sharding)
    return ShardedTable(cols, vmask)


def _host_shards(t: ShardedTable) -> tuple[list[dict], list[np.ndarray], int]:
    """Per-shard host tables (valid rows only) plus global row ids.

    Global row id == position in ``gather()`` order, so restoring ascending
    row-id order after an exchange reproduces the pre-exchange row order."""
    cols = {k: np.asarray(v) for k, v in t.cols.items()}
    valid = np.asarray(t.valid)
    parts, rowids = [], []
    offset = 0
    for s in range(valid.shape[0]):
        m = valid[s]
        n = int(m.sum())
        parts.append({k: v[s][m] for k, v in cols.items()})
        rowids.append(offset + np.arange(n, dtype=np.int64))
        offset += n
    return parts, rowids, offset


def _restack(parts: list[dict[str, np.ndarray]], mesh, axis: str,
             template: dict[str, np.dtype]) -> ShardedTable:
    """Stack per-shard host tables (ragged row counts) back into a padded
    device-sharded layout.  ``template`` supplies dtypes for empty shards."""
    S = mesh.shape[axis]
    assert len(parts) == S, (len(parts), S)
    lens = [len(next(iter(p.values()))) if p else 0 for p in parts]
    per = max(max(lens), 1)
    sharding = NamedSharding(mesh, P(axis))
    cols = {}
    for c, dt in template.items():
        stacked = np.zeros((S, per), dtype=dt)
        for s, p in enumerate(parts):
            if lens[s]:
                stacked[s, : lens[s]] = p[c]
        cols[c] = jax.device_put(stacked, sharding)
    valid = np.zeros((S, per), dtype=bool)
    for s, n in enumerate(lens):
        valid[s, :n] = True
    return ShardedTable(cols, jax.device_put(valid, sharding))


def _template(table: dict) -> dict[str, np.dtype]:
    return {k: np.asarray(v[:0]).dtype if hasattr(v, "__getitem__")
            else np.asarray(v).dtype for k, v in table.items()}


# ---------------------------------------------------------------------------
# Key coding: dictionary-coded (integer) key columns combine into one int64
# code via mixed radix over the union of both sides' value ranges, so equal
# tuples get equal codes with no cross-shard factorization pass.


def _int_keys(table_cols: dict, on: Sequence[str]) -> bool:
    for c in on:
        arr = table_cols.get(c)
        if arr is None or np.dtype(arr.dtype).kind not in "iu":
            return False
    return True


def _key_ranges(host_tables: list[dict], dev: ShardedTable | None,
                on: Sequence[str]) -> dict[str, tuple[int, int]] | None:
    """Per-key (min, max) over every participating table; None if any side
    has no rows to bound the range with."""
    ranges: dict[str, tuple[int, int]] = {}
    for c in on:
        los, his = [], []
        for t in host_tables:
            arr = np.asarray(t[c])
            if arr.size:
                los.append(int(arr.min()))
                his.append(int(arr.max()))
        if dev is not None and dev.rows():
            k = dev.cols[c]
            big = jnp.iinfo(k.dtype).max
            small = jnp.iinfo(k.dtype).min
            los.append(int(jnp.min(jnp.where(dev.valid, k, big))))
            his.append(int(jnp.max(jnp.where(dev.valid, k, small))))
        if not los:
            return None
        ranges[c] = (min(los), max(his))
    return ranges


def _combined_radix(ranges: dict[str, tuple[int, int]],
                    on: Sequence[str]) -> list[tuple[int, int]] | None:
    """(offset, radix) per key column; None when the mixed-radix product
    overflows the device integer width (x32 mode → int32)."""
    out = []
    prod = 1
    for c in on:
        lo, hi = ranges[c]
        radix = hi - lo + 1
        prod *= radix
        out.append((lo, radix))
    if prod > (1 << 31) - 1:
        return None
    return out


def _host_code(table: dict, on: Sequence[str],
               spec: list[tuple[int, int]]) -> np.ndarray:
    code = np.zeros(len(np.asarray(table[on[0]])), np.int64)
    for c, (lo, radix) in zip(on, spec):
        code = code * radix + (np.asarray(table[c]).astype(np.int64) - lo)
    return code


def _device_code(t: ShardedTable, on: Sequence[str],
                 spec: list[tuple[int, int]]) -> jax.Array:
    code = jnp.zeros(t.valid.shape, jnp.int32)
    for c, (lo, radix) in zip(on, spec):
        code = code * radix + (t.cols[c].astype(jnp.int32) - lo)
    return code


# ---------------------------------------------------------------------------
# Native distributed join


@traced_op("sharded_join")
def sharded_join(probe: ShardedTable, build: dict, on: Sequence[str],
                 how: str, suffixes, mesh, axis: str) -> ShardedTable | None:
    """Join with the probe side device-resident.  ``build`` is a host table
    (a gathered/handoff/materialized right side).  Returns ``None`` when no
    native path applies — the caller falls back to the eager kernel."""
    on = list(on)
    if how not in ("inner", "left"):
        return None
    build = {k: np.asarray(v) for k, v in build.items()}
    if not (_int_keys(probe.cols, on) and _int_keys(build, on)):
        return None
    build_rows = len(next(iter(build.values()))) if build else 0
    if build_rows == 0 or probe.rows() == 0:
        return None
    ranges = _key_ranges([build], probe, on)
    if ranges is None:
        return None
    spec = _combined_radix(ranges, on)
    if spec is None:
        return None
    bcode = _host_code(build, on, spec)
    build_nbytes = sum(int(v.nbytes) for v in build.values())
    unique_build = np.unique(bcode).shape[0] == build_rows
    if unique_build and build_nbytes <= BROADCAST_BUILD_BYTES:
        pcode = _device_code(probe, on, spec)
        return _broadcast_hash_join(probe, pcode, build, bcode, on, how,
                                    suffixes)
    return _shuffle_join(probe, build, bcode, on, how, suffixes, spec,
                         mesh, axis)


def _broadcast_hash_join(probe: ShardedTable, pcode: jax.Array, build: dict,
                         bcode: np.ndarray, on, how, suffixes
                         ) -> ShardedTable:
    """Shape-preserving probe: replicate the (small, unique-key) build side,
    binary-search its sorted key codes on device, and emit the probe layout
    with gathered payload columns and an updated validity mask.  Never
    touches host memory for the probe side."""
    order = np.argsort(bcode, kind="stable")
    bsorted = jnp.asarray(bcode[order].astype(np.int32))
    B = int(bsorted.shape[0])
    idx = jnp.searchsorted(bsorted, pcode.astype(jnp.int32))
    idx_c = jnp.clip(idx, 0, B - 1)
    matched = (idx < B) & (jnp.take(bsorted, idx_c) == pcode)
    overlap = (set(probe.cols) & set(build)) - set(on)
    out: dict[str, jax.Array] = {}
    for k in on:
        out[k] = probe.cols[k]
    for k, v in probe.cols.items():
        if k in on:
            continue
        out[k + suffixes[0] if k in overlap else k] = v
    for k, v in build.items():
        if k in on:
            continue
        name = k + suffixes[1] if k in overlap else k
        col_sorted = jnp.asarray(v[order])
        taken = jnp.take(col_sorted, idx_c)
        if how == "left":
            if v.dtype.kind == "f":
                taken = jnp.where(matched, taken, jnp.nan)
            else:
                # mirror the host kernel: unmatched rows read build row 0
                taken = jnp.where(matched, taken, jnp.asarray(v[0]))
        out[name] = taken
    valid = probe.valid & matched if how == "inner" else probe.valid
    return ShardedTable(out, valid)


def _shuffle_join(probe: ShardedTable, build: dict, bcode: np.ndarray,
                  on, how, suffixes, spec, mesh, axis: str) -> ShardedTable:
    """Exchange both sides by key code so equal keys co-locate, run the host
    hash-join kernel per shard, then restore probe-row order by a second
    exchange on the carried global row id."""
    S = mesh.shape[axis]
    metric_inc("exchange.shuffles")
    metric_inc("exchange.shards", S)
    parts, rowids, total = _host_shards(probe)
    # exchange 1: co-locate by key code (shard-major iteration keeps rows in
    # global order inside every destination bucket)
    probe_buckets = [[] for _ in range(S)]
    for part, rid in zip(parts, rowids):
        if not len(rid):
            continue
        code = _host_code(part, on, spec)
        dest = code % S
        for s in range(S):
            m = dest == s
            if m.any():
                b = {k: v[m] for k, v in part.items()}
                b[_ROWID] = rid[m]
                probe_buckets[s].append(b)
    build_buckets = []
    bdest = bcode % S
    for s in range(S):
        m = bdest == s
        build_buckets.append({k: v[m] for k, v in build.items()})
    # per-shard local join (the worker kernel)
    joined: list[dict] = []
    out_template: dict[str, np.dtype] | None = None
    for s in range(S):
        if probe_buckets[s]:
            pb = {k: np.concatenate([b[k] for b in probe_buckets[s]])
                  for k in probe_buckets[s][0]}
        else:
            pb = {k: np.asarray(v[:0]) for k, v in parts[0].items()}
            pb[_ROWID] = np.zeros(0, np.int64)
        j = apply_join(pb, build_buckets[s], on, how, suffixes)
        joined.append(j)
        if out_template is None:
            out_template = _template(j)
    # exchange 2: restore probe-row order — balanced row-id ranges per shard,
    # then a local stable sort by row id (stability keeps the build-side
    # match order the host kernel emitted)
    out_buckets: list[list[dict]] = [[] for _ in range(S)]
    for j in joined:
        rid = j[_ROWID]
        if not len(rid):
            continue
        dest = (rid * S) // max(total, 1)
        for s in range(S):
            m = dest == s
            if m.any():
                out_buckets[s].append({k: v[m] for k, v in j.items()})
    final_parts = []
    for s in range(S):
        if out_buckets[s]:
            t = {k: np.concatenate([b[k] for b in out_buckets[s]])
                 for k in out_buckets[s][0]}
            order = np.argsort(t[_ROWID], kind="stable")
            t = {k: v[order] for k, v in t.items()}
        else:
            t = {k: np.zeros(0, dt) for k, dt in out_template.items()}
        t.pop(_ROWID, None)
        final_parts.append(t)
    template = {k: dt for k, dt in out_template.items() if k != _ROWID}
    return _restack(final_parts, mesh, axis, template)


# ---------------------------------------------------------------------------
# Native distributed sort


@traced_op("sharded_sort")
def sharded_sort(t: ShardedTable, by: Sequence[str], ascending: bool,
                 mesh, axis: str) -> ShardedTable | None:
    """Range-partition by sampled splitters on the primary key, then a local
    stable lexsort per shard; shard-major gather order is globally sorted
    (descending = globally reversed ascending, matching the host kernel)."""
    by = list(by)
    if any(b not in t.cols for b in by):
        return None
    S = mesh.shape[axis]
    parts, _rowids, total = _host_shards(t)
    template = _template(parts[0])
    if total == 0:
        return _restack([dict(p) for p in parts[:S]], mesh, axis, template)
    # splitters from per-shard samples of the primary sort key
    samples = []
    for p in parts:
        key = np.asarray(p[by[0]])
        if key.size:
            step = max(1, key.size // 64)
            samples.append(np.sort(key)[::step])
    merged = np.sort(np.concatenate(samples))
    cut = [merged[(i * merged.size) // S] for i in range(1, S)]
    splitters = np.asarray(cut, dtype=merged.dtype)
    metric_inc("exchange.shuffles")
    metric_inc("exchange.shards", S)
    buckets: list[list[dict]] = [[] for _ in range(S)]
    for p in parts:
        key = np.asarray(p[by[0]])
        if not key.size:
            continue
        dest = np.searchsorted(splitters, key, side="right")
        for s in range(S):
            m = dest == s
            if m.any():
                buckets[s].append({k: v[m] for k, v in p.items()})
    sorted_parts = []
    for s in range(S):
        if buckets[s]:
            merged_b = {k: np.concatenate([b[k] for b in buckets[s]])
                        for k in buckets[s][0]}
            keys = tuple(merged_b[b] for b in reversed(by))
            idx = (np.lexsort(keys) if len(keys) > 1
                   else np.argsort(keys[0], kind="stable"))
            sorted_parts.append({k: v[idx] for k, v in merged_b.items()})
        else:
            sorted_parts.append({k: np.zeros(0, dt)
                                 for k, dt in template.items()})
    if not ascending:
        sorted_parts = [{k: v[::-1] for k, v in p.items()}
                        for p in reversed(sorted_parts)]
    return _restack(sorted_parts, mesh, axis, template)


# ---------------------------------------------------------------------------
# Native distributed distinct


@traced_op("sharded_distinct")
def sharded_distinct(t: ShardedTable, subset, mesh, axis: str
                     ) -> ShardedTable | None:
    """Shuffle by key code so duplicate keys co-locate, keep the first
    occurrence (minimum global row id) per shard, then restore input order
    by an exchange on the kept row ids."""
    cols = list(subset) if subset else list(t.cols)
    if not _int_keys(t.cols, cols):
        return None
    S = mesh.shape[axis]
    parts, rowids, total = _host_shards(t)
    template = _template(parts[0])
    if total == 0:
        return _restack([dict(p) for p in parts[:S]], mesh, axis, template)
    ranges = _key_ranges(parts, None, cols)
    if ranges is None:
        return None
    spec = _combined_radix(ranges, cols)
    if spec is None:
        return None
    metric_inc("exchange.shuffles")
    metric_inc("exchange.shards", S)
    buckets: list[list[dict]] = [[] for _ in range(S)]
    for part, rid in zip(parts, rowids):
        if not len(rid):
            continue
        code = _host_code(part, cols, spec)
        dest = code % S
        for s in range(S):
            m = dest == s
            if m.any():
                b = {k: v[m] for k, v in part.items()}
                b[_ROWID] = rid[m]
                buckets[s].append(b)
    # local keep-first (bucket rows arrive in ascending row-id order)
    kept: list[dict] = []
    for s in range(S):
        if buckets[s]:
            merged = {k: np.concatenate([b[k] for b in buckets[s]])
                      for k in buckets[s][0]}
            kept.append(apply_drop_duplicates(merged, cols))
        else:
            kept.append(None)
    # order-restoring exchange by kept row id
    out_buckets: list[list[dict]] = [[] for _ in range(S)]
    for k in kept:
        if k is None or not len(k[_ROWID]):
            continue
        dest = (k[_ROWID] * S) // max(total, 1)
        for s in range(S):
            m = dest == s
            if m.any():
                out_buckets[s].append({c: v[m] for c, v in k.items()})
    final_parts = []
    for s in range(S):
        if out_buckets[s]:
            merged = {k: np.concatenate([b[k] for b in out_buckets[s]])
                      for k in out_buckets[s][0]}
            order = np.argsort(merged[_ROWID], kind="stable")
            merged = {k: v[order] for k, v in merged.items()}
        else:
            merged = {k: np.zeros(0, dt) for k, dt in template.items()}
        merged.pop(_ROWID, None)
        final_parts.append(merged)
    return _restack(final_parts, mesh, axis, template)
