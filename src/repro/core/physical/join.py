"""Host hash/sort join (build side = right).

Keys are factorized over the union of both sides so codes align; the probe
side binary-searches the sorted build codes.  Pandas semantics: inner/left,
probe-row order preserved, overlap columns suffixed, unmatched left-join
float columns filled with NaN."""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax.numpy as jnp

from .table import Table, to_jax, to_numpy, xp_of
from ...obs.spans import traced_op


@traced_op("join")
def apply_join(left: Table, right: Table, on: Sequence[str], how="inner",
               suffixes=("_x", "_y")) -> Table:
    lj, rj = to_numpy(left), to_numpy(right)
    was_jax = xp_of(left) is jnp
    lkeys, _ = _factorize_multi_np_pair(lj, rj, on)
    lcode, rcode = lkeys
    order = np.argsort(rcode, kind="stable")
    rsorted = rcode[order]
    lo = np.searchsorted(rsorted, lcode, side="left")
    hi = np.searchsorted(rsorted, lcode, side="right")
    counts = hi - lo
    if how == "inner":
        l_idx = np.repeat(np.arange(lcode.shape[0]), counts)
        starts = np.repeat(lo, counts)
        within = np.arange(l_idx.shape[0]) - np.repeat(
            np.cumsum(counts) - counts, counts)
        r_idx = order[starts + within]
    elif how == "left":
        counts2 = np.maximum(counts, 1)
        l_idx = np.repeat(np.arange(lcode.shape[0]), counts2)
        starts = np.repeat(lo, counts2)
        within = np.arange(l_idx.shape[0]) - np.repeat(
            np.cumsum(counts2) - counts2, counts2)
        matched = np.repeat(counts > 0, counts2)
        if len(order):
            r_idx = np.where(matched, order[np.minimum(starts + within,
                                                       len(order) - 1)], -1)
        else:
            # empty build side: every probe row is unmatched (reachable per
            # shard in the distributed shuffle join's key buckets)
            r_idx = np.full(l_idx.shape[0], -1)
    else:
        raise ValueError(f"join how={how!r} not supported")
    out = {}
    overlap = (set(lj) & set(rj)) - set(on)
    for k in on:
        out[k] = lj[k][l_idx]
    for k, v in lj.items():
        if k in on:
            continue
        out[k + suffixes[0] if k in overlap else k] = v[l_idx]
    for k, v in rj.items():
        if k in on:
            continue
        name = k + suffixes[1] if k in overlap else k
        col = (v[np.maximum(r_idx, 0)] if v.shape[0]
               else np.zeros(r_idx.shape[0], v.dtype))
        if how == "left" and col.dtype.kind == "f":
            col = np.where(r_idx >= 0, col, np.nan)
        out[name] = col
    if was_jax:
        out = to_jax(out)
    return out


def _factorize_multi_np_pair(lt: Table, rt: Table, on: Sequence[str]):
    """Factorize join keys over the union of both sides so codes align."""
    lcode = np.zeros(len(next(iter(lt.values()))), np.int64)
    rcode = np.zeros(len(next(iter(rt.values()))), np.int64)
    for c in on:
        both = np.concatenate([np.asarray(lt[c]), np.asarray(rt[c])])
        uniques, codes = np.unique(both, return_inverse=True)
        lc = codes[: len(lt[c])]
        rc = codes[len(lt[c]):]
        lcode = lcode * len(uniques) + lc
        rcode = rcode * len(uniques) + rc
    return (lcode, rcode), None
