"""Unified physical-operator layer shared by every backend.

This package is the single home of physical execution: explicit operators
(scan helpers, the row-preserving pipeline, hash/sort join, group-by, sort,
distinct, reductions, segment handoff) over a common *table protocol* that
each backend binds to its native representation:

* eager       — whole-table ``dict[str, jnp.ndarray]`` on the default device
* streaming   — ``dict[str, np.ndarray]`` partition chunks (pull streams)
* distributed — :class:`ShardedTable` ``(n_shards, rows)`` device-sharded
                columns + validity mask

Module map
----------
``table``    host-table protocol helpers + handoff payload normalization
``rowwise``  row-preserving pipeline ops (filter/project/assign/…)
``groupby``  factorization + dense segment aggregation + partial/combine
``join``     host hash/sort join and aligned key factorization
``sort``     sort + distinct (host kernels)
``reduce``   whole-column reductions and partial forms
``sharded``  ShardedTable + *native distributed* join / sort / distinct
             (broadcast-hash and shuffle-by-dict-code exchanges)

``repro.core.exec_common`` re-exports everything here for back-compat.
"""
from __future__ import annotations

from .table import (Table, apply_concat, handoff_value, is_jax, table_nbytes,
                    table_rows, to_host_value, to_jax, to_numpy, xp_of)
from .rowwise import (apply_assign, apply_astype, apply_fillna, apply_filter,
                      apply_fused_rowwise, apply_head, apply_map_rows,
                      apply_project, apply_rename)
from .groupby import (_factorize, _factorize_multi, apply_groupby_agg,
                      combine_partials, partial_aggs)
from .join import _factorize_multi_np_pair, apply_join
from .sort import apply_drop_duplicates, apply_sort, apply_top_k
from .reduce import REDUCE_PARTIAL, apply_reduce
from .sharded import (BROADCAST_BUILD_BYTES, ShardedTable, shard_host_table,
                      sharded_distinct, sharded_head, sharded_join,
                      sharded_sort)

__all__ = [
    "Table", "is_jax", "xp_of", "table_rows", "table_nbytes", "to_numpy",
    "to_jax", "to_host_value", "handoff_value", "apply_concat",
    "apply_filter", "apply_project", "apply_assign", "apply_rename",
    "apply_astype", "apply_fillna", "apply_fused_rowwise", "apply_head",
    "apply_map_rows",
    "_factorize", "_factorize_multi", "apply_groupby_agg", "partial_aggs",
    "combine_partials", "apply_join", "_factorize_multi_np_pair",
    "apply_sort", "apply_top_k", "apply_drop_duplicates", "apply_reduce",
    "REDUCE_PARTIAL",
    "ShardedTable", "shard_host_table", "sharded_join", "sharded_sort",
    "sharded_distinct", "sharded_head", "BROADCAST_BUILD_BYTES",
]
