"""Group-by aggregation: factorize keys → dense segment reductions.

The jnp path routes its sum-shaped reductions (sum/mean/count) through
``repro.kernels.ops.groupby_sum`` — the MXU one-hot kernel when the kernel
config resolves to "pallas", its jnp oracle otherwise; the partial/combine
pair is what the
streaming backend uses for out-of-core aggregation (memory scales with the
number of groups, not rows)."""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from .table import Table, is_jax
from ...obs.spans import traced_op


def _factorize(arr):
    """codes, uniques — order of uniques is sorted-value order."""
    if is_jax(arr):
        uniques, codes = jnp.unique(arr, return_inverse=True)
    else:
        uniques, codes = np.unique(arr, return_inverse=True)
    return codes, uniques


def _factorize_multi(table: Table, cols: Sequence[str]):
    """Multi-column factorize via mixed-radix combination.

    Returns (codes, key_arrays_fn) where key_arrays_fn(group_codes) maps the
    final group code array back to per-column key values.
    """
    per = []
    radices = []
    for c in cols:
        codes, uniques = _factorize(table[c])
        per.append((codes, uniques))
        radices.append(int(uniques.shape[0]))
    xp = jnp if is_jax(per[0][0]) else np
    combined = per[0][0].astype(np.int64 if xp is np else jnp.int32)
    for (codes, _), r in zip(per[1:], radices[1:]):
        combined = combined * r + codes

    def decode(group_codes):
        out = {}
        rem = group_codes
        for (c, (_, uniques)), r in zip(
                reversed(list(zip(cols, per))), reversed(radices)):
            out[c] = uniques[rem % r]
            rem = rem // r
        return out

    return combined, decode


@traced_op("groupby_agg")
def apply_groupby_agg(table: Table, keys: Sequence[str],
                      aggs: Mapping[str, tuple[str, str]]) -> Table:
    """Dense aggregation: factorize keys → segment reductions.

    Device (jnp) tables dispatch sum-shaped reductions through the kernel
    layer (``repro.kernels.ops.groupby_sum``)."""
    combined, decode = _factorize_multi(table, list(keys))
    if is_jax(combined):
        groups, inv = jnp.unique(combined, return_inverse=True)
        num = int(groups.shape[0])
        out = decode(groups)
        for out_name, (col, fn) in aggs.items():
            out[out_name] = _segment_agg_jax(table, col, fn, inv, num)
    else:
        groups, inv = np.unique(combined, return_inverse=True)
        num = int(groups.shape[0])
        out = decode(groups)
        for out_name, (col, fn) in aggs.items():
            out[out_name] = _segment_agg_np(table, col, fn, inv, num)
    return out


def _segment_agg_jax(table, col, fn, seg_ids, num):
    # sum-shaped aggregations dispatch through the kernel layer: the MXU
    # one-hot kernel on TPU ("pallas"), the segment_sum oracle elsewhere
    from ...kernels import ops as K
    ones = jnp.ones((seg_ids.shape[0],), jnp.float32)
    if fn == "count":
        return K.groupby_sum(seg_ids, ones, num).astype(jnp.int64)
    vals = table[col]
    if vals.dtype.kind in "iub" and vals.dtype.itemsize < 4:
        vals = vals.astype(jnp.int32)   # widen narrow ints: no int8 accumulate
    if fn == "sum":
        return K.groupby_sum(seg_ids, vals, num)
    if fn == "mean":
        s = K.groupby_sum(seg_ids, vals.astype(jnp.float32), num)
        c = K.groupby_sum(seg_ids, ones, num)
        return s / c
    if fn == "min":
        return jax.ops.segment_min(vals, seg_ids, num)
    if fn == "max":
        return jax.ops.segment_max(vals, seg_ids, num)
    if fn == "nunique":
        sub_codes, _ = _factorize(vals)
        pair = seg_ids.astype(jnp.int64) * (jnp.max(sub_codes) + 1) + sub_codes
        uniq_pairs = jnp.unique(pair)
        seg_of_pair = uniq_pairs // (jnp.max(sub_codes) + 1)
        return jax.ops.segment_sum(jnp.ones_like(seg_of_pair), seg_of_pair, num)
    raise ValueError(f"unknown agg fn {fn}")


def _segment_agg_np(table, col, fn, seg_ids, num):
    if fn == "count":
        return np.bincount(seg_ids, minlength=num).astype(np.int64)
    vals = table[col]
    if fn == "sum":
        return np.bincount(seg_ids, weights=vals, minlength=num).astype(
            vals.dtype if vals.dtype.kind == "f" else np.float64)
    if fn == "mean":
        s = np.bincount(seg_ids, weights=vals.astype(np.float64), minlength=num)
        c = np.bincount(seg_ids, minlength=num)
        return s / np.maximum(c, 1)
    if fn in ("min", "max"):
        out = np.full(num, np.inf if fn == "min" else -np.inf, dtype=np.float64)
        ufn = np.minimum if fn == "min" else np.maximum
        ufn.at(out, seg_ids, vals.astype(np.float64))
        return out.astype(vals.dtype) if vals.dtype.kind == "f" else out
    if fn == "nunique":
        sub_codes, _ = _factorize(vals)
        pair = seg_ids.astype(np.int64) * (int(sub_codes.max()) + 1) + sub_codes
        uniq = np.unique(pair)
        seg = (uniq // (int(sub_codes.max()) + 1)).astype(np.int64)
        return np.bincount(seg, minlength=num).astype(np.int64)
    raise ValueError(f"unknown agg fn {fn}")


# partial/combine pairs for the streaming backend (out-of-core group-by).

_PARTIAL_FORMS = {
    "sum": ["sum"], "count": ["count"], "min": ["min"], "max": ["max"],
    "mean": ["sum", "count"],
}


def partial_aggs(aggs: Mapping[str, tuple[str, str]]):
    """Decompose logical aggs into partial aggs computable per partition."""
    partial = {}
    for out_name, (col, fn) in aggs.items():
        for p in _PARTIAL_FORMS[fn]:
            partial[f"{out_name}::{p}"] = (col, p)
    return partial


@traced_op("combine_partials")
def combine_partials(keys, parts: list[Table],
                     aggs: Mapping[str, tuple[str, str]]) -> Table:
    """Re-aggregate concatenated per-partition partials, then finalize."""
    xp = jnp if (parts and is_jax(next(iter(parts[0].values())))) else np
    concat = {k: xp.concatenate([p[k] for p in parts]) for k in parts[0]}
    combine_spec = {}
    for pname in concat:
        if "::" not in pname:
            continue
        _out, p = pname.rsplit("::", 1)
        combine_spec[pname] = (pname, "max" if p == "max" else
                               ("min" if p == "min" else "sum"))
    merged = apply_groupby_agg(concat, list(keys), combine_spec)
    out = {k: merged[k] for k in keys}
    for out_name, (_col, fn) in aggs.items():
        if fn == "mean":
            out[out_name] = (merged[f"{out_name}::sum"] /
                             xp.maximum(merged[f"{out_name}::count"], 1))
        elif fn == "count":
            # combining count partials goes through a weighted-sum path that
            # widens to float; counts are integral (pandas conformance)
            out[out_name] = merged[f"{out_name}::count"].astype(
                np.int64 if xp is np else jnp.int64)
        else:
            out[out_name] = merged[f"{out_name}::{fn}"]
    return out
