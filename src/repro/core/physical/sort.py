"""Sort and distinct (host + device whole-table kernels).

Stable lexsort keeps pandas row-order semantics (descending = reversed
ascending, ties included); distinct keeps first occurrences in input order.
The distributed shuffle variants in ``sharded.py`` reuse these as their
per-shard local kernels."""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax.numpy as jnp

from .groupby import _factorize_multi
from .table import Table, xp_of
from ...obs.spans import traced_op


@traced_op("sort")
def apply_sort(table: Table, by: Sequence[str], ascending: bool = True) -> Table:
    xp = xp_of(table)
    # lexsort: last key is primary in np.lexsort; jnp has lexsort too.
    keys = tuple(table[b] for b in reversed(by))
    idx = xp.lexsort(keys) if len(keys) > 1 else xp.argsort(keys[0], stable=True)
    if not ascending:
        idx = idx[::-1]
    return {k: v[idx] for k, v in table.items()}


def _order_indices(cols, ascending: bool, ties_first: bool, xp):
    """Stable row ordering by ``cols`` (first column primary).

    ``ties_first=True`` keeps the first occurrence of equal keys first in
    the output (pandas ``keep='first'``); ``ties_first=False`` with
    descending reproduces the reversed-stable-ascending order of
    ``apply_sort(ascending=False)`` exactly."""
    def asc(cs):
        if len(cs) > 1:
            return xp.lexsort(tuple(reversed(cs)))
        return xp.argsort(cs[0], stable=True)

    if ascending:
        return asc(cols)                   # stable ascending ⇒ ties first
    if not ties_first:
        return asc(cols)[::-1]             # reversed stable ⇒ ties last
    # descending with first-occurrence ties: argsort the reversed arrays so
    # stability prefers the original first occurrence, then map back.
    n_rows = int(cols[0].shape[0])
    rev = asc(tuple(c[::-1] for c in cols))
    return ((n_rows - 1) - rev)[::-1]


@traced_op("top_k")
def apply_top_k(table: Table, by: Sequence[str], n: int,
                ascending: bool = True, mode: str = "sort") -> Table:
    """First ``n`` rows of the stable sort by ``by`` without materializing
    the full sorted table (only ``n`` rows of every column are gathered).

    ``mode="sort"`` equals ``apply_sort(table, by, ascending)[:n]`` row for
    row (ties, NaN placement included); ``mode="select"`` is pandas
    ``nlargest``/``nsmallest``: rows with NaN sort keys are dropped and
    ties keep the first occurrence.  The k selection indices are always
    computed on host numpy — they are tiny, the host partition/argsort
    avoids per-call device dispatch, and device columns are only gathered
    at the final k-row index — with an O(rows) ``np.partition`` threshold
    pass for single numeric keys so only ~n candidate rows are argsorted."""
    keys = [np.asarray(table[b]) for b in by]
    sel = None
    if mode == "select":
        mask = None
        for kk in keys:
            if kk.dtype.kind == "f":
                m = np.isnan(kk)
                mask = m if mask is None else (mask | m)
        if mask is not None and mask.any():
            sel = np.nonzero(~mask)[0]
            keys = [kk[sel] for kk in keys]
    total = int(keys[0].shape[0]) if keys else 0
    k = max(0, min(int(n), total))
    if k == 0:
        return {c: v[:0] for c, v in table.items()}
    ties_first = ascending or mode == "select"
    cand = None
    first = keys[0]
    if (len(keys) == 1 and k < total
            and first.dtype.kind in "biuf"
            and not (first.dtype.kind == "f" and np.isnan(first).any())):
        pos = k - 1 if ascending else total - k
        thr = np.partition(first, pos)[pos]
        cand = np.nonzero(first <= thr if ascending else first >= thr)[0]
        keys = [first[cand]]
    order = _order_indices(tuple(keys), ascending, ties_first, np)[:k]
    idx = cand[order] if cand is not None else order
    if sel is not None:
        idx = sel[idx]
    return {c: v[idx] for c, v in table.items()}


@traced_op("drop_duplicates")
def apply_drop_duplicates(table: Table, subset=None) -> Table:
    cols = list(subset) if subset else list(table.keys())
    codes, _ = _factorize_multi(table, cols)
    xp = xp_of(table)
    if xp is jnp:
        _, first_idx = jnp.unique(codes, return_index=True)
        idx = jnp.sort(first_idx)
    else:
        _, first_idx = np.unique(codes, return_index=True)
        idx = np.sort(first_idx)
    return {k: v[idx] for k, v in table.items()}
