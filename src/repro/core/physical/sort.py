"""Sort and distinct (host + device whole-table kernels).

Stable lexsort keeps pandas row-order semantics (descending = reversed
ascending, ties included); distinct keeps first occurrences in input order.
The distributed shuffle variants in ``sharded.py`` reuse these as their
per-shard local kernels."""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax.numpy as jnp

from .groupby import _factorize_multi
from .table import Table, xp_of
from ...obs.spans import traced_op


@traced_op("sort")
def apply_sort(table: Table, by: Sequence[str], ascending: bool = True) -> Table:
    xp = xp_of(table)
    # lexsort: last key is primary in np.lexsort; jnp has lexsort too.
    keys = tuple(table[b] for b in reversed(by))
    idx = xp.lexsort(keys) if len(keys) > 1 else xp.argsort(keys[0], stable=True)
    if not ascending:
        idx = idx[::-1]
    return {k: v[idx] for k, v in table.items()}


@traced_op("drop_duplicates")
def apply_drop_duplicates(table: Table, subset=None) -> Table:
    cols = list(subset) if subset else list(table.keys())
    codes, _ = _factorize_multi(table, cols)
    xp = xp_of(table)
    if xp is jnp:
        _, first_idx = jnp.unique(codes, return_index=True)
        idx = jnp.sort(first_idx)
    else:
        _, first_idx = np.unique(codes, return_index=True)
        idx = np.sort(first_idx)
    return {k: v[idx] for k, v in table.items()}
