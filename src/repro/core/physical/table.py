"""Chunk/shard table protocol.

A host *table* is ``dict[str, array]`` of equal-length 1-D columns; arrays
are either numpy (host / streaming chunks) or jax (eager whole-table).  The
distributed backend's :class:`~repro.core.physical.sharded.ShardedTable`
binds the same column-dict shape to ``(n_shards, rows)`` device-sharded
arrays plus a validity mask.  Physical operators dispatch on the array type
(``xp_of``), so one implementation serves every chunk granularity.

Segment handoff payloads (``graph.Handoff``) are normalized here: host
tables, scalars, or — for distributed→distributed chains — device-resident
``ShardedTable`` values that never round-trip through host memory.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

Table = dict


def is_jax(arr) -> bool:
    return isinstance(arr, jax.Array)


def xp_of(table: Table):
    for v in table.values():
        return jnp if is_jax(v) else np
    return np


def table_rows(table: Table) -> int:
    for v in table.values():
        return int(v.shape[0])
    return 0


def table_nbytes(table: Table) -> int:
    return sum(int(v.nbytes) for v in table.values())


def to_numpy(table: Table) -> Table:
    return {k: np.asarray(v) for k, v in table.items()}


def to_jax(table: Table) -> Table:
    return {k: jnp.asarray(v) for k, v in table.items()}


def apply_concat(tables: list[Table]) -> Table:
    xp = xp_of(tables[0])
    cols = set(tables[0])
    for t in tables[1:]:
        cols &= set(t)
    return {c: xp.concatenate([t[c] for t in tables]) for c in sorted(cols)}


# ---------------------------------------------------------------------------
# Segment handoff (operator-granular hybrid placement)
#
# When the planner splits one plan across engines, values crossing a segment
# boundary are normalized to host representation: tables become numpy column
# dicts, device scalars become python numbers.  This is the explicit
# materialization the cost model charges as transfer at every cut edge.
# The one exception is a distributed→distributed boundary, where the payload
# stays a device-resident ShardedTable (see ``runtime.execute_segments``).


def to_host_value(value):
    """Normalize a segment output for transfer to another engine."""
    from .sharded import ShardedTable
    if isinstance(value, ShardedTable):
        return value.gather()
    if isinstance(value, dict):
        return to_numpy(value)
    if isinstance(value, (jax.Array, np.generic)):
        arr = np.asarray(value)
        return arr.item() if arr.ndim == 0 else arr
    return value


def handoff_value(node, device_arrays: bool = False):
    """Evaluate a ``graph.Handoff`` leaf inside a backend: return its
    pre-materialized payload, converting tables onto the device when the
    consuming engine wants device-resident columns.  A device-resident
    ``ShardedTable`` payload is gathered defensively — only the distributed
    backend consumes it in place (``DistributedBackend._eval_inner``)."""
    from .sharded import ShardedTable
    v = node.value
    if isinstance(v, ShardedTable):
        v = v.gather()
    if isinstance(v, dict):
        return to_jax(v) if device_arrays else v
    return v
