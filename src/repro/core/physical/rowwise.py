"""Row-preserving pipeline operators (np/jnp dispatch via the table
protocol).  These run identically on whole tables (eager), partition chunks
(streaming), and — lifted over ``(n_shards, rows)`` arrays — inside the
distributed backend's shard programs."""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from .table import Table, table_rows, xp_of
from ...obs.spans import traced_op


@traced_op("filter")
def apply_filter(table: Table, predicate) -> Table:
    mask = predicate.evaluate(table)
    # boolean advanced indexing works eagerly for both np and jnp
    return {k: v[mask] for k, v in table.items()}


@traced_op("project")
def apply_project(table: Table, columns: Sequence[str]) -> Table:
    return {c: table[c] for c in columns}


@traced_op("assign")
def apply_assign(table: Table, name: str, expr) -> Table:
    out = dict(table)
    val = expr.evaluate(table)
    xp = xp_of(table)
    if np.isscalar(val) or getattr(val, "ndim", 1) == 0:
        val = xp.full((table_rows(table),), val)
    out[name] = val
    return out


@traced_op("rename")
def apply_rename(table: Table, mapping: Mapping[str, str]) -> Table:
    return {mapping.get(k, k): v for k, v in table.items()}


@traced_op("astype")
def apply_astype(table: Table, dtypes: Mapping[str, str]) -> Table:
    out = dict(table)
    for c, dt in dtypes.items():
        out[c] = out[c].astype(dt)
    return out


@traced_op("fillna")
def apply_fillna(table: Table, value, columns=None) -> Table:
    xp = xp_of(table)
    out = dict(table)
    for c in (columns or table.keys()):
        arr = out[c]
        if arr.dtype.kind == "f":
            out[c] = xp.where(xp.isnan(arr), xp.asarray(value, dtype=arr.dtype), arr)
    return out


@traced_op("head")
def apply_head(table: Table, n: int) -> Table:
    return {k: v[:n] for k, v in table.items()}


# ---------------------------------------------------------------------------
# Fused rowwise chains (graph.FusedRowwise, built by core.fuse)


def _apply_member(table: Table, m) -> Table:
    """One chain member, op-at-a-time (streaming chunks + the non-jit
    fallback).  Dispatches on op name so this module needs no graph import."""
    op = m.op
    if op == "filter":
        return apply_filter(table, m.predicate)
    if op == "project":
        return apply_project(table, m.columns)
    if op == "assign":
        return apply_assign(table, m.name, m.expr)
    if op == "rename":
        return apply_rename(table, m.mapping)
    if op == "astype":
        return apply_astype(table, m.dtypes)
    if op == "fillna":
        return apply_fillna(table, m.value, m.columns)
    raise NotImplementedError(f"fused member {op}")


# jitted composed chains keyed by (member params, kernel impl); jax caches
# compiled executables per input aval under each entry
_FUSED_JIT_CACHE: dict[tuple, object] = {}
_FUSED_JIT_CACHE_MAX = 256


def _kernel_cfg(impl: str | None):
    from ...kernels import ops as K
    if impl is None or impl == "auto":
        return K.get_kernel_config()
    return K.KernelConfig(impl=impl)


def _fused_jax_fn(ops: tuple, cfg):
    """Build (and cache) the single-dispatch jitted chain body.  Compute
    members run on full columns while Filter members AND into one deferred
    validity mask (every fusable op is elementwise, so values at surviving
    rows are unchanged).  Compaction happens in the caller: shapes depend
    on data, so packing inside the jit would force the scatter-based path
    even where a dynamic gather is cheaper."""
    import jax

    key = (tuple(m.key()[:-1] for m in ops), cfg.resolved(), cfg.interpret)
    fn = _FUSED_JIT_CACHE.get(key)
    if fn is not None:
        return fn

    def composed(cols):
        import jax.numpy as jnp
        mask = None
        for m in ops:
            if m.op == "filter":
                pred = m.predicate.evaluate(cols)
                mask = pred if mask is None else (mask & pred)
            elif m.op == "project":
                cols = {c: cols[c] for c in m.columns}
            elif m.op == "assign":
                val = m.expr.evaluate(cols)
                if np.isscalar(val) or getattr(val, "ndim", 1) == 0:
                    val = jnp.full((table_rows(cols),), val)
                cols = dict(cols)
                cols[m.name] = val
            elif m.op == "rename":
                cols = {m.mapping.get(c, c): v for c, v in cols.items()}
            elif m.op == "astype":
                cols = dict(cols)
                for c, dt in m.dtypes.items():
                    cols[c] = cols[c].astype(dt)
            elif m.op == "fillna":
                cols = dict(cols)
                for c in (m.columns or tuple(cols)):
                    arr = cols[c]
                    if arr.dtype.kind == "f":
                        cols[c] = jnp.where(
                            jnp.isnan(arr),
                            jnp.asarray(m.value, dtype=arr.dtype), arr)
            else:
                raise NotImplementedError(f"fused member {m.op}")
        return cols, mask

    fn = jax.jit(composed)
    if len(_FUSED_JIT_CACHE) >= _FUSED_JIT_CACHE_MAX:
        _FUSED_JIT_CACHE.clear()
    _FUSED_JIT_CACHE[key] = fn
    return fn


def _output_columns(names, ops):
    """Column order the member chain would produce — jax.jit returns dict
    pytrees with *sorted* keys, so the caller must restore pandas order."""
    names = list(names)
    for m in ops:
        if m.op == "project":
            names = list(m.columns)
        elif m.op == "assign":
            if m.name not in names:
                names.append(m.name)
        elif m.op == "rename":
            names = [m.mapping.get(c, c) for c in names]
    return names


@traced_op("fused_rowwise")
def apply_fused_rowwise(table: Table, ops, impl: str | None = None) -> Table:
    """Execute a FusedRowwise chain as one composed pass.

    jnp tables: one device dispatch through a cached jitted body (no
    intermediate tables); Filter-terminated chains compact survivors with
    the ``repro.kernels`` filter_compact kernel when ``impl`` resolves to
    "pallas" (TPU), and via XLA's dynamic boolean gather on "xla" hosts
    where the kernel's scatter packing loses to a plain gather.  numpy
    tables (streaming chunks) and any chain that fails to trace fall back
    to op-at-a-time members — identical semantics, just without the
    single-dispatch win."""
    if xp_of(table) is np:
        out = table
        for m in ops:
            out = _apply_member(out, m)
        return out
    cfg = _kernel_cfg(impl)
    try:
        cols, mask = _fused_jax_fn(tuple(ops), cfg)(dict(table))
    except Exception:  # noqa: BLE001 — untraceable chain: run unfused
        out = table
        for m in ops:
            out = _apply_member(out, m)
        return out
    cols = {c: cols[c] for c in _output_columns(table.keys(), ops)}
    if mask is None:
        return cols
    if cfg.resolved() == "pallas":
        from ...kernels import ops as K
        out, count = {}, None
        for c, v in cols.items():
            out[c], count = K.filter_compact(v, mask, cfg)
        k = int(count) if count is not None else 0
        return {c: v[:k] for c, v in out.items()}
    # xla hosts: jax's eager dynamic gather re-dispatches per column and
    # loses badly to one host boolean gather; arrays round-trip through
    # numpy (near zero-copy on CPU) and come back device-resident
    import jax.numpy as jnp
    host_mask = np.asarray(mask)
    return {c: jnp.asarray(np.asarray(v)[host_mask]) for c, v in cols.items()}


@traced_op("map_rows")
def apply_map_rows(table: Table, fn) -> Table:
    return fn(dict(table))
