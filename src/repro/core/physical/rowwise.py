"""Row-preserving pipeline operators (np/jnp dispatch via the table
protocol).  These run identically on whole tables (eager), partition chunks
(streaming), and — lifted over ``(n_shards, rows)`` arrays — inside the
distributed backend's shard programs."""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from .table import Table, table_rows, xp_of
from ...obs.spans import traced_op


@traced_op("filter")
def apply_filter(table: Table, predicate) -> Table:
    mask = predicate.evaluate(table)
    # boolean advanced indexing works eagerly for both np and jnp
    return {k: v[mask] for k, v in table.items()}


@traced_op("project")
def apply_project(table: Table, columns: Sequence[str]) -> Table:
    return {c: table[c] for c in columns}


@traced_op("assign")
def apply_assign(table: Table, name: str, expr) -> Table:
    out = dict(table)
    val = expr.evaluate(table)
    xp = xp_of(table)
    if np.isscalar(val) or getattr(val, "ndim", 1) == 0:
        val = xp.full((table_rows(table),), val)
    out[name] = val
    return out


@traced_op("rename")
def apply_rename(table: Table, mapping: Mapping[str, str]) -> Table:
    return {mapping.get(k, k): v for k, v in table.items()}


@traced_op("astype")
def apply_astype(table: Table, dtypes: Mapping[str, str]) -> Table:
    out = dict(table)
    for c, dt in dtypes.items():
        out[c] = out[c].astype(dt)
    return out


@traced_op("fillna")
def apply_fillna(table: Table, value, columns=None) -> Table:
    xp = xp_of(table)
    out = dict(table)
    for c in (columns or table.keys()):
        arr = out[c]
        if arr.dtype.kind == "f":
            out[c] = xp.where(xp.isnan(arr), xp.asarray(value, dtype=arr.dtype), arr)
    return out


@traced_op("head")
def apply_head(table: Table, n: int) -> Table:
    return {k: v[:n] for k, v in table.items()}


@traced_op("map_rows")
def apply_map_rows(table: Table, fn) -> Table:
    return fn(dict(table))
