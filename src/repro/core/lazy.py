"""DEPRECATED drop-in namespace — use ``import repro.pandas as pd``.

This module is a thin shim kept for back-compat: it re-exports the
`repro.pandas` facade (same objects, same behaviour, including the working
module-level ``BACKEND_ENGINE`` property) and emits a ``DeprecationWarning``
on import."""
from __future__ import annotations

import sys
import warnings

warnings.warn(
    "repro.core.lazy is deprecated; use `import repro.pandas as pd` "
    "(the two-line drop-in facade)", DeprecationWarning, stacklevel=2)

from repro.pandas import (  # noqa: E402,F401 — re-exports
    BackendEngines, DataFrame, FallbackEvent, LaFPContext, LazyColumn,
    LazyFrame, Result, Series, analyze, concat, default_context, flush,
    from_arrays, get_context, isna, merge, notna, pop_session, push_session,
    read_csv, read_npz, read_source, session, set_backend, to_datetime,
)
from repro.pandas import _FacadeModule  # noqa: E402
from repro.pandas.io import _looks_datetime, _parse_datetimes  # noqa: E402,F401

__all__ = [
    "analyze", "flush", "read_source", "read_npz", "from_arrays", "read_csv",
    "BackendEngines", "set_backend", "LazyFrame", "DataFrame", "Series",
    "concat", "merge", "to_datetime", "isna", "session",
]

# same live BACKEND_ENGINE property as the facade (module-class swap)
sys.modules[__name__].__class__ = _FacadeModule
