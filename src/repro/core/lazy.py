"""The drop-in namespace (paper Fig. 2):

    import repro.core.lazy as pd
    pd.analyze()
    ...rest of the program in plain pandas style...

Exposes read_* constructors, the backend switch, analyze(), and flush().
"""
from __future__ import annotations

import numpy as np

from .context import BackendEngines, get_context
from .lazyframe import LazyFrame, from_arrays as _from_arrays, read_npz as _read_npz, read_source as _read_source
from .source import InMemorySource, encode_strings
from .tracer import analyze, usecols_hint
from .runtime import flush

__all__ = ["analyze", "flush", "read_source", "read_npz", "from_arrays",
           "read_csv", "BackendEngines", "set_backend", "LazyFrame"]


class _BackendProxy:
    """pd.BACKEND_ENGINE = pd.BackendEngines.X (paper §2.6 one-liner)."""

    def __get__(self, obj, objtype=None):
        return get_context().backend

    def __set__(self, obj, value):
        get_context().backend = value


def set_backend(engine: BackendEngines, **options):
    ctx = get_context()
    ctx.backend = engine
    ctx.backend_options.update(options)


def _apply_usecols(source, cols):
    """Record static usecols for this source (column selection, §3.1)."""
    ctx = get_context()
    if cols is not None and ctx.analysis:
        ctx.analysis.setdefault("scan_extra_cols", {})[id(source)] = list(cols)
    return source


def read_source(source):
    cols = usecols_hint()
    frame = _read_source(_apply_usecols(source, cols))
    if cols is not None:
        from . import graph as G
        valid = [c for c in cols if c in source.schema]
        if valid:
            frame = LazyFrame(G.Scan(source, tuple(valid)),
                              source_vocab=source.dicts)
    return frame


def read_npz(path: str):
    from .source import NpzDirectorySource
    return read_source(NpzDirectorySource(path))


def from_arrays(arrays, partition_rows: int = 1 << 16, dicts=None,
                datetimes=(), name="mem"):
    src = InMemorySource(arrays, partition_rows, dicts, datetimes, name)
    return read_source(src)


def read_csv(path: str, usecols=None, dtype=None, parse_dates=()):
    """Minimal CSV reader: numeric columns inferred, strings dictionary-
    encoded, ISO datetimes → int64 epoch seconds.  ``usecols`` comes from the
    user or from static analysis (paper Fig. 4)."""
    import csv as _csv

    hint = usecols if usecols is not None else usecols_hint()
    with open(path, newline="") as f:
        reader = _csv.reader(f)
        header = next(reader)
        keep = [i for i, h in enumerate(header)
                if hint is None or h in hint]
        names = [header[i] for i in keep]
        cols: dict[str, list] = {n: [] for n in names}
        for row in reader:
            for i, n in zip(keep, names):
                cols[n].append(row[i])
    arrays: dict[str, np.ndarray] = {}
    dicts: dict[str, list] = {}
    datetimes: list[str] = list(parse_dates)
    for n, vals in cols.items():
        arr = None
        if n in datetimes:
            arrays[n] = _parse_datetimes(vals)
            continue
        try:
            arr = np.asarray(vals, dtype=np.int64)
        except ValueError:
            try:
                arr = np.asarray(vals, dtype=np.float64)
            except ValueError:
                if _looks_datetime(vals):
                    arrays[n] = _parse_datetimes(vals)
                    datetimes.append(n)
                    continue
                codes, vocab = encode_strings(vals)
                arrays[n] = codes
                dicts[n] = vocab
                continue
        if dtype and n in dtype:
            arr = arr.astype(dtype[n])
        arrays[n] = arr
    src = InMemorySource(arrays, dicts=dicts, datetimes=datetimes,
                         name=path)
    return _read_source(_apply_usecols(src, hint))


def _looks_datetime(vals) -> bool:
    probe = vals[0] if vals else ""
    return len(probe) >= 10 and probe[4:5] == "-" and probe[7:8] == "-"


def _parse_datetimes(vals) -> np.ndarray:
    import datetime as _dt
    out = np.empty(len(vals), np.int64)
    for i, v in enumerate(vals):
        v = v.strip().replace("T", " ")
        fmt = "%Y-%m-%d %H:%M:%S" if len(v) > 10 else "%Y-%m-%d"
        out[i] = int(_dt.datetime.strptime(v, fmt)
                     .replace(tzinfo=_dt.timezone.utc).timestamp())
    return out


# module-level attribute emulation for BACKEND_ENGINE
def __getattr__(name):
    if name == "BACKEND_ENGINE":
        return get_context().backend
    raise AttributeError(name)


def __setattr__unused(name, value):  # modules can't easily hook setattr; use set_backend
    raise AttributeError
