"""Lazy print (paper §3.3).

``repro.core.func.print`` builds a SinkPrint node instead of printing.  Parts
are either literal strings (possibly containing the f-string escape marker
``\\x00LAFP:<node_id>\\x00`` produced by ``LazyScalar.__format__``) or direct
frame/scalar references.  An ordering edge to the previous sink preserves
output order; execution renders parts, substituting computed values.
"""
from __future__ import annotations

import re
from typing import Any

from . import graph as G
from .context import LaFPContext, get_context

_ESC_RE = re.compile("\x00LAFP:(\\d+)\x00")


def make_print(args: tuple, ctx: LaFPContext | None = None) -> G.SinkPrint:
    """Build a lazy print node from print() args."""
    from .lazyframe import LazyColumn, LazyFrame, LazyScalar
    ctx = ctx or get_context()
    parts: list[Any] = []
    data_inputs: list[G.Node] = []

    def add_node(node: G.Node):
        parts.append(("node", len(data_inputs)))
        data_inputs.append(node)

    for a in args:
        if isinstance(a, LazyFrame):
            add_node(a._node)
        elif isinstance(a, LazyColumn):
            bound = a.frame._node_for_expr_column(a.expr)
            add_node(G.Project(bound._inner, [bound._col_name]))
        elif isinstance(a, LazyScalar):
            add_node(a.node)
        elif isinstance(a, str):
            # resolve f-string escapes to node references
            pieces: list[Any] = []
            pos = 0
            for m in _ESC_RE.finditer(a):
                if m.start() > pos:
                    pieces.append(("str", a[pos:m.start()]))
                node = ctx.scalar_registry.get(int(m.group(1)))
                if node is None:
                    pieces.append(("str", "<stale-lazy-ref>"))
                else:
                    pieces.append(("node", len(data_inputs)))
                    data_inputs.append(node)
                pos = m.end()
            if pos < len(a):
                pieces.append(("str", a[pos:]))
            parts.extend(pieces)
        else:
            parts.append(("str", str(a)))
    sink = G.SinkPrint(parts, data_inputs, ctx.last_sink)
    ctx.sink_chain_add(sink)
    return sink


def render_sink(n: G.SinkPrint, data_vals: list[Any], ctx: LaFPContext):
    from .lazyframe import Result
    pieces = []
    for part in n.parts:
        kind, v = part
        if kind == "str":
            pieces.append(v)
        else:
            val = data_vals[v]
            if isinstance(val, dict):
                val = Result(val)
            pieces.append(str(val))
    ctx.print_fn(" ".join(pieces) if len(pieces) > 1 else
                 (pieces[0] if pieces else ""))
