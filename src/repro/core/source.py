"""Partitioned columnar sources.

A Source is the leaf of the task graph: an ordered list of partitions, each a
dict of 1-D column arrays.  Partition-major order is the engine's row order
(this replaces Dask's "no row order" caveat from the paper — our streaming
and distributed backends preserve partition-major order, see DESIGN §2).

Per-partition zone maps (min/max/rows) back the metadata store (§3.6) and
beyond-paper partition pruning.
"""
from __future__ import annotations

import json
import os
from typing import Mapping, Sequence

import numpy as np

from .schema import TableSchema, infer_schema, narrow_int_dtype


class Source:
    """Protocol: subclasses provide schema, dicts, n_partitions,
    load_partition, partition_meta."""

    schema: TableSchema
    dicts: dict[str, list]          # vocab per dict-encoded column
    name: str = "source"
    # scan-layer capabilities: whether the optimizer may sink filter
    # conjuncts into scans over this source (predicate evaluation happens
    # in the shared loader, so any host-array source qualifies), and
    # whether the streaming backend should decode partitions ahead on the
    # prefetch thread (only worthwhile when load_partition does real IO)
    supports_pushdown: bool = False
    prefetchable: bool = False

    @property
    def n_partitions(self) -> int:
        raise NotImplementedError

    def load_partition(self, i: int, columns: Sequence[str] | None = None
                       ) -> dict[str, np.ndarray]:
        raise NotImplementedError

    def partition_meta(self, i: int) -> dict:
        """{'rows': int, 'zonemap': {col: (min, max)}} — may be {} if stats
        were never computed."""
        return {}

    def cache_token(self):
        """Identity token used in ``Scan.key()``.  Disk-backed sources
        override with a path-stable token so plan keys (and therefore the
        persisted stats store's cardinality feedback) survive process
        restarts; in-memory sources stay identity-keyed."""
        return ("mem", id(self))

    def total_rows(self) -> int | None:
        metas = [self.partition_meta(i) for i in range(self.n_partitions)]
        if any("rows" not in m for m in metas):
            return None
        return sum(m["rows"] for m in metas)

    # -- planner-facing statistics extraction ------------------------------
    def total_bytes(self) -> int | None:
        """Estimated resident size of the full table (rows × schema width)."""
        rows = self.total_rows()
        if rows is None:
            return None
        return rows * self.schema.row_bytes()

    def column_ndv(self, name: str) -> int | None:
        """Distinct-count estimate for one column, from metadata only:
        exact vocab size for dict-encoded columns; integer zone-map span
        (capped by row count) for integer columns; None when unknown."""
        if name in self.dicts:
            return len(self.dicts[name])
        try:
            cs = self.schema.col(name)
        except KeyError:
            return None
        if cs.np_dtype.kind not in "iu":
            return None
        lo = hi = None
        for pi in range(self.n_partitions):
            zm = self.partition_meta(pi).get("zonemap", {})
            if name not in zm:
                return None
            plo, phi = zm[name]
            lo = plo if lo is None else min(lo, plo)
            hi = phi if hi is None else max(hi, phi)
        if lo is None:
            return None
        span = int(hi) - int(lo) + 1
        rows = self.total_rows()
        return min(span, rows) if rows is not None else span


def _zonemap(arrays: Mapping[str, np.ndarray]) -> dict:
    """Per-partition (min, max) column stats for partition skipping.

    When the kernel config resolves to a device implementation ("pallas"
    on TPU hosts) numeric columns route through the blocked
    ``repro.kernels.ops.zonemap`` kernel; host builds keep the numpy fast
    path — same contract, no device round-trip."""
    kernel = None
    try:
        from ..kernels import ops as _K
        if _K.get_kernel_config().resolved() == "pallas":
            kernel = _K
    except Exception:  # noqa: BLE001 — stats are best-effort
        kernel = None
    zm = {}
    for name, arr in arrays.items():
        if arr.dtype.kind in "ifu" and arr.size:
            if kernel is not None:
                mins, maxs = kernel.zonemap(arr)
                zm[name] = (np.asarray(mins).min().item(),
                            np.asarray(maxs).max().item())
            else:
                zm[name] = (arr.min().item(), arr.max().item())
    return zm


class InMemorySource(Source):
    """Arrays held in memory, split into fixed-size partitions."""

    supports_pushdown = True

    def __init__(self, arrays: Mapping[str, np.ndarray],
                 partition_rows: int = 1 << 16,
                 dicts: Mapping[str, Sequence] | None = None,
                 datetimes: Sequence[str] = (),
                 name: str = "mem"):
        lengths = {len(a) for a in arrays.values()}
        if len(lengths) != 1:
            raise ValueError("ragged columns")
        self._arrays = {k: np.asarray(v) for k, v in arrays.items()}
        self._rows = lengths.pop()
        self._part_rows = partition_rows
        self.dicts = {k: list(v) for k, v in (dicts or {}).items()}
        self.schema = infer_schema(self._arrays, self.dicts, datetimes)
        self.name = name
        self._metas = None
        self._token = None

    def cache_token(self):
        """Content fingerprint (dtype + shape + full-bytes hash) instead of
        object identity, so structural plan keys — and therefore the
        persisted stats store's cardinality/peak feedback — survive process
        restarts for in-memory plans too: a fresh process ingesting the
        same data produces the same token.

        The hash covers the *complete* column bytes: the token feeds
        correctness-bearing consumers (the persist cache serves results by
        plan key), so a sampled digest that collides for tables differing
        only in unsampled rows is not acceptable.  blake2b streams at
        ~1 GB/s and the digest is computed once per source and cached; the
        engine treats sources as immutable after ingest (as the identity
        token did)."""
        if self._token is None:
            import hashlib
            h = hashlib.blake2b(digest_size=16)
            h.update(str(self._rows).encode())
            for cname in sorted(self._arrays):
                arr = self._arrays[cname]
                h.update(cname.encode())
                h.update(str(arr.dtype).encode())
                h.update(str(arr.shape).encode())
                if arr.size:
                    h.update(np.ascontiguousarray(arr).tobytes())
            for cname in sorted(self.dicts):
                h.update(cname.encode())
                h.update(repr(self.dicts[cname]).encode())
            self._token = ("mem", self._rows, h.hexdigest())
        return self._token

    @property
    def n_partitions(self):
        return max(1, -(-self._rows // self._part_rows))

    def _bounds(self, i):
        lo = i * self._part_rows
        return lo, min(lo + self._part_rows, self._rows)

    def load_partition(self, i, columns=None):
        lo, hi = self._bounds(i)
        names = columns if columns is not None else list(self._arrays)
        return {n: self._arrays[n][lo:hi] for n in names}

    def partition_meta(self, i):
        if self._metas is None:
            self._metas = {}
        if i not in self._metas:
            lo, hi = self._bounds(i)
            part = {n: a[lo:hi] for n, a in self._arrays.items()}
            self._metas[i] = {"rows": hi - lo, "zonemap": _zonemap(part)}
        return self._metas[i]


class NpzDirectorySource(Source):
    """Out-of-core source: directory of part-NNNNN.npz files + _meta.json.

    This is the engine's "larger than memory" substrate — partitions are
    loaded one at a time by the streaming backend.  ``write_npz_source``
    builds one (and its metadata, incl. zone maps) from arrays or a
    generator.
    """

    supports_pushdown = True
    prefetchable = True

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, "_meta.json")) as f:
            meta = json.load(f)
        self._parts = meta["partitions"]          # list of {file, rows, zonemap}
        self.dicts = meta.get("dicts", {})
        cols = meta["columns"]                    # {name: {dtype, is_dict, is_datetime}}
        from .schema import ColumnSchema
        self.schema = TableSchema(tuple(
            ColumnSchema(n, c["dtype"], is_dict=c.get("is_dict", False),
                         dict_size=len(self.dicts.get(n, [])) or None,
                         is_datetime=c.get("is_datetime", False))
            for n, c in cols.items()))
        self.name = os.path.basename(path.rstrip("/"))
        if any("rows" not in p or "zonemap" not in p for p in self._parts):
            self._restore_stats()
        # content fingerprint over the partition metadata (files, row
        # counts, zone maps): a rewritten directory gets a fresh token, so
        # correctness-bearing key consumers (persist cache) never serve
        # stale results for structurally-identical plans over changed data
        import hashlib
        self._fingerprint = hashlib.md5(
            json.dumps(meta, sort_keys=True).encode()).hexdigest()[:16]

    def _restore_stats(self):
        """Fill missing per-partition rows/zone maps from the ``_stats.json``
        sidecar — or, when the sidecar is absent/stale, with ONE data scan
        whose result is persisted to the sidecar, so the next open of this
        directory is metadata-only.  (``_meta.json`` written by
        ``write_npz_source`` already carries stats; this path serves
        hand-built or pre-sidecar directories.)"""
        # function-level import: repro.io.parquet imports this module
        from repro.io import sidecar as SC
        files = [os.path.join(self.path, p["file"]) for p in self._parts]
        payload = SC.read_sidecar(self.path, data_files=files)
        if payload is None:
            stats = []
            for p in self._parts:
                with np.load(os.path.join(self.path, p["file"])) as z:
                    arrays = {n: z[n] for n in z.files}
                rows = len(next(iter(arrays.values()))) if arrays else 0
                stats.append({"file": p["file"], "rows": rows,
                              "zonemap": _zonemap(arrays)})
            payload = SC.write_sidecar(self.path, stats, data_files=files)
        by_file = {sp.get("file"): sp for sp in payload["partitions"]}
        for p in self._parts:
            sp = by_file.get(p["file"], {})
            if "rows" not in p and "rows" in sp:
                p["rows"] = sp["rows"]
            if "zonemap" not in p:
                p["zonemap"] = sp.get("zonemap", {})

    def cache_token(self):
        """Path-stable, covering file identity: the _meta.json content
        fingerprint plus the stats sidecar's mtime (0 when absent) — a
        rewritten directory or refreshed sidecar yields a fresh token."""
        from repro.io import sidecar as SC
        return ("npz", os.path.abspath(self.path), self._fingerprint,
                SC.sidecar_mtime_ns(self.path))

    @property
    def n_partitions(self):
        return len(self._parts)

    def load_partition(self, i, columns=None):
        with np.load(os.path.join(self.path, self._parts[i]["file"])) as z:
            names = columns if columns is not None else list(z.files)
            return {n: z[n] for n in names}

    def partition_meta(self, i):
        p = self._parts[i]
        return {"rows": p["rows"],
                "zonemap": {k: tuple(v) for k, v in p.get("zonemap", {}).items()}}


def write_npz_source(path: str, arrays: Mapping[str, np.ndarray],
                     partition_rows: int = 1 << 18,
                     dicts: Mapping[str, Sequence] | None = None,
                     datetimes: Sequence[str] = ()) -> NpzDirectorySource:
    os.makedirs(path, exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in arrays.items()}
    dicts = {k: list(v) for k, v in (dicts or {}).items()}
    rows = len(next(iter(arrays.values())))
    parts = []
    for pi, lo in enumerate(range(0, rows, partition_rows)):
        hi = min(lo + partition_rows, rows)
        part = {k: a[lo:hi] for k, a in arrays.items()}
        fname = f"part-{pi:05d}.npz"
        np.savez(os.path.join(path, fname), **part)
        parts.append({"file": fname, "rows": hi - lo, "zonemap": _zonemap(part)})
    cols = {}
    for name, arr in arrays.items():
        cols[name] = {"dtype": str(arr.dtype), "is_dict": name in dicts,
                      "is_datetime": name in datetimes}
    meta = {"partitions": parts, "columns": cols, "dicts": dicts}
    with open(os.path.join(path, "_meta.json"), "w") as f:
        json.dump(meta, f)
    # stats sidecar at ingest: reopening never rescans data even if the
    # partition list is later rewritten without stats
    from repro.io import sidecar as SC
    SC.write_sidecar(path, parts, columns=cols, dicts=dicts,
                     datetimes=list(datetimes),
                     data_files=[os.path.join(path, p["file"])
                                 for p in parts])
    return NpzDirectorySource(path)


def encode_strings(values: Sequence[str]) -> tuple[np.ndarray, list]:
    """Dictionary-encode a string column (paper §3.6 category optimization)."""
    vocab, codes = np.unique(np.asarray(values, dtype=object), return_inverse=True)
    return codes.astype(np.int32), [str(v) for v in vocab]


def narrow_arrays(arrays: Mapping[str, np.ndarray],
                  float32: bool = True) -> dict[str, np.ndarray]:
    """Metadata-driven dtype narrowing (paper §3.6): ints to the smallest
    width that fits; float64→float32 when allowed."""
    out = {}
    for name, arr in arrays.items():
        if arr.dtype.kind == "i" and arr.size:
            out[name] = arr.astype(narrow_int_dtype(int(arr.min()), int(arr.max())))
        elif arr.dtype == np.float64 and float32:
            out[name] = arr.astype(np.float32)
        else:
            out[name] = arr
    return out
