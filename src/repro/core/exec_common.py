"""Physical operator implementations shared by the backends.

A "table" is ``dict[str, array]`` of equal-length 1-D columns; arrays are
either numpy (host / streaming backend) or jax (eager device backend) — the
ops below dispatch on the array type.  Group-by and filter have Pallas TPU
kernel counterparts in ``repro.kernels`` (selected via ``repro.kernels.ops``);
these jnp paths double as their oracles' production fallback.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp

Table = dict


def is_jax(arr) -> bool:
    return isinstance(arr, jax.Array)


def xp_of(table: Table):
    for v in table.values():
        return jnp if is_jax(v) else np
    return np


def table_rows(table: Table) -> int:
    for v in table.values():
        return int(v.shape[0])
    return 0


def table_nbytes(table: Table) -> int:
    return sum(int(v.nbytes) for v in table.values())


def to_numpy(table: Table) -> Table:
    return {k: np.asarray(v) for k, v in table.items()}


def to_jax(table: Table) -> Table:
    return {k: jnp.asarray(v) for k, v in table.items()}


# ---------------------------------------------------------------------------
# Segment handoff (operator-granular hybrid placement)
#
# When the planner splits one plan across engines, values crossing a segment
# boundary are normalized to host representation: tables become numpy column
# dicts, device scalars become python numbers.  This is the explicit
# materialization the cost model charges as transfer at every cut edge.


def to_host_value(value):
    """Normalize a segment output for transfer to another engine."""
    if isinstance(value, dict):
        return to_numpy(value)
    if isinstance(value, (jax.Array, np.generic)):
        arr = np.asarray(value)
        return arr.item() if arr.ndim == 0 else arr
    return value


def handoff_value(node, device_arrays: bool = False):
    """Evaluate a ``graph.Handoff`` leaf inside a backend: return its
    pre-materialized payload, converting tables onto the device when the
    consuming engine wants device-resident columns."""
    v = node.value
    if isinstance(v, dict):
        return to_jax(v) if device_arrays else v
    return v


# ---------------------------------------------------------------------------
# Row-preserving ops


def apply_filter(table: Table, predicate) -> Table:
    mask = predicate.evaluate(table)
    # boolean advanced indexing works eagerly for both np and jnp
    return {k: v[mask] for k, v in table.items()}


def apply_project(table: Table, columns: Sequence[str]) -> Table:
    return {c: table[c] for c in columns}


def apply_assign(table: Table, name: str, expr) -> Table:
    out = dict(table)
    val = expr.evaluate(table)
    xp = xp_of(table)
    if np.isscalar(val) or getattr(val, "ndim", 1) == 0:
        val = xp.full((table_rows(table),), val)
    out[name] = val
    return out


def apply_rename(table: Table, mapping: Mapping[str, str]) -> Table:
    return {mapping.get(k, k): v for k, v in table.items()}


def apply_astype(table: Table, dtypes: Mapping[str, str]) -> Table:
    out = dict(table)
    for c, dt in dtypes.items():
        out[c] = out[c].astype(dt)
    return out


def apply_fillna(table: Table, value, columns=None) -> Table:
    xp = xp_of(table)
    out = dict(table)
    for c in (columns or table.keys()):
        arr = out[c]
        if arr.dtype.kind == "f":
            out[c] = xp.where(xp.isnan(arr), xp.asarray(value, dtype=arr.dtype), arr)
    return out


def apply_head(table: Table, n: int) -> Table:
    return {k: v[:n] for k, v in table.items()}


def apply_sort(table: Table, by: Sequence[str], ascending: bool = True) -> Table:
    xp = xp_of(table)
    # lexsort: last key is primary in np.lexsort; jnp has lexsort too.
    keys = tuple(table[b] for b in reversed(by))
    idx = xp.lexsort(keys) if len(keys) > 1 else xp.argsort(keys[0], stable=True)
    if not ascending:
        idx = idx[::-1]
    return {k: v[idx] for k, v in table.items()}


def apply_drop_duplicates(table: Table, subset=None) -> Table:
    cols = list(subset) if subset else list(table.keys())
    codes, _ = _factorize_multi(table, cols)
    xp = xp_of(table)
    if xp is jnp:
        _, first_idx = jnp.unique(codes, return_index=True)
        idx = jnp.sort(first_idx)
    else:
        _, first_idx = np.unique(codes, return_index=True)
        idx = np.sort(first_idx)
    return {k: v[idx] for k, v in table.items()}


def apply_map_rows(table: Table, fn) -> Table:
    return fn(dict(table))


# ---------------------------------------------------------------------------
# Group-by aggregation


def _factorize(arr):
    """codes, uniques — order of uniques is sorted-value order."""
    if is_jax(arr):
        uniques, codes = jnp.unique(arr, return_inverse=True)
    else:
        uniques, codes = np.unique(arr, return_inverse=True)
    return codes, uniques


def _factorize_multi(table: Table, cols: Sequence[str]):
    """Multi-column factorize via mixed-radix combination.

    Returns (codes, key_arrays_fn) where key_arrays_fn(group_codes) maps the
    final group code array back to per-column key values.
    """
    per = []
    radices = []
    for c in cols:
        codes, uniques = _factorize(table[c])
        per.append((codes, uniques))
        radices.append(int(uniques.shape[0]))
    xp = jnp if is_jax(per[0][0]) else np
    combined = per[0][0].astype(np.int64 if xp is np else jnp.int32)
    for (codes, _), r in zip(per[1:], radices[1:]):
        combined = combined * r + codes

    def decode(group_codes):
        out = {}
        rem = group_codes
        for (c, (_, uniques)), r in zip(
                reversed(list(zip(cols, per))), reversed(radices)):
            out[c] = uniques[rem % r]
            rem = rem // r
        return out

    return combined, decode


def apply_groupby_agg(table: Table, keys: Sequence[str],
                      aggs: Mapping[str, tuple[str, str]]) -> Table:
    """Dense aggregation: factorize keys → segment reductions.

    This jnp/np path is also the oracle for the MXU one-hot kernel
    (``repro.kernels.groupby_sum``)."""
    combined, decode = _factorize_multi(table, list(keys))
    if is_jax(combined):
        groups, inv = jnp.unique(combined, return_inverse=True)
        num = int(groups.shape[0])
        out = decode(groups)
        for out_name, (col, fn) in aggs.items():
            out[out_name] = _segment_agg_jax(table, col, fn, inv, num)
    else:
        groups, inv = np.unique(combined, return_inverse=True)
        num = int(groups.shape[0])
        out = decode(groups)
        for out_name, (col, fn) in aggs.items():
            out[out_name] = _segment_agg_np(table, col, fn, inv, num)
    return out


def _segment_agg_jax(table, col, fn, seg_ids, num):
    ones = jnp.ones((seg_ids.shape[0],), jnp.float32)
    if fn == "count":
        return jax.ops.segment_sum(ones, seg_ids, num).astype(jnp.int64)
    vals = table[col]
    if vals.dtype.kind in "iub" and vals.dtype.itemsize < 4:
        vals = vals.astype(jnp.int32)   # widen narrow ints: no int8 accumulate
    if fn == "sum":
        return jax.ops.segment_sum(vals, seg_ids, num)
    if fn == "mean":
        s = jax.ops.segment_sum(vals.astype(jnp.float32), seg_ids, num)
        c = jax.ops.segment_sum(ones, seg_ids, num)
        return s / c
    if fn == "min":
        return jax.ops.segment_min(vals, seg_ids, num)
    if fn == "max":
        return jax.ops.segment_max(vals, seg_ids, num)
    if fn == "nunique":
        sub_codes, _ = _factorize(vals)
        pair = seg_ids.astype(jnp.int64) * (jnp.max(sub_codes) + 1) + sub_codes
        uniq_pairs = jnp.unique(pair)
        seg_of_pair = uniq_pairs // (jnp.max(sub_codes) + 1)
        return jax.ops.segment_sum(jnp.ones_like(seg_of_pair), seg_of_pair, num)
    raise ValueError(f"unknown agg fn {fn}")


def _segment_agg_np(table, col, fn, seg_ids, num):
    if fn == "count":
        return np.bincount(seg_ids, minlength=num).astype(np.int64)
    vals = table[col]
    if fn == "sum":
        return np.bincount(seg_ids, weights=vals, minlength=num).astype(
            vals.dtype if vals.dtype.kind == "f" else np.float64)
    if fn == "mean":
        s = np.bincount(seg_ids, weights=vals.astype(np.float64), minlength=num)
        c = np.bincount(seg_ids, minlength=num)
        return s / np.maximum(c, 1)
    if fn in ("min", "max"):
        out = np.full(num, np.inf if fn == "min" else -np.inf, dtype=np.float64)
        ufn = np.minimum if fn == "min" else np.maximum
        ufn.at(out, seg_ids, vals.astype(np.float64))
        return out.astype(vals.dtype) if vals.dtype.kind == "f" else out
    if fn == "nunique":
        sub_codes, _ = _factorize(vals)
        pair = seg_ids.astype(np.int64) * (int(sub_codes.max()) + 1) + sub_codes
        uniq = np.unique(pair)
        seg = (uniq // (int(sub_codes.max()) + 1)).astype(np.int64)
        return np.bincount(seg, minlength=num).astype(np.int64)
    raise ValueError(f"unknown agg fn {fn}")


# partial/combine pairs for the streaming backend (out-of-core group-by).

_PARTIAL_FORMS = {
    "sum": ["sum"], "count": ["count"], "min": ["min"], "max": ["max"],
    "mean": ["sum", "count"],
}


def partial_aggs(aggs: Mapping[str, tuple[str, str]]):
    """Decompose logical aggs into partial aggs computable per partition."""
    partial = {}
    for out_name, (col, fn) in aggs.items():
        for p in _PARTIAL_FORMS[fn]:
            partial[f"{out_name}::{p}"] = (col, p)
    return partial


def combine_partials(keys, parts: list[Table],
                     aggs: Mapping[str, tuple[str, str]]) -> Table:
    """Re-aggregate concatenated per-partition partials, then finalize."""
    xp = jnp if (parts and is_jax(next(iter(parts[0].values())))) else np
    concat = {k: xp.concatenate([p[k] for p in parts]) for k in parts[0]}
    combine_spec = {}
    for pname in concat:
        if "::" not in pname:
            continue
        _out, p = pname.rsplit("::", 1)
        combine_spec[pname] = (pname, "max" if p == "max" else
                               ("min" if p == "min" else "sum"))
    merged = apply_groupby_agg(concat, list(keys), combine_spec)
    out = {k: merged[k] for k in keys}
    for out_name, (_col, fn) in aggs.items():
        if fn == "mean":
            out[out_name] = (merged[f"{out_name}::sum"] /
                             xp.maximum(merged[f"{out_name}::count"], 1))
        elif fn == "count":
            # combining count partials goes through a weighted-sum path that
            # widens to float; counts are integral (pandas conformance)
            out[out_name] = merged[f"{out_name}::count"].astype(
                np.int64 if xp is np else jnp.int64)
        else:
            out[out_name] = merged[f"{out_name}::{fn}"]
    return out


# ---------------------------------------------------------------------------
# Reductions

def apply_reduce(table: Table, column: str | None, fn: str):
    xp = xp_of(table)
    if fn == "count":
        return table_rows(table) if column is None else int(table[column].shape[0])
    vals = table[column]
    if xp is jnp and vals.dtype.kind in "iub" and vals.dtype.itemsize < 4:
        vals = vals.astype(jnp.int32)   # widen: no int8 accumulation
    if fn == "sum":
        return xp.sum(vals)
    if fn == "mean":
        return xp.mean(vals.astype(xp.float64 if xp is np else jnp.float32))
    if fn == "min":
        return xp.min(vals)
    if fn == "max":
        return xp.max(vals)
    if fn == "nunique":
        return int(xp.unique(vals).shape[0])
    raise ValueError(fn)


REDUCE_PARTIAL = {
    "sum": ("sum", lambda xs, xp: xp.sum(xp.asarray(xs))),
    "min": ("min", lambda xs, xp: xp.min(xp.asarray(xs))),
    "max": ("max", lambda xs, xp: xp.max(xp.asarray(xs))),
    "count": ("count", lambda xs, xp: int(np.sum(xs))),
}


# ---------------------------------------------------------------------------
# Join (host-side hash/sort join; build side = right)


def apply_join(left: Table, right: Table, on: Sequence[str], how="inner",
               suffixes=("_x", "_y")) -> Table:
    lj, rj = to_numpy(left), to_numpy(right)
    was_jax = xp_of(left) is jnp
    lkeys, _ = _factorize_multi_np_pair(lj, rj, on)
    lcode, rcode = lkeys
    order = np.argsort(rcode, kind="stable")
    rsorted = rcode[order]
    lo = np.searchsorted(rsorted, lcode, side="left")
    hi = np.searchsorted(rsorted, lcode, side="right")
    counts = hi - lo
    if how == "inner":
        l_idx = np.repeat(np.arange(lcode.shape[0]), counts)
        starts = np.repeat(lo, counts)
        within = np.arange(l_idx.shape[0]) - np.repeat(
            np.cumsum(counts) - counts, counts)
        r_idx = order[starts + within]
    elif how == "left":
        counts2 = np.maximum(counts, 1)
        l_idx = np.repeat(np.arange(lcode.shape[0]), counts2)
        starts = np.repeat(lo, counts2)
        within = np.arange(l_idx.shape[0]) - np.repeat(
            np.cumsum(counts2) - counts2, counts2)
        matched = np.repeat(counts > 0, counts2)
        r_idx = np.where(matched, order[np.minimum(starts + within,
                                                   len(order) - 1)], -1)
    else:
        raise ValueError(f"join how={how!r} not supported")
    out = {}
    overlap = (set(lj) & set(rj)) - set(on)
    for k in on:
        out[k] = lj[k][l_idx]
    for k, v in lj.items():
        if k in on:
            continue
        out[k + suffixes[0] if k in overlap else k] = v[l_idx]
    for k, v in rj.items():
        if k in on:
            continue
        name = k + suffixes[1] if k in overlap else k
        col = v[np.maximum(r_idx, 0)]
        if how == "left" and col.dtype.kind == "f":
            col = np.where(r_idx >= 0, col, np.nan)
        out[name] = col
    if was_jax:
        out = to_jax(out)
    return out


def _factorize_multi_np_pair(lt: Table, rt: Table, on: Sequence[str]):
    """Factorize join keys over the union of both sides so codes align."""
    lcode = np.zeros(len(next(iter(lt.values()))), np.int64)
    rcode = np.zeros(len(next(iter(rt.values()))), np.int64)
    for c in on:
        both = np.concatenate([np.asarray(lt[c]), np.asarray(rt[c])])
        uniques, codes = np.unique(both, return_inverse=True)
        lc = codes[: len(lt[c])]
        rc = codes[len(lt[c]):]
        lcode = lcode * len(uniques) + lc
        rcode = rcode * len(uniques) + rc
    return (lcode, rcode), None


def apply_concat(tables: list[Table]) -> Table:
    xp = xp_of(tables[0])
    cols = set(tables[0])
    for t in tables[1:]:
        cols &= set(t)
    return {c: xp.concatenate([t[c] for t in tables]) for c in sorted(cols)}
