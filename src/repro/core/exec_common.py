"""Back-compat shim — the physical operators moved to
``repro.core.physical`` (the unified physical-operator layer shared by all
backends).  Import from there in new code; this module re-exports the full
surface so existing ``from .. import exec_common as X`` call sites keep
working unchanged.
"""
from __future__ import annotations

from .physical import *  # noqa: F401,F403
from .physical import __all__  # noqa: F401
