"""Live-DataFrame-driven persist planning (paper §3.5).

At a force point, frames live *after* the point (known from JIT static
analysis, or passed explicitly as ``live_df=[...]``) identify shared
subexpressions between the forced task graph and future computations; those
nodes are marked ``persist`` and cached across force points.  Cache entries
are evicted once no longer a subexpression of any live frame (paper's
last-use discard rule).
"""
from __future__ import annotations

from . import graph as G
from .context import LaFPContext


def plan_persists(roots: list[G.Node], live_nodes: list[G.Node]) -> set[int]:
    """Mark shared subexpressions: nodes that (a) define a live frame or are
    maximal shared nodes between the forced graph and a live frame's graph."""
    forced = {n.id for n in G.walk(roots)}
    persist: set[int] = set()
    for ln in live_nodes:
        live_reach = G.walk([ln])
        shared = [n for n in live_reach if n.id in forced]
        if not shared:
            continue
        shared_ids = {n.id for n in shared}
        if ln.id in forced:
            persist.add(ln.id)
            continue
        # maximal shared nodes: shared nodes none of whose parents (within the
        # live frame's graph) are shared
        pmap = G.parents_map([ln])
        for n in shared:
            ps = pmap.get(n.id, [])
            if not any(p.id in shared_ids for p in ps):
                persist.add(n.id)
    return persist


def apply_persist_marks(roots: list[G.Node], persist_ids: set[int]) -> None:
    for n in G.walk(roots):
        if n.id in persist_ids:
            n.persist = True


def evict_dead_entries(ctx: LaFPContext, live_nodes: list[G.Node]) -> int:
    """Drop cache entries that are no longer subexpressions of live frames
    (paper: 'discarded after their last use')."""
    if not ctx.persist_cache:
        return 0
    live_keys = set()
    for n in G.walk(live_nodes):
        live_keys.add(n.key())
    dead = [k for k in ctx.persist_cache if k not in live_keys]
    for k in dead:
        del ctx.persist_cache[k]
    return len(dead)
