"""Segment-level rowwise fusion (the last optimizer stage).

Collapses maximal single-consumer chains of rowwise operators
(filter/project/assign/rename/astype/fillna — ``Clip``/``Round`` ride along
inside Assign expressions) into one :class:`graph.FusedRowwise` node, the
same move as Dask's low-level ``fuse`` pass.  The physical layer then
executes the whole chain as a single composed pass: one jitted device
dispatch on the jnp path (``physical.rowwise.apply_fused_rowwise``, which
compacts Filter survivors with the ``repro.kernels`` filter_compact kernel)
and one chunk-loop body on the streaming path — no intermediate tables
between members.

Safety mirrors the pushdown rules: interior nodes must have exactly one
consumer, no persist mark (a planned §3.5 materialization point), no side
effects, and no opaque UDF in their expressions (a UDF may close over numpy
calls that cannot trace through jit).  ``session(fusion=False)`` disables
the pass; each applied fusion emits a ``PlannerEvent(kind="fuse")`` and the
``fuse.applied`` metric.
"""
from __future__ import annotations

import dataclasses

from . import expr as E
from . import graph as G

FUSABLE_OPS = ("filter", "project", "assign", "rename", "astype", "fillna")


def _expr_has_udf(x) -> bool:
    if isinstance(x, E.UDF):
        return True
    if isinstance(x, E.Expr):
        return any(_expr_has_udf(getattr(x, f.name))
                   for f in dataclasses.fields(x))
    if isinstance(x, (tuple, list)):
        return any(_expr_has_udf(v) for v in x)
    return False


def _fusable(n: G.Node) -> bool:
    if n.op not in FUSABLE_OPS or n.persist or n.has_side_effects():
        return False
    return not (_expr_has_udf(getattr(n, "predicate", None))
                or _expr_has_udf(getattr(n, "expr", None)))


def fuse_rowwise_chains(roots: list[G.Node], ctx=None, trace=None
                        ) -> tuple[list[G.Node], dict[int, G.Node]]:
    """Collapse every maximal fusable chain of length ≥ 2; returns
    (new_roots, idmap) like the other optimizer rules."""
    from .optimizer import _rebuild
    parents = G.parents_map(roots)
    root_ids = {r.id for r in roots}

    def extends_down(n: G.Node) -> bool:
        # n's child can join n's chain: fusable, single-consumer, and not
        # itself a force-point root (its value must stay addressable)
        c = n.inputs[0]
        return (_fusable(c) and c.id not in root_ids
                and len(parents.get(c.id, [])) == 1)

    replace: dict[int, G.Node] = {}
    consumed: set[int] = set()
    for n in reversed(G.walk(roots)):        # parents before children
        if n.id in consumed or not _fusable(n) or not extends_down(n):
            continue
        members = [n]
        while extends_down(members[-1]):
            members.append(members[-1].inputs[0])
        consumed.update(m.id for m in members)
        child = members[-1].inputs[0]
        fused = G.FusedRowwise(child, tuple(reversed(members)))
        G.copy_runtime_flags(n, fused)
        replace[n.id] = fused
        op_list = ",".join(m.op for m in fused.ops)
        if ctx is not None:
            from ..obs import PlannerEvent
            ctx.planner_trace.append(PlannerEvent(
                f"fuse: {len(fused.ops)} rowwise ops [{op_list}] "
                f"into fused_rowwise",
                kind="fuse", head=n.id, n_ops=len(fused.ops),
                ops=[m.op for m in fused.ops]))
            metrics = getattr(ctx, "metrics", None)
            if metrics is not None:
                metrics.inc("fuse.applied")
        if trace is not None:
            trace.append(f"fuse_rowwise #{n.id}: [{op_list}]")
    if not replace:
        return roots, {}
    return _rebuild(roots, replace)
