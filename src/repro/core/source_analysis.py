"""JIT static analysis (paper §2.2–§2.4, §3.1, §3.5), on Python `ast`.

The paper converts source → SCIRPy (a Soot IR) and runs dataflow analyses.
The analyses themselves are IR-agnostic; we build a statement-level CFG from
`ast` and run the same backward Gen/Kill fixpoint:

* **Live Attribute Analysis (LAA)** — per (frame, column) liveness with the
  paper's rules: whole-frame use gens ALL, frame (re)definition kills ALL,
  derived-frame liveness flows to sources, aggregates kill all but key/agg
  columns, `head/info/describe` ignored (paper's heuristic).
* **Live DataFrame Analysis (LDA)** — which frame vars are live after each
  program point; consumed at force points for persist planning (`live_df`).
* **read-site usecols** — live columns at each `read_*` call (column
  selection, Fig. 4).
* **read-only columns** — never-assigned columns, the §3.6 guard for
  category/dtype narrowing.

Results go into ``LaFPContext.analysis`` keyed by source line number; the
lazy runtime looks them up by call-site reflection (this replaces the paper's
source rewriting — semantically it is the same `usecols=[...]` /
``live_df=[...]`` injection).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable

ALL = "<ALL>"

_READ_FNS = {"read_csv", "read_parquet", "read_npz", "read_source",
             "from_arrays", "read_table"}
_IGNORED_METHODS = {"head", "info", "describe"}  # paper §3.1 heuristic
_FRAME_METHODS_IDENTITY = {
    "sort_values", "drop_duplicates", "fillna", "astype", "rename", "assign",
    "head", "copy", "reset_index",
}
_FORCE_METHODS = {"compute", "materialize", "to_numpy_table"}


@dataclasses.dataclass
class StmtNode:
    stmt: ast.stmt
    succs: list[int] = dataclasses.field(default_factory=list)
    gen: set = dataclasses.field(default_factory=set)
    kill: set = dataclasses.field(default_factory=set)
    out: set = dataclasses.field(default_factory=set)
    inn: set = dataclasses.field(default_factory=set)


class AnalysisResult:
    def __init__(self):
        self.usecols: dict[int, list[str] | None] = {}   # read lineno -> cols
        self.live_at: dict[int, list[str]] = {}          # force lineno -> frame vars
        self.readonly_cols: set[str] = set()
        self.assigned_cols: set[str] = set()
        self.frame_vars: set[str] = set()
        self.all_used_cols: set[str] = set()

    def as_context_dict(self) -> dict:
        return {
            "usecols": self.usecols,
            "live_at": self.live_at,
            "readonly_cols": (self.all_used_cols - self.assigned_cols),
            "frame_vars": self.frame_vars,
            "scan_extra_cols": {},
        }


# ---------------------------------------------------------------------------
# CFG construction


def _build_cfg(body: list[ast.stmt]) -> list[StmtNode]:
    nodes: list[StmtNode] = []

    def add(stmt) -> int:
        nodes.append(StmtNode(stmt))
        return len(nodes) - 1

    def seq(stmts: list[ast.stmt], preds: list[int]) -> list[int]:
        """Wire statements sequentially; returns exit node ids."""
        cur = preds
        for s in stmts:
            if isinstance(s, ast.If):
                i = add(s)  # condition evaluation node
                for p in cur:
                    nodes[p].succs.append(i)
                then_exits = seq(s.body, [i])
                else_exits = seq(s.orelse, [i]) if s.orelse else [i]
                cur = then_exits + else_exits
            elif isinstance(s, (ast.For, ast.While)):
                i = add(s)  # header
                for p in cur:
                    nodes[p].succs.append(i)
                body_exits = seq(s.body, [i])
                for e in body_exits:
                    nodes[e].succs.append(i)  # back edge
                cur = [i] + (seq(s.orelse, [i]) if s.orelse else [])
            elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                i = add(s)
                for p in cur:
                    nodes[p].succs.append(i)
                cur = [i]
            elif isinstance(s, ast.With):
                i = add(s)
                for p in cur:
                    nodes[p].succs.append(i)
                cur = seq(s.body, [i])
            elif isinstance(s, ast.Try):
                i = add(s)
                for p in cur:
                    nodes[p].succs.append(i)
                body_exits = seq(s.body, [i])
                handler_exits = []
                for h in s.handlers:
                    handler_exits += seq(h.body, [i] + body_exits)
                final_preds = body_exits + handler_exits
                cur = seq(s.finalbody, final_preds) if s.finalbody else final_preds
            else:
                i = add(s)
                for p in cur:
                    nodes[p].succs.append(i)
                cur = [i]
        return cur

    seq(body, [])
    return nodes


# ---------------------------------------------------------------------------
# Expression inspection


def _const_str_list(node) -> list[str] | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.List, ast.Tuple)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
            else:
                return None
        return out
    return None


class _ExprUses(ast.NodeVisitor):
    """Collect (frame, col) uses from an expression (Gen set contribution),
    plus frame derivation sources."""

    _AGG_METHODS = {"sum", "mean", "min", "max", "count", "nunique", "size",
                    "agg", "groupby"}

    def __init__(self, frame_vars: set[str]):
        self.frame_vars = frame_vars
        self.uses: set[tuple[str, str]] = set()
        self.sources: set[str] = set()       # all frames this expr derives from
        # identity derivations propagate the derived frame's live columns to
        # the source 1:1; aggregation derivations cut liveness (paper §3.1:
        # "aggregates kill all columns except those used in the aggregate or
        # groupby") — their uses are recorded explicitly instead.
        self.identity_sources: set[str] = set()

    def _frame_name(self, node) -> str | None:
        if isinstance(node, ast.Name) and node.id in self.frame_vars:
            return node.id
        return None

    def visit_Name(self, node: ast.Name):
        # bare frame reference (passed around / f-string / alias): whole use
        if isinstance(node.ctx, ast.Load) and node.id in self.frame_vars:
            self.uses.add((node.id, ALL))
            self.sources.add(node.id)
            self.identity_sources.add(node.id)

    def visit_Attribute(self, node: ast.Attribute):
        f = self._frame_name(node.value)
        if f is not None:
            attr = node.attr
            if attr in _IGNORED_METHODS:
                self.sources.add(f)
                return
            if attr in ("dt", "str"):
                # accessor chains: df.col.dt.x — handled by recursion below
                self.visit(node.value)
                return
            if attr in self._AGG_METHODS:
                self.sources.add(f)
                return
            if attr in _FRAME_METHODS_IDENTITY or attr in _FORCE_METHODS \
                    or attr in ("merge", "apply", "loc", "iloc"):
                self.sources.add(f)
                self.identity_sources.add(f)
                return
            # plain column attribute access
            self.uses.add((f, attr))
            self.sources.add(f)
            self.identity_sources.add(f)
            return
        self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript):
        root = self._chain_root(node.value)
        if root is not None:
            cols = _const_str_list(node.slice)
            if cols is not None:
                for c in cols:
                    self.uses.add((root, c))
            else:
                # boolean-mask / expression subscript: visit the index expr
                self.visit(node.slice)
            self.sources.add(root)
            # subscripting an aggregation chain is not identity; a direct
            # frame subscript is
            if self._frame_name(node.value) is not None:
                self.identity_sources.add(root)
            if self._frame_name(node.value) is None:
                self.visit(node.value)
            return
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        # method chains on frames: df.groupby('k')['c'].sum(), df.merge(d2,on=)
        fn = node.func
        if isinstance(fn, ast.Attribute):
            base = fn.value
            # find root frame of the chain
            root = self._chain_root(base)
            if root is not None:
                if fn.attr in _IGNORED_METHODS:
                    self.sources.add(root)
                    return
                self._chain_uses(node, root)
                self.sources.add(root)
                return
        # plain call: frames passed as args are whole-frame uses
        for arg in list(node.args) + [k.value for k in node.keywords]:
            f = self._frame_name(arg)
            if f is not None:
                self.uses.add((f, ALL))
                self.sources.add(f)
            else:
                self.visit(arg)
        if isinstance(fn, ast.Attribute) and self._frame_name(fn.value) is None:
            self.visit(fn.value)

    def _chain_root(self, node) -> str | None:
        while True:
            f = self._frame_name(node)
            if f is not None:
                return f
            if isinstance(node, ast.Attribute):
                node = node.value
            elif isinstance(node, ast.Subscript):
                node = node.value
            elif isinstance(node, ast.Call) and isinstance(node.func,
                                                           ast.Attribute):
                node = node.func.value
            else:
                return None

    def _chain_uses(self, call: ast.Call, root: str):
        """Extract column uses from a method-call chain rooted at a frame."""
        fn = call.func
        method = fn.attr if isinstance(fn, ast.Attribute) else None
        if method == "groupby":
            cols = _const_str_list(call.args[0]) if call.args else None
            for c in cols or []:
                self.uses.add((root, c))
        elif method == "merge":
            self.identity_sources.add(root)
            for kw in call.keywords:
                if kw.arg == "on":
                    for c in _const_str_list(kw.value) or []:
                        self.uses.add((root, c))
            for a in call.args:
                f = self._frame_name(a)
                if f is not None:
                    self.sources.add(f)
                    self.identity_sources.add(f)
        elif method in ("sort_values", "drop_duplicates"):
            self.identity_sources.add(root)
            args = list(call.args) + [k.value for k in call.keywords]
            for a in args:
                for c in _const_str_list(a) or []:
                    self.uses.add((root, c))
        elif method in ("sum", "mean", "min", "max", "count", "nunique",
                        "size", "agg"):
            pass  # uses come from the inner subscript/groupby visited below
        elif method in _FRAME_METHODS_IDENTITY or method in _FORCE_METHODS:
            self.identity_sources.add(root)
        elif method is not None:
            # unknown method on a frame: conservative whole-frame use
            self.uses.add((root, ALL))
            self.identity_sources.add(root)
        # recurse into the chain below the call and into args — but do not
        # re-visit the bare root Name (that would spuriously gen ALL)
        if isinstance(fn, ast.Attribute) and self._frame_name(fn.value) is None:
            self.visit(fn.value)
        for a in call.args:
            if _const_str_list(a) is None and self._frame_name(a) is None:
                self.visit(a)


# ---------------------------------------------------------------------------
# Main analysis


def _top_level_identity(expr, frames: set[str]) -> set[str]:
    """Frames whose live columns map 1:1 into a var assigned this expr.
    Aggregation chains (groupby/sum/mean/...) cut the mapping (paper §3.1
    aggregate-kill rule); row-preserving forms (subscript, sort, fillna,
    merge, alias) propagate it."""
    helper = _ExprUses(frames)
    if isinstance(expr, ast.Name):
        return {expr.id} if expr.id in frames else set()
    if isinstance(expr, ast.Subscript):
        f = helper._frame_name(expr.value)
        return {f} if f is not None else set()
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Attribute):
        method = expr.func.attr
        root = helper._chain_root(expr.func.value)
        if root is None:
            return set()
        if method in _ExprUses._AGG_METHODS:
            return set()
        out = {root} if method in (_FRAME_METHODS_IDENTITY | {"merge"}) else set()
        if method == "merge":
            for a in expr.args:
                f = helper._frame_name(a)
                if f is not None:
                    out.add(f)
        return out
    return set()


def _is_read_call(node) -> bool:
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else None)
    return name in _READ_FNS


def _frame_vars_pass(nodes: list[StmtNode]) -> set[str]:
    """Flow-insensitive: vars assigned from read_* or derived from frames."""
    frames: set[str] = set()
    changed = True
    while changed:
        changed = False
        for sn in nodes:
            s = sn.stmt
            if isinstance(s, ast.Assign) and len(s.targets) == 1 \
                    and isinstance(s.targets[0], ast.Name):
                tgt = s.targets[0].id
                if tgt in frames:
                    continue
                if _is_read_call(s.value):
                    frames.add(tgt)
                    changed = True
                    continue
                u = _ExprUses(frames)
                u.visit(s.value)
                if u.sources and _produces_frame(s.value, frames):
                    frames.add(tgt)
                    changed = True
    return frames


def _produces_frame(expr, frames: set[str]) -> bool:
    """Heuristic: subscripts/method-chains on frames produce frames (scalars
    from reductions are also fine to treat as frames for liveness)."""
    if isinstance(expr, ast.Subscript):
        root = _ExprUses(frames)._chain_root(expr.value)
        return root is not None
    if isinstance(expr, ast.Call):
        root = _ExprUses(frames)._chain_root(expr)
        return root is not None
    if isinstance(expr, ast.Attribute):
        return _ExprUses(frames)._chain_root(expr) is not None
    return False


def analyze_source(source: str) -> AnalysisResult:
    tree = ast.parse(source)
    body = tree.body
    # unwrap a single function def (decorator use)
    if len(body) == 1 and isinstance(body[0], ast.FunctionDef):
        body = body[0].body
    nodes = _build_cfg(body)
    res = AnalysisResult()
    frames = _frame_vars_pass(nodes)
    res.frame_vars = frames

    # Gen/Kill per statement (paper equations (1)/(2))
    read_sites: dict[int, tuple[int, str]] = {}   # node idx -> (lineno, var)
    force_sites: list[tuple[int, int]] = []       # (node idx, lineno)
    for idx, sn in enumerate(nodes):
        s = sn.stmt
        gen: set = set()
        kill: set = set()
        if isinstance(s, ast.Assign) and len(s.targets) == 1:
            tgt = s.targets[0]
            if isinstance(tgt, ast.Name) and tgt.id in frames:
                # frame (re)definition kills all its columns
                kill.add((tgt.id, ALL))
                if _is_read_call(s.value):
                    read_sites[idx] = (s.lineno, tgt.id)
                else:
                    u = _ExprUses(frames)
                    u.visit(s.value)
                    gen |= u.uses
                    # derived-frame rule handled in transfer; only identity
                    # derivations propagate live columns 1:1
                    sn.derives_from = _top_level_identity(s.value, frames)  # type: ignore[attr-defined]
            elif isinstance(tgt, ast.Subscript):
                f = tgt.value.id if isinstance(tgt.value, ast.Name) else None
                cols = _const_str_list(tgt.slice)
                if f in frames and cols:
                    for c in cols:
                        kill.add((f, c))
                        res.assigned_cols.add(c)
                u = _ExprUses(frames)
                u.visit(s.value)
                gen |= u.uses
            else:
                u = _ExprUses(frames)
                u.visit(s.value)
                gen |= u.uses
        else:
            for sub in ast.walk(s):
                if isinstance(sub, ast.Call) and isinstance(sub.func,
                                                            ast.Attribute) \
                        and sub.func.attr in _FORCE_METHODS:
                    force_sites.append((idx, sub.lineno))
            u = _ExprUses(frames)
            if isinstance(s, (ast.Expr, ast.Return)) and s.value is not None:
                u.visit(s.value)
            elif isinstance(s, (ast.If, ast.While)):
                u.visit(s.test)
            elif isinstance(s, ast.For):
                u.visit(s.iter)
            elif isinstance(s, ast.AugAssign):
                u.visit(s.value)
                u.visit(s.target)
            gen |= u.uses
        sn.gen = gen
        sn.kill = kill
        for (_f, c) in gen:
            if c != ALL:
                res.all_used_cols.add(c)

    # Backward fixpoint: Out = ∪ In(succ); In = Gen ∪ (Out − Kill),
    # with the derived-frame rule: liveness of a derived frame adds liveness
    # of mapped columns on its sources (identity mapping, conservative).
    changed = True
    iters = 0
    while changed and iters < 200:
        iters += 1
        changed = False
        for sn in reversed(nodes):
            out = set()
            for succ in sn.succs:
                out |= nodes[succ].inn
            inn = set(sn.gen)
            s = sn.stmt
            # derived-frame liveness propagation
            if isinstance(s, ast.Assign) and len(s.targets) == 1 and \
                    isinstance(s.targets[0], ast.Name) and \
                    s.targets[0].id in frames and \
                    hasattr(sn, "derives_from"):
                tgt = s.targets[0].id
                tgt_live = {c for (f, c) in out if f == tgt}
                for src in sn.derives_from:  # type: ignore[attr-defined]
                    for c in tgt_live:
                        inn.add((src, c))
            kill_frames = {f for (f, c) in sn.kill if c == ALL}
            kill_cols = {(f, c) for (f, c) in sn.kill if c != ALL}
            for item in out:
                f, c = item
                if f in kill_frames or item in kill_cols:
                    continue
                inn.add(item)
            if out != sn.out or inn != sn.inn:
                sn.out = out
                sn.inn = inn
                changed = True

    # read-site usecols = live columns of the var at Out of the read stmt
    for idx, (lineno, var) in read_sites.items():
        live_cols = {c for (f, c) in nodes[idx].out if f == var}
        if ALL in live_cols:
            res.usecols[lineno] = None
        else:
            res.usecols[lineno] = sorted(live_cols)

    # force-site live frames (LDA): frames with any live column at Out
    for idx, lineno in force_sites:
        live_frames = sorted({f for (f, _c) in nodes[idx].out})
        res.live_at[lineno] = live_frames

    return res
