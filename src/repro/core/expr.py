"""Scalar / predicate expression trees.

Expressions are built by operator overloading on ``LazyColumn`` and evaluated
column-at-a-time with jnp (device) or numpy (host metadata path).  They carry
``used_cols()`` so the optimizer can compute ``used_attrs`` for pushdown
safety (paper §3.2) and liveness Gen sets (paper §3.1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import numpy as np

# Binary ops usable on device arrays.
_BINOPS: dict[str, Callable] = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "truediv": lambda a, b: a / b,
    "floordiv": lambda a, b: a // b,
    "mod": lambda a, b: a % b,
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
}

_COMPARISONS = {"eq", "ne", "lt", "le", "gt", "ge"}

# int64-epoch-seconds datetime accessors (TPU adaptation of pandas .dt).
_DT_FIELDS: dict[str, Callable] = {
    # 1970-01-01 was a Thursday; pandas dayofweek: Monday=0.
    "dayofweek": lambda ts: ((ts // 86400) + 3) % 7,
    "hour": lambda ts: (ts // 3600) % 24,
    "minute": lambda ts: (ts // 60) % 60,
    "second": lambda ts: ts % 60,
    "day": None,    # filled below (calendar math)
    "month": None,
    "year": None,
}


def _where(cond, a, b):
    """np.where that stays traceable: jax tracers (fused-chain jit) cannot
    pass through numpy, so dispatch on the condition's array type."""
    if isinstance(cond, (np.ndarray, np.generic, bool, int)):
        return np.where(cond, a, b)
    import jax.numpy as jnp
    return jnp.where(cond, a, b)


def _civil_from_days(days):
    """Days-since-epoch -> (year, month, day), vectorized (Howard Hinnant's
    algorithm, integer-only so it runs on device)."""
    z = days + 719468
    era = _where(z >= 0, z, z - 146096) // 146097
    doe = z - era * 146097
    yoe = (doe - doe // 1460 + doe // 36524 - doe // 146096) // 365
    y = yoe + era * 400
    doy = doe - (365 * yoe + yoe // 4 - yoe // 100)
    mp = (5 * doy + 2) // 153
    d = doy - (153 * mp + 2) // 5 + 1
    m = mp + _where(mp < 10, 3, -9)
    y = y + (m <= 2)
    return y, m, d


_DT_FIELDS["year"] = lambda ts: _civil_from_days(ts // 86400)[0]
_DT_FIELDS["month"] = lambda ts: _civil_from_days(ts // 86400)[1]
_DT_FIELDS["day"] = lambda ts: _civil_from_days(ts // 86400)[2]
# pandas .dt.quarter: 1-4 from the calendar month
_DT_FIELDS["quarter"] = \
    lambda ts: (_civil_from_days(ts // 86400)[1] - 1) // 3 + 1


class Expr:
    """Base class. Immutable, hashable by structure."""

    def used_cols(self) -> frozenset[str]:
        raise NotImplementedError

    def evaluate(self, cols: Mapping[str, Any]):
        raise NotImplementedError

    def key(self) -> tuple:
        raise NotImplementedError

    def __hash__(self):
        return hash(self.key())

    def __eq__(self, other):
        return isinstance(other, Expr) and self.key() == other.key()

    # -- interval arithmetic over zone maps (beyond-paper: partition pruning).
    def bounds(self, zonemaps: Mapping[str, tuple]) -> tuple | None:
        """(lo, hi) bounds of this expr given per-column (min,max); None if
        unbounded/unsupported."""
        return None


@dataclasses.dataclass(frozen=True, eq=False)
class Col(Expr):
    name: str

    def used_cols(self):
        return frozenset([self.name])

    def evaluate(self, cols):
        return cols[self.name]

    def key(self):
        return ("col", self.name)

    def bounds(self, zonemaps):
        return zonemaps.get(self.name)


@dataclasses.dataclass(frozen=True, eq=False)
class Lit(Expr):
    value: Any

    def used_cols(self):
        return frozenset()

    def evaluate(self, cols):
        return self.value

    def key(self):
        return ("lit", repr(self.value))

    def bounds(self, zonemaps):
        if isinstance(self.value, (int, float)):
            return (self.value, self.value)
        return None


@dataclasses.dataclass(frozen=True, eq=False)
class BinOp(Expr):
    op: str
    left: Expr
    right: Expr

    def used_cols(self):
        return self.left.used_cols() | self.right.used_cols()

    def evaluate(self, cols):
        return _BINOPS[self.op](self.left.evaluate(cols), self.right.evaluate(cols))

    def key(self):
        return ("bin", self.op, self.left.key(), self.right.key())

    def bounds(self, zonemaps):
        lb = self.left.bounds(zonemaps)
        rb = self.right.bounds(zonemaps)
        if lb is None or rb is None:
            return None
        (llo, lhi), (rlo, rhi) = lb, rb
        if self.op == "add":
            return (llo + rlo, lhi + rhi)
        if self.op == "sub":
            return (llo - rhi, lhi - rlo)
        if self.op == "mul":
            prods = [llo * rlo, llo * rhi, lhi * rlo, lhi * rhi]
            return (min(prods), max(prods))
        return None

    def prune_partition(self, zonemaps: Mapping[str, tuple]) -> bool:
        """True if this predicate is provably all-False on a partition with
        the given per-column (min, max) zone maps → the partition can be
        skipped (beyond-paper zone-map pruning)."""
        if self.op == "and":
            for side in (self.left, self.right):
                if isinstance(side, BinOp) and side.prune_partition(zonemaps):
                    return True
            return False
        if self.op == "or":
            return (isinstance(self.left, BinOp) and isinstance(self.right, BinOp)
                    and self.left.prune_partition(zonemaps)
                    and self.right.prune_partition(zonemaps))
        if self.op not in _COMPARISONS:
            return False
        lb = self.left.bounds(zonemaps)
        rb = self.right.bounds(zonemaps)
        if lb is None or rb is None:
            return False
        (llo, lhi), (rlo, rhi) = lb, rb
        if self.op == "lt":
            return llo >= rhi          # no l < r possible
        if self.op == "le":
            return llo > rhi
        if self.op == "gt":
            return lhi <= rlo
        if self.op == "ge":
            return lhi < rlo
        if self.op == "eq":
            return lhi < rlo or llo > rhi
        return False                    # ne: rarely prunable


@dataclasses.dataclass(frozen=True, eq=False)
class Not(Expr):
    child: Expr

    def used_cols(self):
        return self.child.used_cols()

    def evaluate(self, cols):
        return ~self.child.evaluate(cols)

    def key(self):
        return ("not", self.child.key())


@dataclasses.dataclass(frozen=True, eq=False)
class DtField(Expr):
    child: Expr
    field: str

    def used_cols(self):
        return self.child.used_cols()

    def evaluate(self, cols):
        return _DT_FIELDS[self.field](self.child.evaluate(cols))

    def key(self):
        return ("dt", self.field, self.child.key())


@dataclasses.dataclass(frozen=True, eq=False)
class Cast(Expr):
    child: Expr
    dtype: str

    def used_cols(self):
        return self.child.used_cols()

    def evaluate(self, cols):
        return self.child.evaluate(cols).astype(self.dtype)

    def key(self):
        return ("cast", self.dtype, self.child.key())

    def bounds(self, zonemaps):
        return self.child.bounds(zonemaps)


@dataclasses.dataclass(frozen=True, eq=False)
class Clip(Expr):
    """``Series.clip(lower, upper)`` — array-method based so it traces
    through jit on both numpy and jnp columns."""
    child: Expr
    lower: Any = None
    upper: Any = None

    def used_cols(self):
        return self.child.used_cols()

    def evaluate(self, cols):
        return self.child.evaluate(cols).clip(self.lower, self.upper)

    def key(self):
        return ("clip", repr(self.lower), repr(self.upper), self.child.key())

    def bounds(self, zonemaps):
        b = self.child.bounds(zonemaps)
        if b is None:
            return None
        lo, hi = b
        if self.lower is not None:
            lo, hi = max(lo, self.lower), max(hi, self.lower)
        if self.upper is not None:
            lo, hi = min(lo, self.upper), min(hi, self.upper)
        return (lo, hi)


@dataclasses.dataclass(frozen=True, eq=False)
class Round(Expr):
    """``Series.round(decimals)`` — banker's rounding, matching numpy and
    pandas ``round`` semantics."""
    child: Expr
    decimals: int = 0

    def used_cols(self):
        return self.child.used_cols()

    def evaluate(self, cols):
        return self.child.evaluate(cols).round(self.decimals)

    def key(self):
        return ("round", self.decimals, self.child.key())

    def bounds(self, zonemaps):
        b = self.child.bounds(zonemaps)
        if b is None:
            return None
        pad = 0.5 * 10.0 ** (-self.decimals)
        return (b[0] - pad, b[1] + pad)


@dataclasses.dataclass(frozen=True, eq=False)
class IsIn(Expr):
    child: Expr
    values: tuple

    def used_cols(self):
        return self.child.used_cols()

    def evaluate(self, cols):
        arr = self.child.evaluate(cols)
        out = arr == self.values[0]
        for v in self.values[1:]:
            out = out | (arr == v)
        return out

    def key(self):
        return ("isin", self.values, self.child.key())


@dataclasses.dataclass(frozen=True, eq=False)
class UDF(Expr):
    """Opaque elementwise UDF — blocks pushdown (used_attrs unknowable ⇒ we
    conservatively report its declared inputs; mod semantics opaque)."""
    fn: Callable
    args: tuple[Expr, ...]
    name: str = "udf"

    def used_cols(self):
        out = frozenset()
        for a in self.args:
            out |= a.used_cols()
        return out

    def evaluate(self, cols):
        return self.fn(*[a.evaluate(cols) for a in self.args])

    def key(self):
        return ("udf", id(self.fn)) + tuple(a.key() for a in self.args)


def conjoin(preds):
    """AND-fold a list of predicates (filter fusion, paper §3.2)."""
    out = preds[0]
    for p in preds[1:]:
        out = BinOp("and", out, p)
    return out
