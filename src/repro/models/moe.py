"""Mixture-of-Experts FFN: shared + routed experts, top-k gating, capacity-
bounded sort-based dispatch (dropless up to the capacity factor).

Dispatch is formulated as static-shape gather/scatter + grouped einsum
``ecd,edf->ecf`` so that GSPMD shards the expert dim over the ``model`` mesh
axis (expert parallelism): the token→expert scatter lowers to an all-to-all,
the grouped matmuls run expert-local, and the combine gathers back.

DeepSeekMoE (arXiv:2401.06066) pattern: fine-grained routed experts + shared
experts always active; Jamba uses the same machinery with 16e top-2 and no
shared experts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import ParamSpec, leaf, swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_routed: int
    n_shared: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_noise: float = 0.0


def moe_spec(cfg: MoEConfig, prefix: str) -> ParamSpec:
    D, E, F = cfg.d_model, cfg.n_routed, cfg.d_ff_expert
    s = ParamSpec()
    s[f"{prefix}/router"] = leaf((D, E), ("embed", None))
    s[f"{prefix}/w_gate"] = leaf((E, D, F), ("expert", "embed", None))
    s[f"{prefix}/w_up"] = leaf((E, D, F), ("expert", "embed", None))
    s[f"{prefix}/w_down"] = leaf((E, F, D), ("expert", None, "embed"))
    if cfg.n_shared:
        Fs = cfg.d_ff_expert * cfg.n_shared
        s[f"{prefix}/shared_gate"] = leaf((D, Fs), ("embed", "mlp"))
        s[f"{prefix}/shared_up"] = leaf((D, Fs), ("embed", "mlp"))
        s[f"{prefix}/shared_down"] = leaf((Fs, D), ("mlp", "embed"))
    return s


def moe_forward(params, cfg: MoEConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, D) → (out (B,T,D), aux_loss ()).

    Under a mesh, dispatch runs per-data-shard via shard_map with the
    ``model`` axis left automatic: routing is per-token, so the argsort/
    scatter must NOT be global — a pure-pjit formulation replicates the
    global token dim across the data axis (2M-token f32 buffers and ~112
    GB/step of all-reduce on jamba; §Perf iteration 2)."""
    import os
    from jax.sharding import NamedSharding, PartitionSpec as P
    from ..distributed.sharding import _ACT_CTX, batch_axes
    mesh = _ACT_CTX["mesh"]
    B, T, D = x.shape
    btotal = 1
    ba = None
    if mesh is not None:
        ba = batch_axes(mesh)
        for a in ba:
            btotal *= mesh.shape[a]
    if mesh is None or btotal <= 1 or B % btotal != 0 or \
            os.environ.get("REPRO_MOE_GLOBAL_DISPATCH") == "1":  # baseline
        return _moe_local(params, cfg, x)
    # batch the dispatch over a static leading dim equal to the data-shard
    # count: per-slice argsort/scatter stay shard-local (batched sort), and
    # the (slice × expert) transpose in the grouped einsum becomes the EP
    # all-to-all.
    xs = x.reshape(btotal, (B // btotal) * T, 1, D)
    xs = jax.lax.with_sharding_constraint(
        xs, NamedSharding(mesh, P(ba, None, None, None)))
    out, aux = jax.vmap(lambda xl: _moe_local(params, cfg, xl))(xs)
    out = out.reshape(B, T, D)
    return out, jnp.mean(aux)


def _moe_local(params, cfg: MoEConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    B, T, D = x.shape
    E, K = cfg.n_routed, cfg.top_k
    N = B * T
    xf = x.reshape(N, D)

    router_logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                               params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (N,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1),
        axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch -------------------------------------------
    NK = N * K
    flat_expert = expert_idx.reshape(NK)
    flat_token = jnp.repeat(jnp.arange(N), K)
    flat_gate = gate_vals.reshape(NK)
    order = jnp.argsort(flat_expert)                          # stable
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # rank within expert = position - start offset of that expert
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank = jnp.arange(NK) - starts[se]
    cap = int(cfg.capacity_factor * NK / E) or 1
    keep = rank < cap
    slot = se * cap + jnp.minimum(rank, cap - 1)              # (NK,)

    # scatter tokens into (E*cap, D) buffer (dropped tokens excluded)
    buf = jnp.zeros((E * cap, D), x.dtype)
    buf = buf.at[jnp.where(keep, slot, E * cap - 1)].add(
        jnp.where(keep[:, None], xf[st], 0).astype(x.dtype), mode="drop")
    buf = buf.reshape(E, cap, D)

    # expert-local grouped SwiGLU: (E,cap,D)×(E,D,F)
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = out_buf.reshape(E * cap, D)

    # combine: gather each kept slot back to its token, weighted by gate
    contrib = out_buf[slot] * (sg * keep)[:, None].astype(x.dtype)
    out = jnp.zeros((N, D), x.dtype).at[st].add(contrib)

    if cfg.n_shared:
        out = out + swiglu(xf, params["shared_gate"], params["shared_up"],
                           params["shared_down"])
    return out.reshape(B, T, D), aux
