"""Model assembly: embedding → (prelude layers) → scan over layer groups →
(postlude layers) → final norm → logits.

Heterogeneous stacking patterns (gemma3 5:1 local/global, jamba 1:7
attn:mamba, xlstm sLSTM/mLSTM pairs) are expressed as a repeating *group* of
LayerSpecs scanned ``n_groups`` times — one `lax.scan` keeps the HLO small
(constant in depth) which bounds both compile time and code size on 512-way
meshes.  Remat wraps the group body.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as A
from . import moe as M
from . import ssm as S
from . import xlstm as X
from ..distributed.sharding import shard_activations, shard_logits
from .layers import ParamSpec, flatten, leaf, rms_norm, swiglu, unflatten


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"        # attn | mla | mamba | mlstm | slstm
    ffn: str = "dense"         # dense | moe | none
    window: int | None = None  # sliding window for local attention


# ---------------------------------------------------------------------------
# Param specs


def _layer_spec(cfg, ls: LayerSpec, prefix: str) -> ParamSpec:
    s = ParamSpec()
    D = cfg.d_model
    s[f"{prefix}/ln1"] = leaf((D,), ("embed",))
    if ls.mixer in ("attn", "mla"):
        acfg = cfg.attn_config(ls)
        sub = A.mla_spec(acfg, f"{prefix}/mixer") if ls.mixer == "mla" \
            else A.gqa_spec(acfg, f"{prefix}/mixer")
        s.update(sub)
    elif ls.mixer == "mamba":
        s.update(S.mamba_spec(cfg.mamba_config(), f"{prefix}/mixer"))
    elif ls.mixer == "mlstm":
        s.update(X.mlstm_spec(cfg.xlstm_config(), f"{prefix}/mixer"))
    elif ls.mixer == "slstm":
        s.update(X.slstm_spec(cfg.xlstm_config(), f"{prefix}/mixer"))
    else:
        raise ValueError(ls.mixer)
    if ls.ffn != "none":
        s[f"{prefix}/ln2"] = leaf((D,), ("embed",))
    if ls.ffn == "dense":
        F = cfg.d_ff
        s[f"{prefix}/ffn/w_gate"] = leaf((D, F), ("embed", "mlp"))
        s[f"{prefix}/ffn/w_up"] = leaf((D, F), ("embed", "mlp"))
        s[f"{prefix}/ffn/w_down"] = leaf((F, D), ("mlp", "embed"))
    elif ls.ffn == "moe":
        s.update(M.moe_spec(cfg.moe_config(), f"{prefix}/ffn"))
    return s


def model_spec(cfg) -> ParamSpec:
    s = ParamSpec()
    D, V = cfg.d_model, cfg.vocab
    if cfg.modality == "text":
        s["embed"] = leaf((V, D), ("vocab", "embed"))
    s["final_norm"] = leaf((D,), ("embed",))
    s["unembed"] = leaf((D, V), ("embed", "vocab"))
    for i, ls in enumerate(cfg.prelude):
        s.update(_layer_spec(cfg, ls, f"prelude_{i}"))
    for i, ls in enumerate(cfg.postlude):
        s.update(_layer_spec(cfg, ls, f"postlude_{i}"))
    if cfg.n_groups:
        gs = ParamSpec()
        for i, ls in enumerate(cfg.group):
            gs.update(_layer_spec(cfg, ls, f"g{i}"))
        for path, (shape, dt, axes) in gs.items():
            s[f"group/{path}"] = ((cfg.n_groups,) + shape, dt,
                                  ("layers",) + axes)
    return s


# ---------------------------------------------------------------------------
# Caches


def _layer_cache_shape(cfg, ls: LayerSpec, B: int, S: int, dtype):
    """ShapeDtypeStructs for one layer's decode cache."""
    D = cfg.d_model
    if ls.mixer == "attn":
        a = cfg.attn_config(ls)
        kv = jax.ShapeDtypeStruct((B, S, a.n_kv_heads, a.head_dim), dtype)
        return (kv, kv)
    if ls.mixer == "mla":
        a = cfg.attn_config(ls)
        return (jax.ShapeDtypeStruct((B, S, a.kv_lora_rank), dtype),
                jax.ShapeDtypeStruct((B, S, a.qk_rope_dim), dtype))
    if ls.mixer == "mamba":
        mc = cfg.mamba_config()
        return (jax.ShapeDtypeStruct((B, mc.d_conv - 1, mc.d_inner), dtype),
                jax.ShapeDtypeStruct((B, mc.d_inner, mc.d_state), jnp.float32))
    if ls.mixer == "mlstm":
        xc = cfg.xlstm_config()
        return (jax.ShapeDtypeStruct((B, xc.n_heads, xc.head_dim,
                                      xc.head_dim), jnp.float32),
                jax.ShapeDtypeStruct((B, xc.n_heads, xc.head_dim), jnp.float32))
    if ls.mixer == "slstm":
        xc = cfg.xlstm_config()
        hd = cfg.d_model // xc.n_heads
        st = jax.ShapeDtypeStruct((B, xc.n_heads, hd), jnp.float32)
        return (st, st, st)
    raise ValueError(ls.mixer)


def cache_shapes(cfg, B: int, S: int, dtype=jnp.bfloat16):
    """Cache pytree of ShapeDtypeStructs: {'prelude': [...], 'group': pytree
    with leading (n_groups,), 'postlude': [...]}."""
    out: dict[str, Any] = {
        "prelude": [_layer_cache_shape(cfg, ls, B, S, dtype)
                    for ls in cfg.prelude],
        "postlude": [_layer_cache_shape(cfg, ls, B, S, dtype)
                     for ls in cfg.postlude],
    }
    if cfg.n_groups:
        glayer = [_layer_cache_shape(cfg, ls, B, S, dtype) for ls in cfg.group]
        out["group"] = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((cfg.n_groups,) + sd.shape,
                                            sd.dtype), tuple(glayer))
    else:
        out["group"] = ()
    return out


def _layer_cache_init(cfg, ls: LayerSpec, B: int, S: int, dtype):
    shapes = _layer_cache_shape(cfg, ls, B, S, dtype)
    vals = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), shapes)
    if ls.mixer == "slstm":
        c, n, h = vals
        vals = (c, jnp.ones_like(n), h)   # sLSTM normalizer starts at 1
    return vals


def init_cache(cfg, B: int, S: int, dtype=jnp.bfloat16):
    out: dict[str, Any] = {
        "prelude": [_layer_cache_init(cfg, ls, B, S, dtype)
                    for ls in cfg.prelude],
        "postlude": [_layer_cache_init(cfg, ls, B, S, dtype)
                     for ls in cfg.postlude],
    }
    if cfg.n_groups:
        glayer = [_layer_cache_init(cfg, ls, B, S, dtype) for ls in cfg.group]
        out["group"] = jax.tree.map(
            lambda v: jnp.broadcast_to(v, (cfg.n_groups,) + v.shape).copy(),
            tuple(glayer))
    else:
        out["group"] = ()
    return out


# ---------------------------------------------------------------------------
# Forward


def _layer_forward(lp, cfg, ls: LayerSpec, x, positions, cache, cache_len):
    """One block: norm→mixer→residual (→norm→ffn→residual)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if ls.mixer == "attn":
        out, new_cache = A.gqa_forward(lp["mixer"], cfg.attn_config(ls), h,
                                       positions, cache, cache_len)
        out = A.gqa_out(lp["mixer"], out)
    elif ls.mixer == "mla":
        out, new_cache = A.mla_forward(lp["mixer"], cfg.attn_config(ls), h,
                                       positions, cache, cache_len)
    elif ls.mixer == "mamba":
        out, new_cache = S.mamba_forward(lp["mixer"], cfg.mamba_config(), h,
                                         cache)
    elif ls.mixer == "mlstm":
        out, new_cache = X.mlstm_forward(lp["mixer"], cfg.xlstm_config(), h,
                                         cache)
    elif ls.mixer == "slstm":
        out, new_cache = X.slstm_forward(lp["mixer"], cfg.xlstm_config(), h,
                                         cache)
    else:
        raise ValueError(ls.mixer)
    x = x + out
    if ls.ffn == "dense":
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                       lp["ffn"]["w_down"])
    elif ls.ffn == "moe":
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        out, aux = M.moe_forward(lp["ffn"], cfg.moe_config(), h)
        x = x + out
    return x, new_cache, aux


def forward(params, cfg, inputs: dict, mode: str = "train",
            cache=None, cache_len=None):
    """Full model forward.

    inputs: {"tokens": (B,T) int32} or {"embeds": (B,T,D)} (modality stub),
    optional {"positions": (B,T)}.
    mode: "train" (no cache IO) | "prefill" (build cache) | "decode"
    (consume+update cache; T is the new-token count, usually 1).

    Returns (logits (B,T,V), new_cache|None, aux_loss)."""
    # compute-dtype policy: matrices cast to activation dtype (master f32
    # weights live in the optimizer); 1-D scales/biases stay f32 for norms.
    params = jax.tree.map(
        lambda p: p.astype(cfg.activation_dtype)
        if (hasattr(p, "ndim") and p.ndim >= 2) else p, params)
    if cfg.modality == "text":
        tokens = inputs["tokens"]
        x = params["embed"][tokens].astype(cfg.activation_dtype)
        if cfg.embed_scale:
            x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    else:
        x = inputs["embeds"].astype(cfg.activation_dtype)
    x = shard_activations(x)   # pin batch-over-data after the embed gather
    B, T = x.shape[:2]
    if mode == "decode":
        positions = cache_len[:, None] + jnp.arange(T)[None, :]   # (B,T)
    else:
        positions = inputs.get("positions", jnp.arange(T))
    aux_total = jnp.zeros((), jnp.float32)
    use_cache = mode != "train"

    new_prelude, new_postlude = [], []
    for i, ls in enumerate(cfg.prelude):
        c = cache["prelude"][i] if (cache is not None) else None
        x, nc, aux = _layer_forward(params[f"prelude_{i}"], cfg, ls, x,
                                    positions, c, cache_len)
        aux_total += aux
        new_prelude.append(nc if use_cache else None)

    if cfg.n_groups:
        gparams = params["group"]

        # per-layer remat inside multi-layer groups: without it the whole
        # group (e.g. jamba's 8 layers) is recomputed as one block during
        # backward, so all 8 layers' intermediates are live at once
        per_layer_ckpt = (cfg.remat and mode == "train" and len(cfg.group) > 1)

        def group_body(carry, xs):
            xc, aux_c = carry
            gp_flat, gcache = xs
            gp = unflatten(gp_flat)
            xc = shard_activations(xc)
            new_caches = []
            for i, ls in enumerate(cfg.group):
                c = gcache[i] if gcache is not None else None
                lf = _layer_forward
                if per_layer_ckpt:
                    lf = jax.checkpoint(
                        _layer_forward, static_argnums=(1, 2),
                        policy=jax.checkpoint_policies.nothing_saveable)
                xc, nc, aux = lf(gp[f"g{i}"], cfg, ls, xc,
                                 positions, c, cache_len)
                aux_c = aux_c + aux
                new_caches.append(nc if use_cache else jnp.zeros((), jnp.float32))
            return (xc, aux_c), tuple(new_caches)

        body = group_body
        if cfg.remat and mode == "train" and not per_layer_ckpt:
            # single-layer groups: remat the whole body.  Multi-layer groups
            # use per-layer checkpoints instead — wrapping both would
            # recompute inner layers twice (3× forward collectives).
            body = jax.checkpoint(group_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        gp_flat = flatten(gparams)
        gcache_xs = cache["group"] if cache is not None else None
        xs = (gp_flat, gcache_xs) if gcache_xs is not None else (gp_flat, None)
        if gcache_xs is None:
            (x, aux_total), group_caches = jax.lax.scan(
                lambda c, gp: body(c, (gp, None)), (x, aux_total), gp_flat)
        else:
            (x, aux_total), group_caches = jax.lax.scan(
                body, (x, aux_total), (gp_flat, gcache_xs))
    else:
        group_caches = ()

    for i, ls in enumerate(cfg.postlude):
        c = cache["postlude"][i] if cache is not None else None
        x, nc, aux = _layer_forward(params[f"postlude_{i}"], cfg, ls, x,
                                    positions, c, cache_len)
        aux_total += aux
        new_postlude.append(nc if use_cache else None)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard_logits(jnp.einsum("btd,dv->btv", x, params["unembed"]))
    new_cache = None
    if use_cache:
        new_cache = {"prelude": new_prelude, "group": group_caches,
                     "postlude": new_postlude}
    return logits, new_cache, aux_total
