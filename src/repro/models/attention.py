"""Attention mixers: GQA (optionally sliding-window) and MLA (DeepSeek-V2
latent attention), with train / prefill / decode paths and KV caches.

Caches:
* GQA   — k/v: (B, S_max, H_kv, hd)
* MLA   — latent c_kv: (B, S_max, r) + rope key: (B, S_max, rope_dim)
          (this *is* MLA's memory win: r + rope_dim ≪ 2·H·hd)
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_attn_heads
from .layers import ParamSpec, apply_rotary, leaf, rotary_cache

NEG_INF = -2.0 ** 30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    window: int | None = None          # sliding-window size (local layers)
    rope_theta: float = 10000.0
    # MLA:
    kv_lora_rank: int | None = None
    qk_rope_dim: int = 64
    v_head_dim: int | None = None
    # implementation: "dense" materializes (T,S) logits; "chunked" is the
    # flash-style online-softmax scan over KV chunks (O(T·C) working set)
    attn_impl: str = "dense"
    kv_chunk: int = 1024


def gqa_spec(cfg: AttnConfig, prefix: str) -> ParamSpec:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = ParamSpec()
    s[f"{prefix}/wq"] = leaf((D, H, hd), ("embed", "heads", None))
    s[f"{prefix}/wk"] = leaf((D, Hkv, hd), ("embed", "heads", None))
    s[f"{prefix}/wv"] = leaf((D, Hkv, hd), ("embed", "heads", None))
    s[f"{prefix}/wo"] = leaf((H, hd, D), ("heads", None, "embed"))
    if cfg.qkv_bias:
        s[f"{prefix}/bq"] = leaf((H, hd), ("heads", None))
        s[f"{prefix}/bk"] = leaf((Hkv, hd), ("heads", None))
        s[f"{prefix}/bv"] = leaf((Hkv, hd), ("heads", None))
    return s


def mla_spec(cfg: AttnConfig, prefix: str) -> ParamSpec:
    D, H = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    nope = cfg.head_dim
    rope = cfg.qk_rope_dim
    vhd = cfg.v_head_dim or cfg.head_dim
    s = ParamSpec()
    s[f"{prefix}/wq"] = leaf((D, H, nope + rope), ("embed", "heads", None))
    s[f"{prefix}/w_dkv"] = leaf((D, r), ("embed", None))
    s[f"{prefix}/w_krope"] = leaf((D, rope), ("embed", None))
    s[f"{prefix}/w_uk"] = leaf((r, H, nope), (None, "heads", None))
    s[f"{prefix}/w_uv"] = leaf((r, H, vhd), (None, "heads", None))
    s[f"{prefix}/wo"] = leaf((H, vhd, D), ("heads", None, "embed"))
    return s


# ---------------------------------------------------------------------------
# Core attention math


def _sdpa(q, k, v, mask, scale):
    """q: (B,T,H,hd) k/v: (B,S,Hkv,*) grouped-query attention."""
    B, T, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    q = q.reshape(B, T, Hkv, G, hd)
    logits = jnp.einsum("bthgd,bshd->bhgts", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgts,bshe->bthge", probs, v)
    return out.reshape(B, T, Hkv * G, -1)


def _chunked_sdpa(q, k, v, scale, window, kv_chunk):
    """Flash-style attention: `lax.scan` over KV chunks with online softmax.
    Causal (train/prefill) only; working set is O(B·H·T·C) instead of
    O(B·H·T·S).  TPU adaptation of flash attention — the online-softmax
    rescale trick is hardware-agnostic; block sizes are picked for VMEM
    tiles rather than SM shared memory."""
    B, T, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    S = k.shape[1]
    C = min(kv_chunk, S)
    assert S % C == 0, (S, C)
    nc = S // C
    qg = q.reshape(B, T, Hkv, G, hd)
    tpos = jnp.arange(T)[:, None]

    k_c = k.reshape(B, nc, C, Hkv, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(B, nc, C, Hkv, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, j = inp
        spos = j * C + jnp.arange(C)[None, :]
        mask = spos <= tpos                       # (T, C) causal
        if window is not None:
            mask = mask & (tpos - spos < window)
        s = jnp.einsum("bthgd,bshd->bhgts", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        r = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * r + jnp.sum(p, axis=-1)
        acc = acc * r[..., None] + jnp.einsum("bhgts,bshe->bhgte",
                                              p.astype(vc.dtype), vc)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, T), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, T, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (k_c, v_c, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd).astype(q.dtype)


def _causal_mask(T, S, offset, window):
    """(T, S) mask: query t (absolute position offset+t) sees key s iff
    s ≤ offset+t and (no window or offset+t-s < window)."""
    tpos = jnp.arange(T)[:, None] + offset
    spos = jnp.arange(S)[None, :]
    m = spos <= tpos
    if window is not None:
        m = m & (tpos - spos < window)
    return m


def gqa_forward(params, cfg: AttnConfig, x, positions, cache=None,
                cache_len=None):
    """x: (B,T,D).  Train/prefill: cache None, positions (T,) or (B,T).
    Decode: cache (k,v) with (B,S_max,...), cache_len (B,) current lengths.

    Returns (out, new_cache)."""
    B, T, D = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    cos, sin = rotary_cache(positions, cfg.head_dim, cfg.rope_theta)
    if cos.ndim == 2:            # (T, hd/2) → broadcast over batch
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    else:
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if cache is None:
        q = shard_attn_heads(q)    # heads→model, or seq→model fallback
        if cfg.attn_impl == "chunked" and T > cfg.kv_chunk:
            out = _chunked_sdpa(q, k, v, scale, cfg.window, cfg.kv_chunk)
        else:
            mask = _causal_mask(T, T, 0, cfg.window)[None]
            out = _sdpa(q, k, v, mask, scale)
        return out, (k, v)
    ck, cv = cache                                  # (B, S_max, Hkv, hd)
    S_max = ck.shape[1]
    # decode (T small, usually 1): write new k/v at cache_len
    idx = (cache_len[:, None] + jnp.arange(T)[None, :])  # (B, T)
    bidx = jnp.arange(B)[:, None]
    ck = ck.at[bidx, idx].set(k.astype(ck.dtype))
    cv = cv.at[bidx, idx].set(v.astype(cv.dtype))
    spos = jnp.arange(S_max)[None, :]
    valid = spos <= (cache_len[:, None] + T - 1)
    if cfg.window is not None:
        valid = valid & (spos > cache_len[:, None] + T - 1 - cfg.window)
    mask = valid[:, None, :] & jnp.ones((B, T, S_max), bool)
    out = _sdpa(q, ck, cv, mask, scale)
    return out, (ck, cv)


def gqa_out(params, out):
    return jnp.einsum("bthe,hed->btd", out, params["wo"])


def mla_forward(params, cfg: AttnConfig, x, positions, cache=None,
                cache_len=None):
    """DeepSeek-V2 MLA.  Latent cache: c_kv (B,S,r), k_rope (B,S,rope)."""
    B, T, D = x.shape
    nope, rope = cfg.head_dim, cfg.qk_rope_dim
    vhd = cfg.v_head_dim or cfg.head_dim
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])     # (B,T,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    c_kv = jnp.einsum("btd,dr->btr", x, params["w_dkv"])    # latent
    k_rope = jnp.einsum("btd,dr->btr", x, params["w_krope"])  # (B,T,rope)
    cos, sin = rotary_cache(positions, rope, cfg.rope_theta)
    q_rope = apply_rotary(q_rope, cos, sin)
    k_rope = apply_rotary(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    if cache is not None:
        cc, cr = cache
        idx = (cache_len[:, None] + jnp.arange(T)[None, :])
        bidx = jnp.arange(B)[:, None]
        cc = cc.at[bidx, idx].set(c_kv.astype(cc.dtype))
        cr = cr.at[bidx, idx].set(k_rope.astype(cr.dtype))
        c_all, r_all = cc, cr
        S = cc.shape[1]
        spos = jnp.arange(S)[None, :]
        mask = (spos <= (cache_len[:, None] + T - 1))[:, None, :] \
            & jnp.ones((B, T, S), bool)
        new_cache = (cc, cr)
    else:
        c_all, r_all = c_kv, k_rope
        S = T
        mask = _causal_mask(T, S, 0, None)[None]
        new_cache = (c_kv, k_rope)
    # up-project keys/values from the latent
    k_nope = jnp.einsum("bsr,rhk->bshk", c_all, params["w_uk"])
    vv = jnp.einsum("bsr,rhv->bshv", c_all, params["w_uv"])
    scale = 1.0 / math.sqrt(nope + rope)
    logits = (jnp.einsum("bthk,bshk->bhts", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bthr,bsr->bhts", q_rope, r_all,
                           preferred_element_type=jnp.float32)) * scale
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bhts,bshv->bthv", probs, vv)
    return jnp.einsum("bthv,hvd->btd", out, params["wo"]), new_cache
