"""Mamba-1 selective SSM mixer (Jamba's sequence mixer, arXiv:2403.19887).

TPU adaptation: the CUDA selective-scan kernel becomes a **chunked linear
recurrence** — `lax.scan` over sequence chunks carrying state (B, d_inner, N)
with `associative_scan` inside each chunk.  The (B, Lc, d_inner, N) working
set is bounded by the chunk length and shards over `model` on d_inner, so
VMEM/HBM stay bounded for 500k-token sequences (this is why Jamba runs the
``long_500k`` cell).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import ParamSpec, leaf


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 128
    scan_dtype: str = "float32"     # "bfloat16" halves SSM scan HBM traffic
                                    # (state carry stays f32 across chunks)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return -(-self.d_model // 16)


def mamba_spec(cfg: MambaConfig, prefix: str) -> ParamSpec:
    D, Di, N, R = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank
    s = ParamSpec()
    s[f"{prefix}/in_proj"] = leaf((D, 2 * Di), ("embed", "mlp"))
    s[f"{prefix}/conv_w"] = leaf((cfg.d_conv, Di), (None, "mlp"))
    s[f"{prefix}/conv_bias"] = leaf((Di,), ("mlp",))
    s[f"{prefix}/x_proj"] = leaf((Di, R + 2 * N), ("mlp", None))
    s[f"{prefix}/dt_proj"] = leaf((R, Di), (None, "mlp"))
    s[f"{prefix}/dt_bias"] = leaf((Di,), ("mlp",))
    s[f"{prefix}/A_log"] = leaf((Di, N), ("mlp", None))
    s[f"{prefix}/D_skip"] = leaf((Di,), ("mlp",))
    s[f"{prefix}/out_proj"] = leaf((Di, D), ("mlp", "embed"))
    return s


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv, x: (B,L,Di), w: (K,Di).  With ``state``
    (B,K-1,Di) (decode), prepends it and returns (out, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, L+K-1, Di)
    out = sum(xp[:, k:k + x.shape[1], :] * w[k] for k in range(K)) + b
    new_state = xp[:, -(K - 1):, :]
    return out, new_state


def _ssm_scan_chunked(a, b, h0, chunk):
    """First-order recurrence h_t = a_t h_{t-1} + b_t over axis 1 of
    (B, L, Di, N), carrying h0 (B, Di, N).  Returns (h_all, h_last)."""
    B, L, Di, N = a.shape
    nc = L // chunk

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    def step(h, ab):
        ac, bc = ab                                   # (B, chunk, Di, N)
        # fold carry into the first element
        bc = bc.at[:, 0].add(ac[:, 0] * h)
        aa, bb = jax.lax.associative_scan(op, (ac, bc), axis=1)
        return bb[:, -1], bb

    a_c = a.reshape(B, nc, chunk, Di, N).swapaxes(0, 1)
    b_c = b.reshape(B, nc, chunk, Di, N).swapaxes(0, 1)
    h_last, h_all = jax.lax.scan(step, h0, (a_c, b_c))
    h_all = h_all.swapaxes(0, 1).reshape(B, L, Di, N)
    return h_all, h_last


def mamba_forward(params, cfg: MambaConfig, x, cache=None):
    """x: (B, L, D).  Train/prefill: cache None.  Decode: cache =
    (conv_state (B,K-1,Di), h (B,Di,N)), L == 1.

    Returns (out (B,L,D), new_cache)."""
    B, L, D = x.shape
    Di, N, R = cfg.d_inner, cfg.d_state, cfg.dt_rank
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"])
    xin, z = xz[..., :Di], xz[..., Di:]
    conv_state = cache[0] if cache is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"], params["conv_bias"],
                                conv_state)
    xc = jax.nn.silu(xc)
    dbl = jnp.einsum("bld,de->ble", xc, params["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dbl[..., :R], params["dt_proj"])
        + params["dt_bias"])                                  # (B,L,Di)
    Bm = dbl[..., R:R + N]                                    # (B,L,N)
    Cm = dbl[..., R + N:]                                     # (B,L,N)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))         # (Di,N)
    sdt = jnp.bfloat16 if cfg.scan_dtype == "bfloat16" else jnp.float32
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A).astype(sdt)
    bmat = ((dt * xc).astype(jnp.float32)[..., None]
            * Bm[:, :, None, :]).astype(sdt)                  # (B,L,Di,N)
    h0 = cache[1].astype(sdt) if cache is not None else \
        jnp.zeros((B, Di, N), sdt)
    if L == 1:
        h_last = a[:, 0] * h0 + bmat[:, 0]
        h_all = h_last[:, None]
    else:
        chunk = min(cfg.chunk, L)
        assert L % chunk == 0, (L, chunk)
        h_all, h_last = _ssm_scan_chunked(a, bmat, h0, chunk)
    y = jnp.einsum("blde,ble->bld", h_all, Cm.astype(sdt),
                   preferred_element_type=jnp.float32)
    y = y.astype(x.dtype) + params["D_skip"] * xc
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bld,de->ble", y, params["out_proj"])
    return out, (new_conv, h_last.astype(jnp.float32))
