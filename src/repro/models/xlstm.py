"""xLSTM mixers (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential), alternating blocks.

TPU adaptation: mLSTM's recurrence is computed **chunkwise** (GLA-style):
within a chunk the output is an attention-like quadratic form with
cumulative-decay weights; across chunks a (B, H, hd, hd) matrix state and a
(B, H, hd) normalizer carry.  sLSTM is inherently sequential (the paper says
so) and runs as a `lax.scan` of per-step cell updates — its state is O(B·D),
which is what makes the ``long_500k`` decode cell O(1) in sequence length.

Stabilization: we use sigmoid forget gates and sigmoid input gates (bounded)
instead of the paper's exp-with-max-stabilizer; DESIGN.md records this
deviation (the exp/m-stabilizer variant adds a running-max carry with
identical structure).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import ParamSpec, leaf, rms_norm


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    proj_factor: float = 2.0
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return int(self.proj_factor * self.d_model)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM


def mlstm_spec(cfg: XLSTMConfig, prefix: str) -> ParamSpec:
    D, Di, H, hd = cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.head_dim
    s = ParamSpec()
    s[f"{prefix}/up"] = leaf((D, 2 * Di), ("embed", "mlp"))
    s[f"{prefix}/wq"] = leaf((Di, H, hd), ("mlp", "heads", None))
    s[f"{prefix}/wk"] = leaf((Di, H, hd), ("mlp", "heads", None))
    s[f"{prefix}/wv"] = leaf((Di, H, hd), ("mlp", "heads", None))
    s[f"{prefix}/w_if"] = leaf((Di, 2 * H), ("mlp", None))
    s[f"{prefix}/norm"] = leaf((Di,), ("mlp",))
    s[f"{prefix}/down"] = leaf((Di, D), ("mlp", "embed"))
    return s


def _mlstm_chunk(q, k, v, log_f, i_gate, C0, n0):
    """One chunk.  q,k,v: (B,Lc,H,hd); log_f,i_gate: (B,Lc,H);
    C0: (B,H,hd,hd); n0: (B,H,hd).  Returns (h, C1, n1)."""
    B, Lc, H, hd = q.shape
    cum = jnp.cumsum(log_f, axis=1)                  # log Π_{τ≤t} f_τ
    d_t = jnp.exp(cum)                               # (B,Lc,H)
    # intra-chunk: W[t,s] = exp(cum_t - cum_s) · i_s · causal(t≥s)
    w_log = cum[:, :, None, :] - cum[:, None, :, :]  # (B,t,s,H)
    causal = (jnp.arange(Lc)[:, None] >= jnp.arange(Lc)[None, :])
    w = jnp.exp(jnp.where(causal[None, :, :, None], w_log, -jnp.inf))
    w = w * i_gate[:, None, :, :]                    # (B,t,s,H)
    scores = jnp.einsum("bthd,bshd->btsh", q, k) / jnp.sqrt(float(hd))
    num_intra = jnp.einsum("btsh,btsh,bshd->bthd", scores, w, v)
    # inter-chunk from carry
    num_inter = d_t[..., None] * jnp.einsum("bthd,bhde->bthe", q, C0) \
        / jnp.sqrt(float(hd))
    num = num_intra + num_inter
    # normalizer n_t = d_t n0 + Σ_{s≤t} (d_t/d_s) i_s k_s
    n_intra = jnp.einsum("btsh,bshd->bthd", w, k)
    n_t = d_t[..., None] * n0[:, None] + n_intra
    den = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", q, n_t))
                      / jnp.sqrt(float(hd)), 1.0)
    h = num / den[..., None]
    # carry updates
    d_end = jnp.exp(cum[:, -1])                       # (B,H)
    rel = jnp.exp(cum[:, -1][:, None, :] - cum) * i_gate   # (B,Lc,H)
    C1 = d_end[..., None, None] * C0 + jnp.einsum("blh,blhd,blhe->bhde",
                                                  rel, k, v)
    n1 = d_end[..., None] * n0 + jnp.einsum("blh,blhd->bhd", rel, k)
    return h, C1, n1


def mlstm_forward(params, cfg: XLSTMConfig, x, cache=None):
    """x: (B,L,D) → (out, cache=(C, n)).  Decode: L==1 single-step update."""
    B, L, D = x.shape
    Di, H, hd = cfg.d_inner, cfg.n_heads, cfg.head_dim
    up = jnp.einsum("bld,de->ble", x, params["up"])
    xm, z = up[..., :Di], up[..., Di:]
    q = jnp.einsum("ble,ehd->blhd", xm, params["wq"])
    k = jnp.einsum("ble,ehd->blhd", xm, params["wk"])
    v = jnp.einsum("ble,ehd->blhd", xm, params["wv"])
    gates = jnp.einsum("ble,eh->blh", xm, params["w_if"])
    i_gate = jax.nn.sigmoid(gates[..., :H]).astype(jnp.float32)
    log_f = jnp.log(jax.nn.sigmoid(gates[..., H:]).astype(jnp.float32) + 1e-6)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    if cache is not None:
        C0, n0 = cache
    else:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
    if L == 1:
        h, C1, n1 = _mlstm_chunk(qf, kf, vf, log_f, i_gate, C0, n0)
    else:
        chunk = min(cfg.chunk, L)
        assert L % chunk == 0
        nc = L // chunk

        def step(carry, inp):
            C, n = carry
            qc, kc, vc, lf, ig = inp
            h, C, n = _mlstm_chunk(qc, kc, vc, lf, ig, C, n)
            return (C, n), h

        def split(t):
            return t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

        (C1, n1), hs = jax.lax.scan(
            step, (C0, n0), (split(qf), split(kf), split(vf),
                             split(log_f), split(i_gate)))
        h = hs.swapaxes(0, 1).reshape(B, L, H, hd)
    h = h.reshape(B, L, Di).astype(x.dtype)
    h = rms_norm(h, params["norm"])
    out = jnp.einsum("ble,ed->bld", h * jax.nn.silu(z), params["down"])
    return out, (C1, n1)


# ---------------------------------------------------------------------------
# sLSTM


def slstm_spec(cfg: XLSTMConfig, prefix: str) -> ParamSpec:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.d_model // cfg.n_heads
    s = ParamSpec()
    # 4 gates (z, i, f, o): input weights + per-head recurrent weights
    s[f"{prefix}/w_gates"] = leaf((D, 4, H, hd), ("embed", None, "heads", None))
    s[f"{prefix}/r_gates"] = leaf((4, H, hd, hd), (None, "heads", None, None))
    s[f"{prefix}/b_gates"] = leaf((4, H, hd), (None, "heads", None))
    s[f"{prefix}/norm"] = leaf((D,), ("embed",))
    s[f"{prefix}/down"] = leaf((D, D), ("embed", "embed2"))
    return s


def _slstm_cell(carry, wx_t, R, bias):
    c, n, h = carry
    rec = jnp.einsum("bhe,ghef->bghf", h, R)               # (B,4,H,hd)
    pre = wx_t.astype(jnp.float32) + rec + bias
    z = jnp.tanh(pre[:, 0])
    i = jax.nn.sigmoid(pre[:, 1])
    f = jax.nn.sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    c = f * c + i * z
    n = f * n + i
    h = o * (c / jnp.maximum(n, 1e-6))
    return (c, n, h), (h, pre)


@jax.custom_vjp
def _slstm_scan(wx, R, bias, c0, n0, h0):
    """Sequential sLSTM over time with a hand-written backward.

    The automatic VJP of the scan accumulates dR/dbias in the carry, whose
    data-sharded-batch contraction makes GSPMD emit a psum over `data` at
    EVERY timestep (≈200 GB/step at 4k seq — §Perf xlstm iteration).  The
    custom backward stacks per-step dpre instead and reduces the weight
    grads in ONE einsum after the reverse scan."""
    (c1, n1, h1), (hs, _pres) = jax.lax.scan(
        lambda carry, wx_t: _slstm_cell(carry, wx_t, R, bias),
        (c0, n0, h0), wx)
    return hs, c1, n1, h1


def _slstm_fwd(wx, R, bias, c0, n0, h0):
    (c1, n1, h1), (hs, pres) = jax.lax.scan(
        lambda carry, wx_t: _slstm_cell(carry, wx_t, R, bias),
        (c0, n0, h0), wx)
    # save h-sequence and pre-activations; states are recomputed backwards
    return (hs, c1, n1, h1), (wx, R, bias, c0, n0, h0, hs, pres)


def _slstm_bwd(res, grads):
    wx, R, bias, c0, n0, h0, hs, pres = res
    dhs, dc1, dn1, dh1 = grads
    L = wx.shape[0]
    h_prev = jnp.concatenate([h0[None], hs[:-1]], axis=0)     # (L,B,H,hd)

    # recompute c/n sequences forward (cheap elementwise) for the backward
    def cn_step(carry, pre):
        c, n = carry
        z = jnp.tanh(pre[:, 0])
        i = jax.nn.sigmoid(pre[:, 1])
        f = jax.nn.sigmoid(pre[:, 2])
        c1 = f * c + i * z
        n1 = f * n + i
        return (c1, n1), (c, n)                                # prev states
    (_cl, _nl), (c_prev, n_prev) = jax.lax.scan(cn_step, (c0, n0), pres)

    def bwd_step(carry, inp):
        dc, dn, dh = carry
        pre, cp, np_, dh_out = inp
        z = jnp.tanh(pre[:, 0])
        i = jax.nn.sigmoid(pre[:, 1])
        f = jax.nn.sigmoid(pre[:, 2])
        o = jax.nn.sigmoid(pre[:, 3])
        c = f * cp + i * z
        n = f * np_ + i
        nmax = jnp.maximum(n, 1e-6)
        dh_t = dh + dh_out
        do = dh_t * (c / nmax)
        dc_t = dc + dh_t * o / nmax
        dn_t = dn - dh_t * o * c / (nmax * nmax) * (n > 1e-6)
        dz = dc_t * i
        di = dc_t * z + dn_t
        df = dc_t * cp + dn_t * np_
        dpre = jnp.stack([
            dz * (1 - z * z),
            di * i * (1 - i),
            df * f * (1 - f),
            do * o * (1 - o),
        ], axis=1)                                             # (B,4,H,hd)
        # grads to previous step
        dc_p = dc_t * f
        dn_p = dn_t * f
        dh_p = jnp.einsum("bghf,ghef->bhe", dpre, R)
        return (dc_p, dn_p, dh_p), dpre

    (dc0, dn0, dh0), dpres = jax.lax.scan(
        bwd_step, (dc1, dn1, dh1),
        (pres, c_prev, n_prev, dhs), reverse=True)
    # weight grads in ONE contraction each (outside the loop — the point)
    dR = jnp.einsum("lbghf,lbhe->ghef", dpres, h_prev)
    dbias = jnp.sum(dpres, axis=(0, 1))
    dwx = dpres.astype(wx.dtype)
    return dwx, dR, dbias, dc0, dn0, dh0


_slstm_scan.defvjp(_slstm_fwd, _slstm_bwd)


def slstm_forward(params, cfg: XLSTMConfig, x, cache=None):
    """Sequential sLSTM.  x: (B,L,D) → (out, cache=(c,n,h)).  States are
    (B,H,hd) each — O(1) in sequence length."""
    B, L, D = x.shape
    H = cfg.n_heads
    hd = D // H
    wx = jnp.einsum("bld,dghe->blghe", x, params["w_gates"])   # (B,L,4,H,hd)
    if cache is not None:
        c0, n0, h0 = cache
    else:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.ones((B, H, hd), jnp.float32)
        h0 = jnp.zeros((B, H, hd), jnp.float32)

    R = params["r_gates"].astype(jnp.float32)
    bias = params["b_gates"].astype(jnp.float32)
    hs, c1, n1, h1 = _slstm_scan(wx.swapaxes(0, 1), R, bias, c0, n0, h0)
    h = hs.swapaxes(0, 1).reshape(B, L, D).astype(x.dtype)
    h = rms_norm(h, params["norm"])
    out = jnp.einsum("bld,de->ble", h, params["down"])
    return out, (c1, n1, h1)
