"""Shared model layers: RMSNorm, SwiGLU MLP, rotary embeddings, embed/unembed.

Params are plain pytrees (nested dicts of jnp arrays).  Every creator returns
``(init_fn, spec)`` where ``spec`` maps leaf path → (shape, dtype, logical
axes); ``repro.distributed.sharding`` turns logical axes into NamedSharding.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any

# Logical axis vocabulary (→ mesh axes in distributed/sharding.py):
#   "vocab"   → model     (TP over vocabulary)
#   "embed"   → data      (FSDP over the d_model dim)
#   "heads"   → model     (TP over attention heads)
#   "mlp"     → model     (TP over FFN hidden)
#   "expert"  → model     (EP over routed experts)
#   "layers"  → None      (scan dim, unsharded)
#   None      → replicated


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: (…, D) → (…, D).  w_gate/w_up: (D, F); w_down: (F, D)."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def rotary_cache(positions: jax.Array, head_dim: int,
                 theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """(…,) int positions → cos/sin of shape (…, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, T, H, hd); cos/sin: (B, T, hd/2) or (T, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Param spec machinery


class ParamSpec(dict):
    """path → (shape tuple, dtype, logical axis tuple)."""


def leaf(shape, axes, dtype=jnp.float32):
    assert len(shape) == len(axes), (shape, axes)
    return (tuple(shape), dtype, tuple(axes))


def init_from_spec(spec: ParamSpec, key: jax.Array,
                   dtype=jnp.float32) -> Params:
    """Materialize params (smoke tests / real training).  Fan-in scaled
    normal init."""
    flat = {}
    paths = sorted(spec.keys())
    keys = jax.random.split(key, max(len(paths), 1))
    for k, path in zip(keys, paths):
        shape, _dt, _axes = spec[path]
        if not shape or path.endswith("norm") or path.endswith("scale"):
            flat[path] = jnp.ones(shape, dtype)
        elif path.endswith("bias"):
            flat[path] = jnp.zeros(shape, dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            flat[path] = (jax.random.normal(k, shape, dtype)
                          * (1.0 / jnp.sqrt(fan_in)))
    return unflatten(flat)


def abstract_from_spec(spec: ParamSpec, dtype=jnp.float32) -> Params:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    flat = {path: jax.ShapeDtypeStruct(shape, dtype)
            for path, (shape, _dt, _axes) in spec.items()}
    return unflatten(flat)


def axes_from_spec(spec: ParamSpec) -> Params:
    flat = {path: axes for path, (_s, _d, axes) in spec.items()}
    return unflatten(flat)


def unflatten(flat: dict[str, Any]) -> Params:
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        cur = tree
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return tree


def flatten(tree: Params, prefix="") -> dict[str, Any]:
    out = {}
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(flatten(v, path))
        else:
            out[path] = v
    return out
