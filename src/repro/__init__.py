"""repro — Lazy Fat Pandas reproduction.

Top-level convenience surface for the open engine registry: out-of-tree
execution engines register here and become first-class planner citizens
(AUTO candidates, calibrated, explainable) without any core edits:

    import repro
    repro.register_engine("pool", PoolEngine, capability)

Installed distributions can instead expose a ``repro.engines`` entry point
(a zero-argument callable performing the registration) and are discovered
automatically on first engine lookup.
"""
from repro.core.engines import (AUTO, BackendCapability, Engine, EngineSpec,
                                create_engine, default_registry,
                                engine_names, get_capability,
                                register_engine, unregister_engine)

__all__ = [
    "AUTO", "BackendCapability", "Engine", "EngineSpec",
    "register_engine", "unregister_engine", "engine_names",
    "get_capability", "create_engine", "default_registry",
]
