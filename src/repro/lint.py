"""Pre-execution pandas linter: warn about expensive idioms before they run.

Static companion to the runtime rewrite engine (``repro.core.rewrite``) and
the fallback layer (``repro.pandas.fallback``): the same frame-variable
discovery that powers the §3.1 liveness analysis finds the dataframe
variables in a user program, and every method call rooted at one is
cross-referenced against

* the **rewrite rule set** — idioms the optimizer will transparently
  rewrite (``sort_values().head(n)`` → top-k, dedup-before-sort, …) get an
  informational diagnostic quoting the rule;
* the **fallback kernel tables** — calls that will leave the lazy graph
  and materialize through a pandas kernel (``df.sample``, ``s.median``, …)
  get a warning, calls served as lazy elementwise UDFs a note;
* **nothing at all** — methods with no native implementation *and* no
  fallback kernel will raise ``AttributeError`` at runtime; those are the
  regressions CI fails on (exit code 1).

Entry points: :func:`lint_source` (used by ``pd.analyze()``, which attaches
the diagnostics to ``ctx.analysis["diagnostics"]`` and thence to
``pd.explain()``), and ``python -m repro.lint <file> [--json]``.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import sys

LEVELS = ("info", "warn")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One line-anchored finding in the user program."""
    line: int
    col: int
    kind: str               # dotted category, e.g. "fallback.materialize"
    message: str
    symbol: str = ""        # the method/idiom the diagnostic is about
    level: str = "info"

    def __str__(self):
        return f"{self.level} L{self.line}:{self.col} [{self.kind}] {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _tables():
    from .core.lazyframe import GroupBy, LazyColumn, LazyFrame
    from .pandas import fallback as fb
    return {
        "frame_native": frozenset(d for d in dir(LazyFrame)
                                  if not d.startswith("_")),
        "series_native": frozenset(d for d in dir(LazyColumn)
                                   if not d.startswith("_")),
        "groupby_native": frozenset(d for d in dir(GroupBy)
                                    if not d.startswith("_")),
        "frame_kernels": frozenset(fb.FRAME_KERNELS),
        "series_kernels": frozenset(fb.SERIES_KERNELS),
        "series_elementwise": frozenset(fb.SERIES_ELEMENTWISE),
        "groupby_kernels": frozenset(fb.GROUPBY_REDUCERS),
    }


def _rule_summary(rule_name: str) -> str:
    from .core.rewrite import DEFAULT_RULES
    for r in DEFAULT_RULES:
        if r.name == rule_name:
            return r.summary
    return ""


def _frame_vars(tree: ast.Module) -> set[str]:
    from .core.source_analysis import _build_cfg, _frame_vars_pass
    body = tree.body
    if len(body) == 1 and isinstance(body[0], ast.FunctionDef):
        body = body[0].body
    return _frame_vars_pass(_build_cfg(body))


def _chain_root(node, frames: set[str]) -> str | None:
    from .core.source_analysis import _ExprUses
    return _ExprUses(frames)._chain_root(node)


def _keyword(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _is_false(node) -> bool:
    return isinstance(node, ast.Constant) and node.value is False


def _method_call(node, attr: str | None = None) -> ast.Call | None:
    """``node`` as a method call (optionally of a specific name)."""
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
            and (attr is None or node.func.attr == attr)):
        return node
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, frames: set[str], tables: dict):
        self.frames = frames
        self.t = tables
        self.diags: list[Diagnostic] = []
        self._claimed: set[int] = set()     # id() of calls a chain consumed

    def _emit(self, node, kind, message, symbol, level="info"):
        self.diags.append(Diagnostic(
            line=node.lineno, col=node.col_offset, kind=kind,
            message=message, symbol=symbol, level=level))

    # -- chain idioms the rewrite engine recognizes --------------------------

    def _check_rewrites(self, call: ast.Call) -> bool:
        attr = call.func.attr
        inner = _method_call(call.func.value, "sort_values")
        if inner is None or _chain_root(inner.func.value, self.frames) is None:
            return False
        if attr == "head":
            self._claimed.add(id(inner))
            self._emit(call, "rewrite.top_k",
                       "sort_values().head() — "
                       + _rule_summary("sort_head_to_top_k"),
                       symbol="sort_values().head")
            return True
        if attr == "drop_duplicates":
            asc = _keyword(inner, "ascending")
            subset = call.args or _keyword(call, "subset") is not None
            if _is_false(asc) or (len(inner.args) > 1 and
                                  _is_false(inner.args[1])) or subset:
                return False            # guarded out at runtime too
            self._claimed.add(id(inner))
            self._emit(call, "rewrite.dedup_before_sort",
                       "sort_values().drop_duplicates() — "
                       + _rule_summary("dedup_before_sort"),
                       symbol="sort_values().drop_duplicates")
            return True
        return False

    # -- single method calls -------------------------------------------------

    def _check_method(self, call: ast.Call, root: str):
        attr = call.func.attr
        base = call.func.value
        on_frame = isinstance(base, ast.Name) and base.id in self.frames
        on_series = (isinstance(base, ast.Subscript)
                     and isinstance(base.value, ast.Name)
                     and base.value.id in self.frames)
        on_groupby = (_method_call(base, "groupby") is not None
                      or (isinstance(base, ast.Subscript)
                          and _method_call(base.value, "groupby") is not None))
        if attr in ("nlargest", "nsmallest") and (on_frame or on_series):
            self._emit(call, "native.top_k",
                       f"{root}.{attr} runs as a native top-k selection "
                       "(no fallback materialization)", symbol=attr)
            return
        if attr == "apply_rows" and on_frame:
            self._emit(call, "rewrite.vectorize",
                       f"{root}.apply_rows — "
                       + _rule_summary("map_rows_vectorize"), symbol=attr)
            return
        if on_frame:
            native, kernels = self.t["frame_native"], self.t["frame_kernels"]
            what = "DataFrame"
        elif on_series:
            native, kernels = self.t["series_native"], self.t["series_kernels"]
            what = "Series"
        elif on_groupby:
            native = self.t["groupby_native"]
            kernels = self.t["groupby_kernels"]
            what = "GroupBy"
        else:
            return                      # deeper chains: skip (conservative)
        if attr in native:
            return
        if what == "Series" and attr in self.t["series_elementwise"]:
            self._emit(call, "fallback.udf",
                       f"{root}[...].{attr} stays lazy but runs as an opaque "
                       "elementwise UDF (blocks predicate pushdown through "
                       "it)", symbol=attr)
        elif attr in kernels:
            self._emit(call, "fallback.materialize",
                       f"{root}…{attr} will materialize the frame and run "
                       "via the pandas fallback kernel", symbol=attr,
                       level="warn")
        elif not on_groupby:            # unknown groupby attrs: too noisy
            self._emit(call, "fallback.failed",
                       f"{what}.{attr} has no native lazy implementation "
                       "and no fallback kernel — raises AttributeError at "
                       "runtime", symbol=attr, level="warn")

    def visit_Call(self, node: ast.Call):
        if isinstance(node.func, ast.Attribute) and id(node) not in self._claimed:
            root = _chain_root(node.func.value, self.frames)
            if root is not None and not self._check_rewrites(node):
                self._check_method(node, root)
        self.generic_visit(node)


def lint_source(source: str, offset: int = 0) -> list[Diagnostic]:
    """Lint a user program (or a decorated function's body).  ``offset``
    shifts reported line numbers (for function sources extracted mid-file)."""
    tree = ast.parse(source)
    linter = _Linter(_frame_vars(tree), _tables())
    linter.visit(tree)
    diags = sorted(linter.diags, key=lambda d: (d.line, d.col))
    if offset:
        diags = [dataclasses.replace(d, line=d.line + offset) for d in diags]
    return diags


def lint_file(path: str) -> list[Diagnostic]:
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read())


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    as_json = "--json" in argv
    paths = [a for a in argv if a != "--json"]
    if not paths:
        print("usage: python -m repro.lint <file.py> [...] [--json]",
              file=sys.stderr)
        return 2
    failed = False
    all_diags = []
    for path in paths:
        diags = lint_file(path)
        all_diags.append({"file": path,
                          "diagnostics": [d.to_dict() for d in diags]})
        if not as_json:
            for d in diags:
                print(f"{path}:{d}")
        failed |= any(d.kind == "fallback.failed" for d in diags)
    if as_json:
        print(json.dumps(all_diags, indent=2))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
