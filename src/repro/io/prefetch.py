"""Bounded async partition prefetcher.

``prefetch_iter`` runs a loader on a background thread, keeping at most
``depth`` decoded partitions in flight, so partition decode (disk read,
parquet decompression, dict-code mapping) overlaps with downstream
compute.  It is the IO half of the streaming backend's
partition-at-a-time pipeline; the compute half pulls from the queue.

The consumer contract matches plain generators, including the abandoned
case: the streaming ``Head`` operator early-exits its upstream generators
(``GeneratorExit``), so ``close()`` must stop a worker that may be blocked
on a full queue — the worker uses timed puts and re-checks a stop event,
and the generator's ``finally`` drains the queue and joins the thread.
Loader exceptions are re-raised in the consumer at the failing partition's
position in the stream.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Sequence

_DONE = object()


def prefetch_iter(indices: Sequence[int], load: Callable[[int], object],
                  depth: int = 2,
                  on_prefetch: Callable[[int], None] | None = None
                  ) -> Iterator[object]:
    """Yield ``load(i)`` for each ``i`` in order, loading up to ``depth``
    items ahead on a background thread.

    ``on_prefetch(i)`` (if given) fires on the worker thread only for
    partitions whose decode completed *before the consumer requested
    them* — i.e. genuinely decoded ahead of the consumer, not merely
    routed through the prefetch thread — the hook for
    ``io.partitions_prefetched`` accounting.  A partition the consumer is
    already blocked waiting for is demand-loaded, not prefetched.  Falls
    back to plain sequential loading when ``depth`` < 1 or there is ≤ 1
    item (nothing to overlap)."""
    indices = list(indices)
    if depth < 1 or len(indices) <= 1:
        for i in indices:
            yield load(i)
        return

    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()
    # number of q.get() calls the consumer has started; the k-th item was
    # decoded ahead of the consumer iff the consumer had not yet begun its
    # (k+1)-th get when the decode finished (int-in-list: GIL-atomic)
    requested = [0]

    def worker():
        try:
            for k, i in enumerate(indices):
                if stop.is_set():
                    return
                try:
                    item = (i, load(i), None)
                    if on_prefetch is not None and requested[0] <= k:
                        on_prefetch(i)
                except BaseException as exc:  # noqa: BLE001 — re-raised consumer-side
                    item = (i, None, exc)
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
                if item[2] is not None:
                    return
        finally:
            while not stop.is_set():
                try:
                    q.put(_DONE, timeout=0.05)
                    break
                except queue.Full:
                    continue

    t = threading.Thread(target=worker, name="repro-io-prefetch", daemon=True)
    t.start()
    try:
        while True:
            requested[0] += 1
            item = q.get()
            if item is _DONE:
                return
            _, value, exc = item
            if exc is not None:
                raise exc
            yield value
    finally:
        stop.set()
        while True:                      # unblock a worker stuck on put()
            try:
                q.get_nowait()
            except queue.Empty:
                break
        t.join(timeout=5.0)
