"""Chunked columnar Parquet source (pyarrow-backed).

``ParquetSource`` serves a single ``.parquet`` file or a directory of
``part-*.parquet`` files as engine partitions — one partition per row
group — with the engine's column conventions applied at decode time:
string columns dictionary-encoded to int32 codes against a global vocab,
timestamp columns lowered to int64 epoch seconds.  Projection happens at
the pyarrow layer (only requested columns are read), so bytes-read scales
with the pushed-down column set.

Statistics never require a second scan: the first open builds per-row-group
zone maps from the parquet footer (numeric columns) plus one vocab pass for
string columns, then persists everything in the JSON sidecar
(``repro.io.sidecar``); subsequent opens are metadata-only.

``write_parquet_source`` is the ingest path: engine arrays (codes + vocab,
epoch-second datetimes) become plain interoperable parquet (real strings,
real timestamps) plus a sidecar, one file per partition.

pyarrow is optional: ``HAS_PYARROW`` gates the source, and the NPZ
directory layout (``repro.core.source.NpzDirectorySource``) is the
no-pyarrow fallback with the same sidecar/pushdown contract.

Null policy: parquet nulls are unsupported — the engine's host arrays are
dense (float NaN round-trips as a real NaN value, not a parquet null), so
externally-written files containing nulls are rejected with a clear
``ValueError`` at stats build and again at partition decode, never a
``KeyError`` deep in code mapping.
"""
from __future__ import annotations

import datetime
import glob
import os
from typing import Mapping, Sequence

import numpy as np

try:
    import pyarrow as pa
    import pyarrow.parquet as pq
    HAS_PYARROW = True
except Exception:  # noqa: BLE001 — pyarrow genuinely optional
    pa = pq = None
    HAS_PYARROW = False

from repro.core.schema import ColumnSchema, TableSchema
from repro.core.source import Source, _zonemap

from . import sidecar as SC


def _require_pyarrow():
    if not HAS_PYARROW:
        raise ImportError(
            "pyarrow is required for Parquet sources; install it or use "
            "the NPZ directory layout (write_npz_source/read_npz)")


def _stats_epoch(v) -> int:
    """Epoch seconds of a row-group min/max timestamp statistic.  pyarrow
    decodes footer stats to *naive* ``datetime`` objects that represent
    UTC instants; a naive ``.timestamp()`` would re-interpret them in the
    machine's local zone and shift the zone map by the UTC offset —
    silently wrong pruning on any non-UTC host."""
    if isinstance(v, (int, float)):
        return int(v)
    if v.tzinfo is None:
        v = v.replace(tzinfo=datetime.timezone.utc)
    return int(v.timestamp())


def _null_error(column: str, where: str) -> ValueError:
    return ValueError(
        f"ParquetSource does not support null values (column {column!r} "
        f"in {where}); drop or fill nulls before ingest")


def parquet_files(path: str) -> list[str]:
    """Data files for a parquet source path (single file or directory)."""
    if os.path.isdir(path):
        return sorted(glob.glob(os.path.join(path, "*.parquet")))
    return [path]


class ParquetSource(Source):
    """Partitioned parquet reader with sidecar-backed metadata.

    Partitions are row groups in file order.  ``load_partition`` reads only
    the requested columns of one row group and decodes them to the engine's
    host-array conventions."""

    supports_pushdown = True
    prefetchable = True

    def __init__(self, path: str):
        _require_pyarrow()
        self.path = path
        files = parquet_files(path)
        if not files:
            raise FileNotFoundError(f"no .parquet files under {path!r}")
        self._files = files
        self._handles: dict[int, "pq.ParquetFile"] = {}
        self.name = os.path.basename(path.rstrip("/"))
        payload = SC.read_sidecar(path, data_files=files)
        if payload is None:
            payload = self._build_stats(files)
            SC.write_sidecar(path, payload["partitions"],
                             columns=payload["columns"],
                             dicts=payload["dicts"],
                             datetimes=payload["datetimes"],
                             data_files=files)
        self._parts = payload["partitions"]   # {"file","row_group","rows","zonemap"}
        self.dicts = {k: list(v) for k, v in payload["dicts"].items()}
        self._datetimes = tuple(payload["datetimes"])
        self.schema = TableSchema(tuple(
            ColumnSchema(n, c["dtype"], is_dict=c.get("is_dict", False),
                         dict_size=len(self.dicts.get(n, [])) or None,
                         is_datetime=c.get("is_datetime", False))
            for n, c in payload["columns"].items()))
        self._code_maps: dict[str, dict] = {}
        self._fingerprint = SC.fingerprint(payload)

    # -- identity -----------------------------------------------------------
    def cache_token(self):
        """Path-stable token covering source file identity: the sidecar's
        content digest (which records every data file's size+mtime) plus
        the sidecar file's own mtime — a rewritten directory or sidecar
        yields a fresh token, so plan-key consumers never reuse
        data-derived state across file changes."""
        return ("parquet", os.path.abspath(self.path), self._fingerprint,
                SC.sidecar_mtime_ns(self.path))

    # -- stats build (first open only) --------------------------------------
    def _build_stats(self, files: list[str]) -> dict:
        """One metadata pass over footers + one data pass over string
        columns (vocab build).  Numeric zone maps come from row-group
        statistics; string-column zone maps are code ranges against the
        global vocab; timestamp zone maps are epoch-second ranges."""
        columns: dict[str, dict] = {}
        dicts: dict[str, list[str]] = {}
        datetimes: list[str] = []
        first = pq.ParquetFile(files[0])
        str_cols: list[str] = []
        for field in first.schema_arrow:
            name = field.name
            t = field.type
            if pa.types.is_string(t) or pa.types.is_large_string(t) \
                    or pa.types.is_dictionary(t):
                columns[name] = {"dtype": "dict", "is_dict": True,
                                 "is_datetime": False}
                str_cols.append(name)
            elif pa.types.is_timestamp(t):
                columns[name] = {"dtype": "datetime64[s]", "is_dict": False,
                                 "is_datetime": True}
                datetimes.append(name)
            elif pa.types.is_boolean(t):
                columns[name] = {"dtype": "bool", "is_dict": False,
                                 "is_datetime": False}
            else:
                columns[name] = {"dtype": str(t.to_pandas_dtype().__name__
                                              if hasattr(t, "to_pandas_dtype")
                                              else t),
                                 "is_dict": False, "is_datetime": False}
        # global vocab per string column: one pass over just those columns
        if str_cols:
            vocab_sets: dict[str, set] = {c: set() for c in str_cols}
            for f in files:
                t = pq.ParquetFile(f).read(columns=str_cols)
                for c in str_cols:
                    col = t.column(c)
                    if col.null_count:
                        raise _null_error(c, f)
                    if pa.types.is_dictionary(col.type):
                        col = col.cast(pa.string())
                    vocab_sets[c].update(
                        v for v in col.to_pylist() if v is not None)
            for c in str_cols:
                dicts[c] = sorted(str(v) for v in vocab_sets[c])
        code_maps = {c: {v: i for i, v in enumerate(dicts[c])}
                     for c in str_cols}
        partitions: list[dict] = []
        for fi, f in enumerate(files):
            pf = pq.ParquetFile(f)
            md = pf.metadata
            names = [md.schema.column(ci).name
                     for ci in range(len(md.schema))]
            for rg in range(md.num_row_groups):
                rgm = md.row_group(rg)
                zm: dict[str, tuple] = {}
                for ci, name in enumerate(names):
                    if name not in columns:
                        continue
                    spec = columns[name]
                    stats = rgm.column(ci).statistics
                    if stats is not None and stats.has_null_count \
                            and stats.null_count:
                        raise _null_error(name, f)
                    if spec["is_dict"]:
                        if stats is not None and stats.has_min_max:
                            cmap = code_maps.get(name, {})
                            lo = cmap.get(str(stats.min))
                            hi = cmap.get(str(stats.max))
                            if lo is not None and hi is not None:
                                zm[name] = (lo, hi)
                        continue
                    if stats is None or not stats.has_min_max:
                        continue
                    lo, hi = stats.min, stats.max
                    if spec["is_datetime"]:
                        try:
                            lo = _stats_epoch(lo)
                            hi = _stats_epoch(hi)
                        except (AttributeError, OSError, OverflowError):
                            continue
                    if isinstance(lo, (int, float)) \
                            and isinstance(hi, (int, float)) \
                            and not isinstance(lo, bool):
                        zm[name] = (lo, hi)
                partitions.append({"file": os.path.basename(f),
                                   "row_group": rg,
                                   "rows": rgm.num_rows,
                                   "zonemap": zm})
        return {"version": SC.SIDECAR_VERSION, "partitions": partitions,
                "columns": columns, "dicts": dicts, "datetimes": datetimes}

    # -- Source protocol ----------------------------------------------------
    @property
    def n_partitions(self) -> int:
        return len(self._parts)

    def partition_meta(self, i: int) -> dict:
        p = self._parts[i]
        return {"rows": p["rows"],
                "zonemap": {k: tuple(v) for k, v in
                            p.get("zonemap", {}).items()}}

    def _handle(self, fname: str) -> "pq.ParquetFile":
        fi = next((i for i, f in enumerate(self._files)
                   if os.path.basename(f) == fname), None)
        if fi is None:
            raise FileNotFoundError(
                f"data file {fname!r} referenced by partition metadata is "
                f"missing from {self.path!r} (directory changed after open?)")
        h = self._handles.get(fi)
        if h is None:
            h = self._handles[fi] = pq.ParquetFile(self._files[fi])
        return h

    def _codes(self, name: str, col: "pa.ChunkedArray") -> np.ndarray:
        if pa.types.is_dictionary(col.type):
            col = col.cast(pa.string())
        cmap = self._code_maps.get(name)
        if cmap is None:
            cmap = self._code_maps[name] = {
                v: i for i, v in enumerate(self.dicts[name])}
        values = col.to_pylist()
        return np.fromiter((cmap[v] for v in values), dtype=np.int32,
                           count=len(values))

    def load_partition(self, i: int, columns: Sequence[str] | None = None
                       ) -> dict[str, np.ndarray]:
        p = self._parts[i]
        pf = self._handle(p["file"])
        names = list(columns) if columns is not None else None
        table = pf.read_row_group(p["row_group"], columns=names)
        out: dict[str, np.ndarray] = {}
        for name in (names if names is not None else table.column_names):
            col = table.column(name).combine_chunks()
            if col.null_count:
                raise _null_error(name, p["file"])
            cs = self.schema.col(name)
            if cs.is_dict:
                out[name] = self._codes(name, col)
            elif cs.is_datetime:
                out[name] = np.asarray(
                    col.cast(pa.timestamp("s")).cast(pa.int64()),
                    dtype=np.int64)
            else:
                out[name] = col.to_numpy(zero_copy_only=False)
        return out


def write_parquet_source(path: str, arrays: Mapping[str, np.ndarray],
                         partition_rows: int = 1 << 18,
                         dicts: Mapping[str, Sequence[str]] | None = None,
                         datetimes: Sequence[str] = (),
                         ingest: Mapping[str, object] | None = None
                         ) -> ParquetSource:
    """Ingest engine arrays as a parquet directory source + sidecar.

    Dict-encoded columns (``dicts``) are written as real strings,
    epoch-second datetime columns as ``timestamp[s]`` — the files are plain
    parquet any reader understands.  The sidecar is written from the
    in-memory arrays, so the resulting source never rescans its own data.
    ``ingest`` records upstream file states (e.g. a CSV cache's origin)."""
    _require_pyarrow()
    os.makedirs(path, exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in arrays.items()}
    dicts = {k: list(v) for k, v in (dicts or {}).items()}
    rows = len(next(iter(arrays.values())))
    columns: dict[str, dict] = {}
    for name, arr in arrays.items():
        columns[name] = {"dtype": ("dict" if name in dicts else
                                   "datetime64[s]" if name in datetimes else
                                   str(arr.dtype)),
                         "is_dict": name in dicts,
                         "is_datetime": name in datetimes}
    parts: list[dict] = []
    files: list[str] = []
    for pi, lo in enumerate(range(0, max(rows, 1), partition_rows)):
        hi = min(lo + partition_rows, rows)
        part = {k: a[lo:hi] for k, a in arrays.items()}
        cols = {}
        for name, arr in part.items():
            if name in dicts:
                vocab = np.asarray(dicts[name], dtype=object)
                cols[name] = pa.array(vocab[arr], type=pa.string())
            elif name in datetimes:
                cols[name] = pa.array(arr.astype(np.int64)).cast(
                    pa.timestamp("s"))
            else:
                cols[name] = pa.array(arr)
        fname = f"part-{pi:05d}.parquet"
        fpath = os.path.join(path, fname)
        pq.write_table(pa.table(cols), fpath)
        files.append(fpath)
        parts.append({"file": fname, "row_group": 0, "rows": hi - lo,
                      "zonemap": _zonemap(part)})
    SC.write_sidecar(path, parts, columns=columns, dicts=dicts,
                     datetimes=datetimes, data_files=files, ingest=ingest)
    return ParquetSource(path)
