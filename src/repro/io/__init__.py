"""Columnar on-disk IO subsystem.

The engine's scan boundary: chunked columnar sources (Parquet via
pyarrow, the NPZ directory layout as the no-pyarrow fallback) that serve
column-pruned, predicate-filtered partitions; JSON zone-map/row-count
sidecars so reopening a source never rescans data; a bounded async
prefetcher overlapping partition decode with compute; and the shared
pushdown-aware scan loader all three backends execute through.
"""
from __future__ import annotations

import os

from .parquet import (HAS_PYARROW, ParquetSource, parquet_files,
                      write_parquet_source)
from .prefetch import prefetch_iter
from .scan import (empty_scan_table, iter_scan_partitions,
                   load_scan_partition, pushdown_read_cols,
                   scan_partition_indices)
from .sidecar import (read_sidecar, sidecar_mtime_ns, sidecar_path,
                      write_sidecar)

__all__ = [
    "HAS_PYARROW", "ParquetSource", "parquet_files", "write_parquet_source",
    "prefetch_iter", "empty_scan_table", "iter_scan_partitions",
    "load_scan_partition", "pushdown_read_cols", "scan_partition_indices",
    "read_sidecar", "sidecar_mtime_ns", "sidecar_path", "write_sidecar",
    "open_source",
]


def open_source(path: str):
    """Open an on-disk source by layout: ``.parquet`` file or directory of
    parquet files → :class:`ParquetSource`; directory with ``_meta.json``
    → :class:`~repro.core.source.NpzDirectorySource`."""
    from repro.core.source import NpzDirectorySource
    if os.path.isdir(path):
        if os.path.exists(os.path.join(path, "_meta.json")):
            return NpzDirectorySource(path)
        return ParquetSource(path)
    return ParquetSource(path)
