"""JSON zone-map/row-count sidecars for on-disk columnar sources.

A sidecar (``_stats.json`` inside a source directory, ``<file>.stats.json``
next to a single-file source) persists everything the planner needs from a
source *without touching data*: per-partition row counts and zone maps,
the column schema, dictionary vocabularies, and datetime markers.  It is
written once at ingest; reopening the source reads the sidecar instead of
rescanning partitions.

Staleness is detected by recording each data file's ``(size, mtime_ns)``
at write time: a sidecar whose recorded file set or states no longer
match the files on disk — including a recorded file that was deleted —
is ignored (the source rebuilds stats and rewrites it).  The
sidecar file's own mtime participates in the source ``cache_token`` so a
rewritten directory — or a hand-edited sidecar — never serves stale
plan-key consumers (persist cache, stats feedback).
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Mapping, Sequence

SIDECAR_NAME = "_stats.json"
SIDECAR_VERSION = 1


def sidecar_path(base: str) -> str:
    """Sidecar location for a source rooted at ``base`` (directory or
    single data file)."""
    if os.path.isdir(base):
        return os.path.join(base, SIDECAR_NAME)
    return base + ".stats.json"


def file_state(path: str) -> list[int]:
    """``[size, mtime_ns]`` — the staleness fingerprint of one data file."""
    st = os.stat(path)
    return [int(st.st_size), int(st.st_mtime_ns)]


def sidecar_mtime_ns(base: str) -> int:
    """mtime of the sidecar file itself (0 when absent) — folded into the
    source ``cache_token`` so token consumers see sidecar rewrites."""
    try:
        return int(os.stat(sidecar_path(base)).st_mtime_ns)
    except OSError:
        return 0


def _json_safe(v):
    """Coerce numpy scalars / tuples to JSON-serializable values."""
    if isinstance(v, dict):
        return {k: _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    item = getattr(v, "item", None)
    if callable(item) and getattr(v, "ndim", 0) == 0:
        return v.item()
    return v


def write_sidecar(base: str, partitions: Sequence[dict],
                  columns: Mapping[str, dict] | None = None,
                  dicts: Mapping[str, Sequence[str]] | None = None,
                  datetimes: Sequence[str] = (),
                  data_files: Sequence[str] | None = None,
                  ingest: Mapping[str, object] | None = None) -> dict:
    """Persist stats for a source rooted at ``base``.

    ``partitions`` — one ``{"file": name, "rows": int, "zonemap": {...}}``
    per partition (``file`` optional for row-group partitions).
    ``data_files`` — absolute paths of the data files the stats describe
    (their states are recorded for staleness checks).  ``ingest`` —
    optional upstream-file states (e.g. the CSV a cache was built from).
    Written atomically (tmp + rename).  Returns the payload.
    """
    payload = {
        "version": SIDECAR_VERSION,
        "partitions": _json_safe(list(partitions)),
        "columns": _json_safe(dict(columns or {})),
        "dicts": _json_safe({k: list(v) for k, v in (dicts or {}).items()}),
        "datetimes": list(datetimes),
        "files": {os.path.basename(f): file_state(f)
                  for f in (data_files or ())},
    }
    if ingest:
        payload["ingest"] = _json_safe(dict(ingest))
    path = sidecar_path(base)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)
    return payload


def read_sidecar(base: str,
                 data_files: Sequence[str] | None = None) -> dict | None:
    """Load the sidecar for ``base``; ``None`` when absent, unparseable, a
    different version, or stale.  Stale means the recorded data-file set
    differs from ``data_files`` in EITHER direction — a current file not
    recorded, or a recorded file deleted from disk (whose partitions would
    reference a missing file) — or any recorded ``(size, mtime_ns)`` state
    mismatches the file on disk."""
    path = sidecar_path(base)
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return None
    if payload.get("version") != SIDECAR_VERSION:
        return None
    states = payload.get("files", {})
    if data_files is not None:
        if set(states) != {os.path.basename(f) for f in data_files}:
            return None
        for f in data_files:
            try:
                if list(states[os.path.basename(f)]) != file_state(f):
                    return None
            except OSError:
                return None
    return payload


def fingerprint(payload: Mapping) -> str:
    """Content digest of a sidecar payload (part of disk-source tokens)."""
    blob = json.dumps(_json_safe(dict(payload)), sort_keys=True).encode()
    return hashlib.md5(blob).hexdigest()[:16]
