"""Shared pushdown-aware Scan execution for all three backends.

One loader implements the ``Scan.pushdown`` contract — read only the
columns the plan needs (output projection ∪ predicate columns), apply the
pushed-down predicate per partition right after decode, then project away
predicate-only columns — so eager, streaming, and distributed stay
bit-identical by construction.  ``iter_scan_partitions`` adds the async
prefetch pipeline on top for sources that advertise ``prefetchable``.

Accounting (``io.*`` counters on the session metrics registry, ``io``
spans on the session tracer) happens here, at the single point where
bytes actually leave the source:

* ``io.partitions_loaded`` / ``io.bytes_read`` — partitions decoded and
  their decoded column bytes (pruned partitions never count — they are
  never requested).
* ``io.partitions_pruned`` — partitions skipped via ``skip_partitions``.
* ``io.partitions_prefetched`` — partitions decoded ahead of the consumer
  by the background prefetch thread.
* ``io.pushdown_rows_in`` / ``io.pushdown_rows_out`` — row counts around
  the pushed-down predicate.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.core import graph as G
from repro.obs.spans import io_span

from .prefetch import prefetch_iter


def pushdown_read_cols(n: "G.Scan") -> list[str] | None:
    """Columns to request from the source: the scan's output projection
    plus any predicate-only columns the pushed-down conjuncts need
    (``None`` = all columns, mirroring ``Scan.columns``)."""
    if n.columns is None:
        return None
    cols = list(n.columns)
    if n.pushdown is not None:
        names = set(n.source.schema.names)
        have = set(cols)
        cols += [c for c in sorted(n.pushdown.used_cols())
                 if c in names and c not in have]
    return cols


def scan_partition_indices(n: "G.Scan") -> list[int]:
    """Partition indices the scan will actually read (prune set removed)."""
    return [i for i in range(n.source.n_partitions)
            if i not in n.skip_partitions]


def empty_scan_table(n: "G.Scan") -> dict[str, np.ndarray]:
    """0-row table with the scan's output schema (all partitions pruned,
    or every row filtered by the pushed-down predicate)."""
    cols = n.columns if n.columns is not None else n.source.schema.names
    out = {}
    for c in cols:
        dt = n.dtype_overrides.get(c, n.source.schema.col(c).np_dtype)
        out[c] = np.zeros(0, dt)
    return out


def load_scan_partition(n: "G.Scan", pi: int, metrics=None, tracer=None
                        ) -> dict[str, np.ndarray]:
    """Load one partition of a scan: read the pushed-down column set,
    apply dtype overrides, evaluate the pushed-down predicate (host
    numpy — same arrays and semantics the Filter operator would see, so
    pushdown on/off is bit-identical), and project to the output columns."""
    read_cols = pushdown_read_cols(n)
    with io_span("load_partition", tracer=tracer, source=n.source.name,
                 partition=pi) as sp:
        part = n.source.load_partition(pi, read_cols)
        part = {k: np.asarray(v) for k, v in part.items()}
        nbytes = sum(int(a.nbytes) for a in part.values())
        for c, dt in n.dtype_overrides.items():
            if c in part:
                part[c] = part[c].astype(dt)
        if metrics is not None:
            metrics.inc("io.partitions_loaded")
            metrics.inc("io.bytes_read", nbytes)
        rows_in = len(next(iter(part.values()))) if part else 0
        if n.pushdown is not None:
            mask = np.asarray(n.pushdown.predicate.evaluate(part))
            if mask.ndim == 0:            # constant predicate (e.g. Lit)
                part = part if bool(mask) else {k: v[:0]
                                                for k, v in part.items()}
            else:
                part = {k: v[mask] for k, v in part.items()}
            rows_out = len(next(iter(part.values()))) if part else 0
            if metrics is not None:
                metrics.inc("io.pushdown_rows_in", rows_in)
                metrics.inc("io.pushdown_rows_out", rows_out)
        else:
            rows_out = rows_in
        if n.columns is not None:
            part = {c: part[c] for c in n.columns}
        sp.set(bytes=nbytes, rows_in=rows_in, rows_out=rows_out)
    return part


def iter_scan_partitions(n: "G.Scan", ctx=None
                         ) -> Iterator[dict[str, np.ndarray]]:
    """Stream a scan's unpruned partitions in order, prefetching ahead on
    a background thread when the source supports it.

    Always yields at least one (possibly 0-row) table so downstream
    operators keep the schema.  The prefetch depth comes from the session
    knob ``io_prefetch`` (default 2; 0 disables); metrics/spans go to the
    given context's registry/tracer so background-thread loads attribute
    to the right session."""
    if ctx is None:
        from repro.core.context import get_context
        ctx = get_context()
    metrics = getattr(ctx, "metrics", None)
    tracer = getattr(ctx, "tracer", None)
    indices = scan_partition_indices(n)
    if metrics is not None and n.skip_partitions:
        metrics.inc("io.partitions_pruned", len(n.skip_partitions))
    if not indices:
        yield empty_scan_table(n)
        return
    depth = 0
    if getattr(n.source, "prefetchable", False):
        opts = getattr(ctx, "backend_options", {}) or {}
        depth = int(opts.get("io_prefetch", 2))

    def load(pi: int) -> dict[str, np.ndarray]:
        return load_scan_partition(n, pi, metrics=metrics, tracer=tracer)

    def on_prefetch(pi: int) -> None:
        if metrics is not None:
            metrics.inc("io.partitions_prefetched")

    yield from prefetch_iter(indices, load, depth=depth,
                             on_prefetch=on_prefetch)
