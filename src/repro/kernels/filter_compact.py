"""Filter + stream compaction kernel (TPU adaptation of LaFP's filter hot
path, DESIGN §2).

GPU compaction uses warp ballots and shared-memory scans; neither exists on
TPU.  The TPU-native design:

* grid steps run **sequentially** on a TensorCore, so a running output
  offset lives in an SMEM scratch cell and threads the blocks together
  (a decoupled look-back scan without the look-back);
* within a block, compaction is a **permutation matmul** on the MXU:
  ``packed = onehotᵀ · values`` where ``onehot[j, cumsum(mask)_j-1] = mask_j``
  — scatter-free, branch-free;
* the packed block is stored at the running offset with a dynamic slice
  into the full VMEM-resident output; garbage beyond each block's count is
  overwritten by the next block (the valid prefix grows monotonically).

Output must fit VMEM (~4M f32 rows); `ops.filter_compact_chunked` stitches
larger arrays in 1M-row chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compact_kernel(mask_ref, values_ref, out_ref, count_ref, off_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        off_ref[0] = 0
        out_ref[...] = jnp.zeros_like(out_ref)

    mask = mask_ref[...]                       # (B,) bool
    values = values_ref[...]                   # (B,) f32
    b = mask.shape[0]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1          # in-block slot
    cnt = jnp.sum(mask.astype(jnp.int32))
    slots = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    onehot = ((pos[:, None] == slots) & mask[:, None]).astype(jnp.float32)
    # NaN-safe permutation: 0·NaN = NaN would poison every matmul slot, so
    # the matmul moves zeroed values alongside an isnan indicator column
    # and NaNs are re-materialized in their permuted slots afterwards
    nan_row = jnp.isnan(values)
    rhs = jnp.stack([jnp.where(nan_row, 0.0, values.astype(jnp.float32)),
                     nan_row.astype(jnp.float32)], axis=1)       # (B, 2)
    packed2 = jax.lax.dot_general(
        onehot, rhs,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (B, 2) permuted
    packed = jnp.where(packed2[:, 1] > 0, jnp.nan, packed2[:, 0])
    off = off_ref[0]
    out_ref[pl.ds(off, b)] = packed
    off_ref[0] = off + cnt

    @pl.when(i == pl.num_programs(0) - 1)
    def _fin():
        count_ref[0] = off + cnt


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def filter_compact(values: jax.Array, mask: jax.Array, block_rows: int = 512,
                   interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Pack values[mask] to the front (stable); returns (packed (N,), count).

    Slots ≥ count are zeroed."""
    n = values.shape[0]
    nb = -(-max(n, block_rows) // block_rows) * block_rows
    vals_p = jnp.zeros((nb,), jnp.float32).at[:n].set(
        values.astype(jnp.float32))
    mask_p = jnp.zeros((nb,), bool).at[:n].set(mask)
    grid = nb // block_rows
    packed, count = pl.pallas_call(
        _compact_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((nb + block_rows,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb + block_rows,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(mask_p, vals_p)
    count = count[0]
    valid = jnp.arange(n) < count
    out = jnp.where(valid, packed[:n], 0).astype(values.dtype) \
        if values.dtype != jnp.float32 else jnp.where(valid, packed[:n], 0)
    return out, count
