"""Pure-jnp oracles for the Pallas kernels.

Each function is the semantic ground truth; kernel tests sweep shapes/dtypes
and assert_allclose against these.
"""
from __future__ import annotations

import jax.numpy as jnp


def groupby_sum_ref(codes: jnp.ndarray, values: jnp.ndarray,
                    num_groups: int) -> jnp.ndarray:
    """Segment-sum of ``values`` (N,) or (N, V) by int ``codes`` (N,) into
    (G,) or (G, V).  Out-of-range codes contribute nothing."""
    import jax
    valid = (codes >= 0) & (codes < num_groups)
    safe = jnp.where(valid, codes, num_groups)
    if values.ndim == 1:
        vals = jnp.where(valid, values, 0)
        return jax.ops.segment_sum(vals, safe, num_groups + 1)[:num_groups]
    vals = jnp.where(valid[:, None], values, 0)
    return jax.ops.segment_sum(vals, safe, num_groups + 1)[:num_groups]


def filter_count_ref(mask: jnp.ndarray) -> jnp.ndarray:
    """Number of surviving rows."""
    return jnp.sum(mask.astype(jnp.int32))


def filter_compact_ref(values: jnp.ndarray, mask: jnp.ndarray
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stable compaction: surviving values packed to the front, padded with
    zeros; returns (packed (N,), count ())."""
    n = values.shape[0]
    idx = jnp.cumsum(mask.astype(jnp.int32)) - 1          # target slot per row
    count = jnp.sum(mask.astype(jnp.int32))
    safe_idx = jnp.where(mask, idx, n)                    # masked rows → spill
    out = jnp.zeros((n + 1,), values.dtype).at[safe_idx].set(values)[:n]
    valid = jnp.arange(n) < count
    return jnp.where(valid, out, 0), count


def zonemap_ref(values: jnp.ndarray, block: int
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block (min, max) over a 1-D array padded to a multiple of block.
    Padding uses +inf/-inf identities."""
    n = values.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    if values.dtype.kind == "f":
        lo_id, hi_id = jnp.inf, -jnp.inf
    else:
        info = jnp.iinfo(values.dtype)
        lo_id, hi_id = info.max, info.min
    v_lo = jnp.concatenate([values, jnp.full((pad,), lo_id, values.dtype)])
    v_hi = jnp.concatenate([values, jnp.full((pad,), hi_id, values.dtype)])
    mins = v_lo.reshape(nb, block).min(axis=1)
    maxs = v_hi.reshape(nb, block).max(axis=1)
    return mins, maxs
