"""MXU group-by aggregation kernel (TPU adaptation of LaFP's group-by hot
path, DESIGN §2).

GPU/CPU dataframe engines aggregate via hash tables — branchy scalar probing
that has no TPU analogue.  The TPU-native rethink: per row-block, build a
one-hot matrix of the group codes and *matmul* it against the value block on
the MXU:

    out[g, v] += Σ_j onehot[j, g] · values[j, v]      (Gp,B)·(B,Vp)

The output block (Gp, Vp) stays resident in VMEM across all grid steps
(constant index_map), so the aggregation is a single pass over HBM with
arithmetic intensity B·G·V / (B·V) = G — compute-bound for G ≥ ~100, versus
the memory-bound scatter a hash aggregation would be.

Block shapes: rows B=256 (sublane multiple), groups padded to 8·k, value
columns padded to 128·k (lane width).  Dict-encoded (category) key columns
from the metadata store guarantee a dense, bounded code domain — the same
invariant the distributed backend's segment-sum path uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _groupby_kernel(codes_ref, values_ref, out_ref, *, num_groups_padded: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]            # (B,)
    values = values_ref[...]          # (B, Vp) f32
    groups = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0],
                                                  num_groups_padded), 1)
    onehot = (codes[:, None] == groups).astype(jnp.float32)   # (B, Gp)
    # MXU: (Gp, B) @ (B, Vp) — accumulate into the resident output block
    contrib = jax.lax.dot_general(
        onehot, values,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (Gp, Vp)
    out_ref[...] += contrib


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("num_groups", "block_rows",
                                             "interpret"))
def groupby_sum(codes: jax.Array, values: jax.Array, num_groups: int,
                block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """Segment-sum values (N,) or (N, V) by codes (N,) → (G,) or (G, V).

    Rows with codes outside [0, num_groups) contribute nothing (they hit
    padded one-hot columns)."""
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    n, v = values.shape
    gp = _pad_to(max(num_groups, 8), 8)
    vp = _pad_to(max(v, 128), 128)
    nb = _pad_to(max(n, block_rows), block_rows)
    codes_p = jnp.full((nb,), gp, jnp.int32).at[:n].set(
        codes.astype(jnp.int32))                    # pad rows → dead group
    values_p = jnp.zeros((nb, vp), jnp.float32).at[:n, :v].set(
        values.astype(jnp.float32))
    grid = nb // block_rows
    out = pl.pallas_call(
        functools.partial(_groupby_kernel, num_groups_padded=gp),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, vp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((gp, vp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, vp), jnp.float32),
        interpret=interpret,
    )(codes_p, values_p)
    out = out[:num_groups, :v]
    return out[:, 0] if squeeze else out
