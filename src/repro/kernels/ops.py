"""jit'd dispatch wrappers for the Pallas kernels.

``impl`` selects:
* ``"pallas"``   — TPU-target kernels (validated with interpret=True on CPU;
                   on a real TPU pass interpret=False via KernelConfig)
* ``"xla"``      — the pure-jnp reference path (production fallback; also
                   the oracle used in tests)

The engine picks "xla" on CPU hosts and "pallas" on TPU; this mirrors the
paper's backend-capability fallback.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import ref
from .filter_compact import filter_compact as _filter_compact_pallas
from .groupby_sum import groupby_sum as _groupby_sum_pallas
from .zonemap import zonemap as _zonemap_pallas


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    impl: str = "auto"          # auto | pallas | xla
    interpret: bool = True      # Pallas interpret mode (CPU validation)

    def resolved(self) -> str:
        if self.impl != "auto":
            return self.impl
        platform = jax.devices()[0].platform
        return "pallas" if platform == "tpu" else "xla"


_CONFIG = KernelConfig()


def set_kernel_config(cfg: KernelConfig):
    global _CONFIG
    _CONFIG = cfg


def get_kernel_config() -> KernelConfig:
    return _CONFIG


def groupby_sum(codes, values, num_groups: int, cfg: KernelConfig | None = None):
    cfg = cfg or _CONFIG
    if cfg.resolved() == "pallas":
        return _groupby_sum_pallas(codes, values, num_groups,
                                   interpret=cfg.interpret)
    return ref.groupby_sum_ref(codes, values, num_groups)


def filter_compact(values, mask, cfg: KernelConfig | None = None):
    cfg = cfg or _CONFIG
    if cfg.resolved() == "pallas":
        return _filter_compact_pallas(values, mask, interpret=cfg.interpret)
    return ref.filter_compact_ref(values, mask)


def filter_compact_chunked(values, mask, chunk: int = 1 << 20,
                           cfg: KernelConfig | None = None):
    """Two-level compaction for arrays beyond VMEM residency: compact each
    chunk, then compact the concatenated survivors' prefix mask."""
    n = values.shape[0]
    if n <= chunk:
        return filter_compact(values, mask, cfg)
    packed_parts, counts = [], []
    for lo in range(0, n, chunk):
        p, c = filter_compact(values[lo:lo + chunk], mask[lo:lo + chunk], cfg)
        packed_parts.append(p)
        counts.append(c)
    packed = jnp.concatenate(packed_parts)
    counts = jnp.stack(counts)
    # validity mask of the concatenated chunks, then one more compaction
    sizes = jnp.asarray([p.shape[0] for p in packed_parts])
    offs = jnp.cumsum(sizes) - sizes
    idx = jnp.arange(packed.shape[0])
    chunk_id = jnp.searchsorted(offs, idx, side="right") - 1
    valid = (idx - offs[chunk_id]) < counts[chunk_id]
    return filter_compact(packed, valid, cfg)


def zonemap(values, block_rows: int = 4096, cfg: KernelConfig | None = None):
    cfg = cfg or _CONFIG
    if values.shape[0] == 0:
        # unified empty contract: no rows → no blocks (the Pallas kernel
        # would otherwise emit one identity-padded block)
        return (jnp.zeros((0,), values.dtype), jnp.zeros((0,), values.dtype))
    if cfg.resolved() == "pallas":
        return _zonemap_pallas(values, block_rows=block_rows,
                               interpret=cfg.interpret)
    return ref.zonemap_ref(values, block_rows)
