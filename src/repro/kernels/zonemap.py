"""Zone-map statistics kernel: per-block (min, max, count) for the metadata
store (paper §3.6) and partition pruning (DESIGN §6).

One pass over HBM; each grid step reduces a (B,) tile in VMEM to one output
row.  Output rows are (NB, 1) tiles (index-mapped per step).  Runs at read
time on the device so the "background metadata task" costs one streaming
read of the column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _zonemap_kernel(values_ref, mins_ref, maxs_ref, *, rows: int,
                    block_rows: int):
    i = pl.program_id(0)
    vals = values_ref[...]                      # (B,)
    b = vals.shape[0]
    # mask out padding in the final block with reduction identities
    idx = jax.lax.broadcasted_iota(jnp.int32, (b,), 0) + i * block_rows
    in_range = idx < rows
    lo = jnp.where(in_range, vals, jnp.inf)
    hi = jnp.where(in_range, vals, -jnp.inf)
    mins_ref[0, 0] = jnp.min(lo)
    maxs_ref[0, 0] = jnp.max(hi)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def zonemap(values: jax.Array, block_rows: int = 4096,
            interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Per-block (min, max) of a 1-D array; blocks of ``block_rows``."""
    n = values.shape[0]
    nb = -(-max(n, 1) // block_rows)
    pad = nb * block_rows - n
    vals_p = jnp.concatenate(
        [values.astype(jnp.float32),
         jnp.zeros((pad,), jnp.float32)]) if pad else values.astype(jnp.float32)
    mins, maxs = pl.pallas_call(
        functools.partial(_zonemap_kernel, rows=n, block_rows=block_rows),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(vals_p)
    return mins[:, 0], maxs[:, 0]
