"""Sharded, async, resharding-capable checkpointing.

Layout (one directory per step):

    ckpt_dir/step_000123/
      manifest.json            # pytree structure, shapes, dtypes, mesh shape
      arrays/<leaf-path>.npy   # full (unsharded) array per leaf
      COMMIT                   # atomic commit marker written last

* **Atomicity**: readers ignore directories without COMMIT; a preempted save
  never corrupts restore state.
* **Async**: `save_async` snapshots to host memory synchronously (cheap) and
  writes files on a background thread — the train loop never blocks on disk.
* **Resharding / elasticity**: arrays are stored unsharded; restore places
  them under *any* mesh via `jax.device_put` with the new sharding, so a job
  can resume on a different device count (elastic re-launch).  At real
  fleet scale the same manifest+leaf layout extends to per-shard files with
  index metadata; the full-array form keeps this container honest (single
  host) while exercising the identical restore path.
* **Retention**: keep the last N checkpoints (default 3).
* **Pipeline state**: arbitrary JSON-able extras (data cursor, rng) ride in
  the manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _leaf_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_leaf_paths(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_leaf_paths(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _tree_structure(tree):
    if isinstance(tree, dict):
        return {k: _tree_structure(v) for k, v in tree.items()}
    if isinstance(tree, tuple):
        return {"__tuple__": [_tree_structure(v) for v in tree]}
    if isinstance(tree, list):
        return {"__list__": [_tree_structure(v) for v in tree]}
    return None


def _rebuild(structure, leaves, prefix=""):
    if structure is None:
        return leaves[prefix[:-1]]
    if "__tuple__" in structure:
        return tuple(_rebuild(s, leaves, f"{prefix}{i}/")
                     for i, s in enumerate(structure["__tuple__"]))
    if "__list__" in structure:
        return [_rebuild(s, leaves, f"{prefix}{i}/")
                for i, s in enumerate(structure["__list__"])]
    return {k: _rebuild(v, leaves, f"{prefix}{k}/")
            for k, v in structure.items()}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self.save_count = 0

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, extras: dict | None = None,
             block: bool = True):
        """Snapshot state; write synchronously (block=True) or in the
        background."""
        snap = jax.tree.map(lambda x: np.asarray(x), state)
        self.wait()    # never two writers (e.g. final save racing an async one)
        if block:
            self._write(step, snap, extras or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, snap, extras or {}),
                daemon=True)
            self._thread.start()

    def save_async(self, step: int, state, extras: dict | None = None):
        self.save(step, state, extras, block=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, snap, extras: dict):
        d = os.path.join(self.dir, f"step_{step:09d}")
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
        leaves = _leaf_paths(snap)
        manifest = {
            "step": step,
            "structure": _tree_structure(snap),
            "leaves": {},
            "extras": extras,
            "written_at": time.time(),
        }
        for path, arr in leaves.items():
            arr = np.asarray(arr)
            fname = path.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, "arrays", fname), arr)
            manifest["leaves"][path] = {"file": fname,
                                        "shape": list(arr.shape),
                                        "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        with open(os.path.join(d, "COMMIT"), "w") as f:
            f.write(str(step))
        self.save_count += 1
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings=None
                ) -> tuple[int, dict, dict]:
        """Returns (step, state, extras).  With ``shardings`` (a pytree of
        NamedSharding matching the state) arrays are placed sharded — this is
        the cross-mesh resharding path: the stored full arrays are sliced by
        device_put under whatever mesh the new job runs."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = {}
        for path, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, "arrays", meta["file"]))
            leaves[path] = arr
        state = _rebuild(manifest["structure"], leaves)
        if shardings is not None:
            flat_s = _leaf_paths(shardings)
            state_leaves = _leaf_paths(state)
            placed = {p: jax.device_put(a, flat_s[p]) if p in flat_s else a
                      for p, a in state_leaves.items()}
            state = _rebuild(manifest["structure"], placed)
        else:
            state = jax.tree.map(lambda x: jax.numpy.asarray(x), state)
        return step, state, manifest.get("extras", {})
