"""Gradient compression for the slow (DCN / pod) axis: int8 block
quantization with error feedback.

At 1000+-node scale the cross-pod gradient all-reduce is DCN-bound (~25
GB/s/host vs 50 GB/s/link ICI); int8 quantization cuts those bytes 4× at the
cost of quantization noise, which error feedback (residual carry) removes in
expectation.  Used by train_step when ``compress_pod_grads=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization → (q int8, scales f32)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)]) if pad else flat
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32
                    ) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str,
                    residual: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """All-reduce with int8 on the wire + error feedback (shard_map form).

    Per-shard blockwise scales cannot be summed remotely, so the exchange is
    an all-gather of (int8 payload, f32 block scales) followed by a local
    dequantize-and-sum — 8·N + 32·N/BLOCK wire bits vs 32·N for a float
    all-reduce (≈3.9× fewer bytes).  Error feedback carries the quantization
    residual into the next step."""
    if residual is not None:
        x = x + residual.astype(x.dtype)
    q, scale = quantize_int8(x)
    deq_local = dequantize_int8(q, scale, x.shape, jnp.float32)
    new_residual = x.astype(jnp.float32) - deq_local     # error feedback
    q_all = jax.lax.all_gather(q, axis_name)             # (P, nblk, BLOCK) int8
    s_all = jax.lax.all_gather(scale, axis_name)         # (P, nblk)
    deq_all = q_all.astype(jnp.float32) * s_all[..., None]
    flat = jnp.sum(deq_all, axis=0).reshape(-1)
    n = 1
    for d in x.shape:
        n *= d
    summed = flat[:n].reshape(x.shape)
    return summed.astype(x.dtype), new_residual


def compress_tree(grads, residuals):
    """Elementwise error-feedback quantize/dequantize of a gradient pytree —
    models the wire format; the actual psum happens in the caller's pjit
    (GSPMD inserts the cross-pod all-reduce on the dequantized values).

    Returns (quantized-dequantized grads, new residuals)."""
    def one(g, r):
        x = g.astype(jnp.float32) + (r if r is not None else 0.0)
        q, scale = quantize_int8(x)
        deq = dequantize_int8(q, scale, x.shape, jnp.float32)
        return deq.astype(g.dtype), x - deq
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                 grads)
    pairs = jax.tree.map(one, grads, residuals)
    new_g = jax.tree.map(lambda p: p[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda p: p[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_r
