"""Logical-axis → mesh-axis sharding rules (FSDP × TP × EP × SP).

Every model weight carries logical axes (models/layers.py); these rules bind
them to the physical mesh:

* TP over ``model``: vocab, attention heads, FFN hidden, experts
* FSDP over ``data``: the d_model ("embed") dim of every weight
* ``pod`` (multi-pod): pure DP — parameters replicated across pods, so the
  only DCN-crossing collective is the gradient all-reduce
* KV/state caches: batch over ``data`` when divisible, and the largest
  model-divisible dim (sequence for KV caches → sequence parallelism at
  decode; d_inner for SSM states) over ``model``.

Divisibility fallback: a dim that does not divide its mesh axis is
replicated instead (e.g. kv_heads=2 with model=16 — the kv projections are
tiny, replication is the standard GQA-TP practice).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str | None, str | None] = {
    "vocab": "model",
    "embed": "data",
    "embed2": "model",
    "heads": "model",
    "mlp": "model",
    "expert": "model",
    "layers": None,
    None: None,
}


def _axis_size(mesh: Mesh, axis: str | None) -> int:
    if axis is None:
        return 1
    return mesh.shape[axis]


def spec_for(shape: tuple, axes: tuple, mesh: Mesh,
             rules: dict | None = None) -> P:
    rules = rules or DEFAULT_RULES
    out = []
    for dim, ax in zip(shape, axes):
        phys = rules.get(ax)
        if phys is not None and dim % _axis_size(mesh, phys) != 0:
            phys = None                       # divisibility fallback
        out.append(phys)
    return P(*out)


def param_shardings(spec, mesh: Mesh, rules: dict | None = None):
    """ParamSpec → pytree (nested dict) of NamedSharding."""
    from ..models.layers import unflatten
    flat = {path: NamedSharding(mesh, spec_for(shape, axes, mesh, rules))
            for path, (shape, _dt, axes) in spec.items()}
    return unflatten(flat)


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# Activation sharding context — GSPMD propagation alone resolves the
# embed-gather conflict (embedding D sharded over data vs batch over data) by
# replicating the batch dim, which explodes activation memory 16×; explicit
# constraints at the residual-stream boundaries pin the intended layout.

_ACT_CTX: dict = {"mesh": None, "batch": None, "vocab": None}


def set_activation_context(mesh: Mesh | None):
    """Install (or clear, with None) the activation-sharding context used by
    model forward passes under pjit."""
    if mesh is None:
        _ACT_CTX.update(mesh=None, batch=None, vocab=None)
        return
    _ACT_CTX.update(mesh=mesh, batch=batch_axes(mesh),
                    vocab="model" if "model" in mesh.axis_names else None)


def _batch_spec(mesh, b: int):
    ba = _ACT_CTX["batch"]
    total = 1
    for a in ba or ():
        total *= mesh.shape[a]
    return ba if (ba and b % total == 0) else None


def shard_activations(x):
    """Constrain (B, T, D) residual-stream activations to batch-over-data
    (skipped when the batch doesn't divide, e.g. long_500k B=1)."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None or x.ndim < 2:
        return x
    spec = [_batch_spec(mesh, x.shape[0])] + [None] * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def shard_attn_heads(x):
    """Constrain (B, T, H, hd) q/k/v projections: heads over model when the
    head count divides; otherwise fall back to sequence sharding over model
    (context parallelism) — without this, archs whose head count doesn't
    divide the TP axis (llama 24H, gemma3 8H, GQA kv<16) replicate their
    (B, H, T, S) attention scores and blow past HBM."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None or x.ndim != 4:
        return x
    B, T, H, hd = x.shape
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    batch = _batch_spec(mesh, B)
    if msize > 1 and H % msize == 0:
        spec = P(batch, None, "model", None)
    elif msize > 1 and T % msize == 0 and T > 1:
        spec = P(batch, "model", None, None)
    else:
        spec = P(batch, None, None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_logits(x):
    """Constrain (B, T, V) logits to batch-over-data, vocab-over-model."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(_batch_spec(mesh, x.shape[0]), None,
                                 _ACT_CTX["vocab"])))


def data_sharding(mesh: Mesh, global_batch: int, *trailing) -> NamedSharding:
    """Batch dim over (pod,)data when divisible, else replicated."""
    ba = batch_axes(mesh)
    total = 1
    for a in ba:
        total *= mesh.shape[a]
    if global_batch % total != 0:
        ba = None
    return NamedSharding(mesh, P(ba, *trailing))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def cache_shardings(mesh: Mesh, cache_tree, global_batch: int):
    """Structural cache sharding.  cache_tree is the transformer cache dict
    {"prelude": [...], "group": <stacked leaves, leading n_groups dim>,
    "postlude": [...]}: batch dim over data when divisible, plus the largest
    model-divisible later dim over model (sequence for KV caches → SP at
    decode; d_inner for SSM states)."""
    ba = batch_axes(mesh)
    btotal = 1
    for a in ba:
        btotal *= mesh.shape[a]
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def one(sd, batch_dim: int):
        shape = sd.shape
        spec: list = [None] * len(shape)
        if len(shape) > batch_dim and shape[batch_dim] % btotal == 0 \
                and btotal > 1:
            spec[batch_dim] = ba
        best, best_dim = None, 0
        for i in range(batch_dim + 1, len(shape)):
            if shape[i] % msize == 0 and shape[i] > best_dim and msize > 1:
                best, best_dim = i, shape[i]
        if best is not None:
            spec[best] = "model"
        return NamedSharding(mesh, P(*spec))

    out = {}
    for key, sub in cache_tree.items():
        bd = 1 if key == "group" else 0     # group leaves: (n_groups, B, …)
        out[key] = jax.tree.map(lambda sd, b=bd: one(sd, b), sub)
    return out
