"""Version-compatibility shims for the pinned toolchain.

``jax.shard_map`` became a public API in newer jax; the pinned 0.4.x only
ships ``jax.experimental.shard_map``.  Import from here so both work.
"""
import jax

try:
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

__all__ = ["shard_map"]
