import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
#
# Proves the distribution config is coherent without hardware:
# ``jax.jit(step, in_shardings, out_shardings).lower(**specs).compile()``
# must succeed on the single-pod (16×16) and multi-pod (2×16×16) meshes; the
# compiled artifact yields memory_analysis (fits-HBM proof) and
# cost_analysis + HLO collectives (roofline terms, §Roofline).
#
# The two env lines above MUST run before any jax import — jax locks the
# device count at backend init.
#
# Usage:
#     python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
#     python -m repro.launch.dryrun --all --mesh both --out results.json

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import (SHAPES, get_config, input_specs, list_archs,
                       shape_applicable)
from ..distributed.sharding import (cache_shardings, data_sharding,
                                    param_shardings, replicated,
                                    set_activation_context)
from ..models.layers import abstract_from_spec
from ..models.transformer import model_spec
from ..serve.engine import make_prefill_step, make_serve_step
from ..train.train_step import TrainConfig, make_train_step
from .mesh import make_production_mesh
from .roofline import analyze, model_flops_per_step


def _abstract_state(spec, mesh, rules=None):
    params = abstract_from_spec(spec, jnp.float32)
    shardings = param_shardings(spec, mesh, rules)
    state = {"params": params,
             "opt": {"mu": params, "nu": params,
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}}
    state_sh = {"params": shardings,
                "opt": {"mu": shardings, "nu": shardings,
                        "step": replicated(mesh)}}
    return state, state_sh


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               tcfg: TrainConfig | None = None, rules=None):
    """Lower + compile one cell; returns result dict."""
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    spec = model_spec(arch)
    tcfg = tcfg or TrainConfig()
    if rules is None and arch.sharding_profile == "dp_tp":
        # small models: replicate params over data (no FSDP gathers; the
        # optimizer state fits replicated) — §Perf xlstm iteration
        from ..distributed.sharding import DEFAULT_RULES
        rules = dict(DEFAULT_RULES)
        rules["embed"] = None
    set_activation_context(mesh)
    t0 = time.perf_counter()
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
            else mesh:
        specs = input_specs(arch, shape)
        if shape.kind == "train":
            state, state_sh = _abstract_state(spec, mesh, rules)
            batch_sh = {k: data_sharding(mesh, shape.global_batch)
                        for k in specs}
            step = make_train_step(arch, tcfg,
                                   grad_shardings=state_sh["params"])
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, specs)
        elif shape.kind == "prefill":
            params = abstract_from_spec(spec, jnp.bfloat16)
            p_sh = param_shardings(spec, mesh, rules)
            in_sh = {k: data_sharding(mesh, shape.global_batch)
                     for k in specs}
            step = make_prefill_step(arch)
            jitted = jax.jit(step, in_shardings=(p_sh, in_sh))
            lowered = jitted.lower(params, specs)
        else:  # decode
            params = abstract_from_spec(spec, jnp.bfloat16)
            p_sh = param_shardings(spec, mesh, rules)
            in_sh = {}
            for k, v in specs.items():
                if k == "cache":
                    in_sh[k] = cache_shardings(mesh, v, shape.global_batch)
                else:
                    in_sh[k] = data_sharding(mesh, shape.global_batch)
            step = make_serve_step(arch)
            jitted = jax.jit(step, in_shardings=(p_sh, in_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, specs)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
    set_activation_context(None)

    mem = compiled.memory_analysis()
    # scan-body FLOPs correction: cost_analysis sees the body once; add the
    # analytic (n_groups−1) × per-group param FLOPs (fwd+bwd for train)
    p_group = arch.group_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq
        factor = 6
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq
        factor = 2
    else:
        tokens = shape.global_batch
        factor = 2
    body_corr = max(arch.n_groups - 1, 0) * factor * p_group * tokens / n_chips
    terms = analyze(compiled, body_flops_correction=body_corr)
    mf = model_flops_per_step(arch, shape, n_chips)
    result = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": terms.to_dict(),
        "model_flops_per_chip": mf,
        "hlo_flops_ratio": (mf / terms.flops) if terms.flops else None,
        "roofline_fraction": terms.roofline_fraction(mf),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--loss-mode", default="sharded_vocab")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    archs = list_archs() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    tcfg = TrainConfig(loss_mode=args.loss_mode,
                       microbatches=args.microbatches)

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} × {shape} × {'multi' if mp else 'single'}"
                try:
                    r = lower_cell(arch, shape, mp, tcfg)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    r = {"arch": arch, "shape": shape,
                         "mesh": "multi" if mp else "single",
                         "status": "error", "error": f"{type(e).__name__}: {e}"}
                results.append(r)
                status = r["status"]
                if status == "ok":
                    rf = r["roofline"]
                    print(f"[dryrun] {tag}: OK compile={r['compile_s']}s "
                          f"dominant={rf['dominant']} "
                          f"compute={rf['compute_s']:.4f}s "
                          f"memory={rf['memory_s']:.4f}s "
                          f"collective={rf['collective_s']:.4f}s "
                          f"frac={r['roofline_fraction']:.3f}", flush=True)
                    print(f"         memory_analysis: {r['memory']}", flush=True)
                else:
                    print(f"[dryrun] {tag}: {status} "
                          f"{r.get('reason', r.get('error', ''))}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {len(results)} results to {args.out}")
    n_err = sum(1 for r in results if r["status"] == "error")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
